//! END-TO-END driver: the full three-layer stack on a real (synthetic)
//! RPM workload.
//!
//!   L2/L1 (build time): `make artifacts` lowered the NVSA ConvNet
//!   frontend + Pallas VSA kernels to HLO text.
//!   L3 (this binary): loads the artifacts via PJRT, renders RPM panels,
//!   runs the neural frontend, then solves each puzzle with BOTH symbolic
//!   engines (NVSA hypervector path and PrAE probabilistic path),
//!   measuring the neural/symbolic phase split — the paper's Fig. 2a
//!   observation reproduced live.
//!
//! Run: `make artifacts && cargo run --release --example raven_e2e`
use nscog::coordinator::PhaseMetrics;
use nscog::profiler::taxonomy::PhaseKind;
use nscog::runtime::{Runtime, Tensor};
use nscog::util::Rng;
use nscog::workloads::nvsa::{Nvsa, NvsaEngine};
use nscog::workloads::prae::Prae;
use nscog::workloads::raven::{self, N_ATTRS};

/// Render a panel's attributes into a 32x32 image the frontend can see:
/// attribute values modulate coarse spatial frequency patterns. (The
/// frontend is untrained — characterization needs realistic tensor
/// traffic, not accuracy — so the symbolic engines consume oracle PMFs
/// while the frontend supplies the measured neural phase.)
fn render(panel: &[u8; N_ATTRS], img: usize, rng: &mut Rng) -> Vec<f32> {
    let mut out = vec![0.0f32; img * img];
    for (y, row) in out.chunks_mut(img).enumerate() {
        for (x, v) in row.iter_mut().enumerate() {
            let a = panel[0] as f32 * 0.4 + 1.0;
            let b = panel[1] as f32 * 0.3 + 0.5;
            let c = panel[2] as f32 * 0.2;
            *v = ((x as f32 * a / 5.0).sin() * (y as f32 * b / 7.0).cos() + c / 4.0
                + rng.normal() as f32 * 0.05)
                .tanh();
        }
    }
    out
}

fn main() {
    let mut rt = match Runtime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifacts missing ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {}", rt.platform());
    let dims = rt.manifest.dims;
    let grid = 3usize;
    let n_puzzles = 8;
    let mut rng = Rng::new(2024);
    let nvsa = NvsaEngine::new(Nvsa { grid, ..Default::default() }, 1);
    let prae = Prae { grid, ..Default::default() };
    let mut metrics = PhaseMetrics::default();
    let mut nvsa_ok = 0;
    let mut prae_ok = 0;

    for p in 0..n_puzzles {
        let inst = raven::generate(&mut rng, grid, dims.attr_k);
        // ---- neural phase: render panels, run the AOT'd frontend -------
        let mut data = Vec::with_capacity(dims.panels * dims.img * dims.img);
        for panel in inst.context().iter().chain(inst.candidates.iter()) {
            data.extend(render(panel, dims.img, &mut rng));
        }
        let panels = Tensor::new(vec![dims.panels, dims.img, dims.img, 1], data);
        let outs = metrics.time(format!("nvsa_frontend p{p}"), PhaseKind::Neural, || {
            rt.run("nvsa_frontend", &[panels]).expect("frontend")
        });
        assert_eq!(outs.len(), dims.n_attrs);

        // ---- symbolic phase: both engines on the scene PMFs ------------
        let pmfs = raven::panel_pmfs(&inst, 0.95);
        let sn = metrics.time(format!("nvsa_reason p{p}"), PhaseKind::Symbolic, || {
            nvsa.solve(&inst, &pmfs)
        });
        let sp = metrics.time(format!("prae_reason p{p}"), PhaseKind::Symbolic, || {
            prae.solve(&inst, &pmfs)
        });
        nvsa_ok += sn.correct as usize;
        prae_ok += sp.correct as usize;
    }

    println!("\nper-phase wall clock:");
    print!("{}", metrics.report());
    println!(
        "\naccuracy over {n_puzzles} puzzles: NVSA {:.0}%  PrAE {:.0}%",
        nvsa_ok as f64 / n_puzzles as f64 * 100.0,
        prae_ok as f64 / n_puzzles as f64 * 100.0,
    );
    assert!(nvsa_ok + prae_ok >= n_puzzles, "symbolic engines degenerate");
    println!("raven_e2e OK — all three layers composed");
}
