//! Resonator-network factorization end to end on the VSA accelerator
//! simulator (the paper's FACT workload, Fig. 6's kernel programming),
//! validated against the functional Rust resonator.
//!
//! Run: `cargo run --release --example factorization`
use nscog::accel::compiler::{KernelCompiler, Operand, VecRef};
use nscog::accel::isa::ControlMethod;
use nscog::accel::pipeline::Accelerator;
use nscog::accel::AccelConfig;
use nscog::util::Rng;
use nscog::vsa::hypervector::majority;
use nscog::vsa::{BinaryCodebook, BinaryHV};

fn main() {
    let n = 13; // items per factor (Tab. VII)
    let factors = 3;
    let dim = 8192;
    let mut rng = Rng::new(123);
    let cb = BinaryCodebook::random(&mut rng, n * factors, dim);
    let truth: Vec<usize> = vec![5, n + 9, 2 * n + 1];
    println!("ground truth factors: {truth:?}");

    for cfg in [AccelConfig::acc2(), AccelConfig::acc4(), AccelConfig::acc8()] {
        let name = cfg.name.clone();
        let mut acc = Accelerator::new(cfg.clone());
        let layout = acc.load_items(cb.items(), factors + 3);
        let kc = KernelCompiler::new(cfg, layout.clone());

        // scene = a ⊗ b ⊗ c staged through the accelerator's own bind
        let scene_ops: Vec<Operand> =
            truth.iter().map(|&g| Operand::plain(VecRef::Item(g))).collect();
        let mut report = acc.run(&kc.bind(&scene_ops, 0), ControlMethod::Mopc);

        // init estimates: majority bundle of each factor codebook
        for f in 0..factors {
            let items: Vec<&BinaryHV> =
                (f * n..(f + 1) * n).map(|g| cb.item(g)).collect();
            acc.stage_scratch(&layout, 1 + f, &majority(&items, 99));
        }
        // resonator iterations on the accelerator
        let mut decoded = vec![usize::MAX; factors];
        for it in 0..10 {
            for f in 0..factors {
                let mut ops = vec![Operand::plain(VecRef::Scratch(0))];
                for of in 0..factors {
                    if of != f {
                        ops.push(Operand::plain(VecRef::Scratch(1 + of)));
                    }
                }
                report.merge(&acc.run(&kc.bind(&ops, factors + 1), ControlMethod::Mopc));
                let items: Vec<usize> = (f * n..(f + 1) * n).collect();
                report.merge(&acc.run(
                    &kc.project(factors + 1, &items, 1 + f),
                    ControlMethod::Mopc,
                ));
            }
            // decode current estimates (host-side check)
            decoded = (0..factors)
                .map(|f| cb.nearest(&acc.read_scratch(&layout, 0, 1 + f)).0)
                .collect();
            if decoded == truth {
                println!(
                    "{name}: converged after {} iterations — {} cycles, {}, {}",
                    it + 1,
                    report.cycles,
                    nscog::util::stats::fmt_time(report.time_s),
                    nscog::util::stats::fmt_energy(report.energy_j()),
                );
                break;
            }
        }
        assert_eq!(decoded, truth, "{name} failed to factorize");
    }
    println!("factorization OK on all accelerator instances");
}
