//! Full characterization sweep: regenerates every figure/table of the
//! paper's evaluation in one run and prints per-workload reports.
//!
//! Run: `cargo run --release --example characterize`
use nscog::figures;
use nscog::platform::Platform;
use nscog::profiler::report::WorkloadReport;
use nscog::workloads::all_workloads;

fn main() {
    println!("=== per-workload characterization (RTX 2080 Ti model) ===");
    let gpu = Platform::rtx2080ti();
    for w in all_workloads() {
        let r = WorkloadReport::build(&w.trace(), w.memory(), vec![], &gpu);
        println!("{}", r.summary_line());
    }
    println!();
    for (title, t) in [
        ("Fig. 2a", figures::fig2a()),
        ("Fig. 2b", figures::fig2b()),
        ("Fig. 2c", figures::fig2c()),
        ("Fig. 3a", figures::fig3a()),
        ("Fig. 3b", figures::fig3b()),
        ("Fig. 3c", figures::fig3c()),
        ("Fig. 4", figures::fig4()),
        ("Tab. IV", figures::tab4()),
        ("Fig. 5", figures::fig5()),
        ("Fig. 9", figures::fig9()),
        ("Fig. 11a", figures::fig11a()),
        ("Fig. 11b", figures::fig11b()),
    ] {
        println!("== {title} ==");
        t.print();
        println!();
    }
}
