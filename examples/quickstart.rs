//! Quickstart: the VSA substrate and accelerator simulator in ~60 lines.
//!
//! Run: `cargo run --release --example quickstart`
use nscog::accel::isa::ControlMethod;
use nscog::accel::AccelConfig;
use nscog::util::Rng;
use nscog::vsa::{BinaryCodebook, RealCodebook, Resonator};
use nscog::workloads::suite::{CompiledSuite, SuiteKind};

fn main() {
    let mut rng = Rng::new(7);

    // 1. Hypervector algebra: bind two symbols, recover one.
    let cb = BinaryCodebook::random(&mut rng, 16, 8192);
    let bound = cb.item(3).bind(cb.item(11));
    let recovered = bound.bind(cb.item(3)); // XOR is self-inverse
    let (idx, _) = cb.nearest(&recovered);
    println!("bind/unbind roundtrip: item 11 recovered as {idx}");
    assert_eq!(idx, 11);

    // 2. Resonator network: factorize a 3-factor composition.
    let codebooks: Vec<RealCodebook> = (0..3)
        .map(|_| RealCodebook::random_bipolar(&mut rng, 10, 1024))
        .collect();
    let resonator = Resonator::new(codebooks, 60);
    let scene = resonator.compose(&[4, 7, 2]);
    let result = resonator.factorize(&scene);
    println!(
        "resonator factorized to {:?} in {} iterations (converged: {})",
        result.indices, result.iterations, result.converged
    );
    assert_eq!(result.indices, vec![4, 7, 2]);

    // 3. The paper's accelerator: run REACT on Acc4 under both controls.
    for control in [ControlMethod::Sopc, ControlMethod::Mopc] {
        let mut suite = CompiledSuite::build(SuiteKind::React, AccelConfig::acc4(), 17);
        let r = suite.run(control);
        println!(
            "REACT on Acc4 [{control}]: {} cycles, {}, avg power {:.2} mW",
            r.cycles,
            nscog::util::stats::fmt_time(r.time_s),
            r.avg_power_w() * 1e3
        );
    }
    println!("quickstart OK");
}
