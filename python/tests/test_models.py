"""L2 model shape/semantics tests: every AOT entry point traces and the
outputs satisfy their structural contracts (PMFs sum to 1, bounds ordered,
hypervectors bipolar, ...)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

P, K, D, N = model.NVSA_PANELS, model.ATTR_K, model.HD_DIM, model.CODEBOOK_N


def _panels(n=P, c=1, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, model.IMG, model.IMG, c))


def _bipolar(key, shape):
    return jnp.where(jax.random.normal(key, shape) >= 0, 1.0, -1.0)


def test_nvsa_frontend_pmfs():
    outs = model.nvsa_frontend(_panels())
    assert len(outs) == model.N_ATTRS
    for pmf in outs:
        assert pmf.shape == (P, K)
        np.testing.assert_allclose(pmf.sum(-1), np.ones(P), rtol=1e-5)
        assert float(pmf.min()) >= 0.0


def test_pmf_to_vsa_matches_ref():
    pmf = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(1), (P, K)))
    cb = _bipolar(jax.random.PRNGKey(2), (K, D))
    (out,) = model.pmf_to_vsa(pmf, cb)
    np.testing.assert_allclose(out, ref.pmf_to_vsa_ref(pmf, cb), rtol=1e-5)


def test_vsa_to_pmf_roundtrip_peaks_correctly():
    """One-hot PMF -> VSA -> PMF recovers the argmax category."""
    cb = _bipolar(jax.random.PRNGKey(3), (K, D))
    pmf = jnp.eye(K)[:P % K + 5][:5] if False else jnp.eye(K)[:5]
    (vecs,) = model.pmf_to_vsa(pmf, cb)
    # pad batch to P for the artifact shape; here call directly
    (back,) = model.vsa_to_pmf(vecs, cb)
    assert (jnp.argmax(back, -1) == jnp.argmax(pmf, -1)).all()


def test_vsa_to_pmf_is_normalized():
    cb = _bipolar(jax.random.PRNGKey(4), (K, D))
    vecs = jax.random.normal(jax.random.PRNGKey(5), (P, D))
    (pmf,) = model.vsa_to_pmf(vecs, cb)
    sums = np.asarray(pmf.sum(-1))
    assert ((sums <= 1.0 + 1e-5) & (sums >= 0.0)).all()


def test_ltn_grounding_in_unit_interval():
    x = jax.random.normal(jax.random.PRNGKey(6), (32, model.LTN_FEATURES))
    (truth,) = model.ltn_grounding(x)
    assert truth.shape == (32, model.LTN_PREDICATES)
    assert float(truth.min()) >= 0.0 and float(truth.max()) <= 1.0


def test_nlm_layer_shapes_and_range():
    b, n, c = 4, model.NLM_OBJS, model.NLM_FEATS
    unary = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(7), (b, n, c)))
    binary = jax.nn.sigmoid(
        jax.random.normal(jax.random.PRNGKey(8), (b, n, n, c)))
    u2, b2 = model.nlm_layer(unary, binary)
    assert u2.shape == (b, n, c)
    assert b2.shape == (b, n, n, c)
    for t in (u2, b2):
        assert float(t.min()) >= 0.0 and float(t.max()) <= 1.0


def test_vsait_encoder_bipolar_output():
    (hv,) = model.vsait_encoder(_panels(model.VSAIT_BATCH, 3))
    assert hv.shape == (model.VSAIT_BATCH, D)
    np.testing.assert_allclose(np.abs(np.asarray(hv)), np.ones_like(hv))


def test_vsait_encoder_key_unbind():
    """Binding with the domain key is invertible (VSAIT's core property)."""
    (hv,) = model.vsait_encoder(_panels(model.VSAIT_BATCH, 3))
    key = model._VSAIT_KEYVEC
    content = hv * key  # unbind
    np.testing.assert_allclose(np.abs(np.asarray(content)), 1.0)


def test_zeroc_energy_finite_and_concept_sensitive():
    imgs = _panels(8, 1, seed=9)
    c1 = jax.random.normal(jax.random.PRNGKey(10), (8, model.ZEROC_CONCEPT))
    c2 = jax.random.normal(jax.random.PRNGKey(11), (8, model.ZEROC_CONCEPT))
    (e1,) = model.zeroc_energy(imgs, c1)
    (e2,) = model.zeroc_energy(imgs, c2)
    assert e1.shape == (8,)
    assert np.isfinite(np.asarray(e1)).all()
    assert not np.allclose(np.asarray(e1), np.asarray(e2))


def test_prae_frontend_outputs():
    outs = model.prae_frontend(_panels())
    obj, pmfs = outs[0], outs[1:]
    assert obj.shape == (P,)
    assert float(obj.min()) >= 0.0 and float(obj.max()) <= 1.0
    assert len(pmfs) == model.N_ATTRS
    for pmf in pmfs:
        np.testing.assert_allclose(pmf.sum(-1), np.ones(P), rtol=1e-5)


def test_lnn_grounding_bounds_ordered():
    x = jax.random.normal(jax.random.PRNGKey(12), (32, model.LNN_GROUND))
    (bounds,) = model.lnn_grounding(x)
    assert bounds.shape == (32, 2)
    lo, hi = np.asarray(bounds[:, 0]), np.asarray(bounds[:, 1])
    assert (lo <= hi).all()
    assert (lo >= 0).all() and (hi <= 1).all()


def test_resonator_step_entry_point():
    ks = jax.random.split(jax.random.PRNGKey(13), 4)
    scene = _bipolar(ks[0], (D,))
    o1 = _bipolar(ks[1], (D,))
    o2 = _bipolar(ks[2], (D,))
    cb = _bipolar(ks[3], (N, D))
    est, scores = model.resonator_step(scene, o1, o2, cb)
    assert est.shape == (D,) and scores.shape == (N,)
    np.testing.assert_allclose(np.abs(np.asarray(est)), 1.0)
