"""Kernel-vs-oracle correctness: the CORE L1 signal.

Hypothesis sweeps shapes and dtypes of every Pallas kernel and asserts
allclose against the pure-jnp oracle in kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

DIMS = st.sampled_from([128, 256, 512, 1024])
SMALL_DIMS = st.sampled_from([64, 128, 256])
DTYPES = st.sampled_from([jnp.float32, jnp.float16])
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _bipolar(key, shape, dtype=jnp.float32):
    return jnp.where(
        jax.random.normal(key, shape) >= 0, 1.0, -1.0
    ).astype(dtype)


def _tol(dtype):
    return {"rtol": 2e-2, "atol": 2e-2} if dtype == jnp.float16 else {
        "rtol": 1e-5, "atol": 1e-5}


# ---------------------------------------------------------------- bind ----

@settings(max_examples=20, deadline=None)
@given(d=DIMS, seed=SEEDS, dtype=DTYPES, batch=st.sampled_from([None, 1, 3]))
def test_bind_matches_ref(d, seed, dtype, batch):
    key1, key2 = jax.random.split(jax.random.PRNGKey(seed))
    shape = (d,) if batch is None else (batch, d)
    x = _bipolar(key1, shape, dtype)
    y = _bipolar(key2, shape, dtype)
    np.testing.assert_allclose(
        kernels.bind(x, y), ref.bind_ref(x, y), **_tol(dtype))


def test_bind_self_inverse():
    """Bipolar Hadamard binding is its own inverse: x*(x*y) == y."""
    key1, key2 = jax.random.split(jax.random.PRNGKey(0))
    x = _bipolar(key1, (512,))
    y = _bipolar(key2, (512,))
    np.testing.assert_allclose(kernels.bind(x, kernels.bind(x, y)), y)


def test_bind_quasi_orthogonal():
    """Bound vector is dissimilar to both constituents (paper Sec. VI-A)."""
    key1, key2 = jax.random.split(jax.random.PRNGKey(7))
    d = 1024
    x = _bipolar(key1, (d,))
    y = _bipolar(key2, (d,))
    z = kernels.bind(x, y)
    assert abs(float(jnp.dot(z, x))) / d < 0.15
    assert abs(float(jnp.dot(z, y))) / d < 0.15


def test_bind_rejects_bad_fold():
    x = jnp.ones((100,))
    with pytest.raises(ValueError):
        kernels.bind(x, x, fold=64)


# -------------------------------------------------------------- bundle ----

@settings(max_examples=20, deadline=None)
@given(d=DIMS, seed=SEEDS, m=st.integers(min_value=1, max_value=7))
def test_bundle_matches_ref(d, seed, m):
    xs = _bipolar(jax.random.PRNGKey(seed), (m, d))
    np.testing.assert_allclose(
        kernels.bundle(xs), ref.bundle_ref(xs), rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(d=SMALL_DIMS, seed=SEEDS, m=st.sampled_from([3, 5, 7]))
def test_bundle_sign_matches_ref(d, seed, m):
    xs = _bipolar(jax.random.PRNGKey(seed), (m, d))
    np.testing.assert_allclose(
        kernels.bundle_sign(xs), ref.bundle_sign_ref(xs))


def test_bundle_preserves_similarity():
    """A bundle stays similar to each constituent (superposition)."""
    d = 1024
    xs = _bipolar(jax.random.PRNGKey(3), (3, d))
    s = kernels.bundle_sign(xs)
    for i in range(3):
        assert float(jnp.dot(s, xs[i])) / d > 0.3


# ------------------------------------------------------------- permute ----

@settings(max_examples=15, deadline=None)
@given(d=DIMS, seed=SEEDS, shift=st.integers(min_value=-8, max_value=8))
def test_permute_matches_ref(d, seed, shift):
    x = _bipolar(jax.random.PRNGKey(seed), (d,))
    np.testing.assert_allclose(
        kernels.permute(x, shift), ref.permute_ref(x, shift))


def test_permute_roundtrip():
    x = _bipolar(jax.random.PRNGKey(1), (256,))
    np.testing.assert_allclose(kernels.permute(kernels.permute(x, 3), -3), x)


def test_permute_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(2), (512,))
    np.testing.assert_allclose(
        jnp.linalg.norm(kernels.permute(x, 5)), jnp.linalg.norm(x), rtol=1e-6)


# --------------------------------------------------------- scalar mult ----

@settings(max_examples=10, deadline=None)
@given(d=SMALL_DIMS, seed=SEEDS,
       w=st.floats(min_value=-4.0, max_value=4.0, allow_nan=False))
def test_scalar_mult_matches_ref(d, seed, w):
    x = jax.random.normal(jax.random.PRNGKey(seed), (d,))
    np.testing.assert_allclose(
        kernels.scalar_mult(x, w), ref.scalar_mult_ref(x, jnp.float32(w)),
        rtol=1e-6, atol=1e-6)


# ------------------------------------------------------ circular conv ----

@settings(max_examples=15, deadline=None)
@given(d=SMALL_DIMS, seed=SEEDS)
def test_circular_conv_matches_fft_ref(d, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (d,)) / d**0.5
    y = jax.random.normal(k2, (d,)) / d**0.5
    np.testing.assert_allclose(
        kernels.circular_conv(x, y), ref.circular_conv_ref(x, y),
        rtol=1e-3, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(d=SMALL_DIMS, seed=SEEDS)
def test_circular_corr_matches_fft_ref(d, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (d,)) / d**0.5
    y = jax.random.normal(k2, (d,)) / d**0.5
    np.testing.assert_allclose(
        kernels.circular_corr(x, y), ref.circular_corr_ref(x, y),
        rtol=1e-3, atol=1e-4)


def test_circular_conv_commutative():
    k1, k2 = jax.random.split(jax.random.PRNGKey(11))
    x = jax.random.normal(k1, (128,))
    y = jax.random.normal(k2, (128,))
    np.testing.assert_allclose(
        kernels.circular_conv(x, y), kernels.circular_conv(y, x),
        rtol=1e-4, atol=1e-5)


def test_circular_conv_unbind_recovers():
    """HRR: correlating the bound pair with one factor recovers the other."""
    d = 1024
    k1, k2 = jax.random.split(jax.random.PRNGKey(13))
    x = jax.random.normal(k1, (d,)) / d**0.5
    y = jax.random.normal(k2, (d,)) / d**0.5
    z = kernels.circular_conv(x, y)
    y_hat = kernels.circular_corr(x, z)
    cos = float(jnp.dot(y_hat, y) / (jnp.linalg.norm(y_hat) * jnp.linalg.norm(y)))
    assert cos > 0.5


def test_circular_conv_batched():
    k1, k2 = jax.random.split(jax.random.PRNGKey(17))
    x = jax.random.normal(k1, (3, 128))
    y = jax.random.normal(k2, (3, 128))
    out = kernels.circular_conv(x, y)
    for i in range(3):
        np.testing.assert_allclose(
            out[i], ref.circular_conv_ref(x[i], y[i]), rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------- similarity ----

@settings(max_examples=15, deadline=None)
@given(d=DIMS, seed=SEEDS,
       n=st.sampled_from([8, 16, 64]), b=st.sampled_from([1, 4, 16]))
def test_similarity_matches_ref(d, seed, n, b):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    cb = _bipolar(k1, (n, d))
    q = jax.random.normal(k2, (b, d))
    np.testing.assert_allclose(
        kernels.similarity(cb, q), ref.similarity_ref(cb, q),
        rtol=1e-4, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(d=DIMS, seed=SEEDS)
def test_similarity_fold_invariant(d, seed):
    """Partial-distance accumulation must not depend on the fold width."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    cb = _bipolar(k1, (16, d))
    q = jax.random.normal(k2, (4, d))
    full = kernels.similarity(cb, q, fold=d)
    folded = kernels.similarity(cb, q, fold=d // 4 if d >= 256 else d // 2)
    np.testing.assert_allclose(full, folded, rtol=1e-4, atol=1e-3)


def test_nearest_finds_member():
    """A codebook item queries back to itself."""
    cb = _bipolar(jax.random.PRNGKey(5), (32, 512))
    idx, scores = kernels.nearest(cb, cb[7:8])
    assert int(idx[0]) == 7
    assert scores.shape == (1, 32)


# ----------------------------------------------------------- resonator ----

@settings(max_examples=10, deadline=None)
@given(d=st.sampled_from([256, 512]), seed=SEEDS, n=st.sampled_from([8, 16]))
def test_resonator_step_matches_ref(d, seed, n):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    scene = _bipolar(ks[0], (d,))
    o1 = _bipolar(ks[1], (d,))
    o2 = _bipolar(ks[2], (d,))
    cb = _bipolar(ks[3], (n, d))
    est, scores = kernels.resonator_step(scene, o1, o2, cb)
    est_r, scores_r = ref.resonator_step_ref(scene, o1, o2, cb)
    np.testing.assert_allclose(est, est_r)
    np.testing.assert_allclose(scores, scores_r, rtol=1e-4, atol=1e-3)


def test_resonator_converges_on_exact_factorization():
    """Full resonator loop recovers the 3 factors of s = a*b*c."""
    d, n = 1024, 8
    ks = jax.random.split(jax.random.PRNGKey(99), 6)
    cbs = [_bipolar(k, (n, d)) for k in ks[:3]]
    true_idx = [2, 5, 1]
    a, b, c = (cb[i] for cb, i in zip(cbs, true_idx))
    scene = a * b * c
    # init estimates as bundles of the whole codebook
    ests = [jnp.where(cb.sum(0) >= 0, 1.0, -1.0) for cb in cbs]
    for _ in range(20):
        new = []
        for f in range(3):
            o1, o2 = ests[(f + 1) % 3], ests[(f + 2) % 3]
            est, _ = kernels.resonator_step(scene, o1, o2, cbs[f])
            new.append(est)
        ests = new
    for f in range(3):
        scores = cbs[f] @ ests[f]
        assert int(jnp.argmax(scores)) == true_idx[f]
