"""AOT compiler: lower every L2 entry point to HLO text + a manifest.

Interchange format is HLO *text*, NOT serialized HloModuleProto — the
image's xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit
instruction ids; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Produces artifacts/<name>.hlo.txt per artifact plus artifacts/manifest.json
describing input/output shapes+dtypes for the Rust runtime
(rust/src/runtime/artifact.rs).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def _s(*shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


D = model.HD_DIM
N = model.CODEBOOK_N
K = model.ATTR_K
P = model.NVSA_PANELS
IMG = model.IMG

# name -> (fn, [input specs]).  All fns return tuples (return_tuple=True).
ARTIFACTS = {
    "nvsa_frontend": (model.nvsa_frontend, [_s(P, IMG, IMG, 1)]),
    "pmf_to_vsa": (model.pmf_to_vsa, [_s(P, K), _s(K, D)]),
    "vsa_to_pmf": (model.vsa_to_pmf, [_s(P, D), _s(K, D)]),
    "cconv_bind": (model.cconv_bind, [_s(P, D), _s(P, D)]),
    "hadamard_bind": (model.hadamard_bind, [_s(P, D), _s(P, D)]),
    "codebook_similarity": (model.codebook_similarity, [_s(N, D), _s(P, D)]),
    "resonator_step": (
        model.resonator_step,
        [_s(D), _s(D), _s(D), _s(N, D)],
    ),
    "ltn_grounding": (
        model.ltn_grounding,
        [_s(32, model.LTN_FEATURES)],
    ),
    "nlm_layer": (
        model.nlm_layer,
        [
            _s(4, model.NLM_OBJS, model.NLM_FEATS),
            _s(4, model.NLM_OBJS, model.NLM_OBJS, model.NLM_FEATS),
        ],
    ),
    "vsait_encoder": (model.vsait_encoder, [_s(model.VSAIT_BATCH, IMG, IMG, 3)]),
    "zeroc_energy": (
        model.zeroc_energy,
        [_s(8, IMG, IMG, 1), _s(8, model.ZEROC_CONCEPT)],
    ),
    "prae_frontend": (model.prae_frontend, [_s(P, IMG, IMG, 1)]),
    "lnn_grounding": (model.lnn_grounding, [_s(32, model.LNN_GROUND)]),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def compile_all(out_dir: str, only=None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "hd_dim": D,
        "codebook_n": N,
        "attr_k": K,
        "n_attrs": model.N_ATTRS,
        "panels": P,
        "img": IMG,
        "artifacts": {},
    }
    for name, (fn, specs) in ARTIFACTS.items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = lowered.out_info
        flat, _ = jax.tree_util.tree_flatten(outs)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [_spec_json(s) for s in specs],
            "outputs": [_spec_json(o) for o in flat],
        }
        print(f"  {name}: {len(text)} chars, "
              f"{len(specs)} in, {len(flat)} out")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()
    compile_all(args.out_dir, args.only)
    print(f"wrote manifest to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
