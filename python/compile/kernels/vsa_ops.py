"""L1 Pallas kernels for elementwise vector-symbolic operations.

These are the paper's VOP-subsystem operations (Sec. VI-A): binding
(Hadamard multiply), bundling (elementwise add / majority), and cyclic
permutation.  Hypervectors are tiled into VMEM-sized *folds* along the
last axis — the same folding mechanism the paper's accelerator uses for
extended vector dimensions — expressed as a Pallas grid over fold blocks.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so kernels lower to plain HLO (see DESIGN.md
§Hardware-adaptation for the TPU mapping rationale).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True

#: Default fold width (lanes per grid step). 256 f32 lanes keeps a
#: (items x fold) similarity tile comfortably inside a 4 MiB VMEM budget.
DEFAULT_FOLD = 256


def _fold_for(dim, fold=None):
    fold = fold or min(dim, DEFAULT_FOLD)
    if dim % fold != 0:
        raise ValueError(f"dim {dim} not divisible by fold {fold}")
    return fold


def _bind_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] * y_ref[...]


def bind(x, y, fold=None):
    """Hadamard binding of two equally-shaped hypervector arrays (..., D)."""
    d = x.shape[-1]
    fold = _fold_for(d, fold)
    nlead = len(x.shape) - 1
    blk = x.shape[:-1] + (fold,)
    spec = pl.BlockSpec(blk, lambda k: (0,) * nlead + (k,))
    return pl.pallas_call(
        _bind_kernel,
        grid=(d // fold,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=INTERPRET,
    )(x, y)


def _bundle_kernel(xs_ref, o_ref):
    o_ref[...] = jnp.sum(xs_ref[...], axis=0)


def bundle(xs, fold=None):
    """Bundling: sum M hypervectors (M, ..., D) -> (..., D)."""
    d = xs.shape[-1]
    fold = _fold_for(d, fold)
    nlead = len(xs.shape) - 1  # includes the M axis
    in_spec = pl.BlockSpec(xs.shape[:-1] + (fold,), lambda k: (0,) * nlead + (k,))
    out_spec = pl.BlockSpec(
        xs.shape[1:-1] + (fold,), lambda k: (0,) * (nlead - 1) + (k,)
    )
    return pl.pallas_call(
        _bundle_kernel,
        grid=(d // fold,),
        in_specs=[in_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(xs.shape[1:], xs.dtype),
        interpret=INTERPRET,
    )(xs)


def _bundle_sign_kernel(xs_ref, o_ref):
    s = jnp.sum(xs_ref[...], axis=0)
    o_ref[...] = jnp.where(s >= 0, 1.0, -1.0).astype(o_ref.dtype)


def bundle_sign(xs, fold=None):
    """Bundling with bipolarization (the accelerator's BND -> SGN path)."""
    d = xs.shape[-1]
    fold = _fold_for(d, fold)
    nlead = len(xs.shape) - 1
    in_spec = pl.BlockSpec(xs.shape[:-1] + (fold,), lambda k: (0,) * nlead + (k,))
    out_spec = pl.BlockSpec(
        xs.shape[1:-1] + (fold,), lambda k: (0,) * (nlead - 1) + (k,)
    )
    return pl.pallas_call(
        _bundle_sign_kernel,
        grid=(d // fold,),
        in_specs=[in_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(xs.shape[1:], xs.dtype),
        interpret=INTERPRET,
    )(xs)


def _permute_kernel(x_ref, o_ref, *, shift):
    o_ref[...] = jnp.roll(x_ref[...], shift, axis=-1)


def permute(x, shift=1):
    """Cyclic permutation rho^shift.

    Rolls cross fold boundaries, so this kernel runs as a single block
    (hypervectors at our sizes fit VMEM whole; on real TPU a multi-fold
    roll would use an edge-exchange schedule).
    """
    import functools

    return pl.pallas_call(
        functools.partial(_permute_kernel, shift=shift),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=INTERPRET,
    )(x)


def _scalar_mult_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = x_ref[...] * w_ref[0]


def scalar_mult(x, w, fold=None):
    """Scalar multiplication of a hypervector (the accelerator's MULT unit)."""
    d = x.shape[-1]
    fold = _fold_for(d, fold)
    nlead = len(x.shape) - 1
    spec = pl.BlockSpec(x.shape[:-1] + (fold,), lambda k: (0,) * nlead + (k,))
    w_spec = pl.BlockSpec((1,), lambda k: (0,))
    return pl.pallas_call(
        _scalar_mult_kernel,
        grid=(d // fold,),
        in_specs=[spec, w_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=INTERPRET,
    )(x, jnp.reshape(w, (1,)).astype(x.dtype))
