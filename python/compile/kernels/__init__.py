"""L1 Pallas kernels (interpret=True) + pure-jnp reference oracles."""

from .vsa_ops import bind, bundle, bundle_sign, permute, scalar_mult  # noqa: F401
from .similarity import similarity, nearest  # noqa: F401
from .circular_conv import circular_conv, circular_corr  # noqa: F401
from .resonator import resonator_step  # noqa: F401
