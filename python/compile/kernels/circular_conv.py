"""L1 Pallas kernel for circular-convolution binding (NVSA / HRR).

NVSA binds holographic representations with circular convolution.  On GPU
the paper observes this as a memory-bound streaming op; the TPU rethink is
to phrase it as a circulant-matrix matmul so it lands on the MXU: build
C(y)[i, j] = y[(i - j) mod D] and compute z = C(y) @ x.  For our
hypervector sizes the circulant tile fits VMEM; larger D would block the
circulant row-wise over the same fold grid.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .vsa_ops import INTERPRET


def _cconv_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...]
    y = y_ref[...]
    d = x.shape[-1]
    idx = (jnp.arange(d)[:, None] - jnp.arange(d)[None, :]) % d
    circ = y[..., idx]  # (..., D, D) circulant of y
    o_ref[...] = jnp.einsum("...ij,...j->...i", circ, x).astype(o_ref.dtype)


def circular_conv(x, y):
    """Circular convolution z[i] = sum_j x[j] y[(i-j) mod D], shapes (..., D)."""
    return pl.pallas_call(
        _cconv_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=INTERPRET,
    )(x, y)


def _ccorr_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...]
    y = y_ref[...]
    d = x.shape[-1]
    idx = (jnp.arange(d)[None, :] + jnp.arange(d)[:, None]) % d
    mat = y[..., idx]  # mat[i, j] = y[(j + i) mod D]
    o_ref[...] = jnp.einsum("...ij,...j->...i", mat, x).astype(o_ref.dtype)


def circular_corr(x, y):
    """Circular correlation (unbinding): z[i] = sum_j x[j] y[(j+i) mod D]."""
    return pl.pallas_call(
        _ccorr_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=INTERPRET,
    )(x, y)
