"""L1 Pallas kernel for fold-aware similarity search (the DC subsystem).

The paper's distance computation (Sec. VI-C) streams hypervector folds
through POPCNT/dot units and accumulates *partial* distances in DSUM RF
before ARGMAX.  The TPU analogue: a grid over folds, each step an
(N x fold) @ (fold x B) MXU matmul, with the output block revisited across
grid steps as the DSUM accumulator.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .vsa_ops import INTERPRET, _fold_for


def _sim_kernel(cb_ref, q_ref, o_ref):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Partial-distance accumulation: the paper's DSUM-RF += popcount fold.
    o_ref[...] += jnp.dot(
        q_ref[...], cb_ref[...].T, preferred_element_type=o_ref.dtype
    )


def similarity(codebook, queries, fold=None):
    """Dot-product scores of queries (B, D) against codebook (N, D) -> (B, N).

    Accumulates one fold per grid step, mirroring the accelerator's
    time-multiplexed distance computation.
    """
    n, d = codebook.shape
    b = queries.shape[0]
    fold = _fold_for(d, fold)
    return pl.pallas_call(
        _sim_kernel,
        grid=(d // fold,),
        in_specs=[
            pl.BlockSpec((n, fold), lambda k: (0, k)),
            pl.BlockSpec((b, fold), lambda k: (0, k)),
        ],
        out_specs=pl.BlockSpec((b, n), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), queries.dtype),
        interpret=INTERPRET,
    )(codebook, queries)


def nearest(codebook, queries, fold=None):
    """Nearest-neighbor search: the paper's e(y) = argmax_i d(y_i, y_bar)."""
    scores = similarity(codebook, queries, fold)
    return jnp.argmax(scores, axis=-1), scores
