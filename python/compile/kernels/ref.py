"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest + hypothesis sweep shapes
and dtypes and assert_allclose(kernel(...), ref(...)).  Where possible the
oracle uses a *different* algorithm than the kernel (e.g. FFT circular
convolution vs. the kernel's circulant matmul) so agreement is meaningful.
"""

import jax.numpy as jnp


def bind_ref(x, y):
    """Hadamard (elementwise-multiply) binding of bipolar hypervectors."""
    return x * y


def bundle_ref(xs):
    """Bundling (superposition): elementwise sum over the leading axis."""
    return jnp.sum(xs, axis=0)


def bundle_sign_ref(xs):
    """Bundling followed by bipolarization (majority vote for odd counts)."""
    s = jnp.sum(xs, axis=0)
    return jnp.where(s >= 0, 1.0, -1.0).astype(xs.dtype)


def permute_ref(x, shift=1):
    """Cyclic permutation rho^shift along the last axis."""
    return jnp.roll(x, shift, axis=-1)


def scalar_mult_ref(x, w):
    """Scalar multiplication of a hypervector."""
    return x * w


def circular_conv_ref(x, y):
    """Circular convolution binding (NVSA / HRR), via FFT.

    z[i] = sum_j x[j] * y[(i - j) mod D].  The Pallas kernel computes the
    same quantity with a circulant-matrix matmul, so FFT here is an
    independent algorithm.
    """
    fx = jnp.fft.fft(x)
    fy = jnp.fft.fft(y)
    return jnp.real(jnp.fft.ifft(fx * fy)).astype(x.dtype)


def circular_corr_ref(x, y):
    """Circular correlation — the approximate inverse of circular_conv.

    z[i] = sum_j x[j] * y[(j + i) mod D].
    """
    fx = jnp.fft.fft(x)
    fy = jnp.fft.fft(y)
    return jnp.real(jnp.fft.ifft(jnp.conj(fx) * fy)).astype(x.dtype)


def similarity_ref(codebook, queries):
    """Dot-product similarity of queries (B, D) against codebook (N, D).

    Returns (B, N).  This is the paper's d(y_i, y_bar) with fold
    aggregation collapsed (the kernel accumulates per-fold partials, the
    oracle does the whole contraction at once).
    """
    return queries @ codebook.T


def resonator_step_ref(scene, other1, other2, codebook):
    """One resonator-network iteration for a single factor.

    x_hat = scene (*) other1 (*) other2           (Hadamard unbinding)
    scores = codebook @ x_hat                     (similarity, paper's d)
    est    = sign(codebook^T @ scores)            (projection, paper's c)

    Returns (est (D,), scores (N,)).
    """
    x_hat = scene * other1 * other2
    scores = codebook @ x_hat
    proj = scores @ codebook
    est = jnp.where(proj >= 0, 1.0, -1.0).astype(scene.dtype)
    return est, scores


def pmf_to_vsa_ref(pmf, codebook):
    """NVSA PMF-to-VSA transform: probability-weighted bundling.

    pmf (B, K) x codebook (K, D) -> (B, D).
    """
    return pmf @ codebook


def vsa_to_pmf_ref(vec, codebook):
    """NVSA VSA-to-PMF transform: similarity then normalized ReLU."""
    scores = vec @ codebook.T
    scores = jnp.maximum(scores, 0.0)
    denom = jnp.sum(scores, axis=-1, keepdims=True)
    return scores / jnp.maximum(denom, 1e-9)
