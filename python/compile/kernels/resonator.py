"""L1 Pallas kernel for one resonator-network iteration (Frady et al.).

The paper's Resonator-Network kernel (Sec. VI-B): factorize a composed
vector s = a (*) b (*) c by iterating, per factor,

    x_hat  = s (*) b_est (*) c_est       # Hadamard unbinding
    scores = A @ x_hat                   # similarity d(.) against codebook
    a_new  = sign(A^T @ scores)          # weighted-bundle projection c(.)

Both contractions are MXU matmuls; the elementwise unbind runs on the VPU.
One kernel invocation updates one factor; the L2 model laces three of
these per sweep and the L3 coordinator (or accel simulator) iterates to
convergence.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .vsa_ops import INTERPRET


def _resonator_kernel(s_ref, o1_ref, o2_ref, cb_ref, est_ref, sc_ref):
    x_hat = s_ref[...] * o1_ref[...] * o2_ref[...]
    scores = jnp.dot(cb_ref[...], x_hat, preferred_element_type=sc_ref.dtype)
    proj = jnp.dot(scores, cb_ref[...], preferred_element_type=est_ref.dtype)
    est_ref[...] = jnp.where(proj >= 0, 1.0, -1.0).astype(est_ref.dtype)
    sc_ref[...] = scores.astype(sc_ref.dtype)


def resonator_step(scene, other1, other2, codebook):
    """Update one factor's estimate.  Returns (est (D,), scores (N,))."""
    n, d = codebook.shape
    dtype = scene.dtype
    return pl.pallas_call(
        _resonator_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((d,), dtype),
            jax.ShapeDtypeStruct((n,), dtype),
        ),
        interpret=INTERPRET,
    )(scene, other1, other2, codebook)
