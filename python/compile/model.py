"""L2: JAX compute graphs for the seven neuro-symbolic workloads.

Each workload from the paper (Tab. III) is split into a *neural* phase —
defined here in JAX (calling the L1 Pallas kernels where the hot-spot is
vector-symbolic) and AOT-lowered to HLO text — and a *symbolic* phase that
lives in the Rust coordinator (rust/src/workloads/).

Weights are untrained (fixed-seed random): the characterization study
measures operator mixes, shapes and dependencies, not accuracy.  Weights
are baked into the HLO as constants so the Rust hot path only feeds
activations and codebooks.
"""

import jax
import jax.numpy as jnp

from . import kernels

# ----------------------------------------------------------------------------
# Shared model dimensions (mirrored in rust/src/config.rs via the manifest)
# ----------------------------------------------------------------------------
HD_DIM = 1024          # hypervector dimensionality D
CODEBOOK_N = 64        # item vectors per codebook / factor
IMG = 32               # panel height == width
NVSA_PANELS = 16       # 8 context + 8 candidate panels (3x3 RPM row task)
ATTR_K = 8             # categories per attribute (type / size / color)
N_ATTRS = 3
LTN_FEATURES = 8       # crabs-style tabular features
LTN_PREDICATES = 6     # grounded predicate count
LTN_HIDDEN = 64
NLM_OBJS = 8           # objects in the NLM relational state
NLM_FEATS = 16         # predicate channels per arity
VSAIT_BATCH = 4
ZEROC_CONCEPT = 64
LNN_GROUND = 16        # grounding feature width

_key = jax.random.PRNGKey(20240710)


def _keys(n):
    global _key
    ks = jax.random.split(_key, n + 1)
    _key = ks[0]
    return list(ks[1:])


def _dense_params(key, n_in, n_out):
    k1, k2 = jax.random.split(key)
    scale = (2.0 / n_in) ** 0.5
    return (
        jax.random.normal(k1, (n_in, n_out), jnp.float32) * scale,
        jax.random.normal(k2, (n_out,), jnp.float32) * 0.01,
    )


def _conv_params(key, k, c_in, c_out):
    k1, k2 = jax.random.split(key)
    scale = (2.0 / (k * k * c_in)) ** 0.5
    return (
        jax.random.normal(k1, (k, k, c_in, c_out), jnp.float32) * scale,
        jax.random.normal(k2, (c_out,), jnp.float32) * 0.01,
    )


def _conv2d(x, w, b, stride=1):
    """NHWC conv, SAME padding."""
    y = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


# ----------------------------------------------------------------------------
# Shared ConvNet perception backbone (NVSA / PrAE / VSAIT frontends)
# ----------------------------------------------------------------------------

def _make_backbone(c_in, widths=(8, 16)):
    ks = _keys(len(widths))
    params = []
    c = c_in
    for key, w in zip(ks, widths):
        params.append(_conv_params(key, 3, c, w))
        c = w
    return params


def _backbone_apply(params, x):
    for w, b in params:
        x = jax.nn.relu(_conv2d(x, w, b))
        x = _maxpool2(x)
    return x.reshape(x.shape[0], -1)


# ----------------------------------------------------------------------------
# NVSA (Hersche et al.): ConvNet frontend -> per-attribute PMFs
# ----------------------------------------------------------------------------

_NVSA_BACKBONE = _make_backbone(1)
_NVSA_TRUNK = _dense_params(_keys(1)[0], (IMG // 4) ** 2 * 16, 128)
_NVSA_HEADS = [_dense_params(k, 128, ATTR_K) for k in _keys(N_ATTRS)]


def nvsa_frontend(panels):
    """panels (P, 32, 32, 1) -> tuple of N_ATTRS PMFs, each (P, ATTR_K)."""
    h = _backbone_apply(_NVSA_BACKBONE, panels)
    w, b = _NVSA_TRUNK
    h = jax.nn.relu(h @ w + b)
    outs = []
    for w, b in _NVSA_HEADS:
        outs.append(jax.nn.softmax(h @ w + b, axis=-1))
    return tuple(outs)


def pmf_to_vsa(pmf, codebook):
    """NVSA PMF-to-VSA: probability-weighted bundling over the codebook.

    pmf (B, K), codebook (K, D) -> (B, D).  The weighted bundle is the
    accelerator's MULT+BND path; expressed as an MXU matmul.
    """
    return (pmf @ codebook,)


def vsa_to_pmf(vecs, codebook):
    """NVSA VSA-to-PMF: fold-accumulated similarity then normalized ReLU."""
    scores = kernels.similarity(codebook, vecs)
    scores = jnp.maximum(scores, 0.0)
    denom = jnp.maximum(jnp.sum(scores, axis=-1, keepdims=True), 1e-9)
    return (scores / denom,)


def cconv_bind(x, y):
    """NVSA holographic binding of batched hypervectors (B, D)."""
    return (kernels.circular_conv(x, y),)


def hadamard_bind(x, y):
    """Bipolar Hadamard binding of batched hypervectors (B, D)."""
    return (kernels.bind(x, y),)


def codebook_similarity(codebook, queries):
    """Clean-up / associative memory scores (B, N)."""
    return (kernels.similarity(codebook, queries),)


def resonator_step(scene, est_b, est_c, codebook):
    """One factor update of the resonator network (see kernels.resonator)."""
    est, scores = kernels.resonator_step(scene, est_b, est_c, codebook)
    return (est, scores)


# ----------------------------------------------------------------------------
# LTN (Badreddine et al.): MLP predicate grounding; fuzzy aggregation in L3
# ----------------------------------------------------------------------------

_LTN_L1 = _dense_params(_keys(1)[0], LTN_FEATURES, LTN_HIDDEN)
_LTN_L2 = _dense_params(_keys(1)[0], LTN_HIDDEN, LTN_HIDDEN)
_LTN_HEAD = _dense_params(_keys(1)[0], LTN_HIDDEN, LTN_PREDICATES)


def ltn_grounding(x):
    """x (B, F) tabular samples -> truth degrees (B, P) in [0, 1]."""
    w1, b1 = _LTN_L1
    w2, b2 = _LTN_L2
    wh, bh = _LTN_HEAD
    h = jax.nn.elu(x @ w1 + b1)
    h = jax.nn.elu(h @ w2 + b2)
    return (jax.nn.sigmoid(h @ wh + bh),)


# ----------------------------------------------------------------------------
# NLM (Dong et al.): per-arity MLPs; expand/reduce/permute wiring in L3
# ----------------------------------------------------------------------------

_NLM_UNARY = _dense_params(_keys(1)[0], NLM_FEATS * 3, NLM_FEATS)
_NLM_BINARY = _dense_params(_keys(1)[0], NLM_FEATS * 4, NLM_FEATS)


def nlm_layer(unary, binary):
    """One NLM logic layer.

    unary (B, N, C), binary (B, N, N, C).  The expand (unary->binary),
    reduce (binary->unary, exists/forall as max/min) and transpose
    permutations are the *symbolic wiring*; the learned part is a shared
    MLP with sigmoid 'soft logic' activation.
    """
    b, n, c = unary.shape
    exists = jnp.max(binary, axis=2)
    forall = jnp.min(binary, axis=2)
    u_in = jnp.concatenate([unary, exists, forall], axis=-1)
    w, bias = _NLM_UNARY
    unary_out = jax.nn.sigmoid(u_in @ w + bias)

    expand_r = jnp.broadcast_to(unary[:, :, None, :], (b, n, n, c))
    expand_c = jnp.broadcast_to(unary[:, None, :, :], (b, n, n, c))
    swap = jnp.swapaxes(binary, 1, 2)
    b_in = jnp.concatenate([binary, swap, expand_r, expand_c], axis=-1)
    w2, bias2 = _NLM_BINARY
    binary_out = jax.nn.sigmoid(b_in @ w2 + bias2)
    return (unary_out, binary_out)


# ----------------------------------------------------------------------------
# VSAIT (Theiss et al.): ConvNet features -> random hypervector projection
# ----------------------------------------------------------------------------

_VSAIT_BACKBONE = _make_backbone(3)
_VSAIT_PROJ = jax.random.normal(
    _keys(1)[0], ((IMG // 4) ** 2 * 16, HD_DIM), jnp.float32
) / ((IMG // 4) ** 2 * 16) ** 0.5
_VSAIT_KEYVEC = jnp.where(
    jax.random.normal(_keys(1)[0], (HD_DIM,)) >= 0, 1.0, -1.0
).astype(jnp.float32)


def vsait_encoder(images):
    """images (B, 32, 32, 3) -> source-content hypervectors (B, D).

    Features are projected into random hyperspace, bipolarized, then bound
    (Pallas Hadamard bind) with a domain key vector — VSAIT's invertible
    source->target mapping setup.
    """
    feats = _backbone_apply(_VSAIT_BACKBONE, images)
    hv = feats @ _VSAIT_PROJ
    hv = jnp.where(hv >= 0, 1.0, -1.0).astype(jnp.float32)
    key = jnp.broadcast_to(_VSAIT_KEYVEC, hv.shape)
    return (kernels.bind(hv, key),)


# ----------------------------------------------------------------------------
# ZeroC (Wu et al.): energy-based model over image & concept embedding
# ----------------------------------------------------------------------------

_ZEROC_BACKBONE = _make_backbone(1)
_ZEROC_FILM = _dense_params(_keys(1)[0], ZEROC_CONCEPT, (IMG // 4) ** 2 * 16)
_ZEROC_HEAD = _dense_params(_keys(1)[0], (IMG // 4) ** 2 * 16, 1)


def zeroc_energy(images, concept):
    """E(image, concept): (B,32,32,1) x (B,64) -> (B,) energies.

    FiLM-style modulation of conv features by the concept embedding — the
    inner loop of ZeroC's relational energy inference (the graph search
    over concept compositions is the L3 symbolic phase).
    """
    feats = _backbone_apply(_ZEROC_BACKBONE, images)
    wf, bf = _ZEROC_FILM
    gamma = jax.nn.sigmoid(concept @ wf + bf)
    wh, bh = _ZEROC_HEAD
    e = (feats * gamma) @ wh + bh
    return (e[:, 0],)


# ----------------------------------------------------------------------------
# PrAE (Zhang et al.): shared ConvNet + attribute PMF heads (no HD proj)
# ----------------------------------------------------------------------------

_PRAE_BACKBONE = _make_backbone(1)
_PRAE_TRUNK = _dense_params(_keys(1)[0], (IMG // 4) ** 2 * 16, 128)
_PRAE_HEADS = [_dense_params(k, 128, ATTR_K) for k in _keys(N_ATTRS)]
_PRAE_OBJ = _dense_params(_keys(1)[0], 128, 1)


def prae_frontend(panels):
    """panels (P,32,32,1) -> (objectness (P,), attr PMFs (P,K) x N_ATTRS).

    PrAE keeps raw probability mass functions (no hypervector projection) —
    the scene-inference / rule-abduction over these PMFs is L3 symbolic.
    """
    h = _backbone_apply(_PRAE_BACKBONE, panels)
    w, b = _PRAE_TRUNK
    h = jax.nn.relu(h @ w + b)
    wo, bo = _PRAE_OBJ
    obj = jax.nn.sigmoid(h @ wo + bo)[:, 0]
    outs = [obj]
    for w, b in _PRAE_HEADS:
        outs.append(jax.nn.softmax(h @ w + b, axis=-1))
    return tuple(outs)


# ----------------------------------------------------------------------------
# LNN (Riegel et al.): neural grounding of predicates into [lower, upper]
# ----------------------------------------------------------------------------

_LNN_L1 = _dense_params(_keys(1)[0], LNN_GROUND, 32)
_LNN_HEAD = _dense_params(_keys(1)[0], 32, 2)


def lnn_grounding(x):
    """x (B, G) entity features -> truth bounds (B, 2), lower <= upper.

    The weighted Lukasiewicz inference (upward/downward passes over the
    syntax tree) is the L3 symbolic engine; this provides leaf bounds.
    """
    w1, b1 = _LNN_L1
    wh, bh = _LNN_HEAD
    h = jax.nn.relu(x @ w1 + b1)
    raw = jax.nn.sigmoid(h @ wh + bh)
    lower = jnp.minimum(raw[:, 0], raw[:, 1])
    upper = jnp.maximum(raw[:, 0], raw[:, 1])
    return (jnp.stack([lower, upper], axis=-1),)
