#!/usr/bin/env bash
# CI entry point: format check, release build, tests, and a hot-path bench
# smoke run that emits BENCH_hotpath.json so successive PRs accumulate a
# perf trajectory (see PERF.md).
#
# Usage: ./ci.sh            # full pipeline
#        NSCOG_THREADS=4 ./ci.sh   # also exercises the threaded scans
set -euo pipefail
cd "$(dirname "$0")"

echo "== fmt =="
# Advisory: rustfmt is not installed in every environment this repo
# builds in; when present, drift is reported but does not fail the run
# (the build/test/bench gates below are the hard ones).
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all --check || echo "WARNING: cargo fmt --check reported drift"
else
    echo "rustfmt unavailable; skipping format check"
fi

echo "== build (release) =="
cargo build --release

echo "== tests =="
cargo test -q

echo "== bench smoke: hotpath =="
NSCOG_BENCH_JSON="$(pwd)/BENCH_hotpath.json" cargo bench --bench hotpath

echo "== perf trajectory =="
test -s BENCH_hotpath.json && echo "BENCH_hotpath.json written:" && cat BENCH_hotpath.json

echo "CI OK"
