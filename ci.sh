#!/usr/bin/env bash
# CI entry point: format check, release build, tests, and a hot-path bench
# smoke run that emits BENCH_hotpath.json so successive PRs accumulate a
# perf trajectory (see PERF.md).
#
# Usage: ./ci.sh            # full pipeline
#        NSCOG_THREADS=4 ./ci.sh   # also exercises the threaded scans
set -euo pipefail
cd "$(dirname "$0")"

echo "== fmt =="
# Advisory: rustfmt is not installed in every environment this repo
# builds in; when present, drift is reported but does not fail the run
# (the build/test/bench gates below are the hard ones).
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all --check || echo "WARNING: cargo fmt --check reported drift"
else
    echo "rustfmt unavailable; skipping format check"
fi

echo "== build (release) =="
cargo build --release

echo "== tests =="
cargo test -q

echo "== tests: forced NSCOG_SIMD=scalar kernel/scan subset =="
# the dispatched kernels must stay bit-identical when the scalar tier is
# forced through the env override (the A/B path the bench comparison uses)
NSCOG_SIMD=scalar cargo test -q --test kernel_equivalence --test pruned_equivalence

echo "== bench smoke: hotpath (NSCOG_SIMD=scalar baseline) =="
NSCOG_SIMD=scalar NSCOG_BENCH_JSON="$(pwd)/BENCH_hotpath_scalar.json" \
    cargo bench --bench hotpath

echo "== bench smoke: hotpath (auto simd dispatch) =="
NSCOG_BENCH_JSON="$(pwd)/BENCH_hotpath.json" cargo bench --bench hotpath

# Merge the two runs into simd-vs-scalar speedup entries keyed on shared
# bench names, so PERF.md numbers are attributable to a code path.
if command -v python3 >/dev/null 2>&1; then
    echo "== merge simd-vs-scalar speedups into BENCH_hotpath.json =="
    python3 - <<'PYEOF'
import json
try:
    auto = json.load(open('BENCH_hotpath.json'))
    scal = json.load(open('BENCH_hotpath_scalar.json'))
except (OSError, json.JSONDecodeError):
    print('bench JSONs unavailable; skipping simd merge')
    raise SystemExit(0)
pairs = {
    'simd hamming 8192b': 'vsa/hamming_bulk 8192b x16',
    'simd dot 8192b': 'vsa/dot_bulk 8192b x16',
    'simd majority 9x8192b': 'vsa/majority 9x8192b (word-sliced)',
    'simd batched-scan 100q': 'vsa/nearest_batch 100q (blocked)',
}
p50 = lambda r: {e['name']: e['p50_s'] for e in r.get('entries', [])}
a, s = p50(auto), p50(scal)
merged = []
for label, entry in pairs.items():
    if entry in a and entry in s and a[entry] > 0:
        merged.append({'kernel': label, 'scalar_p50_s': s[entry],
                       'simd_p50_s': a[entry],
                       'speedup': round(s[entry] / a[entry], 3)})
auto['simd_speedups'] = merged
json.dump(auto, open('BENCH_hotpath.json', 'w'), indent=2)
tier = auto.get('simd', 'unknown')
print(f"simd tier '{tier}':")
for m in merged:
    print(f"  {m['kernel']}: {m['speedup']:.2f}x vs forced scalar")
if tier == 'scalar':
    print('host resolved the scalar tier (no AVX2/NEON); simd floors will be skipped')
PYEOF
fi

# Large-store gate: the hotpath bench's 200k-item section records one
# prune ledger per scan mode (single-level sketch, 128-bit cascade, ca90
# rematerialized) plus a bit-equality verdict across all of them. The
# validator asserts the cascade actually used its coarse level, that the
# rejection levels nest (each level rejects from the previous level's
# survivors), and that both new modes streamed strictly fewer words than
# the single-level baseline. NSCOG_LARGE=0 runs skip cleanly.
echo "== validate BENCH_hotpath.json large-store block =="
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PYEOF'
import json

def validate(r):
    """One hotpath report -> 'pass' or 'skip'; raises AssertionError on a
    violated invariant. Runs without the large-store section (NSCOG_LARGE=0
    or pre-cascade JSONs) skip cleanly."""
    ls = r.get('large_store')
    if ls is None:
        return 'skip'
    assert ls.get('items', 0) >= 200_000, \
        f"large-store section ran below the 200k-item shape: {ls.get('items')}"
    assert ls.get('remat_equal') is True, \
        'large-store scan modes were not bit-identical to exhaustive'
    single, casc, remat = ls['single'], ls['cascade'], ls['remat']
    for name, st in (('single', single), ('cascade', casc), ('remat', remat)):
        assert st['items'] > 0, f'{name}: empty prune ledger'
        assert st['words_streamed'] <= st['words_total'], \
            f'{name}: streamed beyond the exhaustive word count'
        # rejection classes are disjoint item outcomes: coarse rejects
        # first, the sketch rejects from coarse survivors, incremental
        # bounds terminate from sketch survivors
        assert st['coarse_rejected'] + st['sketch_rejected'] + st['early_terminated'] \
            <= st['items'], f'{name}: rejection levels do not nest: {st}'
        assert st['coarse_rejected'] <= st['items'], f'{name}: coarse over-rejects'
    assert single['coarse_rejected'] == 0, \
        'single-level ledger claims coarse rejects with no coarse level'
    assert casc['coarse_rejected'] > 0, \
        'cascade run never used its coarse level (vacuous two-level sketch)'
    assert remat['coarse_rejected'] > 0, \
        'remat run never used its coarse level'
    assert casc['words_streamed'] < single['words_streamed'], \
        'cascade streamed no fewer words than the single-level baseline'
    assert remat['words_streamed'] < single['words_streamed'], \
        'remat streamed no fewer words than the single-level baseline'
    return 'pass'

# Self-test before gating the real artifact: the validator must pass a
# good report, skip sectionless shapes, and FAIL each mutated bad one (a
# gate that cannot fail gates nothing).
st = lambda c, s, e, w: {'items': 1_600_000, 'coarse_rejected': c,
                         'sketch_rejected': s, 'early_terminated': e,
                         'words_streamed': w, 'words_total': 51_200_000,
                         'coarse_reject_rate': c / 1_600_000,
                         'sketch_reject_rate': s / 1_600_000,
                         'words_frac': w / 51_200_000}
ok = {'bench': 'hotpath',
      'large_store': {'items': 200_000, 'dim': 2048, 'remat_equal': True,
                      'single': st(0, 1_500_000, 60_000, 18_000_000),
                      'cascade': st(1_550_000, 30_000, 9_000, 5_200_000),
                      'remat': st(1_550_000, 30_000, 9_000, 6_100_000)}}
assert validate(ok) == 'pass', 'validator rejected a passing large-store block'
assert validate({'bench': 'hotpath', 'large_store': None}) == 'skip', \
    'NSCOG_LARGE=0 run must skip'
assert validate({}) == 'skip', 'pre-cascade JSON must skip'
for mutate, what in [
        (lambda b: b['large_store'].__setitem__('remat_equal', False),
         'a remat/ram divergence'),
        (lambda b: b['large_store'].__setitem__('items', 50_000),
         'a sub-200k shape'),
        (lambda b: b['large_store']['cascade'].__setitem__('coarse_rejected', 0),
         'a cascade that never coarse-rejects'),
        (lambda b: b['large_store']['single'].__setitem__('coarse_rejected', 7),
         'coarse rejects on the single-level ledger'),
        (lambda b: b['large_store']['cascade'].__setitem__('words_streamed', 18_000_000),
         'a cascade streaming no fewer words than single-level'),
        (lambda b: b['large_store']['remat'].__setitem__('words_streamed', 99_000_000),
         'a remat ledger streaming beyond exhaustive'),
        (lambda b: b['large_store']['cascade'].__setitem__('sketch_rejected', 200_000),
         'rejection levels that do not nest')]:
    bad = json.loads(json.dumps(ok))
    mutate(bad)
    try:
        validate(bad)
        raise SystemExit(f'large-store validator accepted a report with {what}')
    except AssertionError:
        pass

r = json.load(open('BENCH_hotpath.json'))
verdict = validate(r)
if verdict == 'skip':
    print('large-store section absent (NSCOG_LARGE=0?); skipped')
else:
    ls = r['large_store']
    print(f"large-store OK (validator self-test passed): {ls['items']}x{ls['dim']}b, "
          f"words streamed single {ls['single']['words_frac']*100:.1f}% / "
          f"cascade {ls['cascade']['words_frac']*100:.1f}% "
          f"(coarse reject {ls['cascade']['coarse_reject_rate']*100:.1f}%) / "
          f"remat {ls['remat']['words_frac']*100:.1f}%")
PYEOF
fi

echo "== bench smoke: serve (3 stores, skewed mix, bounded requests, deterministic seed) =="
NSCOG_SERVE_JSON="$(pwd)/BENCH_serve.json" \
    cargo run --release --quiet --bin nscog -- serve-bench --smoke --stores 3

echo "== validate BENCH_serve.json =="
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PYEOF'
import json
r = json.load(open('BENCH_serve.json'))
assert r['bench'] == 'serve', 'wrong bench tag'
cl, base = r['closed_loop'], r['baseline']
assert cl['mismatches'] == 0, 'batched responses diverged from per-store sequential oracles'
assert cl['rejected'] == 0 and cl['expired'] == 0, 'smoke run shed load unexpectedly'
assert cl.get('rejected_tenant', 0) == 0, 'smoke run tripped a tenant quota unexpectedly'
assert cl.get('internal', 0) == 0, 'smoke run contained a worker panic with no faults injected'
assert cl['qps'] > 0 and base['qps'] > 0, 'degenerate throughput measurement'
assert r.get('chaos') is None, 'clean smoke run must not carry a chaos verdict'
if r.get('open_loop'):
    assert r['open_loop']['pass']['mismatches'] == 0, 'open-loop responses diverged'
pr = r.get('prune')
if pr and pr.get('words_total', 0) > 0:
    assert pr['words_streamed'] < pr['words_total'], \
        'pruned scans streamed no fewer words than exhaustive on the smoke mix'
cache = r.get('cache')
if cache is not None and r['config'].get('repeat_frac', 0) > 0:
    assert cache['hits'] > 0, 'repeated-query smoke mix produced no cache hits'
# Per-store blocks: pass/skip/fail per invariant. Old single-store JSONs
# (no "stores" array) skip cleanly; a multi-store run must carry one
# exercised block per store, and each store must have been served,
# pruned, and (when its cache is on and traffic repeats) cache-hit.
stores = r.get('stores')
store_line = ""
if stores is None:
    print('(no per-store blocks; single-store JSON — store checks skipped)')
else:
    declared = r.get('store_count', len(stores))
    assert len(stores) == declared, \
        f'store_count {declared} != {len(stores)} per-store blocks'
    checked, hit_rates = 0, []
    for s in stores:
        name = s.get('name', f"store{s.get('id', '?')}")
        assert s.get('simd') == r.get('simd'), \
            f"{name}: per-store simd tier disagrees with the run tier"
        assert s.get('store_count') == declared, \
            f"{name}: per-store store_count disagrees with the run"
        assert s.get('completed', 0) > 0, f'{name}: store received no completed traffic'
        sp = s.get('prune') or {}
        if sp.get('words_total', 0) > 0:
            assert sp['words_streamed'] < sp['words_total'], \
                f"{name}: store's scans streamed no fewer words than exhaustive"
        sc = s.get('cache')
        if sc is not None and s.get('repeat_frac', 0) > 0:
            assert sc['hits'] > 0, f'{name}: repeated traffic produced no cache hits'
            hit_rates.append(f"{name} {sc['hit_rate']*100:.0f}%")
        # overload-control counters: present on current JSONs, and all
        # zero on a clean (no chaos, no faults) smoke run
        for key in ('rejected_tenant', 'expired_dropped', 'degraded', 'internal'):
            assert s.get(key, 0) == 0, \
                f'{name}: clean smoke run recorded {key}={s.get(key)}'
        checked += 1
    store_line = f", {checked} stores validated"
    if hit_rates:
        store_line += " (hits: " + ", ".join(hit_rates) + ")"
cache_line = (f", cache hit rate {cache['hit_rate']*100:.0f}%" if cache else "")
prune_line = (f", {pr['words_frac']*100:.0f}% words streamed" if pr else "")
print(f"serve smoke OK: {cl['qps']:.0f} qps vs baseline {base['qps']:.0f} "
      f"(x{r['speedup_qps']:.2f}), mean batch {r['batching']['mean_batch']:.2f}"
      f"{prune_line}{cache_line}{store_line}")
PYEOF
else
    grep -q '"bench": "serve"' BENCH_serve.json
    grep -q '"mismatches": 0' BENCH_serve.json
    grep -q '"stores": \[' BENCH_serve.json
    echo "python3 unavailable; structural grep checks passed"
fi

# Large-store serve smoke: the same serving engine over a 200k-item,
# 2048-bit store, once per row backing (ram rows vs ca90 seeds-only
# rematerialization), both with the two-level sketch cascade on and
# near-duplicate queries (2% noise — the high-score regime where the
# coarse level bulk-rejects). Each run is oracle-verified by the binary;
# the cross-backing validator then asserts the ca90 run really held
# dim/512 = 4x less resident row memory at the same shape, and that both
# runs' coarse levels actually fired.
echo "== bench smoke: serve large store (200k x 2048b, ram backing, cascade 128) =="
cargo run --release --quiet --bin nscog -- serve-bench --smoke --requests 120 \
    --store-items 200000 --store-dims 2048 --sketch-bits 512 --sketch-cascade 128 \
    --noise 0.02 --json "$(pwd)/BENCH_serve_large_ram.json"

echo "== bench smoke: serve large store (200k x 2048b, ca90 backing, cascade 128) =="
cargo run --release --quiet --bin nscog -- serve-bench --smoke --requests 120 \
    --store-items 200000 --store-dims 2048 --sketch-bits 512 --sketch-cascade 128 \
    --noise 0.02 --store-backing ca90 --json "$(pwd)/BENCH_serve_large_ca90.json"

echo "== validate BENCH_serve_large_{ram,ca90}.json (cross-backing) =="
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PYEOF'
import json

def validate(ram, ca):
    """A (ram, ca90) pair of large-store serve reports -> 'pass' or
    'skip'; raises AssertionError on a violated invariant. Pairs without
    per-store memory blocks (pre-backing JSONs) skip cleanly."""
    for tag, r in (('ram', ram), ('ca90', ca)):
        assert r.get('bench') == 'serve', f'{tag}: wrong bench tag'
        cl = r['closed_loop']
        assert cl['mismatches'] == 0, f'{tag}: responses diverged from the oracle'
        assert cl['qps'] > 0, f'{tag}: degenerate throughput'
    stores = lambda r: r.get('stores') or []
    if not stores(ram) or not stores(ca):
        return 'skip'
    rs, cs = stores(ram)[0], stores(ca)[0]
    rm, cm = rs.get('memory'), cs.get('memory')
    if rm is None or cm is None:
        return 'skip'
    assert rm['backing'] == 'ram', f"ram run reports backing '{rm['backing']}'"
    assert cm['backing'] == 'ca90', f"ca90 run reports backing '{cm['backing']}'"
    # seeds-only rows: exactly dim/512 = 4x smaller at 2048b, identical
    # sketch sidecars (the sidecar is always materialized)
    assert cm['row_bytes'] * 4 == rm['row_bytes'], \
        f"ca90 rows not 4x smaller: {cm['row_bytes']} vs {rm['row_bytes']}"
    assert cm['sketch_bytes'] == rm['sketch_bytes'] > 0, \
        'sketch sidecar bytes diverge across backings'
    for tag, m in (('ram', rm), ('ca90', cm)):
        assert m['total_bytes'] == m['row_bytes'] + m['sketch_bytes'] + m['master_bytes'], \
            f'{tag}: memory block does not sum to total_bytes'
    for tag, s in (('ram', rs), ('ca90', cs)):
        pr = s.get('prune') or {}
        assert pr.get('words_total', 0) > 0, f'{tag}: store never scanned'
        assert pr['words_streamed'] < pr['words_total'], \
            f'{tag}: scans streamed no fewer words than exhaustive'
        assert pr.get('coarse_rejected', 0) > 0, \
            f'{tag}: cascade coarse level never fired at 2% noise'
        assert pr['coarse_rejected'] + pr.get('sketch_rejected', 0) \
            + pr.get('early_terminated', 0) <= pr['items'], \
            f'{tag}: rejection levels do not nest: {pr}'
    return 'pass'

# Self-test before gating the real artifacts: pass a good pair, skip
# memoryless shapes, FAIL each mutated bad pair (a gate that cannot fail
# gates nothing).
def report(backing, row_bytes):
    return {'bench': 'serve',
            'closed_loop': {'mismatches': 0, 'qps': 900.0},
            'stores': [{'name': 'default', 'backing': backing,
                        'memory': {'backing': backing, 'row_bytes': row_bytes,
                                   'sketch_bytes': 12_800_000, 'master_bytes': 256,
                                   'total_bytes': row_bytes + 12_800_000 + 256},
                        'prune': {'items': 900_000, 'coarse_rejected': 870_000,
                                  'sketch_rejected': 18_000, 'early_terminated': 4_000,
                                  'words_streamed': 4_000_000,
                                  'words_total': 28_800_000}}]}
good = (report('ram', 51_200_000), report('ca90', 12_800_000))
assert validate(*good) == 'pass', 'validator rejected a passing pair'
nomem = json.loads(json.dumps(good))
nomem[1]['stores'][0]['memory'] = None
assert validate(*nomem) == 'skip', 'memoryless pair must skip'
for which, mutate, what in [
        (0, lambda r: r['closed_loop'].__setitem__('mismatches', 3),
         'oracle mismatches'),
        (1, lambda r: r['stores'][0]['memory'].__setitem__('backing', 'ram'),
         'a ca90 run that kept ram rows'),
        (1, lambda r: r['stores'][0]['memory'].__setitem__('row_bytes', 51_200_000),
         'uncompressed ca90 rows'),
        (1, lambda r: r['stores'][0]['memory'].__setitem__('total_bytes', 1),
         'an inconsistent memory total'),
        (1, lambda r: r['stores'][0]['prune'].__setitem__('coarse_rejected', 0),
         'a coarse level that never fired'),
        (0, lambda r: r['stores'][0]['prune'].__setitem__('words_streamed', 28_800_000),
         'scans streaming no fewer words than exhaustive')]:
    bad = json.loads(json.dumps(good))
    mutate(bad[which])
    try:
        validate(*bad)
        raise SystemExit(f'large-serve validator accepted a pair with {what}')
    except AssertionError:
        pass

ram = json.load(open('BENCH_serve_large_ram.json'))
ca = json.load(open('BENCH_serve_large_ca90.json'))
verdict = validate(ram, ca)
if verdict == 'skip':
    raise SystemExit('large-store serve runs wrote no per-store memory blocks')
rm = ram['stores'][0]['memory']; cm = ca['stores'][0]['memory']
mib = lambda b: b / (1024 * 1024)
print(f"large-store serve OK (validator self-test passed): "
      f"ram {ram['closed_loop']['qps']:.0f} qps / ca90 {ca['closed_loop']['qps']:.0f} qps, "
      f"resident rows {mib(rm['row_bytes']):.1f} MiB -> {mib(cm['row_bytes']):.1f} MiB "
      f"(coarse reject ram {ram['stores'][0]['prune']['coarse_rejected']}, "
      f"ca90 {ca['stores'][0]['prune']['coarse_rejected']})")
PYEOF
else
    grep -q '"backing": "ram"' BENCH_serve_large_ram.json
    grep -q '"backing": "ca90"' BENCH_serve_large_ca90.json
    grep -q '"mismatches": 0' BENCH_serve_large_ram.json
    grep -q '"mismatches": 0' BENCH_serve_large_ca90.json
    echo "python3 unavailable; structural grep checks passed"
fi

# Traced smoke: the same fixture shape with the stage tracer on, through
# a separate JSON pair so the untraced BENCH_serve.json above stays the
# canonical perf artifact. Emits BENCH_serve_trace.json: ring-buffer
# event dump with its drop ledger, per-class stage-latency
# decompositions, queue gauges, and the measured roofline verdict per
# request class.
echo "== bench smoke: serve traced (--trace: stage ring + measured roofline) =="
NSCOG_SERVE_JSON="$(pwd)/BENCH_serve_traced.json" \
NSCOG_SERVE_TRACE_JSON="$(pwd)/BENCH_serve_trace.json" \
    cargo run --release --quiet --bin nscog -- serve-bench --smoke --stores 3 --trace

echo "== validate BENCH_serve_trace.json =="
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PYEOF'
import json

def validate(r):
    """One trace report -> 'pass' or 'skip'; raises AssertionError on a
    violated invariant. Untraced/older JSONs (no serve_trace tag or no
    ring ledger) skip cleanly."""
    if r.get('bench') != 'serve_trace' or 'ring' not in r:
        return 'skip'
    ring = r['ring']
    assert 'events_dropped' in ring, 'trace JSON missing the drop ledger'
    assert ring.get('capacity', 0) > 0, 'trace ring reports no capacity'
    events = r.get('events')
    assert isinstance(events, list), 'trace JSON missing its event dump'
    assert len(events) == ring.get('events_recorded'), \
        'event dump length disagrees with the ring ledger'
    assert len(events) <= ring['capacity'], 'ring dump exceeds its own capacity'
    for ev in events:
        spans = [ev[k] for k in ('queue_s', 'batch_s', 'kernel_s', 'fill_s')]
        assert all(s >= 0 for s in spans), \
            f"negative stage span in event {ev.get('seq')}"
        assert sum(spans) <= ev['total_s'] + 1e-9, \
            f"event {ev.get('seq')}: stage sum exceeds its e2e latency"
    trafficked = set()
    for st in r.get('stages', []):
        if st.get('n', 0) == 0:
            continue
        trafficked.add(st['kind'])
        total = st.get('total') or {}
        assert st['stage_mean_sum_s'] <= total.get('mean_s', 0) * 1.01 + 1e-9, \
            f"{st['kind']}: stage means over-attribute vs the e2e mean"
    assert trafficked, 'trace run recorded no trafficked request class'
    verdicts = []
    for rf in r.get('roofline', []):
        if rf.get('calls', 0) == 0:
            continue
        m = rf.get('measured')
        assert isinstance(m, dict) and isinstance(m.get('memory_bound'), bool), \
            f"{rf['kind']}: kernel-active class missing its measured roofline verdict"
        assert isinstance(rf.get('modelled'), dict), \
            f"{rf['kind']}: kernel-active class missing its modelled roofline point"
        verdicts.append((rf['kind'], m['memory_bound']))
    assert verdicts, 'no request class carried a measured roofline verdict'
    q = r.get('queue')
    assert q is not None and 'depth' in q and isinstance(q.get('lanes'), list), \
        'trace JSON missing the queue gauges'
    return 'pass'

# Self-test before gating the real artifact: the validator must pass a
# good report, skip untraced shapes, and FAIL each mutated bad one (a
# gate that cannot fail gates nothing).
lat = lambda n, mean: {'n': n, 'mean_s': mean, 'p50_s': mean, 'p99_s': mean, 'max_s': mean}
ok = {
    'bench': 'serve_trace', 'store_count': 1, 'requests': 4,
    'ring': {'capacity': 8, 'events_recorded': 2, 'events_dropped': 0},
    'platform': {'name': 'serve-host', 'peak_flops': 7e11, 'dram_bw': 1.15e11,
                 'ridge_intensity': 6.087},
    'stages': [
        {'kind': 'recall', 'n': 2, 'queue': lat(2, 1e-5), 'batch': lat(2, 1e-5),
         'kernel': lat(2, 4e-5), 'fill': lat(2, 1e-5), 'total': lat(2, 9e-5),
         'stage_mean_sum_s': 7e-5},
        {'kind': 'recall_topk', 'n': 0, 'queue': None, 'batch': None, 'kernel': None,
         'fill': None, 'total': None, 'stage_mean_sum_s': 0.0},
        {'kind': 'factorize', 'n': 0, 'queue': None, 'batch': None, 'kernel': None,
         'fill': None, 'total': None, 'stage_mean_sum_s': 0.0}],
    'roofline': [
        {'kind': 'recall', 'calls': 2, 'kernel_elapsed_s': 8e-5, 'flops': 3072,
         'bytes_read': 8192, 'bytes_written': 32, 'intensity': 0.373,
         'measured': {'intensity': 0.373, 'attained_flops': 3.84e7, 'memory_bound': True},
         'modelled': {'intensity': 0.373, 'attained_flops': 8.3e10, 'memory_bound': True}},
        {'kind': 'recall_topk', 'calls': 0, 'kernel_elapsed_s': 0.0, 'flops': 0,
         'bytes_read': 0, 'bytes_written': 0, 'intensity': 0.0,
         'measured': None, 'modelled': None},
        {'kind': 'factorize', 'calls': 0, 'kernel_elapsed_s': 0.0, 'flops': 0,
         'bytes_read': 0, 'bytes_written': 0, 'intensity': 0.0,
         'measured': None, 'modelled': None}],
    'queue': {'depth': 0, 'lanes': [{'store': 0, 'len': 0, 'high': 0, 'deficit': 0,
                                     'weight': 1, 'quota': 512}]},
    'stores': [{'id': 0, 'name': 's0', 'stages': [], 'roofline': []}],
    'events': [
        {'seq': 1, 'store': 0, 'kind': 'recall', 'queue_s': 1e-5, 'batch_s': 1e-5,
         'kernel_s': 4e-5, 'fill_s': 1e-5, 'total_s': 9e-5,
         'degraded': False, 'cache_hit': False},
        {'seq': 2, 'store': 0, 'kind': 'recall', 'queue_s': 1e-5, 'batch_s': 1e-5,
         'kernel_s': 4e-5, 'fill_s': 1e-5, 'total_s': 9e-5,
         'degraded': False, 'cache_hit': False}],
}
assert validate(ok) == 'pass', 'validator rejected a passing trace report'
assert validate({'bench': 'serve'}) == 'skip', 'untraced serve JSON must skip'
assert validate({}) == 'skip', 'empty JSON must skip'
for mutate, what in [
        (lambda b: b['stages'][0].__setitem__('stage_mean_sum_s', 1.2e-4),
         'stage means exceeding the e2e mean'),
        (lambda b: b['ring'].__delitem__('events_dropped'), 'missing drop ledger'),
        (lambda b: b['roofline'][0].__setitem__('measured', None),
         'missing roofline verdict on a kernel-active class'),
        (lambda b: b['events'][0].__setitem__('queue_s', -1e-6),
         'negative event stage span'),
        (lambda b: b['events'][1].__setitem__('kernel_s', 1e-3),
         'event stage sum exceeding its e2e latency')]:
    bad = json.loads(json.dumps(ok))
    mutate(bad)
    try:
        validate(bad)
        raise SystemExit(f'trace validator accepted a report with {what}')
    except AssertionError:
        pass

r = json.load(open('BENCH_serve_trace.json'))
verdict = validate(r)
if verdict == 'skip':
    raise SystemExit('traced smoke run wrote no trace report')
ring = r['ring']
bounds = ", ".join(
    f"{rf['kind']} {'memory' if rf['measured']['memory_bound'] else 'compute'}-bound"
    for rf in r['roofline'] if rf.get('calls', 0) > 0)
print(f"serve trace OK (validator self-test passed): "
      f"{ring['events_recorded']} events (capacity {ring['capacity']}, "
      f"{ring['events_dropped']} dropped oldest); roofline: {bounds}")

# Trace overhead gate: the always-on tracer must stay cheap — traced
# closed-loop throughput >= 95% of the untraced run just above. Skips
# cleanly when either artifact is unpopulated (FLOORS convention).
try:
    un = json.load(open('BENCH_serve.json'))['closed_loop']['qps']
    tr = json.load(open('BENCH_serve_traced.json'))['closed_loop']['qps']
except (OSError, json.JSONDecodeError, KeyError):
    un = tr = None
if not un or not tr:
    print('untraced/traced qps pair unavailable; skipping trace overhead gate')
else:
    assert tr >= 0.95 * un, \
        f'trace overhead: traced {tr:.0f} qps < 95% of untraced {un:.0f} qps'
    print(f'trace overhead OK: traced {tr:.0f} qps vs untraced {un:.0f} qps '
          f'({tr / un * 100:.1f}%)')
PYEOF
else
    grep -q '"bench": "serve_trace"' BENCH_serve_trace.json
    grep -q '"events_dropped"' BENCH_serve_trace.json
    grep -q '"memory_bound"' BENCH_serve_trace.json
    echo "python3 unavailable; structural grep checks passed"
fi

# Chaos smoke: one tenant floods its admission quota through a separate
# engine (and a separate JSON — the clean BENCH_serve.json above must
# stay chaos-free). The binary itself exits non-zero if the fairness or
# liveness invariant fails; the validator re-checks the recorded verdict
# and the per-store damage attribution.
echo "== chaos smoke: serve (3 stores, single-tenant flood) =="
NSCOG_SERVE_JSON="$(pwd)/BENCH_serve_chaos.json" \
    cargo run --release --quiet --bin nscog -- serve-bench --smoke --stores 3 --chaos flood

echo "== validate BENCH_serve_chaos.json =="
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PYEOF'
import json

def validate(r):
    """One chaos verdict -> 'pass' or 'skip'; raises AssertionError on a
    violated invariant. Old JSONs (no chaos key) and chaos-free runs
    (chaos: null) skip cleanly."""
    ch = r.get('chaos')
    if ch is None:
        return 'skip'
    assert ch.get('scenario'), 'chaos block missing its scenario tag'
    assert ch.get('fairness_pass') is True, \
        f"chaos '{ch.get('scenario')}': fairness invariant failed"
    assert ch.get('liveness_pass') is True, \
        f"chaos '{ch.get('scenario')}': liveness invariant failed"
    stores = ch.get('stores') or []
    assert stores, 'chaos block carries no per-store ledgers'
    for s in stores:
        for key in ('offered', 'completed', 'rejected', 'rejected_tenant',
                    'expired', 'internal', 'degraded', 'mismatches'):
            assert key in s, f"chaos ledger for {s.get('name')} missing '{key}'"
        assert s['mismatches'] == 0, \
            f"chaos: store {s.get('name')} served answers diverging from its oracle"
    if ch['scenario'] == 'flood' and len(stores) > 1:
        assert stores[0].get('flooder') and stores[0]['rejected_tenant'] > 0, \
            'flood scenario never tripped the flooder own quota'
        for s in stores[1:]:
            assert s['rejected_tenant'] == 0, \
                f"victim {s.get('name')} paid for the flooder's quota"
    return 'pass'

# Self-test against synthetic verdicts before gating the real run: the
# validator must pass a good verdict, skip chaos-free shapes, and FAIL
# a bad one (a gate that cannot fail gates nothing).
ok = {'chaos': {'scenario': 'flood', 'fairness_pass': True, 'liveness_pass': True,
      'stores': [
          {'name': 's0', 'flooder': True, 'offered': 10, 'completed': 4,
           'rejected': 0, 'rejected_tenant': 6, 'expired': 0, 'internal': 0,
           'degraded': 0, 'mismatches': 0},
          {'name': 's1', 'flooder': False, 'offered': 5, 'completed': 5,
           'rejected': 0, 'rejected_tenant': 0, 'expired': 0, 'internal': 0,
           'degraded': 0, 'mismatches': 0}]}}
assert validate(ok) == 'pass', 'validator rejected a passing chaos verdict'
assert validate({'bench': 'serve'}) == 'skip', 'pre-chaos JSON must skip'
assert validate({'chaos': None}) == 'skip', 'chaos-free run must skip'
for mutate, what in [
        (lambda b: b['chaos'].__setitem__('fairness_pass', False), 'failed fairness'),
        (lambda b: b['chaos'].__setitem__('liveness_pass', False), 'failed liveness'),
        (lambda b: b['chaos']['stores'][1].__setitem__('rejected_tenant', 3), 'shed victim'),
        (lambda b: b['chaos']['stores'][1].__setitem__('mismatches', 1), 'wrong answer')]:
    bad = json.loads(json.dumps(ok))
    mutate(bad)
    try:
        validate(bad)
        raise SystemExit(f'chaos validator accepted a {what} verdict')
    except AssertionError:
        pass

r = json.load(open('BENCH_serve_chaos.json'))
verdict = validate(r)
if verdict == 'skip':
    raise SystemExit('chaos smoke run wrote no chaos block')
ch = r['chaos']
led = ", ".join(
    f"{s['name']}{'[flood]' if s.get('flooder') else ''} "
    f"{s['completed']}/{s['offered']} ok, {s['rejected_tenant']} tenant-shed"
    for s in ch['stores'])
print(f"chaos smoke OK ('{ch['scenario']}', validator self-test passed): {led}")
PYEOF
else
    grep -q '"scenario": "flood"' BENCH_serve_chaos.json
    grep -q '"fairness_pass": true' BENCH_serve_chaos.json
    grep -q '"liveness_pass": true' BENCH_serve_chaos.json
    echo "python3 unavailable; structural grep checks passed"
fi

# Churn smoke: live item inserts/deletes and store creates/drops race
# real traffic through the epoch-swap registry; every Ok answer is
# verified against the per-epoch oracle window it was sealed in,
# dropped stores must answer UnknownStore (never garbage), and each
# surviving store gets a bit-exact post-churn probe. Overwrites
# BENCH_serve_chaos.json — the flood verdict above has already been
# validated, and the churn block below is what the repo keeps.
echo "== chaos smoke: serve (3 stores, live churn) =="
NSCOG_SERVE_JSON="$(pwd)/BENCH_serve_chaos.json" \
    cargo run --release --quiet --bin nscog -- serve-bench --smoke --stores 3 \
    --chaos churn --churn-rate 300 --churn-ops 60

echo "== validate BENCH_serve_chaos.json (churn) =="
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PYEOF'
import json

def validate(r):
    """One churn verdict -> 'pass' or 'skip'; raises AssertionError on a
    violated invariant. Non-chaos JSONs and non-churn scenarios (their
    churn block is null) skip cleanly."""
    ch = r.get('chaos')
    if ch is None:
        return 'skip'
    c = ch.get('churn')
    if c is None:
        return 'skip'
    assert ch.get('scenario') == 'churn', 'churn ledger on a non-churn scenario'
    assert ch.get('fairness_pass') is True, 'churn: fairness invariant failed'
    assert ch.get('liveness_pass') is True, 'churn: liveness invariant failed'
    assert c.get('wrong_epoch') == 0, \
        f"churn: {c.get('wrong_epoch')} answers matched no oracle in their epoch window"
    assert c.get('unknown_bad') == 0, \
        f"churn: {c.get('unknown_bad')} live stores answered UnknownStore"
    assert c.get('panics') == 0, f"churn: {c.get('panics')} uncontained panics"
    assert c.get('op_failures') == 0, \
        f"churn: engine refused {c.get('op_failures')} legal mutations"
    assert c.get('monotonic') is True, 'churn: a store epoch went backwards'
    assert c.get('probed', 0) >= 1 and c.get('probe_pass') is True, \
        'churn: missing or failed post-churn bit-exact probe'
    ops = c.get('ops', 0)
    assert ops > 0, 'churn ran zero mutations'
    assert (c.get('inserts', 0) + c.get('deletes', 0) + c.get('creates', 0)
            + c.get('drops', 0) + c.get('op_failures', 0)) == ops, \
        'churn op ledger does not reconcile with ops'
    finals = c.get('final_epochs')
    assert isinstance(finals, list) and finals, 'churn block lists no surviving stores'
    for f in finals:
        assert f.get('name') and isinstance(f.get('epoch'), int), \
            'malformed final-epoch entry'
    return 'pass'

# Self-test before gating the real run: pass a good verdict, skip
# chaos-free and non-churn shapes, and FAIL each mutated bad verdict
# (a gate that cannot fail gates nothing).
ok = {'chaos': {'scenario': 'churn', 'fairness_pass': True, 'liveness_pass': True,
      'churn': {'ops': 60, 'inserts': 30, 'deletes': 14, 'creates': 9, 'drops': 7,
                'op_failures': 0, 'wrong_epoch': 0, 'unknown_ok': 3, 'unknown_bad': 0,
                'panics': 0, 'monotonic': True, 'probed': 4, 'probe_pass': True,
                'final_epochs': [{'name': 'store0', 'epoch': 17},
                                 {'name': 'churn0', 'epoch': 3}]},
      'stores': []}}
assert validate(ok) == 'pass', 'validator rejected a passing churn verdict'
assert validate({'bench': 'serve'}) == 'skip', 'pre-chaos JSON must skip'
assert validate({'chaos': {'scenario': 'flood', 'churn': None}}) == 'skip', \
    'non-churn scenario must skip'
for mutate, what in [
        (lambda b: b['chaos']['churn'].__setitem__('wrong_epoch', 1), 'wrong-epoch answer'),
        (lambda b: b['chaos']['churn'].__setitem__('probed', 0), 'missing post-churn probe'),
        (lambda b: b['chaos']['churn'].__setitem__('panics', 2), 'panicking'),
        (lambda b: b['chaos']['churn'].__setitem__('monotonic', False), 'non-monotonic epoch'),
        (lambda b: b['chaos']['churn'].__setitem__('unknown_bad', 1), 'live-store UnknownStore'),
        (lambda b: b['chaos']['churn'].__setitem__('op_failures', 1), 'refused-mutation'),
        (lambda b: b['chaos']['churn'].__setitem__('probe_pass', False), 'drifted-probe')]:
    bad = json.loads(json.dumps(ok))
    mutate(bad)
    try:
        validate(bad)
        raise SystemExit(f'churn validator accepted a {what} verdict')
    except AssertionError:
        pass

r = json.load(open('BENCH_serve_chaos.json'))
verdict = validate(r)
if verdict == 'skip':
    raise SystemExit('churn smoke run wrote no churn block')
c = r['chaos']['churn']
finals = ", ".join(f"{f['name']}@e{f['epoch']}" for f in c['final_epochs'])
print(f"churn smoke OK (validator self-test passed): {c['ops']} ops "
      f"({c['inserts']} ins/{c['deletes']} del/{c['creates']} create/{c['drops']} drop), "
      f"{c['unknown_ok']} legal UnknownStore, {c['probed']} probes bit-exact; {finals}")
PYEOF
else
    grep -q '"scenario": "churn"' BENCH_serve_chaos.json
    grep -q '"wrong_epoch": 0' BENCH_serve_chaos.json
    grep -q '"panics": 0' BENCH_serve_chaos.json
    grep -q '"probe_pass": true' BENCH_serve_chaos.json
    echo "python3 unavailable; structural grep checks passed"
fi

# Wire smoke: the smoke schedule once more, but through real TCP
# sockets — the framed length-prefixed protocol, one connection per
# client thread, every socket response bit-exact vs the sequential
# oracle. Separate JSON so the in-process BENCH_serve.json above stays
# the canonical perf artifact; the wire-vs-in-process delta is the
# front-end's measured overhead.
echo "== bench smoke: serve wire (--wire: framed TCP socket pass) =="
NSCOG_SERVE_JSON="$(pwd)/BENCH_serve_wire.json" \
    cargo run --release --quiet --bin nscog -- serve-bench --smoke --stores 2 --wire

echo "== validate BENCH_serve_wire.json =="
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PYEOF'
import json

def validate(r):
    """One wire verdict -> 'pass' or 'skip'; raises AssertionError on a
    violated invariant. JSONs without a wire pass skip cleanly."""
    w = r.get('wire')
    if w is None:
        return 'skip'
    p = w.get('pass') or {}
    c = w.get('counters') or {}
    assert p.get('ok', 0) > 0, 'wire pass served nothing'
    assert p.get('mismatches') == 0, \
        f"wire: {p.get('mismatches')} socket responses diverged from the oracle"
    assert w.get('net_errors') == 0, \
        f"wire: {w.get('net_errors')} transport errors on a clean loopback"
    assert c.get('protocol_errors') == 0, \
        'wire: protocol errors from a well-formed client'
    assert c.get('accepted', 0) >= 1, 'wire: no connections accepted'
    assert c.get('frames_out', 0) >= p.get('ok', 0), \
        'wire: fewer response frames than answers'
    assert c.get('bytes_in', 0) > 0 and c.get('bytes_out', 0) > 0, \
        'wire: no bytes moved'
    return 'pass'

# Self-test before gating the real run: pass a good verdict, skip
# wireless shapes, and FAIL each mutated bad verdict (a gate that
# cannot fail gates nothing).
ok = {'wire': {'pass': {'ok': 64, 'mismatches': 0}, 'net_errors': 0,
      'counters': {'accepted': 4, 'frames_in': 64, 'frames_out': 64,
                   'bytes_in': 70000, 'bytes_out': 9000, 'protocol_errors': 0}}}
assert validate(ok) == 'pass', 'validator rejected a passing wire verdict'
assert validate({'bench': 'serve'}) == 'skip', 'wireless JSON must skip'
assert validate({'wire': None}) == 'skip', 'null wire block must skip'
for mutate, what in [
        (lambda b: b['wire']['pass'].__setitem__('mismatches', 1), 'oracle-diverging'),
        (lambda b: b['wire']['pass'].__setitem__('ok', 0), 'nothing-served'),
        (lambda b: b['wire'].__setitem__('net_errors', 2), 'transport-erroring'),
        (lambda b: b['wire']['counters'].__setitem__('protocol_errors', 1),
         'protocol-erroring'),
        (lambda b: b['wire']['counters'].__setitem__('frames_out', 3), 'frame-dropping'),
        (lambda b: b['wire']['counters'].__setitem__('bytes_out', 0), 'byteless')]:
    bad = json.loads(json.dumps(ok))
    mutate(bad)
    try:
        validate(bad)
        raise SystemExit(f'wire validator accepted a {what} verdict')
    except AssertionError:
        pass

r = json.load(open('BENCH_serve_wire.json'))
if validate(r) == 'skip':
    raise SystemExit('wire smoke run wrote no wire block')
w = r['wire']
print(f"wire smoke OK (validator self-test passed): {w['pass']['ok']} answers over "
      f"{w['counters']['accepted']} conns, {w['counters']['bytes_in']} B in / "
      f"{w['counters']['bytes_out']} B out, 0 mismatches")
PYEOF
else
    grep -q '"net_errors": 0' BENCH_serve_wire.json
    grep -q '"protocol_errors": 0' BENCH_serve_wire.json
    grep -q '"mismatches": 0' BENCH_serve_wire.json
    echo "python3 unavailable; structural grep checks passed"
fi

# Network chaos matrix: four hostile peers against a real TCP listener —
# a mid-frame staller (slow-loris), a silent half-open socket, a
# mid-stream disconnector, and a garbage-byte speaker — while victim
# clients drive the schedule over their own connections. Gates: the
# attacker is reaped/refused per the wire contract, every victim answer
# stays bit-exact, and completed + refused + expired == offered holds
# exactly. Overwrites BENCH_serve_chaos.json per scenario; each verdict
# is validated before the next run, and the last (garbage) is what the
# repo keeps.
for sc in slowloris halfopen disconnect garbage; do
    echo "== chaos smoke: serve wire ($sc) =="
    NSCOG_SERVE_JSON="$(pwd)/BENCH_serve_chaos.json" \
        cargo run --release --quiet --bin nscog -- serve-bench --smoke --stores 2 \
        --chaos "$sc"

    echo "== validate BENCH_serve_chaos.json ($sc) =="
    if command -v python3 >/dev/null 2>&1; then
        python3 - "$sc" <<'PYEOF'
import json, sys

expected = sys.argv[1]

def validate(r, scenario=None):
    """One net-chaos verdict -> 'pass' or 'skip'; raises AssertionError
    on a violated invariant. Non-chaos JSONs and non-network scenarios
    (their net block is null) skip cleanly."""
    ch = r.get('chaos')
    if ch is None:
        return 'skip'
    n = ch.get('net')
    if n is None:
        return 'skip'
    assert ch.get('scenario') in ('slowloris', 'halfopen', 'disconnect', 'garbage'), \
        'net ledger on a non-network scenario'
    if scenario is not None:
        assert ch.get('scenario') == scenario, \
            f"expected scenario {scenario}, found {ch.get('scenario')}"
    assert ch.get('fairness_pass') is True, 'net chaos: fairness invariant failed'
    assert ch.get('liveness_pass') is True, 'net chaos: liveness invariant failed'
    assert n.get('offered', 0) > 0, 'net chaos: victims offered zero requests'
    assert n.get('accounting_exact') is True, 'net chaos: inexact accounting flag'
    assert n.get('completed', 0) + n.get('refused', 0) + n.get('expired', 0) \
        == n.get('offered', -1), \
        'net chaos: completed + refused + expired != offered'
    assert n.get('mismatches') == 0, \
        f"net chaos: {n.get('mismatches')} victim answers diverged from the oracle"
    assert n.get('net_errors') == 0, \
        f"net chaos: {n.get('net_errors')} victim transport errors"
    assert n.get('victim_clean') is True, 'net chaos: victims damaged'
    assert n.get('reap_within_deadline') is True, \
        'net chaos: hostile peer not reaped/refused within its deadline'
    assert n.get('probe_pass') is True, 'net chaos: post-attack probe not bit-exact'
    return 'pass'

# Self-test before gating the real run (PR 6/8 pattern): pass a good
# verdict, skip chaos-free and non-network shapes, FAIL each mutation.
ok = {'chaos': {'scenario': 'slowloris', 'fairness_pass': True, 'liveness_pass': True,
      'net': {'offered': 90, 'completed': 88, 'refused': 2, 'expired': 0,
              'mismatches': 0, 'net_errors': 0, 'accounting_exact': True,
              'reaped': 1, 'reap_within_deadline': True, 'protocol_errors': 0,
              'disconnects': 0, 'victim_clean': True, 'probe_pass': True},
      'stores': []}}
assert validate(ok) == 'pass', 'validator rejected a passing net verdict'
assert validate({'bench': 'serve'}) == 'skip', 'pre-chaos JSON must skip'
assert validate({'chaos': {'scenario': 'flood', 'net': None}}) == 'skip', \
    'non-network scenario must skip'
for mutate, what in [
        (lambda b: b['chaos']['net'].__setitem__('mismatches', 1), 'oracle-diverging'),
        (lambda b: b['chaos']['net'].__setitem__('net_errors', 3), 'victim-io-error'),
        (lambda b: b['chaos']['net'].__setitem__('completed', 87), 'leaky-ledger'),
        (lambda b: b['chaos']['net'].__setitem__('accounting_exact', False),
         'inexact-accounting'),
        (lambda b: b['chaos']['net'].__setitem__('reap_within_deadline', False),
         'unreaped-staller'),
        (lambda b: b['chaos']['net'].__setitem__('victim_clean', False), 'damaged-victim'),
        (lambda b: b['chaos']['net'].__setitem__('probe_pass', False), 'failed-probe'),
        (lambda b: b['chaos'].__setitem__('fairness_pass', False), 'fairness-failing'),
        (lambda b: b['chaos'].__setitem__('liveness_pass', False), 'liveness-failing')]:
    bad = json.loads(json.dumps(ok))
    mutate(bad)
    try:
        validate(bad)
        raise SystemExit(f'net chaos validator accepted a {what} verdict')
    except AssertionError:
        pass

r = json.load(open('BENCH_serve_chaos.json'))
if validate(r, expected) == 'skip':
    raise SystemExit(f'net chaos run wrote no net block for {expected}')
n = r['chaos']['net']
print(f"net chaos {expected} OK (validator self-test passed): "
      f"{n['completed']}+{n['refused']}+{n['expired']} == {n['offered']} exact, "
      f"reaped {n['reaped']}, {n['protocol_errors']} protocol errors, "
      f"{n['disconnects']} disconnects, probe bit-exact")
PYEOF
    else
        grep -q "\"scenario\": \"$sc\"" BENCH_serve_chaos.json
        grep -q '"fairness_pass": true' BENCH_serve_chaos.json
        grep -q '"liveness_pass": true' BENCH_serve_chaos.json
        grep -q '"accounting_exact": true' BENCH_serve_chaos.json
        grep -q '"victim_clean": true' BENCH_serve_chaos.json
        echo "python3 unavailable; structural grep checks passed"
    fi
done

# Speedup regression gate: measured speedups in the bench JSONs must not
# drop below the floors recorded in PERF.md's FLOORS table. Skips cleanly
# when the measured numbers are unpopulated (e.g. authoring containers
# without a toolchain never reach this point; a malformed JSON does).
if command -v python3 >/dev/null 2>&1; then
    echo "== speedup regression gate (PERF.md floors) =="
    python3 - <<'PYEOF'
import json, re, sys

src = open('PERF.md').read()
m = re.search(r'<!-- BEGIN FLOORS -->(.*?)<!-- END FLOORS -->', src, re.S)
if not m:
    print('PERF.md has no FLOORS table; skipping gate')
    sys.exit(0)
floors = {}
for line in m.group(1).splitlines():
    cells = [c.strip() for c in line.strip().strip('|').split('|')]
    if len(cells) != 2 or cells[0] in ('kernel', '') or set(cells[1]) <= set('-'):
        continue
    try:
        floors[cells[0]] = float(cells[1].rstrip('x'))
    except ValueError:
        pass
try:
    hp = json.load(open('BENCH_hotpath.json'))
    speedups = {s['kernel']: s['speedup'] for s in hp.get('speedups', [])}
except (OSError, json.JSONDecodeError):
    hp, speedups = {}, {}
if not speedups:
    print('BENCH_hotpath.json unpopulated; skipping speedup gate')
    sys.exit(0)
simd_tier = hp.get('simd')
simd_speedups = {s['kernel']: s['speedup'] for s in hp.get('simd_speedups', [])}
failures, checked, simd_skipped, large_skipped = [], 0, 0, 0
for kernel, floor in floors.items():
    if kernel == 'serve closed-loop qps':
        continue
    if kernel.startswith('large ') and hp.get('large_store') is None:
        # large-store floors only bind when the 200k-item section ran
        # (NSCOG_LARGE=0 skips it on tiny hosts). When it did run, a
        # missing/renamed entry is a hard failure like every other floor.
        large_skipped += 1
        continue
    if kernel.startswith('simd '):
        # simd-vs-scalar floors only bind when the host actually resolved
        # a SIMD tier: hosts without AVX2/NEON skip cleanly. On a SIMD
        # host, a missing/renamed entry is a hard failure like every
        # other floor — drift must not silently disable the gate.
        if simd_tier in (None, 'scalar'):
            simd_skipped += 1
            continue
        if kernel not in simd_speedups:
            failures.append(f"{kernel}: floor has no matching simd_speedups entry")
            continue
        checked += 1
        if simd_speedups[kernel] < floor:
            failures.append(
                f"{kernel}: measured {simd_speedups[kernel]:.2f}x < floor {floor:.2f}x")
        continue
    if kernel not in speedups:
        # a renamed/dropped bench entry must not silently disable its gate
        failures.append(f"{kernel}: floor has no matching BENCH_hotpath.json speedup entry")
        continue
    checked += 1
    if speedups[kernel] < floor:
        failures.append(f"{kernel}: measured {speedups[kernel]:.2f}x < floor {floor:.2f}x")
if simd_skipped:
    print(f"({simd_skipped} simd floors skipped: tier '{simd_tier}' has no SIMD datapath)")
if large_skipped:
    print(f"({large_skipped} large-store floors skipped: no large_store section in this run)")
try:
    sv = json.load(open('BENCH_serve.json'))
except (OSError, json.JSONDecodeError):
    sv = {}
floor = floors.get('serve closed-loop qps')
if floor is not None:
    if sv.get('speedup_qps') is None:
        failures.append('serve closed-loop qps: floor has no BENCH_serve.json measurement')
    else:
        checked += 1
        if sv['speedup_qps'] < floor:
            failures.append(
                f"serve closed-loop qps: measured {sv['speedup_qps']:.2f}x < floor {floor:.2f}x")
if failures:
    print('SPEEDUP REGRESSION below PERF.md floors:')
    for f in failures:
        print('  ' + f)
    sys.exit(1)
print(f"speedup floors OK ({checked} measurements gated)")
PYEOF
fi

echo "== perf trajectory =="
test -s BENCH_hotpath.json && echo "BENCH_hotpath.json written:" && cat BENCH_hotpath.json
test -s BENCH_serve.json && echo "BENCH_serve.json written."

# Fill the measured-numbers block in PERF.md from this run's JSON so the
# first toolchain machine (and every one after) keeps the table current.
if command -v python3 >/dev/null 2>&1; then
    echo "== refresh PERF.md measured numbers =="
    python3 - <<'PYEOF'
import json, re, platform

lines = ["", "Last `./ci.sh` run on this machine "
         f"({platform.machine()}, {platform.processor() or 'unknown cpu'}):", ""]
try:
    hp = json.load(open('BENCH_hotpath.json'))
    lines.append(f"SIMD dispatch tier: `{hp.get('simd', 'unknown')}`")
    lines.append("")
    lines += ["| kernel | reference p50 | optimized p50 | speedup |",
              "|---|---|---|---|"]
    for s in hp.get('speedups', []):
        lines.append(f"| {s['kernel']} | {s['ref_p50_s']:.3e} s "
                     f"| {s['opt_p50_s']:.3e} s | {s['speedup']:.2f}x |")
    simd = hp.get('simd_speedups', [])
    if simd:
        lines += ["", "| kernel (simd vs forced scalar) | scalar p50 | simd p50 | speedup |",
                  "|---|---|---|---|"]
        for s in simd:
            lines.append(f"| {s['kernel']} | {s['scalar_p50_s']:.3e} s "
                         f"| {s['simd_p50_s']:.3e} s | {s['speedup']:.2f}x |")
except (OSError, json.JSONDecodeError):
    lines.append("_(BENCH_hotpath.json unavailable)_")
try:
    sv = json.load(open('BENCH_serve.json'))
    cl, b = sv['closed_loop'], sv['batching']
    lines += ["",
              f"Serving (`serve-bench --smoke --stores {sv.get('store_count', 1)}`): "
              f"closed-loop {cl['qps']:.0f} qps vs "
              f"baseline {sv['baseline']['qps']:.0f} qps "
              f"(**{sv['speedup_qps']:.2f}x**), mean batch occupancy "
              f"{b['mean_batch']:.2f} (max {b['max_batch']})."]
    for s in sv.get('stores', []):
        hit = (f", {s['cache']['hit_rate']*100:.0f}% cache hits" if s.get('cache') else "")
        lines.append(f"  - store `{s['name']}` ({s['items']}x{s['dim']}b, weight {s['weight']}): "
                     f"{s['completed']} served, "
                     f"{s['prune']['words_frac']*100:.0f}% words streamed{hit}")
except (OSError, json.JSONDecodeError):
    lines += ["", "_(BENCH_serve.json unavailable)_"]
lines.append("")

src = open('PERF.md').read()
block = "<!-- BEGIN MEASURED (auto-filled by ci.sh) -->" + "\n".join(lines) + "<!-- END MEASURED -->"
out, n = re.subn(r"<!-- BEGIN MEASURED \(auto-filled by ci\.sh\) -->.*?<!-- END MEASURED -->",
                 block, src, flags=re.S)
if n:
    open('PERF.md', 'w').write(out)
    print("PERF.md measured block refreshed")
else:
    print("PERF.md measured markers missing; skipped")
PYEOF
fi

echo "CI OK"
