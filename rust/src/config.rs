//! Shared configuration: model dimensions (mirroring `python/compile/model.py`
//! via `artifacts/manifest.json`) and repo paths.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Model dimensions shared between the AOT'd L2 graphs and the L3 engines.
/// Defaults match `python/compile/model.py`; [`ModelDims::from_manifest`]
/// overrides them from the artifact manifest when present.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelDims {
    /// Hypervector dimensionality D.
    pub hd_dim: usize,
    /// Item vectors per codebook / factor.
    pub codebook_n: usize,
    /// Categories per panel attribute.
    pub attr_k: usize,
    /// Attributes per panel (type, size, color).
    pub n_attrs: usize,
    /// Panels per RPM instance fed to the frontend.
    pub panels: usize,
    /// Panel image side length.
    pub img: usize,
}

impl Default for ModelDims {
    fn default() -> Self {
        ModelDims {
            hd_dim: 1024,
            codebook_n: 64,
            attr_k: 8,
            n_attrs: 3,
            panels: 16,
            img: 32,
        }
    }
}

impl ModelDims {
    /// Read dimensions from a parsed manifest (missing keys keep defaults).
    pub fn from_manifest(m: &Json) -> ModelDims {
        let d = ModelDims::default();
        ModelDims {
            hd_dim: m.get("hd_dim").and_then(Json::as_usize).unwrap_or(d.hd_dim),
            codebook_n: m
                .get("codebook_n")
                .and_then(Json::as_usize)
                .unwrap_or(d.codebook_n),
            attr_k: m.get("attr_k").and_then(Json::as_usize).unwrap_or(d.attr_k),
            n_attrs: m
                .get("n_attrs")
                .and_then(Json::as_usize)
                .unwrap_or(d.n_attrs),
            panels: m.get("panels").and_then(Json::as_usize).unwrap_or(d.panels),
            img: m.get("img").and_then(Json::as_usize).unwrap_or(d.img),
        }
    }
}

/// Locate the artifacts directory: `$NSCOG_ARTIFACTS`, else `./artifacts`
/// relative to the working directory, else relative to the crate root.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("NSCOG_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let cwd = Path::new("artifacts");
    if cwd.exists() {
        return cwd.to_path_buf();
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_python() {
        let d = ModelDims::default();
        assert_eq!(d.hd_dim, 1024);
        assert_eq!(d.codebook_n, 64);
        assert_eq!(d.attr_k, 8);
        assert_eq!(d.n_attrs, 3);
        assert_eq!(d.panels, 16);
        assert_eq!(d.img, 32);
    }

    #[test]
    fn from_manifest_overrides() {
        let j = Json::parse(r#"{"hd_dim": 2048, "attr_k": 4}"#).unwrap();
        let d = ModelDims::from_manifest(&j);
        assert_eq!(d.hd_dim, 2048);
        assert_eq!(d.attr_k, 4);
        assert_eq!(d.codebook_n, 64); // default kept
    }
}
