//! Figure/table regeneration: one function per paper artifact, each
//! returning an aligned text table (consumed by `cargo bench` targets,
//! the `nscog figures` CLI, and EXPERIMENTS.md).

use crate::accel::isa::ControlMethod;
use crate::accel::AccelConfig;
use crate::coordinator::ExecGraph;
use crate::platform::{counters, Platform};
use crate::profiler::roofline;
use crate::profiler::taxonomy::{OpCategory, PhaseKind};
use crate::util::bench::Table;
use crate::util::stats::{fmt_energy, fmt_time};
use crate::workloads::suite::{gpu_trace, CompiledSuite, SuiteKind};
use crate::workloads::{all_workloads, nvsa::Nvsa, nvsa::NvsaEngine, raven, Workload};

/// Fig. 2a: neural vs symbolic runtime share per workload (RTX model).
pub fn fig2a() -> Table {
    let gpu = Platform::rtx2080ti();
    let mut t = Table::new(&["workload", "total", "neural %", "symbolic %"]);
    for w in all_workloads() {
        let tb = gpu.trace_time(&w.trace(), None);
        t.row(&[
            w.name().into(),
            fmt_time(tb.total),
            format!("{:.1}", (1.0 - tb.symbolic_fraction()) * 100.0),
            format!("{:.1}", tb.symbolic_fraction() * 100.0),
        ]);
    }
    t
}

/// Fig. 2b: NVSA + NLM end-to-end latency across platforms.
pub fn fig2b() -> Table {
    let mut t = Table::new(&["workload", "platform", "total", "vs RTX"]);
    let rtx = Platform::rtx2080ti();
    for w in all_workloads() {
        if w.name() != "NVSA" && w.name() != "NLM" {
            continue;
        }
        let tr = w.trace();
        let base = rtx.trace_time(&tr, None).total;
        for p in Platform::edge_sweep() {
            let tb = p.trace_time(&tr, None);
            t.row(&[
                w.name().into(),
                p.name.into(),
                fmt_time(tb.total),
                format!("{:.1}x", tb.total / base),
            ]);
        }
    }
    t
}

/// Fig. 2c: NVSA latency vs RPM task size (2×2 … 3×3).
pub fn fig2c() -> Table {
    let gpu = Platform::rtx2080ti();
    let mut t = Table::new(&["task size", "total", "symbolic %", "vs 2x2"]);
    let mut base = None;
    for grid in [2usize, 3] {
        let w = Nvsa {
            grid,
            ..Default::default()
        };
        let tb = gpu.trace_time(&Workload::trace(&w), None);
        let b = *base.get_or_insert(tb.total);
        t.row(&[
            format!("{grid}x{grid}"),
            fmt_time(tb.total),
            format!("{:.1}", tb.symbolic_fraction() * 100.0),
            format!("{:.2}x", tb.total / b),
        ]);
    }
    t
}

/// Fig. 3a: operator-category runtime breakdown per workload & phase.
pub fn fig3a() -> Table {
    let gpu = Platform::rtx2080ti();
    let mut headers = vec!["workload".to_string(), "phase".to_string()];
    headers.extend(OpCategory::ALL.iter().map(|c| c.label().to_string()));
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hrefs);
    for w in all_workloads() {
        let tr = w.trace();
        for phase in [PhaseKind::Neural, PhaseKind::Symbolic] {
            let tb = gpu.trace_time(&tr, Some(phase));
            let mut row = vec![w.name().to_string(), phase.label().to_string()];
            for f in tb.category_fractions() {
                row.push(format!("{:.1}%", f * 100.0));
            }
            t.row(&row);
        }
    }
    t
}

/// Fig. 3b: memory usage per workload.
pub fn fig3b() -> Table {
    let mut t = Table::new(&[
        "workload",
        "weights",
        "codebooks",
        "neural work",
        "symbolic work",
        "static %",
    ]);
    for w in all_workloads() {
        let m = w.memory();
        let kb = |b: u64| format!("{:.1} KiB", b as f64 / 1024.0);
        t.row(&[
            w.name().into(),
            kb(m.weights_bytes),
            kb(m.codebook_bytes),
            kb(m.neural_working_bytes),
            kb(m.symbolic_working_bytes),
            format!("{:.1}", m.static_fraction() * 100.0),
        ]);
    }
    t
}

/// Fig. 3c: roofline placement of each workload's phases.
pub fn fig3c() -> Table {
    let gpu = Platform::rtx2080ti();
    let mut t = Table::new(&[
        "workload",
        "phase",
        "intensity (FLOP/B)",
        "attained GFLOP/s",
        "bound",
    ]);
    for w in all_workloads() {
        let tr = w.trace();
        for phase in [PhaseKind::Neural, PhaseKind::Symbolic] {
            let pt = roofline::place(&tr, phase, &gpu);
            t.row(&[
                w.name().into(),
                phase.label().into(),
                format!("{:.3}", pt.intensity),
                format!("{:.1}", pt.attained_flops / 1e9),
                if pt.memory_bound { "memory" } else { "compute" }.into(),
            ]);
        }
    }
    t
}

/// Fig. 4: operator-graph / critical-path analysis.
pub fn fig4() -> Table {
    let gpu = Platform::rtx2080ti();
    let mut t = Table::new(&[
        "workload",
        "symbolic after neural",
        "critical path",
        "symbolic on path %",
        "parallelism",
    ]);
    for w in all_workloads() {
        let g = ExecGraph::from_trace(&w.trace(), &gpu);
        let cp = g.critical_path();
        t.row(&[
            w.name().into(),
            if w.symbolic_depends_on_neural() {
                "yes (critical path)"
            } else {
                "compiled-in"
            }
            .into(),
            fmt_time(cp.length),
            format!("{:.1}", cp.symbolic_on_path / cp.length * 100.0),
            format!("{:.1}x", g.parallelism()),
        ]);
    }
    t
}

/// Tab. IV: simulated kernel counters for representative NVSA kernels.
pub fn tab4() -> Table {
    let gpu = Platform::rtx2080ti();
    let mut tr = crate::profiler::trace::Trace::new("kernels");
    let n = 2048u64;
    let gemm = tr.add("sgemm_nn", OpCategory::MatMul, PhaseKind::Neural, 2 * n * n * n, 12 * n * n, 4 * n * n, &[]);
    let relu = tr.add("relu_nn", OpCategory::Conv, PhaseKind::Neural, 16 * n * n, 8 * n * n, 4 * n * n, &[]);
    let velem = tr.add("vectorized_elem", OpCategory::VectorElem, PhaseKind::Symbolic, (64u64 << 20) / 4, 64 << 20, 64 << 20, &[]);
    let elem = tr.add("elementwise", OpCategory::VectorElem, PhaseKind::Symbolic, (16u64 << 20) / 4, 16 << 20, 16 << 20, &[]);
    let mut t = Table::new(&[
        "kernel",
        "compute %",
        "ALU %",
        "L1 tp %",
        "L2 tp %",
        "L1 hit %",
        "L2 hit %",
        "DRAM BW %",
    ]);
    for (idx, variant) in [(gemm, false), (relu, false), (velem, false), (elem, true)] {
        let c = counters::simulate(&gpu, &tr.ops[idx], variant);
        t.row(&[
            c.kernel.clone(),
            format!("{:.1}", c.compute_throughput_pct),
            format!("{:.1}", c.alu_utilization_pct),
            format!("{:.1}", c.l1_throughput_pct),
            format!("{:.1}", c.l2_throughput_pct),
            format!("{:.1}", c.l1_hit_rate_pct),
            format!("{:.1}", c.l2_hit_rate_pct),
            format!("{:.1}", c.dram_bw_utilization_pct),
        ]);
    }
    t
}

/// Fig. 5: measured sparsity of NVSA symbolic modules per attribute.
pub fn fig5() -> Table {
    let engine = NvsaEngine::new(Nvsa::default(), 2024);
    let mut rng = crate::util::Rng::new(55);
    let inst = raven::generate(&mut rng, 3, 8);
    let pmfs = raven::panel_pmfs(&inst, 0.95);
    let sol = engine.solve(&inst, &pmfs);
    let mut t = Table::new(&["module", "attribute", "sparsity %"]);
    for p in &sol.sparsity {
        t.row(&[
            p.module.clone(),
            p.attribute.clone(),
            format!("{:.1}", p.sparsity * 100.0),
        ]);
    }
    t
}

/// Fig. 9: SOPC vs MOPC runtime & power for the resonator workload at
/// increasing factor counts.
pub fn fig9() -> Table {
    let mut t = Table::new(&[
        "factors",
        "SOPC time",
        "MOPC time",
        "speedup",
        "SOPC power",
        "MOPC power",
        "power +%",
    ]);
    for factors in [2usize, 3, 4, 5] {
        let (rs, rm) = fig9_point(factors);
        t.row(&[
            format!("{factors}"),
            fmt_time(rs.time_s),
            fmt_time(rm.time_s),
            format!("{:.2}x", rs.time_s / rm.time_s),
            format!("{:.2} mW", rs.avg_power_w() * 1e3),
            format!("{:.2} mW", rm.avg_power_w() * 1e3),
            format!(
                "+{:.0}%",
                (rm.avg_power_w() / rs.avg_power_w() - 1.0) * 100.0
            ),
        ]);
    }
    t
}

/// One Fig. 9 measurement: resonator with `factors` factors under both
/// control methods on Acc4.
pub fn fig9_point(
    factors: usize,
) -> (crate::accel::SimReport, crate::accel::SimReport) {
    use crate::accel::compiler::{KernelCompiler, Operand, VecRef};
    use crate::accel::pipeline::Accelerator;
    use crate::vsa::BinaryCodebook;

    let cfg = AccelConfig::acc4();
    let n = 8usize; // items per factor
    let dim = 4096usize;
    let mut rng = crate::util::Rng::new(factors as u64);
    let cb = BinaryCodebook::random(&mut rng, n * factors, dim);
    let build = || {
        let mut acc = Accelerator::new(cfg.clone());
        let layout = acc.load_items(cb.items(), factors + 3);
        (acc, KernelCompiler::new(cfg.clone(), layout))
    };
    let run = |control: ControlMethod| {
        let (mut acc, kc) = build();
        let truth: Vec<usize> = (0..factors).map(|f| f * n + f % n).collect();
        let scene_ops: Vec<Operand> = truth
            .iter()
            .map(|&g| Operand::plain(VecRef::Item(g)))
            .collect();
        let mut report = acc.run(&kc.bind(&scene_ops, 0), control);
        for _it in 0..3 {
            for f in 0..factors {
                let mut ops = vec![Operand::plain(VecRef::Scratch(0))];
                for of in 0..factors {
                    if of != f {
                        ops.push(Operand::plain(VecRef::Scratch(1 + of)));
                    }
                }
                report.merge(&acc.run(&kc.bind(&ops, factors + 1), control));
                let items: Vec<usize> = (f * n..(f + 1) * n).collect();
                report.merge(&acc.run(&kc.project(factors + 1, &items, 1 + f), control));
            }
        }
        report
    };
    (run(ControlMethod::Sopc), run(ControlMethod::Mopc))
}

/// Fig. 11a: Acc2/4/8 latency + energy across the four suite workloads.
pub fn fig11a() -> Table {
    let mut t = Table::new(&["workload", "config", "time", "energy", "vs Acc2"]);
    for kind in SuiteKind::ALL {
        let mut base = None;
        for cfg in AccelConfig::paper_instances() {
            let name = cfg.name.clone();
            let mut s = CompiledSuite::build(kind, cfg, 17);
            let r = s.run(ControlMethod::Mopc);
            let b = *base.get_or_insert(r.time_s);
            t.row(&[
                kind.label().into(),
                name,
                fmt_time(r.time_s),
                fmt_energy(r.energy_j()),
                format!("{:.2}x", b / r.time_s),
            ]);
        }
    }
    t
}

/// Fig. 11b: Acc4 vs V100 GPU latency + energy per suite workload.
pub fn fig11b() -> Table {
    let gpu = Platform::v100();
    let mut t = Table::new(&[
        "workload",
        "Acc4 time",
        "GPU time",
        "speedup",
        "Acc4 energy",
        "GPU energy",
        "energy gain",
    ]);
    for kind in SuiteKind::ALL {
        let mut s = CompiledSuite::build(kind, AccelConfig::acc4(), 17);
        let r = s.run(ControlMethod::Mopc);
        let tb = gpu.trace_time(&gpu_trace(kind), None);
        t.row(&[
            kind.label().into(),
            fmt_time(r.time_s),
            fmt_time(tb.total),
            format!("{:.0}x", tb.total / r.time_s),
            fmt_energy(r.energy_j()),
            fmt_energy(tb.energy_j),
            format!("{:.0e}x", tb.energy_j / r.energy_j()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_figures_render() {
        for (name, table) in [
            ("fig2a", fig2a()),
            ("fig2b", fig2b()),
            ("fig2c", fig2c()),
            ("fig3a", fig3a()),
            ("fig3b", fig3b()),
            ("fig3c", fig3c()),
            ("fig4", fig4()),
            ("tab4", tab4()),
            ("fig5", fig5()),
            ("fig11b", fig11b()),
        ] {
            let s = table.to_string();
            assert!(s.lines().count() > 2, "{name} table empty:\n{s}");
        }
    }

    #[test]
    fn fig2c_shows_superlinear_scaling() {
        let gpu = Platform::rtx2080ti();
        let t2 = gpu
            .trace_time(&Workload::trace(&Nvsa { grid: 2, ..Default::default() }), None);
        let t3 = gpu
            .trace_time(&Workload::trace(&Nvsa { grid: 3, ..Default::default() }), None);
        // paper: 5.02x runtime growth 2x2 → 3x3 with stable symbolic
        // share; at our representative sizes the superlinear shape holds
        // (panels x row/column rule contexts both grow)
        // (the paper's full 5.02x also reflects 3x3 RAVEN panels holding
        // more objects each; our panels keep a fixed attribute set)
        let growth = t3.total / t2.total;
        assert!(growth > 1.5, "growth {growth}");
        assert!((t3.symbolic_fraction() - t2.symbolic_fraction()).abs() < 0.10);
    }

    #[test]
    fn fig11b_orders_of_magnitude() {
        let gpu = Platform::v100();
        let mut worst_speedup = f64::INFINITY;
        let mut worst_energy = f64::INFINITY;
        for kind in SuiteKind::ALL {
            let mut s = CompiledSuite::build(kind, AccelConfig::acc4(), 17);
            let r = s.run(ControlMethod::Mopc);
            let tb = gpu.trace_time(&gpu_trace(kind), None);
            worst_speedup = worst_speedup.min(tb.total / r.time_s);
            worst_energy = worst_energy.min(tb.energy_j / r.energy_j());
        }
        // paper: up to 3 orders latency, up to 6 orders energy; even the
        // weakest workload should clear 1 and 3 orders respectively.
        assert!(worst_speedup > 10.0, "speedup {worst_speedup}");
        assert!(worst_energy > 1e3, "energy gain {worst_energy}");
    }

    #[test]
    fn fig9_mopc_band() {
        let (rs, rm) = fig9_point(3);
        let speedup = rs.time_s / rm.time_s;
        let power = rm.avg_power_w() / rs.avg_power_w();
        assert!((1.4..3.2).contains(&speedup), "speedup {speedup}");
        assert!((1.0..2.2).contains(&power), "power ratio {power}");
    }
}
