//! Simulated GPU kernel counters (Tab. IV): the compute/memory/cache
//! behaviour contrast between neural and symbolic kernel classes.
//!
//! ALU utilization and DRAM bandwidth utilization are *derived* from the
//! roofline model (attained / peak under the category's efficiency
//! factors); cache throughput and hit rates are per-class calibration
//! constants taken from the paper's measured contrast — the point of
//! Tab. IV is the neural-vs-symbolic gap, which these reproduce.

use super::Platform;
use crate::profiler::taxonomy::OpCategory;
use crate::profiler::trace::OpRecord;

/// Nsight-style kernel counters.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelCounters {
    pub kernel: String,
    pub compute_throughput_pct: f64,
    pub alu_utilization_pct: f64,
    pub l1_throughput_pct: f64,
    pub l2_throughput_pct: f64,
    pub l1_hit_rate_pct: f64,
    pub l2_hit_rate_pct: f64,
    pub dram_bw_utilization_pct: f64,
}

/// Cache behaviour calibration per kernel class (measured constants from
/// Tab. IV; the roofline supplies the compute/DRAM columns).
fn cache_profile(c: OpCategory, elementwise_variant: bool) -> (f64, f64, f64, f64) {
    // (l1_tp, l2_tp, l1_hit, l2_hit)
    match c {
        OpCategory::MatMul => (79.7, 19.2, 1.6, 86.8),
        OpCategory::Conv => (80.0, 18.0, 40.0, 80.0),
        OpCategory::VectorElem if !elementwise_variant => (28.4, 29.8, 29.5, 48.6),
        OpCategory::VectorElem => (10.8, 22.8, 33.3, 34.3),
        OpCategory::DataTransform => (20.0, 25.0, 25.0, 40.0),
        OpCategory::DataMovement => (5.0, 15.0, 10.0, 20.0),
        OpCategory::Other => (8.0, 12.0, 15.0, 25.0),
    }
}

/// Derive counters for a representative kernel on a platform.
///
/// `relu`-style activations are modelled as Conv-phase element-wise ops
/// with high compute throughput (they fuse well), matching Tab. IV's
/// `relu_nn` row.
pub fn simulate(
    platform: &Platform,
    op: &OpRecord,
    elementwise_variant: bool,
) -> KernelCounters {
    let t = platform.op_time(op) - platform.kernel_launch_s;
    let t = t.max(1e-12);
    let attained_flops = op.flops as f64 / t;
    let attained_bw = op.bytes() as f64 / t;
    let (l1_tp, l2_tp, l1_hit, l2_hit) = cache_profile(op.category, elementwise_variant);
    // ALU utilization tracks issue-slot occupancy: near the compute
    // ceiling for GEMM, tiny for streaming ops.
    let compute_pct = (attained_flops / platform.peak_flops * 100.0).min(100.0);
    let alu_pct = match op.category {
        OpCategory::MatMul => compute_pct * 0.95,
        OpCategory::Conv => compute_pct * 0.80,
        _ => (compute_pct * 2.0).min(9.9), // scalar pipes, sub-10%
    };
    KernelCounters {
        kernel: op.name.clone(),
        compute_throughput_pct: compute_pct,
        alu_utilization_pct: alu_pct,
        l1_throughput_pct: l1_tp,
        l2_throughput_pct: l2_tp,
        l1_hit_rate_pct: l1_hit,
        l2_hit_rate_pct: l2_hit,
        dram_bw_utilization_pct: (attained_bw / platform.dram_bw * 100.0).min(100.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::taxonomy::PhaseKind;
    use crate::profiler::trace::Trace;

    fn op(name: &str, c: OpCategory, flops: u64, bytes: u64) -> OpRecord {
        let mut tr = Trace::new("t");
        tr.add(name, c, PhaseKind::Neural, flops, bytes / 2, bytes / 2, &[]);
        tr.ops.pop().unwrap()
    }

    #[test]
    fn sgemm_counters_match_tab4_contrast() {
        let p = Platform::rtx2080ti();
        let n = 4096u64;
        let gemm = simulate(&p, &op("sgemm_nn", OpCategory::MatMul, 2 * n * n * n, 12 * n * n), false);
        assert!(gemm.compute_throughput_pct > 60.0, "{gemm:?}");
        assert!(gemm.alu_utilization_pct > 55.0);
        assert!(gemm.dram_bw_utilization_pct < 40.0);
    }

    #[test]
    fn symbolic_counters_match_tab4_contrast() {
        let p = Platform::rtx2080ti();
        let bytes = 256u64 << 20;
        let sym = simulate(&p, &op("vectorized_elem", OpCategory::VectorElem, bytes / 4, bytes), false);
        assert!(sym.alu_utilization_pct < 10.0, "{sym:?}");
        assert!(sym.dram_bw_utilization_pct > 70.0);
        assert!(sym.l1_hit_rate_pct < 40.0);
    }

    #[test]
    fn neural_vs_symbolic_gap_is_wide() {
        let p = Platform::rtx2080ti();
        let n = 4096u64;
        let gemm = simulate(&p, &op("sgemm", OpCategory::MatMul, 2 * n * n * n, 12 * n * n), false);
        let bytes = 256u64 << 20;
        let sym = simulate(&p, &op("elem", OpCategory::VectorElem, bytes / 4, bytes), true);
        assert!(gemm.alu_utilization_pct / sym.alu_utilization_pct > 8.0);
        assert!(sym.dram_bw_utilization_pct / gemm.dram_bw_utilization_pct > 2.0);
    }
}
