//! Analytical platform cost models: RTX 2080 Ti / V100 GPUs, Jetson TX2 /
//! Xavier NX edge SoCs, and the Xeon 4114 host CPU.
//!
//! The paper's testbed is unavailable (repro band 0/5); these models
//! substitute for it.  Each platform is a roofline (peak FLOP/s, DRAM
//! bandwidth) plus per-operator-category efficiency factors calibrated
//! from the paper's own Tab. IV measurements (sgemm ≈95% compute
//! throughput vs. <10% ALU utilization for symbolic element-wise
//! kernels), a per-kernel launch overhead, and a host↔device bandwidth
//! for `DataMovement` ops.  Time per op =
//! `max(flops/(peak·c_eff), bytes/(bw·b_eff)) + launch`, energy =
//! board power × time.  See DESIGN.md's substitution ledger.

pub mod counters;

use crate::profiler::taxonomy::{OpCategory, PhaseKind};
use crate::profiler::trace::Trace;

/// An execution platform model.
#[derive(Debug, Clone, PartialEq)]
pub struct Platform {
    pub name: &'static str,
    /// Peak f32 throughput (FLOP/s).
    pub peak_flops: f64,
    /// DRAM bandwidth (bytes/s).
    pub dram_bw: f64,
    /// Per-kernel launch + driver overhead (s).
    pub kernel_launch_s: f64,
    /// Host↔device transfer bandwidth (bytes/s); also charged a launch.
    pub host_dev_bw: f64,
    /// Board / module power while active (W).
    pub power_w: f64,
}

impl Platform {
    /// NVIDIA RTX 2080 Ti (the paper's desktop GPU).
    pub fn rtx2080ti() -> Platform {
        Platform {
            name: "RTX 2080 Ti",
            peak_flops: 13.45e12,
            dram_bw: 616e9,
            kernel_launch_s: 8e-6,
            host_dev_bw: 12e9,
            power_w: 250.0,
        }
    }

    /// NVIDIA V100 (the accelerator case study's GPU baseline).
    pub fn v100() -> Platform {
        Platform {
            name: "V100",
            peak_flops: 15.7e12,
            dram_bw: 900e9,
            kernel_launch_s: 8e-6,
            host_dev_bw: 12e9,
            power_w: 300.0,
        }
    }

    /// NVIDIA Jetson TX2 (15 W edge SoC).
    pub fn tx2() -> Platform {
        Platform {
            name: "Jetson TX2",
            peak_flops: 0.665e12,
            dram_bw: 59.7e9,
            kernel_launch_s: 25e-6,
            host_dev_bw: 20e9, // unified memory: cheap transfers
            power_w: 15.0,
        }
    }

    /// NVIDIA Xavier NX (20 W edge SoC).
    pub fn xavier_nx() -> Platform {
        Platform {
            name: "Xavier NX",
            peak_flops: 0.845e12,
            dram_bw: 51.2e9,
            kernel_launch_s: 15e-6,
            host_dev_bw: 25e9,
            power_w: 20.0,
        }
    }

    /// Intel Xeon Silver 4114 (the paper's host CPU).
    pub fn xeon4114() -> Platform {
        Platform {
            name: "Xeon 4114",
            peak_flops: 0.70e12,
            dram_bw: 115e9,
            kernel_launch_s: 0.3e-6, // function-call scale
            host_dev_bw: 115e9,
            power_w: 85.0,
        }
    }

    /// The live serving host for the measured-roofline bridge
    /// (`serve-bench --trace`): Xeon 4114-class defaults, with the two
    /// roofline-defining parameters overridable via environment when the
    /// deploy target's calibration differs — `NSCOG_HOST_PEAK_FLOPS`
    /// (FLOP/s) and `NSCOG_HOST_DRAM_BW` (bytes/s), both positive f64.
    pub fn host() -> Platform {
        fn env_f64(key: &str) -> Option<f64> {
            let v: f64 = std::env::var(key).ok()?.trim().parse().ok()?;
            (v > 0.0 && v.is_finite()).then_some(v)
        }
        let mut p = Self::xeon4114();
        p.name = "serve-host";
        if let Some(v) = env_f64("NSCOG_HOST_PEAK_FLOPS") {
            p.peak_flops = v;
        }
        if let Some(v) = env_f64("NSCOG_HOST_DRAM_BW") {
            p.dram_bw = v;
        }
        p
    }

    /// The paper's Fig. 2b platform sweep.
    pub fn edge_sweep() -> Vec<Platform> {
        vec![Self::tx2(), Self::xavier_nx(), Self::rtx2080ti()]
    }

    /// Compute-efficiency factor per operator category, calibrated from
    /// Tab. IV (sgemm_nn 95% compute throughput / 90% ALU; symbolic
    /// vectorized_elem 3% compute / 6% ALU).
    pub fn compute_eff(&self, c: OpCategory) -> f64 {
        match c {
            OpCategory::MatMul => 0.75,
            OpCategory::Conv => 0.60,
            OpCategory::VectorElem => 0.05,
            OpCategory::DataTransform => 0.03,
            OpCategory::DataMovement => 0.02,
            OpCategory::Other => 0.01,
        }
    }

    /// Bandwidth-efficiency factor per category (Tab. IV: symbolic
    /// kernels drive DRAM to ~80–90% utilization; GEMM streams far less).
    pub fn bw_eff(&self, c: OpCategory) -> f64 {
        match c {
            OpCategory::MatMul => 0.60,
            OpCategory::Conv => 0.60,
            OpCategory::VectorElem => 0.85,
            OpCategory::DataTransform => 0.45,
            OpCategory::DataMovement => 0.80,
            OpCategory::Other => 0.25,
        }
    }

    /// Modelled execution time of one operator.
    pub fn op_time(&self, op: &crate::profiler::trace::OpRecord) -> f64 {
        let (compute, bytes) = (op.flops as f64, op.bytes() as f64);
        let t = if op.category == OpCategory::DataMovement {
            bytes / (self.host_dev_bw * self.bw_eff(op.category))
        } else {
            let tc = compute / (self.peak_flops * self.compute_eff(op.category));
            let tb = bytes / (self.dram_bw * self.bw_eff(op.category));
            tc.max(tb)
        };
        t + self.kernel_launch_s
    }

    /// Modelled energy of one operator (board power × time).
    pub fn op_energy(&self, op: &crate::profiler::trace::OpRecord) -> f64 {
        self.op_time(op) * self.power_w
    }

    /// Aggregate a trace (optionally one phase) into a time breakdown.
    pub fn trace_time(&self, trace: &Trace, phase: Option<PhaseKind>) -> TimeBreakdown {
        let mut tb = TimeBreakdown::default();
        for op in &trace.ops {
            if let Some(p) = phase {
                if op.phase != p {
                    continue;
                }
            }
            let t = self.op_time(op);
            tb.total += t;
            tb.by_category[cat_idx(op.category)] += t;
            match op.phase {
                PhaseKind::Neural => tb.neural += t,
                PhaseKind::Symbolic => tb.symbolic += t,
            }
            tb.energy_j += t * self.power_w;
        }
        tb
    }
}

fn cat_idx(c: OpCategory) -> usize {
    OpCategory::ALL.iter().position(|&x| x == c).unwrap()
}

/// Time/energy aggregation of a trace on a platform.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TimeBreakdown {
    pub total: f64,
    pub neural: f64,
    pub symbolic: f64,
    /// Indexed by `OpCategory::ALL` order.
    pub by_category: [f64; 6],
    pub energy_j: f64,
}

impl TimeBreakdown {
    /// Fraction of runtime in the symbolic phase (Fig. 2a's key ratio).
    pub fn symbolic_fraction(&self) -> f64 {
        if self.total > 0.0 {
            self.symbolic / self.total
        } else {
            0.0
        }
    }

    /// Per-category runtime fractions (Fig. 3a).
    pub fn category_fractions(&self) -> [f64; 6] {
        let mut out = [0.0; 6];
        if self.total > 0.0 {
            for i in 0..6 {
                out[i] = self.by_category[i] / self.total;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::trace::Trace;

    fn gemm_op(n: u64) -> crate::profiler::trace::OpRecord {
        let mut tr = Trace::new("t");
        tr.add("gemm", OpCategory::MatMul, PhaseKind::Neural, 2 * n * n * n, 8 * n * n, 4 * n * n, &[]);
        tr.ops.pop().unwrap()
    }

    fn elem_op(bytes: u64) -> crate::profiler::trace::OpRecord {
        let mut tr = Trace::new("t");
        tr.add("bind", OpCategory::VectorElem, PhaseKind::Symbolic, bytes / 4, bytes, bytes, &[]);
        tr.ops.pop().unwrap()
    }

    #[test]
    fn gemm_is_compute_limited_on_gpu() {
        let p = Platform::rtx2080ti();
        let op = gemm_op(2048);
        let t = p.op_time(&op);
        let tc = op.flops as f64 / (p.peak_flops * p.compute_eff(OpCategory::MatMul));
        assert!((t - tc - p.kernel_launch_s).abs() / t < 0.01);
    }

    #[test]
    fn elementwise_is_bandwidth_limited_on_gpu() {
        let p = Platform::rtx2080ti();
        let op = elem_op(64 << 20);
        let t = p.op_time(&op);
        let tb = op.bytes() as f64 / (p.dram_bw * p.bw_eff(OpCategory::VectorElem));
        assert!((t - tb - p.kernel_launch_s).abs() / t < 0.01);
    }

    #[test]
    fn tiny_ops_are_launch_dominated() {
        let p = Platform::v100();
        let op = elem_op(4096);
        let t = p.op_time(&op);
        assert!(p.kernel_launch_s / t > 0.9, "launch should dominate tiny ops");
    }

    #[test]
    fn edge_platforms_slower_than_desktop() {
        let op = gemm_op(1024);
        let t_gpu = Platform::rtx2080ti().op_time(&op);
        let t_tx2 = Platform::tx2().op_time(&op);
        let t_nx = Platform::xavier_nx().op_time(&op);
        assert!(t_tx2 > 10.0 * t_gpu);
        assert!(t_nx > 5.0 * t_gpu);
        assert!(t_tx2 > t_nx, "TX2 is the slowest platform");
    }

    #[test]
    fn host_platform_defaults_to_the_xeon_calibration() {
        // (env overrides are not exercised here: tests run in parallel
        // and process-global env mutation would race)
        let h = Platform::host();
        let x = Platform::xeon4114();
        assert_eq!(h.name, "serve-host");
        assert_eq!(h.peak_flops, x.peak_flops);
        assert_eq!(h.dram_bw, x.dram_bw);
        assert_eq!(h.power_w, x.power_w);
    }

    #[test]
    fn breakdown_fractions_sum_to_one() {
        let p = Platform::rtx2080ti();
        let mut tr = Trace::new("t");
        tr.add("gemm", OpCategory::MatMul, PhaseKind::Neural, 1 << 30, 1 << 22, 1 << 22, &[]);
        tr.add("bind", OpCategory::VectorElem, PhaseKind::Symbolic, 1 << 20, 1 << 26, 1 << 26, &[]);
        let tb = p.trace_time(&tr, None);
        assert!((tb.neural + tb.symbolic - tb.total).abs() < 1e-12);
        let frac: f64 = tb.category_fractions().iter().sum();
        assert!((frac - 1.0).abs() < 1e-9);
        assert!(tb.energy_j > 0.0);
    }
}
