//! Micro-benchmark harness used by all `rust/benches/*` targets.
//!
//! Criterion is not vendorable offline, so the benches use this
//! self-contained harness: warmup, fixed-duration sampling, and summary
//! statistics, plus table-printing helpers for regenerating the paper's
//! figures as aligned text tables.

use super::stats::{fmt_time, Summary};
use std::time::{Duration, Instant};

/// Run `f` repeatedly for ~`sample_secs` after a warmup, returning
/// per-iteration timings in seconds.
pub fn sample<F: FnMut()>(mut f: F, warmup_secs: f64, sample_secs: f64) -> Vec<f64> {
    let warm_until = Instant::now() + Duration::from_secs_f64(warmup_secs);
    let mut iters_hint = 0u64;
    while Instant::now() < warm_until {
        f();
        iters_hint += 1;
    }
    let _ = iters_hint;
    let mut times = Vec::new();
    let until = Instant::now() + Duration::from_secs_f64(sample_secs);
    while Instant::now() < until || times.len() < 5 {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
        if times.len() >= 100_000 {
            break;
        }
    }
    times
}

/// Benchmark `f` and print a criterion-style line. Returns the summary.
pub fn bench<F: FnMut()>(name: &str, f: F) -> Summary {
    let times = sample(f, 0.3, 1.0);
    let s = Summary::of(&times);
    println!(
        "{name:<44} time: [{} {} {}]  ({} samples)",
        fmt_time(s.min),
        fmt_time(s.p50),
        fmt_time(s.p95),
        s.n
    );
    s
}

/// Prevent the optimizer from eliding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// A simple aligned-column table printer for figure/table regeneration.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(r[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<w$}", cell, w = widths[c]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_collects_timings() {
        let mut acc = 0u64;
        let times = sample(
            || {
                acc = black_box(acc.wrapping_add(1));
            },
            0.01,
            0.02,
        );
        assert!(times.len() >= 5);
        assert!(times.iter().all(|&t| t >= 0.0));
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer-name".into(), "2.5".into()]);
        let s = t.to_string();
        assert!(s.contains("longer-name"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
