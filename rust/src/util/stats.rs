//! Summary statistics for benchmark results and characterization reports.

/// Descriptive statistics over a sample of measurements.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; panics on empty input.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            p50: percentile(&s, 0.50),
            p95: percentile(&s, 0.95),
            max: s[n - 1],
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Geometric mean (for speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.2} s", secs)
    }
}

/// Format an energy in joules (pJ/nJ/µJ/mJ/J).
pub fn fmt_energy(j: f64) -> String {
    if j < 1e-9 {
        format!("{:.1} pJ", j * 1e12)
    } else if j < 1e-6 {
        format!("{:.2} nJ", j * 1e9)
    } else if j < 1e-3 {
        format!("{:.2} µJ", j * 1e6)
    } else if j < 1.0 {
        format!("{:.2} mJ", j * 1e3)
    } else {
        format!("{:.2} J", j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert!((percentile(&s, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&s, 0.0), 0.0);
        assert_eq!(percentile(&s, 1.0), 10.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.5e-9).contains("ns"));
        assert!(fmt_time(2.5e-6).contains("µs"));
        assert!(fmt_time(2.5e-3).contains("ms"));
        assert!(fmt_time(2.5).contains('s'));
    }

    #[test]
    fn energy_formatting() {
        assert!(fmt_energy(3e-12).contains("pJ"));
        assert!(fmt_energy(3e-9).contains("nJ"));
        assert!(fmt_energy(3e-3).contains("mJ"));
    }
}
