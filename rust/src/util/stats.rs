//! Summary statistics for benchmark results and characterization reports.

/// Descriptive statistics over a sample of measurements.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; panics on empty input.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            p50: percentile(&s, 0.50),
            p95: percentile(&s, 0.95),
            max: s[n - 1],
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Streaming quantile estimator — the P² (piecewise-parabolic) algorithm
/// of Jain & Chlamtac (CACM 1985). Tracks one quantile of an unbounded
/// observation stream in O(1) memory with five markers: exact for the
/// first five observations, a parabolic-interpolation approximation
/// after. This is what lets `serve`'s long-run latency accounting drop
/// its per-request `Vec<f64>` — `record` touches only the fixed-size
/// marker arrays, so steady-state stats recording never allocates.
#[derive(Debug, Clone, Copy)]
pub struct P2Quantile {
    q: f64,
    n: u64,
    /// Marker heights (the first `n` observations, unsorted, until the
    /// estimator seeds at n = 5; sorted marker heights after).
    h: [f64; 5],
    /// Marker positions (1-indexed ranks within the stream so far).
    pos: [f64; 5],
    /// Desired marker positions.
    want: [f64; 5],
}

impl P2Quantile {
    pub fn new(q: f64) -> P2Quantile {
        P2Quantile {
            q: q.clamp(0.0, 1.0),
            n: 0,
            h: [0.0; 5],
            pos: [0.0; 5],
            want: [0.0; 5],
        }
    }

    /// Observations recorded so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// The quantile this estimator tracks.
    pub fn q(&self) -> f64 {
        self.q
    }

    /// Record one observation. O(1), allocation-free.
    pub fn record(&mut self, x: f64) {
        if self.n < 5 {
            self.h[self.n as usize] = x;
            self.n += 1;
            if self.n == 5 {
                self.h.sort_by(f64::total_cmp);
                let q = self.q;
                self.pos = [1.0, 2.0, 3.0, 4.0, 5.0];
                self.want = [
                    1.0,
                    1.0 + 2.0 * q,
                    1.0 + 4.0 * q,
                    3.0 + 2.0 * q,
                    5.0,
                ];
            }
            return;
        }
        // Locate the cell, clamping the extreme markers to the sample
        // min/max.
        let k = if x < self.h[0] {
            self.h[0] = x;
            0
        } else if x >= self.h[4] {
            self.h[4] = x.max(self.h[4]);
            3
        } else {
            let mut cell = 0;
            while cell < 3 && x >= self.h[cell + 1] {
                cell += 1;
            }
            cell
        };
        for i in (k + 1)..5 {
            self.pos[i] += 1.0;
        }
        let q = self.q;
        let dw = [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0];
        for i in 0..5 {
            self.want[i] += dw[i];
        }
        // Nudge the three interior markers toward their desired ranks.
        for i in 1..4 {
            let d = self.want[i] - self.pos[i];
            if (d >= 1.0 && self.pos[i + 1] - self.pos[i] > 1.0)
                || (d <= -1.0 && self.pos[i - 1] - self.pos[i] < -1.0)
            {
                let s = d.signum();
                let hp = self.parabolic(i, s);
                self.h[i] = if self.h[i - 1] < hp && hp < self.h[i + 1] {
                    hp
                } else {
                    self.linear(i, s)
                };
                self.pos[i] += s;
            }
        }
        self.n += 1;
    }

    fn parabolic(&self, i: usize, s: f64) -> f64 {
        let (pm, pi, pp) = (self.pos[i - 1], self.pos[i], self.pos[i + 1]);
        let (hm, hi, hp) = (self.h[i - 1], self.h[i], self.h[i + 1]);
        hi + s / (pp - pm)
            * ((pi - pm + s) * (hp - hi) / (pp - pi) + (pp - pi - s) * (hi - hm) / (pi - pm))
    }

    fn linear(&self, i: usize, s: f64) -> f64 {
        let j = if s > 0.0 { i + 1 } else { i - 1 };
        self.h[i] + s * (self.h[j] - self.h[i]) / (self.pos[j] - self.pos[i])
    }

    /// Current estimate; `None` before any observation. Exact (linear
    /// interpolation over the sorted sample) while n < 5.
    pub fn value(&self) -> Option<f64> {
        if self.n == 0 {
            return None;
        }
        if self.n < 5 {
            let mut head = [0.0; 5];
            let n = self.n as usize;
            head[..n].copy_from_slice(&self.h[..n]);
            head[..n].sort_by(f64::total_cmp);
            return Some(percentile(&head[..n], self.q));
        }
        Some(self.h[2])
    }
}

/// Geometric mean (for speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.2} s", secs)
    }
}

/// Format a byte count human-readably (B/KiB/MiB/GiB).
pub fn fmt_bytes(bytes: usize) -> String {
    let b = bytes as f64;
    if b < 1024.0 {
        format!("{bytes} B")
    } else if b < 1024.0 * 1024.0 {
        format!("{:.1} KiB", b / 1024.0)
    } else if b < 1024.0 * 1024.0 * 1024.0 {
        format!("{:.1} MiB", b / (1024.0 * 1024.0))
    } else {
        format!("{:.2} GiB", b / (1024.0 * 1024.0 * 1024.0))
    }
}

/// Format an energy in joules (pJ/nJ/µJ/mJ/J).
pub fn fmt_energy(j: f64) -> String {
    if j < 1e-9 {
        format!("{:.1} pJ", j * 1e12)
    } else if j < 1e-6 {
        format!("{:.2} nJ", j * 1e9)
    } else if j < 1e-3 {
        format!("{:.2} µJ", j * 1e6)
    } else if j < 1.0 {
        format!("{:.2} mJ", j * 1e3)
    } else {
        format!("{:.2} J", j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert!((percentile(&s, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&s, 0.0), 0.0);
        assert_eq!(percentile(&s, 1.0), 10.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.5e-9).contains("ns"));
        assert!(fmt_time(2.5e-6).contains("µs"));
        assert!(fmt_time(2.5e-3).contains("ms"));
        assert!(fmt_time(2.5).contains('s'));
    }

    #[test]
    fn byte_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert!(fmt_bytes(8 * 1024).contains("KiB"));
        assert!(fmt_bytes(3 * 1024 * 1024).contains("MiB"));
        assert!(fmt_bytes(2 * 1024 * 1024 * 1024).contains("GiB"));
    }

    #[test]
    fn p2_exact_below_five_observations() {
        let mut p = P2Quantile::new(0.5);
        assert_eq!(p.value(), None);
        p.record(3.0);
        assert_eq!(p.value(), Some(3.0));
        p.record(1.0);
        assert!((p.value().unwrap() - 2.0).abs() < 1e-12, "median of {{1,3}}");
        p.record(2.0);
        assert!((p.value().unwrap() - 2.0).abs() < 1e-12);
        assert_eq!(p.count(), 3);
    }

    #[test]
    fn p2_median_of_uniform_ramp_converges() {
        // 1..=1001 in a shuffled-ish deterministic order (stride walk):
        // the true median is 501.
        let n = 1001usize;
        let mut p50 = P2Quantile::new(0.5);
        let mut p99 = P2Quantile::new(0.99);
        let mut i = 0usize;
        for _ in 0..n {
            let x = (i + 1) as f64;
            p50.record(x);
            p99.record(x);
            i = (i + 617) % n; // 617 coprime with 1001 -> full cycle
        }
        assert_eq!(p50.count(), n as u64);
        let m = p50.value().unwrap();
        assert!((m - 501.0).abs() < 25.0, "p50 {m}");
        let t = p99.value().unwrap();
        assert!((t - 991.0).abs() < 25.0, "p99 {t}");
    }

    #[test]
    fn p2_tracks_max_like_tail_on_skewed_stream() {
        // Mostly-small observations with occasional large spikes: the
        // p99 estimate must land between the bulk and the spike level.
        let mut p = P2Quantile::new(0.99);
        for i in 0..5_000 {
            let x = if i % 100 == 99 { 100.0 } else { 1.0 + (i % 7) as f64 * 0.01 };
            p.record(x);
        }
        let v = p.value().unwrap();
        assert!(v > 2.0 && v <= 100.0, "p99 {v} should reflect the spike tail");
    }

    #[test]
    fn energy_formatting() {
        assert!(fmt_energy(3e-12).contains("pJ"));
        assert!(fmt_energy(3e-9).contains("nJ"));
        assert!(fmt_energy(3e-3).contains("mJ"));
    }
}
