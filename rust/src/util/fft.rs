//! Dependency-free radix-2 FFT for the HRR binding hot path.
//!
//! The paper's circular-convolution kernel is the one L3 operation that is
//! compute- rather than memory-bound when evaluated directly (O(D²)); for
//! power-of-two D this module brings it to O(D log D) with a split
//! real/imaginary iterative Cooley–Tukey transform in f64, so the f32
//! outputs of [`cconv_pow2`]/[`ccorr_pow2`] match the direct evaluation to
//! well below the 1e-3 equivalence tolerance used by the property tests.

use std::f64::consts::PI;

/// In-place iterative radix-2 FFT over split re/im arrays.
///
/// `inverse` computes the *unscaled* inverse transform — callers divide by
/// the length. Panics unless `re.len() == im.len()` is a power of two.
pub fn fft_inplace(re: &mut [f64], im: &mut [f64], inverse: bool) {
    let n = re.len();
    assert_eq!(n, im.len());
    assert!(n.is_power_of_two(), "fft length must be a power of two");
    if n <= 1 {
        return;
    }
    // Bit-reversal permutation.
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let mut cr = 1.0f64;
            let mut ci = 0.0f64;
            for k in i..i + len / 2 {
                let l = k + len / 2;
                let tr = re[l] * cr - im[l] * ci;
                let ti = re[l] * ci + im[l] * cr;
                re[l] = re[k] - tr;
                im[l] = im[k] - ti;
                re[k] += tr;
                im[k] += ti;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Circular convolution `z[i] = Σ_j x[j]·y[(i−j) mod n]` via three FFTs.
/// Length must be a power of two (checked by [`fft_inplace`]).
pub fn cconv_pow2(x: &[f32], y: &[f32]) -> Vec<f32> {
    spectral_combine(x, y, false)
}

/// Circular correlation `z[i] = Σ_j x[j]·y[(j+i) mod n]` via three FFTs
/// (`Z = conj(X)·Y`). Length must be a power of two.
pub fn ccorr_pow2(x: &[f32], y: &[f32]) -> Vec<f32> {
    spectral_combine(x, y, true)
}

fn spectral_combine(x: &[f32], y: &[f32], conjugate_x: bool) -> Vec<f32> {
    let n = x.len();
    assert_eq!(n, y.len());
    let mut xr: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    let mut xi = vec![0.0f64; n];
    let mut yr: Vec<f64> = y.iter().map(|&v| v as f64).collect();
    let mut yi = vec![0.0f64; n];
    fft_inplace(&mut xr, &mut xi, false);
    fft_inplace(&mut yr, &mut yi, false);
    for k in 0..n {
        let (pr, pi) = if conjugate_x {
            (xr[k] * yr[k] + xi[k] * yi[k], xr[k] * yi[k] - xi[k] * yr[k])
        } else {
            (xr[k] * yr[k] - xi[k] * yi[k], xr[k] * yi[k] + xi[k] * yr[k])
        };
        xr[k] = pr;
        xi[k] = pi;
    }
    fft_inplace(&mut xr, &mut xi, true);
    let inv = 1.0 / n as f64;
    xr.iter().map(|&v| (v * inv) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_recovers_input() {
        let mut rng = Rng::new(1);
        for n in [2usize, 8, 64, 512] {
            let orig: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut re = orig.clone();
            let mut im = vec![0.0; n];
            fft_inplace(&mut re, &mut im, false);
            fft_inplace(&mut re, &mut im, true);
            for (a, b) in re.iter().zip(&orig) {
                assert!((a / n as f64 - b).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn matches_reference_dft() {
        let mut rng = Rng::new(2);
        let n = 64usize;
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut re = x.clone();
        let mut im = vec![0.0; n];
        fft_inplace(&mut re, &mut im, false);
        for k in 0..n {
            let mut rr = 0.0;
            let mut ii = 0.0;
            for (j, &xj) in x.iter().enumerate() {
                let a = -2.0 * std::f64::consts::PI * (j * k) as f64 / n as f64;
                rr += xj * a.cos();
                ii += xj * a.sin();
            }
            assert!((re[k] - rr).abs() < 1e-8, "re k={k}");
            assert!((im[k] - ii).abs() < 1e-8, "im k={k}");
        }
    }

    #[test]
    fn conv_delta_is_shift() {
        // x ⊛ δ_s cyclically shifts x by s.
        let mut rng = Rng::new(3);
        let n = 128usize;
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut delta = vec![0.0f32; n];
        delta[5] = 1.0;
        let z = cconv_pow2(&x, &delta);
        for i in 0..n {
            assert!((z[i] - x[(i + n - 5) % n]).abs() < 1e-4);
        }
    }

    #[test]
    fn corr_of_conv_recovers_operand() {
        let mut rng = Rng::new(4);
        let n = 256usize;
        let scale = 1.0 / (n as f64).sqrt();
        let x: Vec<f32> = (0..n).map(|_| (rng.normal() * scale) as f32).collect();
        let y: Vec<f32> = (0..n).map(|_| (rng.normal() * scale) as f32).collect();
        let z = cconv_pow2(&x, &y);
        let y_hat = ccorr_pow2(&x, &z);
        let dot: f64 = y_hat.iter().zip(&y).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let na: f64 = y_hat.iter().map(|a| (*a as f64).powi(2)).sum::<f64>().sqrt();
        let nb: f64 = y.iter().map(|a| (*a as f64).powi(2)).sum::<f64>().sqrt();
        assert!(dot / (na * nb) > 0.5);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_pow2() {
        let mut re = vec![0.0; 6];
        let mut im = vec![0.0; 6];
        fft_inplace(&mut re, &mut im, false);
    }
}
