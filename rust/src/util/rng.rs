//! Deterministic PRNG (xoshiro256**, seeded via SplitMix64).
//!
//! The whole characterization pipeline must be reproducible from a seed:
//! codebooks, synthetic RPM panels, and workload generators all draw from
//! this generator.  (The `rand` crate is not vendorable offline; this is a
//! faithful implementation of the public xoshiro256** algorithm.)

/// xoshiro256** PRNG. Not cryptographic; excellent statistical quality for
/// simulation workloads.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's method without bias correction is fine for simulation.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi].
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Random bool with probability `p` of `true`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Random bipolar value (+1.0 / -1.0).
    #[inline]
    pub fn bipolar(&mut self) -> f32 {
        if self.next_u64() & 1 == 1 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Fork an independent stream (for parallel workers / sub-components).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn bipolar_balanced() {
        let mut r = Rng::new(13);
        let pos = (0..10_000).filter(|_| r.bipolar() > 0.0).count();
        assert!((4_500..5_500).contains(&pos), "pos {pos}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(19);
        let idx = r.sample_indices(100, 10);
        assert_eq!(idx.len(), 10);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
    }
}
