//! Tiny property-testing helper (proptest is not vendorable offline).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop` on each, reporting the failing case's index and seed
//! so it can be replayed deterministically.

use super::rng::Rng;

/// Run `prop` on `cases` inputs drawn by `gen`. Panics with the replay
/// seed on the first failure.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let case_seed = rng.next_u64();
        let mut case_rng = Rng::new(case_seed);
        let input = gen(&mut case_rng);
        if !prop(&input) {
            panic!(
                "property failed at case {case} (replay seed {case_seed:#x}): {input:?}"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result<(), String>` for a
/// descriptive failure message.
pub fn forall_res<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let case_seed = rng.next_u64();
        let mut case_rng = Rng::new(case_seed);
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (replay seed {case_seed:#x}): {msg}\ninput: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(1, 50, |r| r.below(100), |_| {
            count += 1;
            true
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(2, 50, |r| r.below(10), |&x| x < 5);
    }

    #[test]
    fn forall_res_reports_message() {
        let result = std::panic::catch_unwind(|| {
            forall_res(3, 10, |r| r.below(4), |&x| {
                if x < 4 {
                    Ok(())
                } else {
                    Err(format!("{x} too big"))
                }
            });
        });
        assert!(result.is_ok());
    }
}
