//! Std-only scoped-thread fan-out for the batched codebook scans.
//!
//! The paper's characterization shows cleanup scans are memory-bandwidth
//! bound; a handful of threads saturates DRAM, so this is deliberately a
//! tiny range-splitting helper (no work stealing, no channels). Worker
//! count comes from the `NSCOG_THREADS` environment variable (default 1 =
//! serial), read per call so tests can exercise several counts in one
//! process.

/// Worker count for batched scans: `NSCOG_THREADS`, default/fallback 1.
pub fn configured_threads() -> usize {
    std::env::var("NSCOG_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

/// Split `0..n` into at most `parts` contiguous, non-empty, in-order
/// ranges that cover every index exactly once. Also the partitioning rule
/// for [`crate::serve::shard`]'s codebook shards, so shard boundaries and
/// scan-thread boundaries agree.
pub fn split_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.clamp(1, n.max(1));
    let chunk = (n + parts - 1) / parts;
    let mut ranges = Vec::with_capacity(parts);
    let mut start = 0;
    while start < n {
        let end = (start + chunk).min(n);
        ranges.push(start..end);
        start = end;
    }
    ranges
}

/// Split `0..n` into `threads` contiguous ranges and map `f` over them on
/// scoped threads, returning per-range outputs in range order. With one
/// thread (or one range) `f` runs inline on the caller's stack.
pub fn map_ranges<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> T + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads == 1 {
        return vec![f(0..n)];
    }
    let ranges = split_ranges(n, threads);
    let f = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = ranges
            .into_iter()
            .map(|r| s.spawn(move || f(r)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("scan worker panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_index_in_order() {
        for threads in [1usize, 2, 3, 7] {
            let parts = map_ranges(100, threads, |r| r.collect::<Vec<usize>>());
            let flat: Vec<usize> = parts.into_iter().flatten().collect();
            assert_eq!(flat, (0..100).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn empty_and_oversubscribed() {
        let parts = map_ranges(0, 4, |r| r.len());
        assert_eq!(parts.iter().sum::<usize>(), 0);
        let parts = map_ranges(3, 16, |r| r.len());
        assert_eq!(parts.iter().sum::<usize>(), 3);
    }

    #[test]
    fn split_ranges_covers_in_order() {
        for (n, parts) in [(0usize, 3usize), (1, 1), (10, 3), (10, 16), (100, 7)] {
            let ranges = split_ranges(n, parts);
            assert!(ranges.len() <= parts.max(1), "n={n} parts={parts}");
            let flat: Vec<usize> = ranges.into_iter().flatten().collect();
            assert_eq!(flat, (0..n).collect::<Vec<_>>(), "n={n} parts={parts}");
        }
    }

    #[test]
    fn default_threads_is_serial() {
        // Unless the environment overrides it, scans stay serial.
        if std::env::var("NSCOG_THREADS").is_err() {
            assert_eq!(configured_threads(), 1);
        }
    }
}
