//! Self-contained utilities: deterministic RNG, a minimal JSON parser for
//! the artifact manifest, summary statistics, a micro-benchmark harness
//! (criterion is not vendorable in this environment), and a tiny
//! property-testing helper used by the invariant tests.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

pub use rng::Rng;
