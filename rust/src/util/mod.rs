//! Self-contained utilities: deterministic RNG, a minimal JSON parser for
//! the artifact manifest, summary statistics, a micro-benchmark harness
//! (criterion is not vendorable in this environment), a radix-2 FFT for
//! the HRR binding hot path, a scoped-thread fan-out for batched scans,
//! and a tiny property-testing helper used by the invariant tests.

pub mod bench;
pub mod fft;
pub mod json;
pub mod parallel;
pub mod prop;
pub mod rng;
pub mod stats;

pub use rng::Rng;
