//! Minimal recursive-descent JSON parser for the artifact manifest.
//!
//! `serde_json` is not vendorable in this offline environment; the manifest
//! written by `python/compile/aot.py` is plain JSON, so a small
//! self-contained parser keeps the interchange format standard.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document from a string.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: src.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
        assert!(j.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn parses_manifest_like() {
        let j = Json::parse(
            r#"{"hd_dim": 1024, "artifacts": {"x": {"file": "x.hlo.txt",
                "inputs": [{"shape": [16, 8], "dtype": "float32"}]}}}"#,
        )
        .unwrap();
        assert_eq!(j.get("hd_dim").unwrap().as_usize(), Some(1024));
        let x = j.get("artifacts").unwrap().get("x").unwrap();
        let shape = x.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.len(), 2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".into())
        );
    }
}
