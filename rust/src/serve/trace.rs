//! Live serve-path observability: per-request lifecycle stage marks and
//! a fixed-capacity ring buffer of per-response trace events.
//!
//! The paper's method is workload characterization — attributing time to
//! operators and placing them on a roofline (Figs. 2–3). This module is
//! the serve-side half of that bridge: every [`super::queue::Ticket`]
//! carries [`StageMarks`] stamped at admit → queue-pop → batch-seal →
//! kernel-start/end, and the moment a response is accounted the marks
//! collapse into a [`StageSample`] ("p99 = queue-wait + batch-wait +
//! kernel + fill") that feeds the per-store, per-class P² breakdowns in
//! [`super::stats::ServeStats`] — always on, a handful of `Instant`
//! reads per request.
//!
//! When tracing is enabled (`EngineConfig::trace_capacity`,
//! `serve-bench --trace`, `NSCOG_TRACE`), each accounted response also
//! lands as a [`TraceEvent`] in a [`TraceRing`]: fixed capacity,
//! preallocated, drop-oldest on overflow with an exact dropped-events
//! counter — steady-state recording never touches the heap (asserted in
//! `tests/alloc_free.rs`), and the tracing-off path is a single
//! `Option` branch in the batcher. [`KernelWork`] carries the measured
//! FLOPs/bytes per `(store, class)` kernel call that the roofline
//! bridge in `loadgen` feeds through `profiler::roofline::place`.

use std::sync::Mutex;
use std::time::Instant;

use super::{RequestKind, StoreId};

/// Lifecycle timestamps carried on a ticket from admission to fill.
///
/// `admit` is stamped at submit time (it doubles as the end-to-end
/// latency origin); the later marks are stamped as the ticket moves
/// through the batcher. Marks are monotone by construction — each is
/// taken strictly after the previous one on the same ticket.
#[derive(Debug, Clone, Copy)]
pub struct StageMarks {
    /// Admission into the queue (`submit_async`).
    pub admit: Instant,
    /// Popped off the admission queue by a worker.
    pub popped: Option<Instant>,
    /// Batch window closed — the ticket's batch is sealed.
    pub sealed: Option<Instant>,
    /// Batched kernel call for the ticket's `(store, class)` group began.
    pub kernel_start: Option<Instant>,
    /// Batched kernel call returned.
    pub kernel_end: Option<Instant>,
    /// Wire read + frame decode span, seconds, measured by the network
    /// front-end *before* submit (0.0 for in-process callers). Stored as
    /// a duration rather than an `Instant` pair because it ends where
    /// `admit` begins — it sits outside the admit-origin window that the
    /// other marks decompose.
    pub net_in_s: f64,
}

impl StageMarks {
    pub fn new(admit: Instant) -> StageMarks {
        StageMarks {
            admit,
            popped: None,
            sealed: None,
            kernel_start: None,
            kernel_end: None,
            net_in_s: 0.0,
        }
    }

    /// Stamp the kernel bracket for the ticket's batched group call.
    pub fn mark_kernel(&mut self, start: Instant, end: Instant) {
        self.kernel_start = Some(start);
        self.kernel_end = Some(end);
    }

    /// Attribute the socket read + decode span that produced this
    /// request (stamped by `net::server` before submit).
    pub fn mark_net_in(&mut self, secs: f64) {
        self.net_in_s = secs.max(0.0);
    }

    /// Collapse the marks into per-stage durations, with `now` standing
    /// in for the slot-fill instant (responses are accounted immediately
    /// before their slot fills — the "stats before fills" invariant).
    ///
    /// Missing marks contribute zero, and every stage uses
    /// `saturating_duration_since`, so each stage is non-negative and
    /// `sample.sum() <= now - admit` always holds: the only time not
    /// attributed to a stage is the group-formation gap between
    /// batch-seal and kernel-start.
    pub fn sample_at(&self, now: Instant) -> StageSample {
        let queue_s = self
            .popped
            .map(|p| p.saturating_duration_since(self.admit).as_secs_f64())
            .unwrap_or(0.0);
        let batch_s = match (self.popped, self.sealed) {
            (Some(p), Some(s)) => s.saturating_duration_since(p).as_secs_f64(),
            _ => 0.0,
        };
        let kernel_s = match (self.kernel_start, self.kernel_end) {
            (Some(a), Some(b)) => b.saturating_duration_since(a).as_secs_f64(),
            _ => 0.0,
        };
        // The fill stage starts where the last stamped mark ends, so the
        // decomposition stays exhaustive on kernel-free paths (cache
        // hits carry no kernel bracket — their probe time lands in fill;
        // error fills may carry no marks at all).
        let fill_origin = self
            .kernel_end
            .or(self.sealed)
            .or(self.popped)
            .unwrap_or(self.admit);
        let fill_s = now.saturating_duration_since(fill_origin).as_secs_f64();
        StageSample {
            queue_s,
            batch_s,
            kernel_s,
            fill_s,
            net_in_s: self.net_in_s,
        }
    }
}

/// One request's stage-latency decomposition, in seconds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageSample {
    /// Admit → queue-pop (time spent waiting in the admission queue).
    pub queue_s: f64,
    /// Queue-pop → batch-seal (time spent waiting for the batch window).
    pub batch_s: f64,
    /// Kernel-start → kernel-end (the batched kernel call itself).
    pub kernel_s: f64,
    /// Kernel-end → accounting/fill (response assembly, cache insert).
    pub fill_s: f64,
    /// Socket read + decode span preceding admission (0.0 in-process).
    /// Pre-admit wire time: part of what the *client* observes, but
    /// outside the admit-origin window — see [`StageSample::sum`].
    pub net_in_s: f64,
}

impl StageSample {
    /// Sum of the four in-process stages — by construction ≤ the
    /// end-to-end latency (admit → accounting) of the same request.
    /// Deliberately excludes [`StageSample::net_in_s`], which is spent
    /// on the wire *before* the admit origin; the network front-end's
    /// hop is aggregated separately (`StageAgg::net_in` /
    /// `StageAgg::net_out` in [`super::stats`]).
    pub fn sum(&self) -> f64 {
        self.queue_s + self.batch_s + self.kernel_s + self.fill_s
    }
}

/// Measured work of the batched kernel calls behind one `(store,
/// class)`: call count, wall time, and the FLOP/byte tallies the
/// roofline bridge places against a host [`crate::platform::Platform`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KernelWork {
    /// Batched kernel invocations (one per `(store, class)` group).
    pub calls: u64,
    /// Measured wall time inside those calls, seconds.
    pub elapsed_s: f64,
    /// Integer/float ALU operations performed (measured where the scan
    /// reports streamed words, modelled from shape for the resonator).
    pub flops: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
}

impl KernelWork {
    pub fn merge(&mut self, other: &KernelWork) {
        self.calls += other.calls;
        self.elapsed_s += other.elapsed_s;
        self.flops += other.flops;
        self.bytes_read += other.bytes_read;
        self.bytes_written += other.bytes_written;
    }

    pub fn bytes(&self) -> u64 {
        self.bytes_read + self.bytes_written
    }

    /// Operational intensity (FLOPs per byte) — the roofline x-axis.
    pub fn intensity(&self) -> f64 {
        self.flops as f64 / self.bytes().max(1) as f64
    }

    /// Measured attained throughput, FLOP/s.
    pub fn attained_flops(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.flops as f64 / self.elapsed_s
        } else {
            0.0
        }
    }
}

/// One accounted response, as recorded into the [`TraceRing`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    /// 1-based global sequence number, assigned by the ring at record
    /// time (strictly increasing across drops).
    pub seq: u64,
    pub store: StoreId,
    /// Epoch of the store snapshot this response was sealed against —
    /// which exact item set the answer reflects (see
    /// [`super::registry`]; 0 for error fills that never resolved a
    /// snapshot).
    pub epoch: u64,
    pub kind: RequestKind,
    pub stages: StageSample,
    /// End-to-end latency (admit → accounting), seconds.
    pub total_s: f64,
    /// Served degraded (top-k capped under backlog).
    pub degraded: bool,
    /// Answered from the response cache (zero-width kernel stage).
    pub cache_hit: bool,
}

struct RingState {
    /// Preallocated to `capacity`; grows by `push` (no reallocation)
    /// until full, then overwrites in place.
    buf: Vec<TraceEvent>,
    /// Index of the oldest event once the buffer has wrapped.
    head: usize,
    dropped: u64,
    seq: u64,
}

/// Fixed-capacity ring buffer of [`TraceEvent`]s with drop-oldest
/// overflow semantics and an exact dropped-events counter.
///
/// Recording is a short critical section writing into preallocated
/// storage — zero heap traffic in steady state. Workers share one ring
/// (contention is bounded by the batch rate, not the request rate:
/// recording happens once per response during batch accounting, and the
/// lock is uncontended in the common single-digit-worker case).
pub struct TraceRing {
    capacity: usize,
    state: Mutex<RingState>,
}

impl TraceRing {
    /// `capacity` is clamped to at least 1.
    pub fn new(capacity: usize) -> TraceRing {
        let capacity = capacity.max(1);
        TraceRing {
            capacity,
            state: Mutex::new(RingState {
                buf: Vec::with_capacity(capacity),
                head: 0,
                dropped: 0,
                seq: 0,
            }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, RingState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Record one event. When the ring is full, the oldest event is
    /// overwritten and `dropped` advances by exactly one.
    pub fn record(&self, mut ev: TraceEvent) {
        let mut s = self.lock();
        s.seq += 1;
        ev.seq = s.seq;
        if s.buf.len() < self.capacity {
            s.buf.push(ev);
        } else {
            let head = s.head;
            s.buf[head] = ev;
            s.head = (head + 1) % self.capacity;
            s.dropped += 1;
        }
    }

    /// Events currently held, oldest first, plus the dropped count.
    pub fn snapshot(&self) -> (Vec<TraceEvent>, u64) {
        let s = self.lock();
        let mut out = Vec::with_capacity(s.buf.len());
        out.extend_from_slice(&s.buf[s.head..]);
        out.extend_from_slice(&s.buf[..s.head]);
        (out, s.dropped)
    }

    /// Total events ever recorded (retained + dropped).
    pub fn recorded(&self) -> u64 {
        self.lock().seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn ev(total_ms: u64) -> TraceEvent {
        TraceEvent {
            seq: 0,
            store: StoreId(0),
            epoch: 0,
            kind: RequestKind::Recall,
            stages: StageSample::default(),
            total_s: total_ms as f64 * 1e-3,
            degraded: false,
            cache_hit: false,
        }
    }

    #[test]
    fn stage_sample_is_monotone_and_bounded_by_total() {
        let t0 = Instant::now();
        let mut m = StageMarks::new(t0);
        let t1 = t0 + Duration::from_micros(100);
        let t2 = t1 + Duration::from_micros(50);
        let t3 = t2 + Duration::from_micros(10);
        let t4 = t3 + Duration::from_micros(200);
        let now = t4 + Duration::from_micros(5);
        m.popped = Some(t1);
        m.sealed = Some(t2);
        m.mark_kernel(t3, t4);
        let s = m.sample_at(now);
        assert!((s.queue_s - 100e-6).abs() < 1e-9);
        assert!((s.batch_s - 50e-6).abs() < 1e-9);
        assert!((s.kernel_s - 200e-6).abs() < 1e-9);
        assert!((s.fill_s - 5e-6).abs() < 1e-9);
        let total = now.saturating_duration_since(t0).as_secs_f64();
        // The seal→kernel-start gap (10 µs) is the only unattributed time.
        assert!(s.sum() <= total + 1e-12);
        assert!((total - s.sum() - 10e-6).abs() < 1e-9);
    }

    #[test]
    fn partial_marks_still_decompose_exhaustively() {
        let t0 = Instant::now();
        let m = StageMarks::new(t0);
        let now = t0 + Duration::from_micros(40);
        let s = m.sample_at(now);
        // No marks: everything lands in fill, nothing is negative.
        assert_eq!(s.queue_s, 0.0);
        assert_eq!(s.batch_s, 0.0);
        assert_eq!(s.kernel_s, 0.0);
        assert!((s.fill_s - 40e-6).abs() < 1e-9);
        assert!(s.sum() <= now.saturating_duration_since(t0).as_secs_f64() + 1e-12);
    }

    #[test]
    fn net_in_span_rides_marks_but_stays_out_of_sum() {
        let t0 = Instant::now();
        let mut m = StageMarks::new(t0);
        assert_eq!(m.net_in_s, 0.0);
        m.mark_net_in(250e-6);
        m.popped = Some(t0 + Duration::from_micros(30));
        let now = t0 + Duration::from_micros(100);
        let s = m.sample_at(now);
        assert!((s.net_in_s - 250e-6).abs() < 1e-12);
        // The in-process invariant is unchanged: sum() is bounded by the
        // admit-origin window even though the wire span exceeds it.
        assert!(s.sum() <= now.saturating_duration_since(t0).as_secs_f64() + 1e-12);
        // Negative wire spans (clock weirdness) clamp to zero.
        m.mark_net_in(-1.0);
        assert_eq!(m.net_in_s, 0.0);
    }

    #[test]
    fn ring_keeps_order_below_capacity() {
        let ring = TraceRing::new(8);
        for i in 0..5 {
            ring.record(ev(i));
        }
        let (events, dropped) = ring.snapshot();
        assert_eq!(dropped, 0);
        assert_eq!(events.len(), 5);
        let seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts_exactly() {
        let ring = TraceRing::new(4);
        for i in 0..10 {
            ring.record(ev(i));
        }
        let (events, dropped) = ring.snapshot();
        assert_eq!(dropped, 6, "10 recorded into capacity 4 drops exactly 6");
        assert_eq!(events.len(), 4);
        // Survivors are the newest four, oldest first.
        let seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![7, 8, 9, 10]);
        let totals: Vec<u64> = events
            .iter()
            .map(|e| (e.total_s * 1e3).round() as u64)
            .collect();
        assert_eq!(totals, vec![6, 7, 8, 9]);
        assert_eq!(ring.recorded(), 10);
    }

    #[test]
    fn ring_capacity_clamps_to_one() {
        let ring = TraceRing::new(0);
        assert_eq!(ring.capacity(), 1);
        ring.record(ev(1));
        ring.record(ev(2));
        let (events, dropped) = ring.snapshot();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].seq, 2);
        assert_eq!(dropped, 1);
    }

    #[test]
    fn kernel_work_merges_and_derives() {
        let mut a = KernelWork {
            calls: 1,
            elapsed_s: 0.5,
            flops: 300,
            bytes_read: 700,
            bytes_written: 100,
        };
        let b = KernelWork {
            calls: 2,
            elapsed_s: 0.5,
            flops: 100,
            bytes_read: 200,
            bytes_written: 0,
        };
        a.merge(&b);
        assert_eq!(a.calls, 3);
        assert_eq!(a.flops, 400);
        assert_eq!(a.bytes(), 1000);
        assert!((a.intensity() - 0.4).abs() < 1e-12);
        assert!((a.attained_flops() - 400.0).abs() < 1e-9);
        assert_eq!(KernelWork::default().attained_flops(), 0.0);
        assert_eq!(KernelWork::default().intensity(), 0.0);
    }
}
