//! Sharded codebook / cleanup-memory stores.
//!
//! A codebook is partitioned into contiguous shards (the same
//! [`parallel::split_ranges`] rule the scan threads use), each shard
//! scanned independently — on the caller's thread or fanned out across
//! scoped worker threads — and the per-shard winners merged under the
//! global (score desc, index asc) total order. Merging in ascending shard
//! order with a strict `>` comparison reproduces the unsharded scan's
//! first-wins tie rule exactly, so sharded results are bit-identical to
//! [`BinaryCodebook::nearest`] / [`BinaryCodebook::top_k`] (and the real
//! equivalents) on the whole item set.
//!
//! Per-shard scans run through the bound-pruned kernels
//! ([`BinaryCodebook::nearest_batch_pruned_with`] and friends — see
//! [`crate::vsa::sketch`]), themselves bit-identical to the exhaustive
//! references, and the `_stats` variants surface the merged
//! [`PruneStats`] so the serving engine can report how much of the item
//! memory each batch actually streamed.

use crate::util::parallel;
use crate::vsa::{BinaryCodebook, BinaryHV, PruneStats, RealCodebook, RealHV};
use std::time::Instant;

/// Per-shard timing from one scan: (shard index, seconds busy).
pub type ShardTimings = Vec<(usize, f64)>;

/// Merge per-query candidate lists (already in global-index terms, each
/// sorted by the shared total order) into the global top-k.
fn merge_top_k<S: Copy + PartialOrd>(
    mut candidates: Vec<(usize, S)>,
    k: usize,
) -> Vec<(usize, S)> {
    candidates.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    candidates.truncate(k);
    candidates
}

/// A binary codebook split into contiguous shards.
#[derive(Debug, Clone)]
pub struct ShardedBinaryCodebook {
    shards: Vec<BinaryCodebook>,
    offsets: Vec<usize>,
    dim: usize,
    len: usize,
}

impl ShardedBinaryCodebook {
    /// Partition `cb` into (at most) `n_shards` contiguous shards.
    pub fn partition(cb: &BinaryCodebook, n_shards: usize) -> Self {
        Self::partition_sketched(cb, n_shards, None)
    }

    /// [`Self::partition`] with an explicit per-shard sketch width
    /// (`None` = default), so each shard's sidecar is built exactly once.
    pub fn partition_sketched(
        cb: &BinaryCodebook,
        n_shards: usize,
        sketch_bits: Option<usize>,
    ) -> Self {
        assert!(!cb.is_empty(), "cannot shard an empty codebook");
        let ranges = parallel::split_ranges(cb.len(), n_shards.max(1));
        let mut shards = Vec::with_capacity(ranges.len());
        let mut offsets = Vec::with_capacity(ranges.len());
        // seeds-only sources stay seeds-only: each shard carries its seed
        // sub-slice, never a materialized copy of the rows
        let seeds = if cb.is_ca90() { Some(cb.seeds()) } else { None };
        for r in ranges {
            offsets.push(r.start);
            shards.push(match &seeds {
                Some(sd) => BinaryCodebook::ca90_from_seeds(&sd[r], cb.dim(), sketch_bits),
                None => BinaryCodebook::from_items_sketched(
                    cb.dim(),
                    r.map(|i| cb.item(i).clone()).collect(),
                    sketch_bits,
                ),
            });
        }
        ShardedBinaryCodebook {
            shards,
            offsets,
            dim: cb.dim(),
            len: cb.len(),
        }
    }

    /// Whether every shard is CA-90 (seeds-only) backed.
    pub fn is_ca90(&self) -> bool {
        self.shards.iter().all(|s| s.is_ca90())
    }

    /// Stable backing name for telemetry (shards share one backing).
    pub fn backing_name(&self) -> &'static str {
        self.shards[0].backing_name()
    }

    /// Enable the hierarchical sketch cascade on every shard; true iff a
    /// coarse level is now active on all shards with a sketch.
    pub fn enable_cascade(&mut self, coarse_bits: usize) -> bool {
        let mut any = false;
        for shard in &mut self.shards {
            any |= shard.enable_cascade(coarse_bits);
        }
        any
    }

    /// Resident bytes across all shards' rows (full rows or seed folds).
    pub fn row_resident_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.row_resident_bytes()).sum()
    }

    /// Resident bytes across all shards' sketch sidecars (cascade
    /// levels included).
    pub fn sketch_resident_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.sketch_resident_bytes()).sum()
    }

    /// Total resident bytes (rows + sidecars) across all shards.
    pub fn resident_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.resident_bytes()).sum()
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Global index of shard `s`'s first item.
    pub fn offset(&self, s: usize) -> usize {
        self.offsets[s]
    }

    pub fn shard(&self, s: usize) -> &BinaryCodebook {
        &self.shards[s]
    }

    /// Rebuild every shard's sketch sidecar at an explicit width (the
    /// serving engine's `--sketch-bits` knob); 0 disables the sidecars.
    pub fn set_sketch_bits(&mut self, sketch_bits: usize) {
        for shard in &mut self.shards {
            shard.rebuild_sketch(sketch_bits);
        }
    }

    /// Batched nearest-item search across all shards, scanning shards on
    /// up to `threads` scoped workers. Result `q` is bit-identical to
    /// `full.nearest(&queries[q])` on the unsharded codebook.
    pub fn nearest_batch_with(
        &self,
        queries: &[BinaryHV],
        threads: usize,
    ) -> Vec<(usize, i64)> {
        self.nearest_batch_timed(queries, threads).0
    }

    /// [`Self::nearest_batch_with`] plus per-shard busy time, for the
    /// serving engine's per-shard metrics.
    pub fn nearest_batch_timed(
        &self,
        queries: &[BinaryHV],
        threads: usize,
    ) -> (Vec<(usize, i64)>, ShardTimings) {
        let (best, timings, _) = self.nearest_batch_stats(queries, threads);
        (best, timings)
    }

    /// [`Self::nearest_batch_timed`] plus merged [`PruneStats`] from the
    /// per-shard bound-pruned scans.
    pub fn nearest_batch_stats(
        &self,
        queries: &[BinaryHV],
        threads: usize,
    ) -> (Vec<(usize, i64)>, ShardTimings, PruneStats) {
        if queries.is_empty() {
            return (Vec::new(), Vec::new(), PruneStats::default());
        }
        // Each worker locally merges its shard range; ranges are ascending
        // and merged in order, so ties resolve to the lowest global index.
        let parts = parallel::map_ranges(self.n_shards(), threads, |sr| {
            let mut best: Vec<(usize, i64)> = vec![(0, i64::MIN); queries.len()];
            let mut timings: ShardTimings = Vec::with_capacity(sr.len());
            let mut prune = PruneStats::default();
            for s in sr {
                let t0 = Instant::now();
                let (local, st) = self.shards[s].nearest_batch_pruned_with(queries, 1);
                timings.push((s, t0.elapsed().as_secs_f64()));
                prune.merge(&st);
                let off = self.offsets[s];
                for (b, (idx, score)) in best.iter_mut().zip(local) {
                    if score > b.1 {
                        *b = (off + idx, score);
                    }
                }
            }
            (best, timings, prune)
        });
        let mut merged: Vec<(usize, i64)> = vec![(0, i64::MIN); queries.len()];
        let mut all_timings = Vec::new();
        let mut prune = PruneStats::default();
        for (best, timings, st) in parts {
            for (m, b) in merged.iter_mut().zip(best) {
                if b.1 > m.1 {
                    *m = b;
                }
            }
            all_timings.extend(timings);
            prune.merge(&st);
        }
        (merged, all_timings, prune)
    }

    /// Batched top-`k` across shards: per-shard top-k lists (already in
    /// the shared total order) merged into the global top-k. Result `q`
    /// equals `full.top_k(&queries[q], k)` on the unsharded codebook.
    pub fn top_k_batch_with(
        &self,
        queries: &[BinaryHV],
        k: usize,
        threads: usize,
    ) -> (Vec<Vec<(usize, i64)>>, ShardTimings) {
        let (tops, timings, _) = self.top_k_batch_stats(queries, k, threads);
        (tops, timings)
    }

    /// [`Self::top_k_batch_with`] plus merged [`PruneStats`].
    pub fn top_k_batch_stats(
        &self,
        queries: &[BinaryHV],
        k: usize,
        threads: usize,
    ) -> (Vec<Vec<(usize, i64)>>, ShardTimings, PruneStats) {
        if queries.is_empty() || k == 0 {
            return (
                queries.iter().map(|_| Vec::new()).collect(),
                Vec::new(),
                PruneStats::default(),
            );
        }
        let parts = parallel::map_ranges(self.n_shards(), threads, |sr| {
            let mut cands: Vec<Vec<(usize, i64)>> =
                queries.iter().map(|_| Vec::with_capacity(k * sr.len())).collect();
            let mut timings: ShardTimings = Vec::with_capacity(sr.len());
            let mut prune = PruneStats::default();
            let mut order = Vec::new();
            for s in sr {
                let t0 = Instant::now();
                let off = self.offsets[s];
                for (q, query) in queries.iter().enumerate() {
                    cands[q].extend(
                        self.shards[s]
                            .top_k_pruned_with_buf(query, k, &mut prune, &mut order)
                            .into_iter()
                            .map(|(i, sc)| (off + i, sc)),
                    );
                }
                timings.push((s, t0.elapsed().as_secs_f64()));
            }
            (cands, timings, prune)
        });
        let mut per_query: Vec<Vec<(usize, i64)>> = queries.iter().map(|_| Vec::new()).collect();
        let mut all_timings = Vec::new();
        let mut prune = PruneStats::default();
        for (cands, timings, st) in parts {
            for (acc, c) in per_query.iter_mut().zip(cands) {
                acc.extend(c);
            }
            all_timings.extend(timings);
            prune.merge(&st);
        }
        (
            per_query.into_iter().map(|c| merge_top_k(c, k)).collect(),
            all_timings,
            prune,
        )
    }
}

/// A real-valued codebook split into contiguous shards (same merge rule).
#[derive(Debug, Clone)]
pub struct ShardedRealCodebook {
    shards: Vec<RealCodebook>,
    offsets: Vec<usize>,
    dim: usize,
    len: usize,
}

impl ShardedRealCodebook {
    pub fn partition(cb: &RealCodebook, n_shards: usize) -> Self {
        assert!(!cb.is_empty(), "cannot shard an empty codebook");
        let ranges = parallel::split_ranges(cb.len(), n_shards.max(1));
        let mut shards = Vec::with_capacity(ranges.len());
        let mut offsets = Vec::with_capacity(ranges.len());
        for r in ranges {
            offsets.push(r.start);
            shards.push(RealCodebook::from_items(
                cb.dim(),
                r.map(|i| cb.item(i).clone()).collect(),
            ));
        }
        ShardedRealCodebook {
            shards,
            offsets,
            dim: cb.dim(),
            len: cb.len(),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Batched nearest across shards; result `q` equals the unsharded
    /// `nearest(&queries[q])` (first-wins ties).
    pub fn nearest_batch_with(&self, queries: &[RealHV], threads: usize) -> Vec<(usize, f64)> {
        if queries.is_empty() {
            return Vec::new();
        }
        let parts = parallel::map_ranges(self.n_shards(), threads, |sr| {
            let mut best: Vec<(usize, f64)> = vec![(0, f64::NEG_INFINITY); queries.len()];
            for s in sr {
                let (local, _) = self.shards[s].nearest_batch_pruned_with(queries, 1);
                let off = self.offsets[s];
                for (b, (idx, score)) in best.iter_mut().zip(local) {
                    if score > b.1 {
                        *b = (off + idx, score);
                    }
                }
            }
            best
        });
        let mut merged: Vec<(usize, f64)> = vec![(0, f64::NEG_INFINITY); queries.len()];
        for best in parts {
            for (m, b) in merged.iter_mut().zip(best) {
                if b.1 > m.1 {
                    *m = b;
                }
            }
        }
        merged
    }

    /// Batched top-`k` across shards; result `q` equals the unsharded
    /// `top_k(&queries[q], k)`.
    pub fn top_k_batch_with(
        &self,
        queries: &[RealHV],
        k: usize,
        threads: usize,
    ) -> Vec<Vec<(usize, f64)>> {
        if queries.is_empty() || k == 0 {
            return queries.iter().map(|_| Vec::new()).collect();
        }
        let parts = parallel::map_ranges(self.n_shards(), threads, |sr| {
            let mut cands: Vec<Vec<(usize, f64)>> =
                queries.iter().map(|_| Vec::with_capacity(k * sr.len())).collect();
            let mut prune = PruneStats::default();
            let (mut qnorms, mut order) = (Vec::new(), Vec::new());
            for s in sr {
                let off = self.offsets[s];
                for (q, query) in queries.iter().enumerate() {
                    cands[q].extend(
                        self.shards[s]
                            .top_k_pruned_with_bufs(query, k, &mut prune, &mut qnorms, &mut order)
                            .into_iter()
                            .map(|(i, sc)| (off + i, sc)),
                    );
                }
            }
            cands
        });
        let mut per_query: Vec<Vec<(usize, f64)>> = queries.iter().map(|_| Vec::new()).collect();
        for cands in parts {
            for (acc, c) in per_query.iter_mut().zip(cands) {
                acc.extend(c);
            }
        }
        per_query.into_iter().map(|c| merge_top_k(c, k)).collect()
    }
}

/// Sharded cleanup memory: the serving engine's item store. Scores are
/// normalized to cosine exactly like [`crate::vsa::CleanupMemory`].
#[derive(Debug, Clone)]
pub struct ShardedCleanup {
    store: ShardedBinaryCodebook,
}

impl ShardedCleanup {
    pub fn partition(cb: &BinaryCodebook, n_shards: usize) -> Self {
        ShardedCleanup {
            store: ShardedBinaryCodebook::partition(cb, n_shards),
        }
    }

    /// [`Self::partition`] with an explicit sketch width for every shard
    /// (`None` = default) — the serving engine's `--sketch-bits` path.
    pub fn partition_sketched(
        cb: &BinaryCodebook,
        n_shards: usize,
        sketch_bits: Option<usize>,
    ) -> Self {
        ShardedCleanup {
            store: ShardedBinaryCodebook::partition_sketched(cb, n_shards, sketch_bits),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.store.n_shards()
    }

    pub fn len(&self) -> usize {
        self.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.store.dim()
    }

    pub fn store(&self) -> &ShardedBinaryCodebook {
        &self.store
    }

    /// Rebuild every shard's sketch at an explicit width (0 disables).
    pub fn set_sketch_bits(&mut self, sketch_bits: usize) {
        self.store.set_sketch_bits(sketch_bits);
    }

    /// Enable the hierarchical sketch cascade on every shard.
    pub fn enable_cascade(&mut self, coarse_bits: usize) -> bool {
        self.store.enable_cascade(coarse_bits)
    }

    /// Whether the item store is CA-90 (seeds-only) backed.
    pub fn is_ca90(&self) -> bool {
        self.store.is_ca90()
    }

    /// Stable backing name for telemetry.
    pub fn backing_name(&self) -> &'static str {
        self.store.backing_name()
    }

    /// Resident bytes of the rows (full rows or seed folds).
    pub fn row_resident_bytes(&self) -> usize {
        self.store.row_resident_bytes()
    }

    /// Resident bytes of the sketch sidecars (cascade levels included).
    pub fn sketch_resident_bytes(&self) -> usize {
        self.store.sketch_resident_bytes()
    }

    /// Total resident bytes (rows + sidecars).
    pub fn resident_bytes(&self) -> usize {
        self.store.resident_bytes()
    }

    /// Batched recall; result `q` is bit-identical to
    /// `CleanupMemory::recall(&queries[q])` on the unsharded codebook.
    pub fn recall_batch_timed(
        &self,
        queries: &[BinaryHV],
        threads: usize,
    ) -> (Vec<(usize, f64)>, ShardTimings) {
        let (best, timings, _) = self.recall_batch_stats(queries, threads);
        (best, timings)
    }

    /// [`Self::recall_batch_timed`] plus merged [`PruneStats`] — what the
    /// serving engine records per batch.
    pub fn recall_batch_stats(
        &self,
        queries: &[BinaryHV],
        threads: usize,
    ) -> (Vec<(usize, f64)>, ShardTimings, PruneStats) {
        let d = self.store.dim() as f64;
        let (best, timings, prune) = self.store.nearest_batch_stats(queries, threads);
        (
            best.into_iter()
                .map(|(idx, score)| (idx, score as f64 / d))
                .collect(),
            timings,
            prune,
        )
    }

    /// Batched top-`k` recall; result `q` is bit-identical to
    /// `CleanupMemory::recall_topk(&queries[q], k)`.
    pub fn recall_topk_batch_timed(
        &self,
        queries: &[BinaryHV],
        k: usize,
        threads: usize,
    ) -> (Vec<Vec<(usize, f64)>>, ShardTimings) {
        let (tops, timings, _) = self.recall_topk_batch_stats(queries, k, threads);
        (tops, timings)
    }

    /// [`Self::recall_topk_batch_timed`] plus merged [`PruneStats`].
    pub fn recall_topk_batch_stats(
        &self,
        queries: &[BinaryHV],
        k: usize,
        threads: usize,
    ) -> (Vec<Vec<(usize, f64)>>, ShardTimings, PruneStats) {
        let d = self.store.dim() as f64;
        let (tops, timings, prune) = self.store.top_k_batch_stats(queries, k, threads);
        (
            tops.into_iter()
                .map(|top| {
                    top.into_iter()
                        .map(|(idx, score)| (idx, score as f64 / d))
                        .collect()
                })
                .collect(),
            timings,
            prune,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::vsa::CleanupMemory;

    #[test]
    fn binary_shard_merge_matches_unsharded() {
        let mut rng = Rng::new(1);
        let cb = BinaryCodebook::random(&mut rng, 53, 1024);
        let queries: Vec<BinaryHV> =
            (0..17).map(|_| BinaryHV::random(&mut rng, 1024)).collect();
        for n_shards in [1usize, 2, 4, 7, 53, 100] {
            let sharded = ShardedBinaryCodebook::partition(&cb, n_shards);
            assert_eq!(sharded.len(), 53);
            for threads in [1usize, 3] {
                let (nb, timings) = sharded.nearest_batch_timed(&queries, threads);
                assert_eq!(timings.len(), sharded.n_shards());
                for (q, query) in queries.iter().enumerate() {
                    assert_eq!(nb[q], cb.nearest(query), "shards={n_shards} q={q}");
                }
                let (tk, _) = sharded.top_k_batch_with(&queries, 5, threads);
                for (q, query) in queries.iter().enumerate() {
                    assert_eq!(tk[q], cb.top_k(query, 5), "shards={n_shards} q={q}");
                }
            }
        }
    }

    #[test]
    fn binary_shard_merge_preserves_tie_rule() {
        // duplicate items across shard boundaries force exact ties
        let mut rng = Rng::new(2);
        let a = BinaryHV::random(&mut rng, 512);
        let b = BinaryHV::random(&mut rng, 512);
        let items = vec![b.clone(), a.clone(), b.clone(), a.clone(), a.clone()];
        let cb = BinaryCodebook::from_items(512, items);
        let sharded = ShardedBinaryCodebook::partition(&cb, 3);
        let (nb, _) = sharded.nearest_batch_timed(std::slice::from_ref(&a), 2);
        assert_eq!(nb[0], cb.nearest(&a));
        assert_eq!(nb[0].0, 1, "lowest-index duplicate must win across shards");
        let (tk, _) = sharded.top_k_batch_with(std::slice::from_ref(&a), 4, 2);
        assert_eq!(tk[0], cb.top_k(&a, 4));
        assert_eq!(
            tk[0].iter().map(|&(i, _)| i).collect::<Vec<_>>()[..3],
            [1, 3, 4],
            "ties must rank by ascending global index"
        );
    }

    #[test]
    fn real_shard_merge_matches_unsharded() {
        let mut rng = Rng::new(3);
        let cb = RealCodebook::random_bipolar(&mut rng, 29, 512);
        let queries: Vec<RealHV> =
            (0..9).map(|_| RealHV::random_bipolar(&mut rng, 512)).collect();
        for n_shards in [1usize, 3, 5, 29] {
            let sharded = ShardedRealCodebook::partition(&cb, n_shards);
            for threads in [1usize, 2] {
                let nb = sharded.nearest_batch_with(&queries, threads);
                let tk = sharded.top_k_batch_with(&queries, 4, threads);
                for (q, query) in queries.iter().enumerate() {
                    assert_eq!(nb[q], cb.nearest(query), "shards={n_shards} q={q}");
                    assert_eq!(tk[q], cb.top_k(query, 4), "shards={n_shards} q={q}");
                }
            }
        }
    }

    #[test]
    fn sharded_cleanup_matches_cleanup_memory() {
        let mut rng = Rng::new(4);
        let cb = BinaryCodebook::random(&mut rng, 40, 2048);
        let cm = CleanupMemory::new(cb.clone());
        let sharded = ShardedCleanup::partition(&cb, 4);
        let queries: Vec<BinaryHV> =
            (0..11).map(|_| BinaryHV::random(&mut rng, 2048)).collect();
        let (recalls, _) = sharded.recall_batch_timed(&queries, 2);
        let (tops, _) = sharded.recall_topk_batch_timed(&queries, 3, 2);
        for (q, query) in queries.iter().enumerate() {
            assert_eq!(recalls[q], cm.recall(query), "q={q}");
            assert_eq!(tops[q], cm.recall_topk(query, 3), "q={q}");
        }
    }

    #[test]
    fn sharded_stats_variants_match_and_report_pruning() {
        let mut rng = Rng::new(6);
        let cb = BinaryCodebook::random(&mut rng, 48, 2048);
        let cm = CleanupMemory::new(cb.clone());
        let mut sharded = ShardedCleanup::partition(&cb, 4);
        // noisy member queries: the distribution pruning pays off on
        let queries: Vec<BinaryHV> = (0..10)
            .map(|i| {
                let mut q = cb.item(i * 4).clone();
                for j in rng.sample_indices(2048, 409) {
                    q.set(j, !q.get(j));
                }
                q
            })
            .collect();
        let (recalls, timings, prune) = sharded.recall_batch_stats(&queries, 2);
        assert_eq!(timings.len(), 4);
        assert_eq!(prune.items, 10 * 48);
        assert!(prune.words_streamed < prune.words_total, "{prune:?}");
        let (tops, _, tprune) = sharded.recall_topk_batch_stats(&queries, 3, 2);
        assert_eq!(tprune.items, 10 * 48);
        for (q, query) in queries.iter().enumerate() {
            assert_eq!(recalls[q], cm.recall(query), "q={q}");
            assert_eq!(tops[q], cm.recall_topk(query, 3), "q={q}");
        }
        // explicit sketch width (and disabling) stays bit-identical
        for bits in [1024usize, 0] {
            sharded.set_sketch_bits(bits);
            let (recalls, _, _) = sharded.recall_batch_stats(&queries, 2);
            for (q, query) in queries.iter().enumerate() {
                assert_eq!(recalls[q], cm.recall(query), "bits={bits} q={q}");
            }
        }
    }

    #[test]
    fn ca90_sharding_matches_ram_sharding_bit_for_bit() {
        let mut rng = Rng::new(7);
        let seeds: Vec<Vec<u64>> = (0..41)
            .map(|_| (0..8).map(|_| rng.next_u64()).collect())
            .collect();
        let ca = BinaryCodebook::ca90_from_seeds(&seeds, 4096, Some(512));
        let ram = ca.materialized();
        let queries: Vec<BinaryHV> =
            (0..9).map(|_| BinaryHV::random(&mut rng, 4096)).collect();
        for n_shards in [1usize, 3, 6] {
            let sc = ShardedBinaryCodebook::partition_sketched(&ca, n_shards, Some(512));
            let sr = ShardedBinaryCodebook::partition_sketched(&ram, n_shards, Some(512));
            assert!(sc.is_ca90());
            assert_eq!(sc.backing_name(), "ca90");
            assert!(!sr.is_ca90());
            // shards hold seeds, not rows: 8x smaller at 4096/512
            assert_eq!(sc.row_resident_bytes() * 8, sr.row_resident_bytes());
            assert_eq!(sc.sketch_resident_bytes(), sr.sketch_resident_bytes());
            for threads in [1usize, 2] {
                let (na, _, _) = sc.nearest_batch_stats(&queries, threads);
                let (nr, _, _) = sr.nearest_batch_stats(&queries, threads);
                assert_eq!(na, nr, "shards={n_shards} threads={threads}");
                let (ta, _, _) = sc.top_k_batch_stats(&queries, 4, threads);
                let (tr, _, _) = sr.top_k_batch_stats(&queries, 4, threads);
                assert_eq!(ta, tr, "shards={n_shards} threads={threads}");
                for (q, query) in queries.iter().enumerate() {
                    assert_eq!(na[q], ram.nearest(query), "q={q}");
                    assert_eq!(ta[q], ram.top_k(query, 4), "q={q}");
                }
            }
        }
    }

    #[test]
    fn cascade_sharding_stays_bit_identical_and_tallies_coarse_rejects() {
        let mut rng = Rng::new(8);
        let cb = BinaryCodebook::random(&mut rng, 64, 8192);
        let cm = CleanupMemory::new(cb.clone());
        let mut sharded = ShardedCleanup::partition_sketched(&cb, 4, Some(512));
        assert!(sharded.enable_cascade(128));
        // noisy member queries: the distribution bulk rejection pays on
        let queries: Vec<BinaryHV> = (0..8)
            .map(|i| {
                let mut q = cb.item(i * 7).clone();
                for j in rng.sample_indices(8192, 800) {
                    q.set(j, !q.get(j));
                }
                q
            })
            .collect();
        let (recalls, _, prune) = sharded.recall_batch_stats(&queries, 2);
        let (tops, _, _) = sharded.recall_topk_batch_stats(&queries, 3, 2);
        for (q, query) in queries.iter().enumerate() {
            assert_eq!(recalls[q], cm.recall(query), "q={q}");
            assert_eq!(tops[q], cm.recall_topk(query, 3), "q={q}");
        }
        assert!(
            prune.coarse_rejected > 0,
            "cascade must bulk-reject on the coarse level: {prune:?}"
        );
    }

    #[test]
    fn oversharding_clamps_to_item_count() {
        let mut rng = Rng::new(5);
        let cb = BinaryCodebook::random(&mut rng, 3, 256);
        let sharded = ShardedBinaryCodebook::partition(&cb, 16);
        assert_eq!(sharded.n_shards(), 3);
        assert_eq!(sharded.offset(2), 2);
        assert_eq!(sharded.shard(1).len(), 1);
    }
}
