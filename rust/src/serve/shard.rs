//! Sharded codebook / cleanup-memory stores.
//!
//! A codebook is partitioned into contiguous shards (the same
//! [`parallel::split_ranges`] rule the scan threads use), each shard
//! scanned independently — on the caller's thread or fanned out across
//! scoped worker threads — and the per-shard winners merged under the
//! global (score desc, index asc) total order. Merging in ascending shard
//! order with a strict `>` comparison reproduces the unsharded scan's
//! first-wins tie rule exactly, so sharded results are bit-identical to
//! [`BinaryCodebook::nearest`] / [`BinaryCodebook::top_k`] (and the real
//! equivalents) on the whole item set.

use crate::util::parallel;
use crate::vsa::{BinaryCodebook, BinaryHV, RealCodebook, RealHV};
use std::time::Instant;

/// Per-shard timing from one scan: (shard index, seconds busy).
pub type ShardTimings = Vec<(usize, f64)>;

/// Merge per-query candidate lists (already in global-index terms, each
/// sorted by the shared total order) into the global top-k.
fn merge_top_k<S: Copy + PartialOrd>(
    mut candidates: Vec<(usize, S)>,
    k: usize,
) -> Vec<(usize, S)> {
    candidates.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    candidates.truncate(k);
    candidates
}

/// A binary codebook split into contiguous shards.
#[derive(Debug, Clone)]
pub struct ShardedBinaryCodebook {
    shards: Vec<BinaryCodebook>,
    offsets: Vec<usize>,
    dim: usize,
    len: usize,
}

impl ShardedBinaryCodebook {
    /// Partition `cb` into (at most) `n_shards` contiguous shards.
    pub fn partition(cb: &BinaryCodebook, n_shards: usize) -> Self {
        assert!(!cb.is_empty(), "cannot shard an empty codebook");
        let ranges = parallel::split_ranges(cb.len(), n_shards.max(1));
        let mut shards = Vec::with_capacity(ranges.len());
        let mut offsets = Vec::with_capacity(ranges.len());
        for r in ranges {
            offsets.push(r.start);
            shards.push(BinaryCodebook::from_items(
                cb.dim(),
                r.map(|i| cb.item(i).clone()).collect(),
            ));
        }
        ShardedBinaryCodebook {
            shards,
            offsets,
            dim: cb.dim(),
            len: cb.len(),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Global index of shard `s`'s first item.
    pub fn offset(&self, s: usize) -> usize {
        self.offsets[s]
    }

    pub fn shard(&self, s: usize) -> &BinaryCodebook {
        &self.shards[s]
    }

    /// Batched nearest-item search across all shards, scanning shards on
    /// up to `threads` scoped workers. Result `q` is bit-identical to
    /// `full.nearest(&queries[q])` on the unsharded codebook.
    pub fn nearest_batch_with(
        &self,
        queries: &[BinaryHV],
        threads: usize,
    ) -> Vec<(usize, i64)> {
        self.nearest_batch_timed(queries, threads).0
    }

    /// [`Self::nearest_batch_with`] plus per-shard busy time, for the
    /// serving engine's per-shard metrics.
    pub fn nearest_batch_timed(
        &self,
        queries: &[BinaryHV],
        threads: usize,
    ) -> (Vec<(usize, i64)>, ShardTimings) {
        if queries.is_empty() {
            return (Vec::new(), Vec::new());
        }
        // Each worker locally merges its shard range; ranges are ascending
        // and merged in order, so ties resolve to the lowest global index.
        let parts = parallel::map_ranges(self.n_shards(), threads, |sr| {
            let mut best: Vec<(usize, i64)> = vec![(0, i64::MIN); queries.len()];
            let mut timings: ShardTimings = Vec::with_capacity(sr.len());
            for s in sr {
                let t0 = Instant::now();
                let local = self.shards[s].nearest_batch_with(queries, 1);
                timings.push((s, t0.elapsed().as_secs_f64()));
                let off = self.offsets[s];
                for (b, (idx, score)) in best.iter_mut().zip(local) {
                    if score > b.1 {
                        *b = (off + idx, score);
                    }
                }
            }
            (best, timings)
        });
        let mut merged: Vec<(usize, i64)> = vec![(0, i64::MIN); queries.len()];
        let mut all_timings = Vec::new();
        for (best, timings) in parts {
            for (m, b) in merged.iter_mut().zip(best) {
                if b.1 > m.1 {
                    *m = b;
                }
            }
            all_timings.extend(timings);
        }
        (merged, all_timings)
    }

    /// Batched top-`k` across shards: per-shard top-k lists (already in
    /// the shared total order) merged into the global top-k. Result `q`
    /// equals `full.top_k(&queries[q], k)` on the unsharded codebook.
    pub fn top_k_batch_with(
        &self,
        queries: &[BinaryHV],
        k: usize,
        threads: usize,
    ) -> (Vec<Vec<(usize, i64)>>, ShardTimings) {
        if queries.is_empty() || k == 0 {
            return (queries.iter().map(|_| Vec::new()).collect(), Vec::new());
        }
        let parts = parallel::map_ranges(self.n_shards(), threads, |sr| {
            let mut cands: Vec<Vec<(usize, i64)>> =
                queries.iter().map(|_| Vec::with_capacity(k * sr.len())).collect();
            let mut timings: ShardTimings = Vec::with_capacity(sr.len());
            for s in sr {
                let t0 = Instant::now();
                let off = self.offsets[s];
                for (q, query) in queries.iter().enumerate() {
                    cands[q].extend(
                        self.shards[s]
                            .top_k(query, k)
                            .into_iter()
                            .map(|(i, sc)| (off + i, sc)),
                    );
                }
                timings.push((s, t0.elapsed().as_secs_f64()));
            }
            (cands, timings)
        });
        let mut per_query: Vec<Vec<(usize, i64)>> = queries.iter().map(|_| Vec::new()).collect();
        let mut all_timings = Vec::new();
        for (cands, timings) in parts {
            for (acc, c) in per_query.iter_mut().zip(cands) {
                acc.extend(c);
            }
            all_timings.extend(timings);
        }
        (
            per_query.into_iter().map(|c| merge_top_k(c, k)).collect(),
            all_timings,
        )
    }
}

/// A real-valued codebook split into contiguous shards (same merge rule).
#[derive(Debug, Clone)]
pub struct ShardedRealCodebook {
    shards: Vec<RealCodebook>,
    offsets: Vec<usize>,
    dim: usize,
    len: usize,
}

impl ShardedRealCodebook {
    pub fn partition(cb: &RealCodebook, n_shards: usize) -> Self {
        assert!(!cb.is_empty(), "cannot shard an empty codebook");
        let ranges = parallel::split_ranges(cb.len(), n_shards.max(1));
        let mut shards = Vec::with_capacity(ranges.len());
        let mut offsets = Vec::with_capacity(ranges.len());
        for r in ranges {
            offsets.push(r.start);
            shards.push(RealCodebook::from_items(
                cb.dim(),
                r.map(|i| cb.item(i).clone()).collect(),
            ));
        }
        ShardedRealCodebook {
            shards,
            offsets,
            dim: cb.dim(),
            len: cb.len(),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Batched nearest across shards; result `q` equals the unsharded
    /// `nearest(&queries[q])` (first-wins ties).
    pub fn nearest_batch_with(&self, queries: &[RealHV], threads: usize) -> Vec<(usize, f64)> {
        if queries.is_empty() {
            return Vec::new();
        }
        let parts = parallel::map_ranges(self.n_shards(), threads, |sr| {
            let mut best: Vec<(usize, f64)> = vec![(0, f64::NEG_INFINITY); queries.len()];
            for s in sr {
                let local = self.shards[s].nearest_batch_with(queries, 1);
                let off = self.offsets[s];
                for (b, (idx, score)) in best.iter_mut().zip(local) {
                    if score > b.1 {
                        *b = (off + idx, score);
                    }
                }
            }
            best
        });
        let mut merged: Vec<(usize, f64)> = vec![(0, f64::NEG_INFINITY); queries.len()];
        for best in parts {
            for (m, b) in merged.iter_mut().zip(best) {
                if b.1 > m.1 {
                    *m = b;
                }
            }
        }
        merged
    }

    /// Batched top-`k` across shards; result `q` equals the unsharded
    /// `top_k(&queries[q], k)`.
    pub fn top_k_batch_with(
        &self,
        queries: &[RealHV],
        k: usize,
        threads: usize,
    ) -> Vec<Vec<(usize, f64)>> {
        if queries.is_empty() || k == 0 {
            return queries.iter().map(|_| Vec::new()).collect();
        }
        let parts = parallel::map_ranges(self.n_shards(), threads, |sr| {
            let mut cands: Vec<Vec<(usize, f64)>> =
                queries.iter().map(|_| Vec::with_capacity(k * sr.len())).collect();
            for s in sr {
                let off = self.offsets[s];
                for (q, query) in queries.iter().enumerate() {
                    cands[q].extend(
                        self.shards[s]
                            .top_k(query, k)
                            .into_iter()
                            .map(|(i, sc)| (off + i, sc)),
                    );
                }
            }
            cands
        });
        let mut per_query: Vec<Vec<(usize, f64)>> = queries.iter().map(|_| Vec::new()).collect();
        for cands in parts {
            for (acc, c) in per_query.iter_mut().zip(cands) {
                acc.extend(c);
            }
        }
        per_query.into_iter().map(|c| merge_top_k(c, k)).collect()
    }
}

/// Sharded cleanup memory: the serving engine's item store. Scores are
/// normalized to cosine exactly like [`crate::vsa::CleanupMemory`].
#[derive(Debug, Clone)]
pub struct ShardedCleanup {
    store: ShardedBinaryCodebook,
}

impl ShardedCleanup {
    pub fn partition(cb: &BinaryCodebook, n_shards: usize) -> Self {
        ShardedCleanup {
            store: ShardedBinaryCodebook::partition(cb, n_shards),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.store.n_shards()
    }

    pub fn len(&self) -> usize {
        self.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.store.dim()
    }

    pub fn store(&self) -> &ShardedBinaryCodebook {
        &self.store
    }

    /// Batched recall; result `q` is bit-identical to
    /// `CleanupMemory::recall(&queries[q])` on the unsharded codebook.
    pub fn recall_batch_timed(
        &self,
        queries: &[BinaryHV],
        threads: usize,
    ) -> (Vec<(usize, f64)>, ShardTimings) {
        let d = self.store.dim() as f64;
        let (best, timings) = self.store.nearest_batch_timed(queries, threads);
        (
            best.into_iter()
                .map(|(idx, score)| (idx, score as f64 / d))
                .collect(),
            timings,
        )
    }

    /// Batched top-`k` recall; result `q` is bit-identical to
    /// `CleanupMemory::recall_topk(&queries[q], k)`.
    pub fn recall_topk_batch_timed(
        &self,
        queries: &[BinaryHV],
        k: usize,
        threads: usize,
    ) -> (Vec<Vec<(usize, f64)>>, ShardTimings) {
        let d = self.store.dim() as f64;
        let (tops, timings) = self.store.top_k_batch_with(queries, k, threads);
        (
            tops.into_iter()
                .map(|top| {
                    top.into_iter()
                        .map(|(idx, score)| (idx, score as f64 / d))
                        .collect()
                })
                .collect(),
            timings,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::vsa::CleanupMemory;

    #[test]
    fn binary_shard_merge_matches_unsharded() {
        let mut rng = Rng::new(1);
        let cb = BinaryCodebook::random(&mut rng, 53, 1024);
        let queries: Vec<BinaryHV> =
            (0..17).map(|_| BinaryHV::random(&mut rng, 1024)).collect();
        for n_shards in [1usize, 2, 4, 7, 53, 100] {
            let sharded = ShardedBinaryCodebook::partition(&cb, n_shards);
            assert_eq!(sharded.len(), 53);
            for threads in [1usize, 3] {
                let (nb, timings) = sharded.nearest_batch_timed(&queries, threads);
                assert_eq!(timings.len(), sharded.n_shards());
                for (q, query) in queries.iter().enumerate() {
                    assert_eq!(nb[q], cb.nearest(query), "shards={n_shards} q={q}");
                }
                let (tk, _) = sharded.top_k_batch_with(&queries, 5, threads);
                for (q, query) in queries.iter().enumerate() {
                    assert_eq!(tk[q], cb.top_k(query, 5), "shards={n_shards} q={q}");
                }
            }
        }
    }

    #[test]
    fn binary_shard_merge_preserves_tie_rule() {
        // duplicate items across shard boundaries force exact ties
        let mut rng = Rng::new(2);
        let a = BinaryHV::random(&mut rng, 512);
        let b = BinaryHV::random(&mut rng, 512);
        let items = vec![b.clone(), a.clone(), b.clone(), a.clone(), a.clone()];
        let cb = BinaryCodebook::from_items(512, items);
        let sharded = ShardedBinaryCodebook::partition(&cb, 3);
        let (nb, _) = sharded.nearest_batch_timed(std::slice::from_ref(&a), 2);
        assert_eq!(nb[0], cb.nearest(&a));
        assert_eq!(nb[0].0, 1, "lowest-index duplicate must win across shards");
        let (tk, _) = sharded.top_k_batch_with(std::slice::from_ref(&a), 4, 2);
        assert_eq!(tk[0], cb.top_k(&a, 4));
        assert_eq!(
            tk[0].iter().map(|&(i, _)| i).collect::<Vec<_>>()[..3],
            [1, 3, 4],
            "ties must rank by ascending global index"
        );
    }

    #[test]
    fn real_shard_merge_matches_unsharded() {
        let mut rng = Rng::new(3);
        let cb = RealCodebook::random_bipolar(&mut rng, 29, 512);
        let queries: Vec<RealHV> =
            (0..9).map(|_| RealHV::random_bipolar(&mut rng, 512)).collect();
        for n_shards in [1usize, 3, 5, 29] {
            let sharded = ShardedRealCodebook::partition(&cb, n_shards);
            for threads in [1usize, 2] {
                let nb = sharded.nearest_batch_with(&queries, threads);
                let tk = sharded.top_k_batch_with(&queries, 4, threads);
                for (q, query) in queries.iter().enumerate() {
                    assert_eq!(nb[q], cb.nearest(query), "shards={n_shards} q={q}");
                    assert_eq!(tk[q], cb.top_k(query, 4), "shards={n_shards} q={q}");
                }
            }
        }
    }

    #[test]
    fn sharded_cleanup_matches_cleanup_memory() {
        let mut rng = Rng::new(4);
        let cb = BinaryCodebook::random(&mut rng, 40, 2048);
        let cm = CleanupMemory::new(cb.clone());
        let sharded = ShardedCleanup::partition(&cb, 4);
        let queries: Vec<BinaryHV> =
            (0..11).map(|_| BinaryHV::random(&mut rng, 2048)).collect();
        let (recalls, _) = sharded.recall_batch_timed(&queries, 2);
        let (tops, _) = sharded.recall_topk_batch_timed(&queries, 3, 2);
        for (q, query) in queries.iter().enumerate() {
            assert_eq!(recalls[q], cm.recall(query), "q={q}");
            assert_eq!(tops[q], cm.recall_topk(query, 3), "q={q}");
        }
    }

    #[test]
    fn oversharding_clamps_to_item_count() {
        let mut rng = Rng::new(5);
        let cb = BinaryCodebook::random(&mut rng, 3, 256);
        let sharded = ShardedBinaryCodebook::partition(&cb, 16);
        assert_eq!(sharded.n_shards(), 3);
        assert_eq!(sharded.offset(2), 2);
        assert_eq!(sharded.shard(1).len(), 1);
    }
}
