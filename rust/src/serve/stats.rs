//! Serving metrics: per-class latency, per-store / per-shard busy time,
//! batch occupancy, admission outcomes, and throughput.
//!
//! All counters live behind one mutex and are updated once per batch (not
//! per request), so the metrics path stays off the kernel hot loops.
//! Every latency sample and every kernel-call timing is tagged with the
//! [`StoreId`] it served, so multi-store engines can attribute load,
//! pruning, degradation, and cache behavior per tenant.
//!
//! Latency distributions are tracked with O(1)-memory P² streaming
//! quantile estimators ([`crate::util::stats::P2Quantile`]) — one pair
//! (p50, p99) per class and per store — plus running mean/max. Nothing
//! in this module grows with request count: a long-lived engine's stats
//! footprint is constant, and steady-state recording is allocation-free
//! (asserted by `tests/alloc_free.rs`).
//!
//! Poisoned guards are recovered (`unwrap_or_else(|p| p.into_inner())`):
//! all updates here are plain counter arithmetic that cannot be left
//! half-done by a panic elsewhere, and losing metrics must never take
//! down a serving path that survived its own fault.

use super::cache::CacheCounters;
use super::queue::LaneGauge;
use super::registry::StoreId;
use super::shard::ShardTimings;
use super::trace::{KernelWork, StageSample};
use super::RequestKind;
use crate::util::stats::{percentile, P2Quantile};
use crate::vsa::PruneStats;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Latency distribution summary (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub n: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

impl LatencySummary {
    /// Summarize a bounded sample exactly; `None` when empty. (The
    /// engine's own long-run accounting uses the streaming estimators
    /// below; this stays for bounded samples like a loadgen run.)
    pub fn of(xs: &[f64]) -> Option<LatencySummary> {
        if xs.is_empty() {
            return None;
        }
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(LatencySummary {
            n: s.len(),
            mean_s: s.iter().sum::<f64>() / s.len() as f64,
            p50_s: percentile(&s, 0.50),
            p99_s: percentile(&s, 0.99),
            max_s: s[s.len() - 1],
        })
    }
}

/// O(1)-memory latency distribution: running n/mean/max plus P²
/// streaming p50/p99. `record` touches only fixed-size state — no
/// allocation, no growth with request count.
#[derive(Debug, Clone, Copy)]
struct StreamingLatency {
    sum_s: f64,
    max_s: f64,
    p50: P2Quantile,
    p99: P2Quantile,
}

impl StreamingLatency {
    fn new() -> StreamingLatency {
        StreamingLatency {
            sum_s: 0.0,
            max_s: 0.0,
            p50: P2Quantile::new(0.50),
            p99: P2Quantile::new(0.99),
        }
    }

    fn record(&mut self, secs: f64) {
        self.sum_s += secs;
        self.max_s = self.max_s.max(secs);
        self.p50.record(secs);
        self.p99.record(secs);
    }

    fn n(&self) -> u64 {
        self.p50.count()
    }

    fn summary(&self) -> Option<LatencySummary> {
        let n = self.p50.count();
        if n == 0 {
            return None;
        }
        Some(LatencySummary {
            n: n as usize,
            mean_s: self.sum_s / n as f64,
            p50_s: self.p50.value().unwrap_or(0.0),
            p99_s: self.p99.value().unwrap_or(0.0),
            max_s: self.max_s,
        })
    }
}

impl Default for StreamingLatency {
    fn default() -> Self {
        StreamingLatency::new()
    }
}

/// O(1)-memory per-stage latency decomposition for one request class:
/// one [`StreamingLatency`] per lifecycle stage (queue wait, batch wait,
/// kernel, fill) plus the end-to-end total of the same requests, so
/// "p99 = queue + batch + kernel + fill" is directly inspectable. All
/// five estimators see exactly the same requests — their counts agree
/// and their means reconcile (stage means sum to ≤ the total mean, the
/// slack being the unattributed batch-seal → kernel-start gap).
#[derive(Debug, Clone, Copy)]
struct StageAgg {
    queue: StreamingLatency,
    batch: StreamingLatency,
    kernel: StreamingLatency,
    fill: StreamingLatency,
    total: StreamingLatency,
    /// Socket read + decode span preceding admission. Wire-borne
    /// requests only — in-process submissions carry a 0.0 span and are
    /// skipped, so this lane's count is the number of requests that
    /// actually crossed the wire (≤ `total`'s count).
    net_in: StreamingLatency,
    /// Response encode + socket-write span, recorded *after* accounting
    /// by [`ServeStats::record_net_out`] (responses are accounted before
    /// they are written, so this cannot ride the per-batch sample).
    net_out: StreamingLatency,
}

impl StageAgg {
    fn new() -> StageAgg {
        StageAgg {
            queue: StreamingLatency::new(),
            batch: StreamingLatency::new(),
            kernel: StreamingLatency::new(),
            fill: StreamingLatency::new(),
            total: StreamingLatency::new(),
            net_in: StreamingLatency::new(),
            net_out: StreamingLatency::new(),
        }
    }

    fn record(&mut self, sample: &StageSample, total_s: f64) {
        self.queue.record(sample.queue_s);
        self.batch.record(sample.batch_s);
        self.kernel.record(sample.kernel_s);
        self.fill.record(sample.fill_s);
        self.total.record(total_s);
        if sample.net_in_s > 0.0 {
            self.net_in.record(sample.net_in_s);
        }
    }

    fn summary(&self, kind: RequestKind) -> StageSummary {
        StageSummary {
            kind,
            n: self.total.n(),
            queue: self.queue.summary(),
            batch: self.batch.summary(),
            kernel: self.kernel.summary(),
            fill: self.fill.summary(),
            total: self.total.summary(),
            net_in: self.net_in.summary(),
            net_out: self.net_out.summary(),
        }
    }
}

impl Default for StageAgg {
    fn default() -> Self {
        StageAgg::new()
    }
}

/// Snapshot of one request class's stage-latency decomposition
/// (seconds). Each stage is a full distribution summary over the same
/// completed requests as `total`; empty when the class saw no traffic.
#[derive(Debug, Clone)]
pub struct StageSummary {
    pub kind: RequestKind,
    /// Completed requests this decomposition covers.
    pub n: u64,
    /// Admit → queue-pop.
    pub queue: Option<LatencySummary>,
    /// Queue-pop → batch-seal.
    pub batch: Option<LatencySummary>,
    /// Kernel-start → kernel-end (zero-width for cache hits).
    pub kernel: Option<LatencySummary>,
    /// Kernel-end → response accounting/fill.
    pub fill: Option<LatencySummary>,
    /// Admit → accounting (the end-to-end latency of the same requests).
    pub total: Option<LatencySummary>,
    /// Socket read + frame decode span preceding admission. Counts only
    /// wire-borne requests (its `n` ≤ this summary's `n`; `None` for a
    /// purely in-process engine), and sits *outside* the admit-origin
    /// window the other stages decompose — the wire hop the roofline
    /// decomposition was blind to before.
    pub net_in: Option<LatencySummary>,
    /// Response encode + socket-write span following accounting. Counts
    /// only responses actually written back over a connection.
    pub net_out: Option<LatencySummary>,
}

impl StageSummary {
    /// Sum of the four *in-process* stage means — ≤ `total`'s mean by
    /// construction (the decomposition never attributes more time than
    /// elapsed). Wire spans (`net_in`/`net_out`) are deliberately
    /// excluded: they fall outside the admit → accounting window.
    pub fn stage_mean_sum_s(&self) -> f64 {
        [&self.queue, &self.batch, &self.kernel, &self.fill]
            .iter()
            .filter_map(|s| s.map(|x| x.mean_s))
            .sum()
    }
}

/// Resident-memory telemetry for one store's live snapshot: what the
/// row payload, pruning sidecars, and master codebook actually hold in
/// memory. This is the bytes-resident side of the bytes-streamed story
/// the scan [`PruneStats`] tell — a CA-90 seeds-only store shows a
/// `row_bytes` that is `dim / FOLD_BITS` times smaller than its RAM
/// twin while serving bit-identical answers. Layered on by
/// [`super::engine::ServeEngine::stats`] from the registry's live
/// snapshot; `None` in a bare [`ServeStats::snapshot`] or once the
/// store is dropped.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StoreMemory {
    /// Row-payload storage mode of the sharded scan codebooks:
    /// `"ram"` (materialized rows) or `"ca90"` (per-item seed folds,
    /// rows rematerialized inside the scan loop).
    pub backing: &'static str,
    /// Bytes held by the sharded row payload (all shards): materialized
    /// rows for `"ram"`, seed folds for `"ca90"`.
    pub row_bytes: usize,
    /// Bytes held by the pruning sidecars across shards: full sketch
    /// prefix blocks plus the coarse cascade level when enabled.
    pub sketch_bytes: usize,
    /// Bytes held by the store's unsharded master codebook (the mutation
    /// / rebuild source; seeds-only when the backing is `"ca90"`).
    pub master_bytes: usize,
}

impl StoreMemory {
    /// Total resident bytes attributable to this store's item storage.
    pub fn total_bytes(&self) -> usize {
        self.row_bytes + self.sketch_bytes + self.master_bytes
    }
}

/// Per-shard accumulated scan work.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ShardStat {
    /// Batched scans this shard participated in.
    pub scans: u64,
    /// Total seconds a worker spent scanning this shard.
    pub busy_s: f64,
}

/// One store's share of an executed micro-batch: the shard timings and
/// merged scan [`PruneStats`] of the kernel calls issued for that store.
/// Built by [`super::batcher::execute`], one per `(store)` with work in
/// the batch.
#[derive(Debug, Clone, Default)]
pub struct StoreWork {
    pub timings: ShardTimings,
    pub prune: PruneStats,
    /// Measured kernel-call work per request class ([`RequestKind::index`]
    /// order): call counts, wall time, and the FLOP/byte tallies behind
    /// the roofline bridge.
    pub measured: [KernelWork; 3],
}

#[derive(Debug, Default)]
struct StoreInner {
    name: String,
    /// Every completed request's latency (all classes, cache hits
    /// included) — `n()` is the store's completed count. Constant-size
    /// streaming state, not a sample vector.
    lat: StreamingLatency,
    /// Per-class stage-latency decomposition ([`RequestKind::index`]
    /// order) over this store's completed requests.
    stages: [StageAgg; 3],
    /// Per-class measured kernel work ([`RequestKind::index`] order).
    work: [KernelWork; 3],
    shards: Vec<ShardStat>,
    prune: PruneStats,
    /// Admissions refused because *this store's* quota was exhausted
    /// ([`super::ServeError::TenantOverloaded`]).
    rejected_tenant: u64,
    /// Tickets answered [`super::ServeError::DeadlineExceeded`] and
    /// dropped before kernel dispatch.
    expired_dropped: u64,
    /// Requests served (or shed) under this store's degraded mode.
    degraded: u64,
    /// Tickets answered [`super::ServeError::Internal`] after a
    /// contained worker panic.
    internal: u64,
}

#[derive(Debug, Default)]
struct StatsInner {
    recall: StreamingLatency,
    topk: StreamingLatency,
    factorize: StreamingLatency,
    /// Engine-wide per-class stage decomposition ([`RequestKind::index`]
    /// order) — same samples as the per-store aggregations.
    stages: [StageAgg; 3],
    /// Engine-wide per-class measured kernel work.
    work: [KernelWork; 3],
    /// Executed micro-batches and their total occupancy / max size —
    /// running aggregates (the former per-batch size vector was the
    /// other unbounded-memory path here).
    batches: u64,
    batch_occupancy: u64,
    max_batch: usize,
    rejected: u64,
    expired: u64,
    unsupported: u64,
    /// Already-admitted tickets terminated with
    /// [`super::ServeError::ShuttingDown`] by an abort shutdown
    /// (engine drop / `shutdown_now`) instead of being executed.
    shed_shutdown: u64,
    stores: Vec<StoreInner>,
}

/// Shared, thread-safe metrics sink for one engine.
#[derive(Debug)]
pub struct ServeStats {
    inner: Mutex<StatsInner>,
    started: Instant,
}

impl ServeStats {
    /// One `(name, shard count)` pair per registered store, in
    /// [`StoreId`] order.
    pub fn new(stores: &[(&str, usize)]) -> ServeStats {
        ServeStats {
            inner: Mutex::new(StatsInner {
                stores: stores
                    .iter()
                    .map(|&(name, n_shards)| StoreInner {
                        name: name.to_string(),
                        shards: vec![ShardStat::default(); n_shards],
                        ..StoreInner::default()
                    })
                    .collect(),
                ..StatsInner::default()
            }),
            started: Instant::now(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, StatsInner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Append a store section at runtime — the serve-time store-creation
    /// path ([`super::engine::ServeEngine::create_store`]), so a
    /// hot-swapped store's traffic is attributed from its first batch.
    /// Returns the new section's index (== the new store's id).
    pub fn register_store(&self, name: &str, n_shards: usize) -> usize {
        let mut g = self.lock();
        g.stores.push(StoreInner {
            name: name.to_string(),
            shards: vec![ShardStat::default(); n_shards],
            ..StoreInner::default()
        });
        g.stores.len() - 1
    }

    /// Record one executed micro-batch: occupancy, per-request latencies
    /// (queue wait + execution — cache hits included) tagged with the
    /// store they served and decomposed into lifecycle stages, and each
    /// store's kernel-call shard timings, merged scan [`PruneStats`], and
    /// measured per-class [`KernelWork`]. Allocation-free in steady
    /// state.
    pub fn record_batch(
        &self,
        executed: usize,
        latencies: &[(StoreId, RequestKind, Duration, StageSample)],
        store_work: &[(StoreId, StoreWork)],
    ) {
        let mut g = self.lock();
        if executed > 0 {
            g.batches += 1;
            g.batch_occupancy += executed as u64;
            g.max_batch = g.max_batch.max(executed);
        }
        for &(store, kind, lat, stages) in latencies {
            let secs = lat.as_secs_f64();
            match kind {
                RequestKind::Recall => g.recall.record(secs),
                RequestKind::RecallTopK => g.topk.record(secs),
                RequestKind::Factorize => g.factorize.record(secs),
            }
            g.stages[kind.index()].record(&stages, secs);
            if let Some(st) = g.stores.get_mut(store.index()) {
                st.lat.record(secs);
                st.stages[kind.index()].record(&stages, secs);
            }
        }
        for (store, work) in store_work {
            for (i, kw) in work.measured.iter().enumerate() {
                g.work[i].merge(kw);
            }
            if let Some(st) = g.stores.get_mut(store.index()) {
                st.prune.merge(&work.prune);
                for (i, kw) in work.measured.iter().enumerate() {
                    st.work[i].merge(kw);
                }
                for &(s, busy) in &work.timings {
                    if let Some(sh) = st.shards.get_mut(s) {
                        sh.scans += 1;
                        sh.busy_s += busy;
                    }
                }
            }
        }
    }

    /// Global-capacity admission rejection
    /// ([`super::ServeError::Overloaded`]) — every tenant backpressured.
    pub fn record_rejected(&self) {
        self.lock().rejected += 1;
    }

    /// Tenant-quota admission rejection
    /// ([`super::ServeError::TenantOverloaded`]) — charged to the store
    /// that flooded, invisible to the others.
    pub fn record_tenant_rejected(&self, store: StoreId) {
        let mut g = self.lock();
        if let Some(st) = g.stores.get_mut(store.index()) {
            st.rejected_tenant += 1;
        }
    }

    /// `n` of `store`'s tickets expired and were answered without
    /// execution (dropped at batch formation, before kernel dispatch).
    pub fn record_expired(&self, store: StoreId, n: u64) {
        let mut g = self.lock();
        g.expired += n;
        if let Some(st) = g.stores.get_mut(store.index()) {
            st.expired_dropped += n;
        }
    }

    /// `n` of `store`'s requests were served or shed under its degraded
    /// mode.
    pub fn record_degraded(&self, store: StoreId, n: u64) {
        let mut g = self.lock();
        if let Some(st) = g.stores.get_mut(store.index()) {
            st.degraded += n;
        }
    }

    /// `n` of `store`'s tickets were answered
    /// [`super::ServeError::Internal`] after a contained worker panic.
    pub fn record_internal(&self, store: StoreId, n: u64) {
        let mut g = self.lock();
        if let Some(st) = g.stores.get_mut(store.index()) {
            st.internal += n;
        }
    }

    /// Response encode + socket-write span for one wire response,
    /// stamped by `net::server` after the write completes. Responses are
    /// accounted (and their slots filled) *before* the writer drains
    /// them, so the outbound hop cannot ride [`ServeStats::record_batch`]
    /// — it lands here, in the `net_out` lane of the same per-class /
    /// per-store stage decomposition.
    pub fn record_net_out(&self, store: StoreId, kind: RequestKind, secs: f64) {
        let secs = secs.max(0.0);
        let mut g = self.lock();
        g.stages[kind.index()].net_out.record(secs);
        if let Some(st) = g.stores.get_mut(store.index()) {
            st.stages[kind.index()].net_out.record(secs);
        }
    }

    /// Requests refused without execution: unsupported kind, dimension
    /// mismatch, or an unknown store id.
    pub fn record_unsupported(&self, n: u64) {
        self.lock().unsupported += n;
    }

    /// `n` already-admitted tickets were answered
    /// [`super::ServeError::ShuttingDown`] by an abort shutdown — the
    /// teardown path's proof that no waiter was left to spin out its own
    /// timeout.
    pub fn record_shed_shutdown(&self, n: u64) {
        self.lock().shed_shutdown += n;
    }

    /// Snapshot every metric (cheap; constant-size streaming state, no
    /// latency vectors to clone). Per-store cache counters are layered
    /// on by [`super::engine::ServeEngine::stats`], which owns the
    /// registry.
    pub fn snapshot(&self) -> StatsSnapshot {
        let g = self.lock();
        let completed = g.recall.n() + g.topk.n() + g.factorize.n();
        let elapsed = self.started.elapsed().as_secs_f64();
        let stores: Vec<StoreSnapshot> = g
            .stores
            .iter()
            .enumerate()
            .map(|(i, st)| StoreSnapshot {
                id: StoreId(i),
                name: st.name.clone(),
                completed: st.lat.n(),
                latency: st.lat.summary(),
                stages: RequestKind::ALL
                    .iter()
                    .map(|&k| st.stages[k.index()].summary(k))
                    .collect(),
                kernel_work: st.work,
                shards: st.shards.clone(),
                prune: st.prune,
                rejected_tenant: st.rejected_tenant,
                expired_dropped: st.expired_dropped,
                degraded: st.degraded,
                internal: st.internal,
                cache: None,
                epoch: 0,
                live: true,
                memory: None,
            })
            .collect();
        // engine-wide aggregates: shard stats concatenated in store
        // order (identical to the pre-multi-store vector when one store
        // is registered), prune telemetry merged across stores
        let shards: Vec<ShardStat> = stores.iter().flat_map(|s| s.shards.clone()).collect();
        let mut prune = PruneStats::default();
        for s in &stores {
            prune.merge(&s.prune);
        }
        StatsSnapshot {
            completed,
            rejected: g.rejected,
            rejected_tenant: stores.iter().map(|s| s.rejected_tenant).sum(),
            expired: g.expired,
            unsupported: g.unsupported,
            shed_shutdown: g.shed_shutdown,
            degraded: stores.iter().map(|s| s.degraded).sum(),
            internal: stores.iter().map(|s| s.internal).sum(),
            batches: g.batches,
            mean_batch: if g.batches > 0 {
                g.batch_occupancy as f64 / g.batches as f64
            } else {
                0.0
            },
            max_batch: g.max_batch,
            qps: if elapsed > 0.0 {
                completed as f64 / elapsed
            } else {
                0.0
            },
            recall: g.recall.summary(),
            topk: g.topk.summary(),
            factorize: g.factorize.summary(),
            stages: RequestKind::ALL
                .iter()
                .map(|&k| g.stages[k.index()].summary(k))
                .collect(),
            kernel_work: g.work,
            shards,
            prune,
            stores,
            cache: None,
            queue_depth: 0,
            lanes: Vec::new(),
        }
    }
}

/// One store's section of a [`StatsSnapshot`].
#[derive(Debug, Clone)]
pub struct StoreSnapshot {
    pub id: StoreId,
    /// Registration name.
    pub name: String,
    /// Requests this store completed (cache hits included).
    pub completed: u64,
    /// End-to-end latency over this store's completed requests (P²
    /// streaming estimates for p50/p99 once n > 5; exact below).
    pub latency: Option<LatencySummary>,
    /// Per-class stage-latency decomposition
    /// (queue/batch/kernel/fill/total), one entry per [`RequestKind`] in
    /// [`RequestKind::ALL`] order.
    pub stages: Vec<StageSummary>,
    /// Per-class measured kernel work ([`RequestKind::index`] order).
    pub kernel_work: [KernelWork; 3],
    /// This store's shard scan counters.
    pub shards: Vec<ShardStat>,
    /// Merged bound-pruned scan telemetry for this store's kernel calls.
    pub prune: PruneStats,
    /// Admissions refused on this store's own quota
    /// ([`super::ServeError::TenantOverloaded`]).
    pub rejected_tenant: u64,
    /// Tickets answered `DeadlineExceeded` and dropped before dispatch.
    pub expired_dropped: u64,
    /// Requests served or shed under degraded mode.
    pub degraded: u64,
    /// Tickets answered `Internal` after a contained worker panic.
    pub internal: u64,
    /// This store's response-cache counters; `None` when it runs
    /// uncached (filled by [`super::engine::ServeEngine::stats`]).
    pub cache: Option<CacheCounters>,
    /// Latest published snapshot epoch (0 at creation, +1 per serve-time
    /// mutation; for dropped stores, the epoch the store died at).
    /// Layered on by [`super::engine::ServeEngine::stats`], which owns
    /// the registry; 0 from a bare [`ServeStats::snapshot`].
    pub epoch: u64,
    /// Whether the store currently has a published snapshot (`false`
    /// once dropped — its counters stay readable for post-mortems).
    /// Layered on by the engine; `true` from a bare snapshot.
    pub live: bool,
    /// Resident-memory telemetry of the live snapshot (row payload,
    /// sketch sidecars, master codebook) and its storage backing.
    /// Layered on by the engine; `None` from a bare snapshot or once
    /// the store is dropped.
    pub memory: Option<StoreMemory>,
}

/// Point-in-time view of an engine's metrics.
#[derive(Debug, Clone)]
pub struct StatsSnapshot {
    pub completed: u64,
    pub rejected: u64,
    /// Tenant-quota rejections, summed across stores.
    pub rejected_tenant: u64,
    pub expired: u64,
    pub unsupported: u64,
    /// Already-admitted tickets answered `ShuttingDown` by an abort
    /// shutdown (engine drop / `shutdown_now`) instead of executing.
    pub shed_shutdown: u64,
    /// Degraded-mode requests, summed across stores.
    pub degraded: u64,
    /// Contained-panic (`Internal`) answers, summed across stores.
    pub internal: u64,
    pub batches: u64,
    /// Mean requests per executed micro-batch (batch occupancy).
    pub mean_batch: f64,
    pub max_batch: usize,
    /// Completed requests per second since engine start.
    pub qps: f64,
    pub recall: Option<LatencySummary>,
    pub topk: Option<LatencySummary>,
    pub factorize: Option<LatencySummary>,
    /// Engine-wide per-class stage-latency decomposition, one entry per
    /// [`RequestKind`] in [`RequestKind::ALL`] order.
    pub stages: Vec<StageSummary>,
    /// Engine-wide per-class measured kernel work
    /// ([`RequestKind::index`] order).
    pub kernel_work: [KernelWork; 3],
    /// Every store's shard stats, concatenated in [`StoreId`] order
    /// (for single-store engines this is exactly the store's shard set).
    pub shards: Vec<ShardStat>,
    /// Merged bound-pruned scan telemetry across every executed batch
    /// and store.
    pub prune: PruneStats,
    /// Per-store sections, in [`StoreId`] order.
    pub stores: Vec<StoreSnapshot>,
    /// Engine-wide response-cache counters, summed across the stores'
    /// caches; `None` when every store runs uncached (filled by
    /// [`super::engine::ServeEngine::stats`], not by
    /// [`ServeStats::snapshot`]).
    pub cache: Option<CacheCounters>,
    /// Total tickets waiting in the admission queue at snapshot time
    /// (layered on by [`super::engine::ServeEngine::stats`], which owns
    /// the queue; 0 from a bare [`ServeStats::snapshot`]).
    pub queue_depth: usize,
    /// Per-lane depth/deficit gauges at snapshot time (layered on by the
    /// engine; empty from a bare snapshot).
    pub lanes: Vec<LaneGauge>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_ms(queue: f64, batch: f64, kernel: f64, fill: f64) -> StageSample {
        StageSample {
            queue_s: queue * 1e-3,
            batch_s: batch * 1e-3,
            kernel_s: kernel * 1e-3,
            fill_s: fill * 1e-3,
            net_in_s: 0.0,
        }
    }

    #[test]
    fn latency_summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = LatencySummary::of(&xs).unwrap();
        assert_eq!(s.n, 100);
        assert!((s.p50_s - 50.5).abs() < 1e-9);
        assert!(s.p99_s > 98.0 && s.p99_s <= 100.0);
        assert_eq!(s.max_s, 100.0);
        assert!(LatencySummary::of(&[]).is_none());
    }

    #[test]
    fn streaming_latency_matches_exact_for_small_n_and_tracks_large_n() {
        let mut sl = StreamingLatency::new();
        assert!(sl.summary().is_none());
        for x in [3.0, 1.0, 2.0] {
            sl.record(x);
        }
        let s = sl.summary().unwrap();
        assert_eq!(s.n, 3);
        assert!((s.mean_s - 2.0).abs() < 1e-12);
        assert!((s.p50_s - 2.0).abs() < 1e-12, "exact below 5 samples");
        assert_eq!(s.max_s, 3.0);

        // large-n: p50/p99 of a 1..=1000 ramp estimated within a few %
        let mut sl = StreamingLatency::new();
        let n = 1000usize;
        let mut i = 0usize;
        for _ in 0..n {
            sl.record((i + 1) as f64);
            i = (i + 333) % n; // 333 coprime with 1000 -> full cycle
        }
        let s = sl.summary().unwrap();
        assert_eq!(s.n, 1000);
        assert!((s.mean_s - 500.5).abs() < 1e-6);
        assert_eq!(s.max_s, 1000.0);
        assert!((s.p50_s - 500.0).abs() < 30.0, "p50 {}", s.p50_s);
        assert!((s.p99_s - 990.0).abs() < 30.0, "p99 {}", s.p99_s);
    }

    #[test]
    fn batch_occupancy_and_per_store_accounting() {
        let st = ServeStats::new(&[("alpha", 2), ("beta", 1)]);
        let prune = PruneStats {
            items: 6,
            coarse_rejected: 0,
            sketch_rejected: 1,
            early_terminated: 2,
            words_streamed: 40,
            words_total: 96,
        };
        let recall_work = {
            let mut m = [KernelWork::default(); 3];
            m[RequestKind::Recall.index()] = KernelWork {
                calls: 1,
                elapsed_s: 0.001,
                flops: 120,
                bytes_read: 320,
                bytes_written: 16,
            };
            m
        };
        st.record_batch(
            3,
            &[
                (
                    StoreId(0),
                    RequestKind::Recall,
                    Duration::from_millis(1),
                    sample_ms(0.2, 0.3, 0.4, 0.05),
                ),
                (
                    StoreId(0),
                    RequestKind::Recall,
                    Duration::from_millis(3),
                    sample_ms(1.0, 0.5, 1.2, 0.1),
                ),
                (
                    StoreId(1),
                    RequestKind::Factorize,
                    Duration::from_millis(9),
                    sample_ms(2.0, 1.0, 5.0, 0.5),
                ),
            ],
            &[
                (
                    StoreId(0),
                    StoreWork {
                        timings: vec![(0, 0.001), (1, 0.002)],
                        prune,
                        measured: recall_work,
                    },
                ),
                (
                    StoreId(1),
                    StoreWork {
                        timings: vec![(0, 0.004)],
                        prune,
                        measured: recall_work,
                    },
                ),
            ],
        );
        st.record_batch(
            1,
            &[(
                StoreId(0),
                RequestKind::RecallTopK,
                Duration::from_millis(2),
                sample_ms(0.5, 0.5, 0.5, 0.1),
            )],
            &[(
                StoreId(0),
                StoreWork {
                    timings: vec![(0, 0.004)],
                    prune,
                    measured: [KernelWork::default(); 3],
                },
            )],
        );
        st.record_rejected();
        st.record_expired(StoreId(0), 2);
        let s = st.snapshot();
        // engine-wide aggregates merge across stores
        assert_eq!(s.prune.items, 18);
        assert_eq!(s.prune.words_streamed, 120);
        assert!(s.cache.is_none());
        assert_eq!(s.completed, 4);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch - 2.0).abs() < 1e-12);
        assert_eq!(s.max_batch, 3);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.expired, 2);
        // concatenated shard vector: alpha's 2 shards then beta's 1
        assert_eq!(s.shards.len(), 3);
        assert_eq!(s.shards[0].scans, 2);
        assert!((s.shards[0].busy_s - 0.005).abs() < 1e-12);
        assert_eq!(s.shards[1].scans, 1);
        assert_eq!(s.shards[2].scans, 1);
        assert_eq!(s.recall.unwrap().n, 2);
        assert_eq!(s.topk.unwrap().n, 1);
        assert_eq!(s.factorize.unwrap().n, 1);
        // per-store sections
        assert_eq!(s.stores.len(), 2);
        assert_eq!(s.stores[0].name, "alpha");
        assert_eq!(s.stores[0].completed, 3);
        assert_eq!(s.stores[0].prune.items, 12);
        assert_eq!(s.stores[0].latency.unwrap().n, 3);
        assert_eq!(s.stores[0].expired_dropped, 2);
        assert_eq!(s.stores[1].name, "beta");
        assert_eq!(s.stores[1].completed, 1);
        assert_eq!(s.stores[1].prune.items, 6);
        assert_eq!(s.stores[1].shards.len(), 1);
        assert!((s.stores[1].shards[0].busy_s - 0.004).abs() < 1e-12);
        // stage decomposition: per class, per store, and engine-wide
        let eng_recall = &s.stages[RequestKind::Recall.index()];
        assert_eq!(eng_recall.n, 2);
        assert!((eng_recall.queue.unwrap().mean_s - 0.6e-3).abs() < 1e-9);
        assert!((eng_recall.kernel.unwrap().mean_s - 0.8e-3).abs() < 1e-9);
        assert!((eng_recall.total.unwrap().mean_s - 2.0e-3).abs() < 1e-9);
        assert!(
            eng_recall.stage_mean_sum_s() <= eng_recall.total.unwrap().mean_s + 1e-12,
            "stage means must not exceed the end-to-end mean"
        );
        let st0_topk = &s.stores[0].stages[RequestKind::RecallTopK.index()];
        assert_eq!(st0_topk.n, 1);
        assert!((st0_topk.stage_mean_sum_s() - 1.6e-3).abs() < 1e-9);
        let st1_fact = &s.stores[1].stages[RequestKind::Factorize.index()];
        assert_eq!(st1_fact.n, 1);
        assert!((st1_fact.kernel.unwrap().max_s - 5.0e-3).abs() < 1e-9);
        assert_eq!(s.stores[1].stages[RequestKind::Recall.index()].n, 0);
        // measured kernel work merges per store and engine-wide
        let kw = s.stores[0].kernel_work[RequestKind::Recall.index()];
        assert_eq!(kw.calls, 1);
        assert_eq!(kw.flops, 120);
        assert_eq!(kw.bytes(), 336);
        let eng_kw = s.kernel_work[RequestKind::Recall.index()];
        assert_eq!(eng_kw.calls, 2, "both stores' calls merge engine-wide");
        assert_eq!(eng_kw.flops, 240);
        assert_eq!(
            s.kernel_work[RequestKind::RecallTopK.index()].calls,
            0,
            "no topk kernel work recorded"
        );
        // gauges default empty from a bare snapshot (engine layers them)
        assert_eq!(s.queue_depth, 0);
        assert!(s.lanes.is_empty());
    }

    #[test]
    fn net_lanes_count_only_wire_borne_requests() {
        let st = ServeStats::new(&[("wire", 1)]);
        // one wire-borne request (pre-admit read span), one in-process
        let wire_sample = StageSample {
            net_in_s: 0.4e-3,
            ..sample_ms(0.2, 0.1, 0.3, 0.05)
        };
        st.record_batch(
            2,
            &[
                (
                    StoreId(0),
                    RequestKind::Recall,
                    Duration::from_millis(2),
                    wire_sample,
                ),
                (
                    StoreId(0),
                    RequestKind::Recall,
                    Duration::from_millis(1),
                    sample_ms(0.2, 0.1, 0.3, 0.05),
                ),
            ],
            &[],
        );
        st.record_net_out(StoreId(0), RequestKind::Recall, 0.7e-3);
        // defensive: out-of-range store still lands engine-wide,
        // negative spans clamp to zero rather than corrupting the mean
        st.record_net_out(StoreId(9), RequestKind::Recall, -1.0);
        let s = st.snapshot();
        let recall = &s.stages[RequestKind::Recall.index()];
        assert_eq!(recall.n, 2);
        let net_in = recall.net_in.unwrap();
        assert_eq!(net_in.n, 1, "only the wire-borne request counts");
        assert!((net_in.mean_s - 0.4e-3).abs() < 1e-9);
        let net_out = recall.net_out.unwrap();
        assert_eq!(net_out.n, 2);
        assert!((net_out.max_s - 0.7e-3).abs() < 1e-9);
        // wire spans stay out of the in-process decomposition sum
        assert!(
            recall.stage_mean_sum_s() <= recall.total.unwrap().mean_s + 1e-12,
            "net lanes must not leak into the stage decomposition"
        );
        // per-store mirror: net_out for the known store counted once
        let st0 = &s.stores[0].stages[RequestKind::Recall.index()];
        assert_eq!(st0.net_in.unwrap().n, 1);
        assert_eq!(st0.net_out.unwrap().n, 1);
        // classes with no wire traffic stay None
        assert!(s.stages[RequestKind::Factorize.index()].net_in.is_none());
        // memory telemetry is engine-layered: bare snapshots carry None
        assert!(s.stores[0].memory.is_none());
        let mem = StoreMemory {
            backing: "ca90",
            row_bytes: 64,
            sketch_bytes: 32,
            master_bytes: 64,
        };
        assert_eq!(mem.total_bytes(), 160);
    }

    #[test]
    fn register_store_appends_a_section_at_runtime() {
        let st = ServeStats::new(&[("boot", 2)]);
        assert_eq!(st.register_store("hot", 3), 1);
        st.record_batch(
            1,
            &[(
                StoreId(1),
                RequestKind::Recall,
                Duration::from_millis(1),
                StageSample::default(),
            )],
            &[(
                StoreId(1),
                StoreWork {
                    timings: vec![(0, 0.001), (2, 0.002)],
                    prune: PruneStats::default(),
                    measured: [KernelWork::default(); 3],
                },
            )],
        );
        let s = st.snapshot();
        assert_eq!(s.stores.len(), 2);
        assert_eq!(s.stores[1].name, "hot");
        assert_eq!(s.stores[1].completed, 1);
        assert_eq!(s.stores[1].shards.len(), 3);
        assert_eq!(s.stores[1].shards[2].scans, 1);
        // engine-wide shard concatenation includes the late section
        assert_eq!(s.shards.len(), 5);
    }

    #[test]
    fn overload_counters_attribute_per_store() {
        let st = ServeStats::new(&[("a", 1), ("b", 1)]);
        st.record_tenant_rejected(StoreId(0));
        st.record_tenant_rejected(StoreId(0));
        st.record_degraded(StoreId(1), 3);
        st.record_internal(StoreId(1), 4);
        st.record_expired(StoreId(1), 5);
        // out-of-range ids must not panic (defensive, like latencies)
        st.record_tenant_rejected(StoreId(9));
        st.record_degraded(StoreId(9), 1);
        st.record_internal(StoreId(9), 1);
        let s = st.snapshot();
        assert_eq!(s.stores[0].rejected_tenant, 2);
        assert_eq!(s.stores[0].degraded, 0);
        assert_eq!(s.stores[1].degraded, 3);
        assert_eq!(s.stores[1].internal, 4);
        assert_eq!(s.stores[1].expired_dropped, 5);
        assert_eq!(s.rejected_tenant, 2);
        assert_eq!(s.degraded, 3);
        assert_eq!(s.internal, 4);
        assert_eq!(s.expired, 5);
    }

    #[test]
    fn latencies_for_unknown_store_ids_still_count_globally() {
        // defensive: a latency tagged with an out-of-range store id must
        // not panic and must still reach the per-class estimators
        let st = ServeStats::new(&[("only", 1)]);
        st.record_batch(
            1,
            &[(
                StoreId(9),
                RequestKind::Recall,
                Duration::from_millis(1),
                StageSample::default(),
            )],
            &[],
        );
        let s = st.snapshot();
        assert_eq!(s.completed, 1);
        assert_eq!(s.stores[0].completed, 0);
        assert_eq!(
            s.stages[RequestKind::Recall.index()].n,
            1,
            "engine-wide stage decomposition still sees the request"
        );
    }
}
