//! Serving metrics: per-class latency, per-store / per-shard busy time,
//! batch occupancy, admission outcomes, and throughput.
//!
//! All counters live behind one mutex and are updated once per batch (not
//! per request), so the metrics path stays off the kernel hot loops.
//! Every latency sample and every kernel-call timing is tagged with the
//! [`StoreId`] it served, so multi-store engines can attribute load,
//! pruning, and cache behavior per tenant.

use super::cache::CacheCounters;
use super::registry::StoreId;
use super::shard::ShardTimings;
use super::RequestKind;
use crate::util::stats::percentile;
use crate::vsa::PruneStats;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Latency distribution summary (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub n: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

impl LatencySummary {
    /// Summarize a sample of latencies; `None` when empty.
    pub fn of(xs: &[f64]) -> Option<LatencySummary> {
        if xs.is_empty() {
            return None;
        }
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(LatencySummary {
            n: s.len(),
            mean_s: s.iter().sum::<f64>() / s.len() as f64,
            p50_s: percentile(&s, 0.50),
            p99_s: percentile(&s, 0.99),
            max_s: s[s.len() - 1],
        })
    }
}

/// Per-shard accumulated scan work.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ShardStat {
    /// Batched scans this shard participated in.
    pub scans: u64,
    /// Total seconds a worker spent scanning this shard.
    pub busy_s: f64,
}

/// One store's share of an executed micro-batch: the shard timings and
/// merged scan [`PruneStats`] of the kernel calls issued for that store.
/// Built by [`super::batcher::execute`], one per `(store)` with work in
/// the batch.
#[derive(Debug, Clone, Default)]
pub struct StoreWork {
    pub timings: ShardTimings,
    pub prune: PruneStats,
}

#[derive(Debug, Default)]
struct StoreInner {
    name: String,
    /// Every completed request's latency (all classes, cache hits
    /// included) — `len()` is the store's completed count. Like the
    /// per-class vectors below, this stores the full sample for exact
    /// percentiles: fine at bench/load-test scale, a second copy per
    /// request on a truly long-lived engine (the ROADMAP's streaming-
    /// quantile follow-on replaces both).
    lat_s: Vec<f64>,
    shards: Vec<ShardStat>,
    prune: PruneStats,
}

#[derive(Debug, Default)]
struct StatsInner {
    recall_lat_s: Vec<f64>,
    topk_lat_s: Vec<f64>,
    factorize_lat_s: Vec<f64>,
    batch_sizes: Vec<usize>,
    rejected: u64,
    expired: u64,
    unsupported: u64,
    stores: Vec<StoreInner>,
}

/// Shared, thread-safe metrics sink for one engine.
#[derive(Debug)]
pub struct ServeStats {
    inner: Mutex<StatsInner>,
    started: Instant,
}

impl ServeStats {
    /// One `(name, shard count)` pair per registered store, in
    /// [`StoreId`] order.
    pub fn new(stores: &[(&str, usize)]) -> ServeStats {
        ServeStats {
            inner: Mutex::new(StatsInner {
                stores: stores
                    .iter()
                    .map(|&(name, n_shards)| StoreInner {
                        name: name.to_string(),
                        shards: vec![ShardStat::default(); n_shards],
                        ..StoreInner::default()
                    })
                    .collect(),
                ..StatsInner::default()
            }),
            started: Instant::now(),
        }
    }

    /// Record one executed micro-batch: occupancy, per-request latencies
    /// (queue wait + execution — cache hits included) tagged with the
    /// store they served, and each store's kernel-call shard timings and
    /// merged scan [`PruneStats`].
    pub fn record_batch(
        &self,
        executed: usize,
        latencies: &[(StoreId, RequestKind, Duration)],
        store_work: &[(StoreId, StoreWork)],
    ) {
        let mut g = self.inner.lock().expect("stats poisoned");
        if executed > 0 {
            g.batch_sizes.push(executed);
        }
        for &(store, kind, lat) in latencies {
            let secs = lat.as_secs_f64();
            match kind {
                RequestKind::Recall => g.recall_lat_s.push(secs),
                RequestKind::RecallTopK => g.topk_lat_s.push(secs),
                RequestKind::Factorize => g.factorize_lat_s.push(secs),
            }
            if let Some(st) = g.stores.get_mut(store.index()) {
                st.lat_s.push(secs);
            }
        }
        for (store, work) in store_work {
            if let Some(st) = g.stores.get_mut(store.index()) {
                st.prune.merge(&work.prune);
                for &(s, busy) in &work.timings {
                    if let Some(sh) = st.shards.get_mut(s) {
                        sh.scans += 1;
                        sh.busy_s += busy;
                    }
                }
            }
        }
    }

    pub fn record_rejected(&self) {
        self.inner.lock().expect("stats poisoned").rejected += 1;
    }

    pub fn record_expired(&self, n: u64) {
        self.inner.lock().expect("stats poisoned").expired += n;
    }

    /// Requests refused without execution: unsupported kind, dimension
    /// mismatch, or an unknown store id.
    pub fn record_unsupported(&self, n: u64) {
        self.inner.lock().expect("stats poisoned").unsupported += n;
    }

    /// Snapshot every metric (cheap; clones the latency vectors).
    /// Per-store cache counters are layered on by
    /// [`super::engine::ServeEngine::stats`], which owns the registry.
    pub fn snapshot(&self) -> StatsSnapshot {
        let g = self.inner.lock().expect("stats poisoned");
        let completed =
            (g.recall_lat_s.len() + g.topk_lat_s.len() + g.factorize_lat_s.len()) as u64;
        let batches = g.batch_sizes.len() as u64;
        let occupancy: u64 = g.batch_sizes.iter().map(|&b| b as u64).sum();
        let elapsed = self.started.elapsed().as_secs_f64();
        let stores: Vec<StoreSnapshot> = g
            .stores
            .iter()
            .enumerate()
            .map(|(i, st)| StoreSnapshot {
                id: StoreId(i),
                name: st.name.clone(),
                completed: st.lat_s.len() as u64,
                latency: LatencySummary::of(&st.lat_s),
                shards: st.shards.clone(),
                prune: st.prune,
                cache: None,
            })
            .collect();
        // engine-wide aggregates: shard stats concatenated in store
        // order (identical to the pre-multi-store vector when one store
        // is registered), prune telemetry merged across stores
        let shards: Vec<ShardStat> = stores.iter().flat_map(|s| s.shards.clone()).collect();
        let mut prune = PruneStats::default();
        for s in &stores {
            prune.merge(&s.prune);
        }
        StatsSnapshot {
            completed,
            rejected: g.rejected,
            expired: g.expired,
            unsupported: g.unsupported,
            batches,
            mean_batch: if batches > 0 {
                occupancy as f64 / batches as f64
            } else {
                0.0
            },
            max_batch: g.batch_sizes.iter().copied().max().unwrap_or(0),
            qps: if elapsed > 0.0 {
                completed as f64 / elapsed
            } else {
                0.0
            },
            recall: LatencySummary::of(&g.recall_lat_s),
            topk: LatencySummary::of(&g.topk_lat_s),
            factorize: LatencySummary::of(&g.factorize_lat_s),
            shards,
            prune,
            stores,
            cache: None,
        }
    }
}

/// One store's section of a [`StatsSnapshot`].
#[derive(Debug, Clone)]
pub struct StoreSnapshot {
    pub id: StoreId,
    /// Registration name.
    pub name: String,
    /// Requests this store completed (cache hits included).
    pub completed: u64,
    /// End-to-end latency over this store's completed requests.
    pub latency: Option<LatencySummary>,
    /// This store's shard scan counters.
    pub shards: Vec<ShardStat>,
    /// Merged bound-pruned scan telemetry for this store's kernel calls.
    pub prune: PruneStats,
    /// This store's response-cache counters; `None` when it runs
    /// uncached (filled by [`super::engine::ServeEngine::stats`]).
    pub cache: Option<CacheCounters>,
}

/// Point-in-time view of an engine's metrics.
#[derive(Debug, Clone)]
pub struct StatsSnapshot {
    pub completed: u64,
    pub rejected: u64,
    pub expired: u64,
    pub unsupported: u64,
    pub batches: u64,
    /// Mean requests per executed micro-batch (batch occupancy).
    pub mean_batch: f64,
    pub max_batch: usize,
    /// Completed requests per second since engine start.
    pub qps: f64,
    pub recall: Option<LatencySummary>,
    pub topk: Option<LatencySummary>,
    pub factorize: Option<LatencySummary>,
    /// Every store's shard stats, concatenated in [`StoreId`] order
    /// (for single-store engines this is exactly the store's shard set).
    pub shards: Vec<ShardStat>,
    /// Merged bound-pruned scan telemetry across every executed batch
    /// and store.
    pub prune: PruneStats,
    /// Per-store sections, in [`StoreId`] order.
    pub stores: Vec<StoreSnapshot>,
    /// Engine-wide response-cache counters, summed across the stores'
    /// caches; `None` when every store runs uncached (filled by
    /// [`super::engine::ServeEngine::stats`], not by
    /// [`ServeStats::snapshot`]).
    pub cache: Option<CacheCounters>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = LatencySummary::of(&xs).unwrap();
        assert_eq!(s.n, 100);
        assert!((s.p50_s - 50.5).abs() < 1e-9);
        assert!(s.p99_s > 98.0 && s.p99_s <= 100.0);
        assert_eq!(s.max_s, 100.0);
        assert!(LatencySummary::of(&[]).is_none());
    }

    #[test]
    fn batch_occupancy_and_per_store_accounting() {
        let st = ServeStats::new(&[("alpha", 2), ("beta", 1)]);
        let prune = PruneStats {
            items: 6,
            sketch_rejected: 1,
            early_terminated: 2,
            words_streamed: 40,
            words_total: 96,
        };
        st.record_batch(
            3,
            &[
                (StoreId(0), RequestKind::Recall, Duration::from_millis(1)),
                (StoreId(0), RequestKind::Recall, Duration::from_millis(3)),
                (StoreId(1), RequestKind::Factorize, Duration::from_millis(9)),
            ],
            &[
                (
                    StoreId(0),
                    StoreWork {
                        timings: vec![(0, 0.001), (1, 0.002)],
                        prune,
                    },
                ),
                (
                    StoreId(1),
                    StoreWork {
                        timings: vec![(0, 0.004)],
                        prune,
                    },
                ),
            ],
        );
        st.record_batch(
            1,
            &[(StoreId(0), RequestKind::RecallTopK, Duration::from_millis(2))],
            &[(
                StoreId(0),
                StoreWork {
                    timings: vec![(0, 0.004)],
                    prune,
                },
            )],
        );
        st.record_rejected();
        st.record_expired(2);
        let s = st.snapshot();
        // engine-wide aggregates merge across stores
        assert_eq!(s.prune.items, 18);
        assert_eq!(s.prune.words_streamed, 120);
        assert!(s.cache.is_none());
        assert_eq!(s.completed, 4);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch - 2.0).abs() < 1e-12);
        assert_eq!(s.max_batch, 3);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.expired, 2);
        // concatenated shard vector: alpha's 2 shards then beta's 1
        assert_eq!(s.shards.len(), 3);
        assert_eq!(s.shards[0].scans, 2);
        assert!((s.shards[0].busy_s - 0.005).abs() < 1e-12);
        assert_eq!(s.shards[1].scans, 1);
        assert_eq!(s.shards[2].scans, 1);
        assert_eq!(s.recall.unwrap().n, 2);
        assert_eq!(s.topk.unwrap().n, 1);
        assert_eq!(s.factorize.unwrap().n, 1);
        // per-store sections
        assert_eq!(s.stores.len(), 2);
        assert_eq!(s.stores[0].name, "alpha");
        assert_eq!(s.stores[0].completed, 3);
        assert_eq!(s.stores[0].prune.items, 12);
        assert_eq!(s.stores[0].latency.unwrap().n, 3);
        assert_eq!(s.stores[1].name, "beta");
        assert_eq!(s.stores[1].completed, 1);
        assert_eq!(s.stores[1].prune.items, 6);
        assert_eq!(s.stores[1].shards.len(), 1);
        assert!((s.stores[1].shards[0].busy_s - 0.004).abs() < 1e-12);
    }

    #[test]
    fn latencies_for_unknown_store_ids_still_count_globally() {
        // defensive: a latency tagged with an out-of-range store id must
        // not panic and must still reach the per-class vectors
        let st = ServeStats::new(&[("only", 1)]);
        st.record_batch(
            1,
            &[(StoreId(9), RequestKind::Recall, Duration::from_millis(1))],
            &[],
        );
        let s = st.snapshot();
        assert_eq!(s.completed, 1);
        assert_eq!(s.stores[0].completed, 0);
    }
}
