//! Serving metrics: per-class latency, per-store / per-shard busy time,
//! batch occupancy, admission outcomes, and throughput.
//!
//! All counters live behind one mutex and are updated once per batch (not
//! per request), so the metrics path stays off the kernel hot loops.
//! Every latency sample and every kernel-call timing is tagged with the
//! [`StoreId`] it served, so multi-store engines can attribute load,
//! pruning, degradation, and cache behavior per tenant.
//!
//! Latency distributions are tracked with O(1)-memory P² streaming
//! quantile estimators ([`crate::util::stats::P2Quantile`]) — one pair
//! (p50, p99) per class and per store — plus running mean/max. Nothing
//! in this module grows with request count: a long-lived engine's stats
//! footprint is constant, and steady-state recording is allocation-free
//! (asserted by `tests/alloc_free.rs`).
//!
//! Poisoned guards are recovered (`unwrap_or_else(|p| p.into_inner())`):
//! all updates here are plain counter arithmetic that cannot be left
//! half-done by a panic elsewhere, and losing metrics must never take
//! down a serving path that survived its own fault.

use super::cache::CacheCounters;
use super::registry::StoreId;
use super::shard::ShardTimings;
use super::RequestKind;
use crate::util::stats::{percentile, P2Quantile};
use crate::vsa::PruneStats;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Latency distribution summary (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub n: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

impl LatencySummary {
    /// Summarize a bounded sample exactly; `None` when empty. (The
    /// engine's own long-run accounting uses the streaming estimators
    /// below; this stays for bounded samples like a loadgen run.)
    pub fn of(xs: &[f64]) -> Option<LatencySummary> {
        if xs.is_empty() {
            return None;
        }
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(LatencySummary {
            n: s.len(),
            mean_s: s.iter().sum::<f64>() / s.len() as f64,
            p50_s: percentile(&s, 0.50),
            p99_s: percentile(&s, 0.99),
            max_s: s[s.len() - 1],
        })
    }
}

/// O(1)-memory latency distribution: running n/mean/max plus P²
/// streaming p50/p99. `record` touches only fixed-size state — no
/// allocation, no growth with request count.
#[derive(Debug, Clone, Copy)]
struct StreamingLatency {
    sum_s: f64,
    max_s: f64,
    p50: P2Quantile,
    p99: P2Quantile,
}

impl StreamingLatency {
    fn new() -> StreamingLatency {
        StreamingLatency {
            sum_s: 0.0,
            max_s: 0.0,
            p50: P2Quantile::new(0.50),
            p99: P2Quantile::new(0.99),
        }
    }

    fn record(&mut self, secs: f64) {
        self.sum_s += secs;
        self.max_s = self.max_s.max(secs);
        self.p50.record(secs);
        self.p99.record(secs);
    }

    fn n(&self) -> u64 {
        self.p50.count()
    }

    fn summary(&self) -> Option<LatencySummary> {
        let n = self.p50.count();
        if n == 0 {
            return None;
        }
        Some(LatencySummary {
            n: n as usize,
            mean_s: self.sum_s / n as f64,
            p50_s: self.p50.value().unwrap_or(0.0),
            p99_s: self.p99.value().unwrap_or(0.0),
            max_s: self.max_s,
        })
    }
}

impl Default for StreamingLatency {
    fn default() -> Self {
        StreamingLatency::new()
    }
}

/// Per-shard accumulated scan work.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ShardStat {
    /// Batched scans this shard participated in.
    pub scans: u64,
    /// Total seconds a worker spent scanning this shard.
    pub busy_s: f64,
}

/// One store's share of an executed micro-batch: the shard timings and
/// merged scan [`PruneStats`] of the kernel calls issued for that store.
/// Built by [`super::batcher::execute`], one per `(store)` with work in
/// the batch.
#[derive(Debug, Clone, Default)]
pub struct StoreWork {
    pub timings: ShardTimings,
    pub prune: PruneStats,
}

#[derive(Debug, Default)]
struct StoreInner {
    name: String,
    /// Every completed request's latency (all classes, cache hits
    /// included) — `n()` is the store's completed count. Constant-size
    /// streaming state, not a sample vector.
    lat: StreamingLatency,
    shards: Vec<ShardStat>,
    prune: PruneStats,
    /// Admissions refused because *this store's* quota was exhausted
    /// ([`super::ServeError::TenantOverloaded`]).
    rejected_tenant: u64,
    /// Tickets answered [`super::ServeError::DeadlineExceeded`] and
    /// dropped before kernel dispatch.
    expired_dropped: u64,
    /// Requests served (or shed) under this store's degraded mode.
    degraded: u64,
    /// Tickets answered [`super::ServeError::Internal`] after a
    /// contained worker panic.
    internal: u64,
}

#[derive(Debug, Default)]
struct StatsInner {
    recall: StreamingLatency,
    topk: StreamingLatency,
    factorize: StreamingLatency,
    /// Executed micro-batches and their total occupancy / max size —
    /// running aggregates (the former per-batch size vector was the
    /// other unbounded-memory path here).
    batches: u64,
    batch_occupancy: u64,
    max_batch: usize,
    rejected: u64,
    expired: u64,
    unsupported: u64,
    stores: Vec<StoreInner>,
}

/// Shared, thread-safe metrics sink for one engine.
#[derive(Debug)]
pub struct ServeStats {
    inner: Mutex<StatsInner>,
    started: Instant,
}

impl ServeStats {
    /// One `(name, shard count)` pair per registered store, in
    /// [`StoreId`] order.
    pub fn new(stores: &[(&str, usize)]) -> ServeStats {
        ServeStats {
            inner: Mutex::new(StatsInner {
                stores: stores
                    .iter()
                    .map(|&(name, n_shards)| StoreInner {
                        name: name.to_string(),
                        shards: vec![ShardStat::default(); n_shards],
                        ..StoreInner::default()
                    })
                    .collect(),
                ..StatsInner::default()
            }),
            started: Instant::now(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, StatsInner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Record one executed micro-batch: occupancy, per-request latencies
    /// (queue wait + execution — cache hits included) tagged with the
    /// store they served, and each store's kernel-call shard timings and
    /// merged scan [`PruneStats`]. Allocation-free in steady state.
    pub fn record_batch(
        &self,
        executed: usize,
        latencies: &[(StoreId, RequestKind, Duration)],
        store_work: &[(StoreId, StoreWork)],
    ) {
        let mut g = self.lock();
        if executed > 0 {
            g.batches += 1;
            g.batch_occupancy += executed as u64;
            g.max_batch = g.max_batch.max(executed);
        }
        for &(store, kind, lat) in latencies {
            let secs = lat.as_secs_f64();
            match kind {
                RequestKind::Recall => g.recall.record(secs),
                RequestKind::RecallTopK => g.topk.record(secs),
                RequestKind::Factorize => g.factorize.record(secs),
            }
            if let Some(st) = g.stores.get_mut(store.index()) {
                st.lat.record(secs);
            }
        }
        for (store, work) in store_work {
            if let Some(st) = g.stores.get_mut(store.index()) {
                st.prune.merge(&work.prune);
                for &(s, busy) in &work.timings {
                    if let Some(sh) = st.shards.get_mut(s) {
                        sh.scans += 1;
                        sh.busy_s += busy;
                    }
                }
            }
        }
    }

    /// Global-capacity admission rejection
    /// ([`super::ServeError::Overloaded`]) — every tenant backpressured.
    pub fn record_rejected(&self) {
        self.lock().rejected += 1;
    }

    /// Tenant-quota admission rejection
    /// ([`super::ServeError::TenantOverloaded`]) — charged to the store
    /// that flooded, invisible to the others.
    pub fn record_tenant_rejected(&self, store: StoreId) {
        let mut g = self.lock();
        if let Some(st) = g.stores.get_mut(store.index()) {
            st.rejected_tenant += 1;
        }
    }

    /// `n` of `store`'s tickets expired and were answered without
    /// execution (dropped at batch formation, before kernel dispatch).
    pub fn record_expired(&self, store: StoreId, n: u64) {
        let mut g = self.lock();
        g.expired += n;
        if let Some(st) = g.stores.get_mut(store.index()) {
            st.expired_dropped += n;
        }
    }

    /// `n` of `store`'s requests were served or shed under its degraded
    /// mode.
    pub fn record_degraded(&self, store: StoreId, n: u64) {
        let mut g = self.lock();
        if let Some(st) = g.stores.get_mut(store.index()) {
            st.degraded += n;
        }
    }

    /// `n` of `store`'s tickets were answered
    /// [`super::ServeError::Internal`] after a contained worker panic.
    pub fn record_internal(&self, store: StoreId, n: u64) {
        let mut g = self.lock();
        if let Some(st) = g.stores.get_mut(store.index()) {
            st.internal += n;
        }
    }

    /// Requests refused without execution: unsupported kind, dimension
    /// mismatch, or an unknown store id.
    pub fn record_unsupported(&self, n: u64) {
        self.lock().unsupported += n;
    }

    /// Snapshot every metric (cheap; constant-size streaming state, no
    /// latency vectors to clone). Per-store cache counters are layered
    /// on by [`super::engine::ServeEngine::stats`], which owns the
    /// registry.
    pub fn snapshot(&self) -> StatsSnapshot {
        let g = self.lock();
        let completed = g.recall.n() + g.topk.n() + g.factorize.n();
        let elapsed = self.started.elapsed().as_secs_f64();
        let stores: Vec<StoreSnapshot> = g
            .stores
            .iter()
            .enumerate()
            .map(|(i, st)| StoreSnapshot {
                id: StoreId(i),
                name: st.name.clone(),
                completed: st.lat.n(),
                latency: st.lat.summary(),
                shards: st.shards.clone(),
                prune: st.prune,
                rejected_tenant: st.rejected_tenant,
                expired_dropped: st.expired_dropped,
                degraded: st.degraded,
                internal: st.internal,
                cache: None,
            })
            .collect();
        // engine-wide aggregates: shard stats concatenated in store
        // order (identical to the pre-multi-store vector when one store
        // is registered), prune telemetry merged across stores
        let shards: Vec<ShardStat> = stores.iter().flat_map(|s| s.shards.clone()).collect();
        let mut prune = PruneStats::default();
        for s in &stores {
            prune.merge(&s.prune);
        }
        StatsSnapshot {
            completed,
            rejected: g.rejected,
            rejected_tenant: stores.iter().map(|s| s.rejected_tenant).sum(),
            expired: g.expired,
            unsupported: g.unsupported,
            degraded: stores.iter().map(|s| s.degraded).sum(),
            internal: stores.iter().map(|s| s.internal).sum(),
            batches: g.batches,
            mean_batch: if g.batches > 0 {
                g.batch_occupancy as f64 / g.batches as f64
            } else {
                0.0
            },
            max_batch: g.max_batch,
            qps: if elapsed > 0.0 {
                completed as f64 / elapsed
            } else {
                0.0
            },
            recall: g.recall.summary(),
            topk: g.topk.summary(),
            factorize: g.factorize.summary(),
            shards,
            prune,
            stores,
            cache: None,
        }
    }
}

/// One store's section of a [`StatsSnapshot`].
#[derive(Debug, Clone)]
pub struct StoreSnapshot {
    pub id: StoreId,
    /// Registration name.
    pub name: String,
    /// Requests this store completed (cache hits included).
    pub completed: u64,
    /// End-to-end latency over this store's completed requests (P²
    /// streaming estimates for p50/p99 once n > 5; exact below).
    pub latency: Option<LatencySummary>,
    /// This store's shard scan counters.
    pub shards: Vec<ShardStat>,
    /// Merged bound-pruned scan telemetry for this store's kernel calls.
    pub prune: PruneStats,
    /// Admissions refused on this store's own quota
    /// ([`super::ServeError::TenantOverloaded`]).
    pub rejected_tenant: u64,
    /// Tickets answered `DeadlineExceeded` and dropped before dispatch.
    pub expired_dropped: u64,
    /// Requests served or shed under degraded mode.
    pub degraded: u64,
    /// Tickets answered `Internal` after a contained worker panic.
    pub internal: u64,
    /// This store's response-cache counters; `None` when it runs
    /// uncached (filled by [`super::engine::ServeEngine::stats`]).
    pub cache: Option<CacheCounters>,
}

/// Point-in-time view of an engine's metrics.
#[derive(Debug, Clone)]
pub struct StatsSnapshot {
    pub completed: u64,
    pub rejected: u64,
    /// Tenant-quota rejections, summed across stores.
    pub rejected_tenant: u64,
    pub expired: u64,
    pub unsupported: u64,
    /// Degraded-mode requests, summed across stores.
    pub degraded: u64,
    /// Contained-panic (`Internal`) answers, summed across stores.
    pub internal: u64,
    pub batches: u64,
    /// Mean requests per executed micro-batch (batch occupancy).
    pub mean_batch: f64,
    pub max_batch: usize,
    /// Completed requests per second since engine start.
    pub qps: f64,
    pub recall: Option<LatencySummary>,
    pub topk: Option<LatencySummary>,
    pub factorize: Option<LatencySummary>,
    /// Every store's shard stats, concatenated in [`StoreId`] order
    /// (for single-store engines this is exactly the store's shard set).
    pub shards: Vec<ShardStat>,
    /// Merged bound-pruned scan telemetry across every executed batch
    /// and store.
    pub prune: PruneStats,
    /// Per-store sections, in [`StoreId`] order.
    pub stores: Vec<StoreSnapshot>,
    /// Engine-wide response-cache counters, summed across the stores'
    /// caches; `None` when every store runs uncached (filled by
    /// [`super::engine::ServeEngine::stats`], not by
    /// [`ServeStats::snapshot`]).
    pub cache: Option<CacheCounters>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = LatencySummary::of(&xs).unwrap();
        assert_eq!(s.n, 100);
        assert!((s.p50_s - 50.5).abs() < 1e-9);
        assert!(s.p99_s > 98.0 && s.p99_s <= 100.0);
        assert_eq!(s.max_s, 100.0);
        assert!(LatencySummary::of(&[]).is_none());
    }

    #[test]
    fn streaming_latency_matches_exact_for_small_n_and_tracks_large_n() {
        let mut sl = StreamingLatency::new();
        assert!(sl.summary().is_none());
        for x in [3.0, 1.0, 2.0] {
            sl.record(x);
        }
        let s = sl.summary().unwrap();
        assert_eq!(s.n, 3);
        assert!((s.mean_s - 2.0).abs() < 1e-12);
        assert!((s.p50_s - 2.0).abs() < 1e-12, "exact below 5 samples");
        assert_eq!(s.max_s, 3.0);

        // large-n: p50/p99 of a 1..=1000 ramp estimated within a few %
        let mut sl = StreamingLatency::new();
        let n = 1000usize;
        let mut i = 0usize;
        for _ in 0..n {
            sl.record((i + 1) as f64);
            i = (i + 333) % n; // 333 coprime with 1000 -> full cycle
        }
        let s = sl.summary().unwrap();
        assert_eq!(s.n, 1000);
        assert!((s.mean_s - 500.5).abs() < 1e-6);
        assert_eq!(s.max_s, 1000.0);
        assert!((s.p50_s - 500.0).abs() < 30.0, "p50 {}", s.p50_s);
        assert!((s.p99_s - 990.0).abs() < 30.0, "p99 {}", s.p99_s);
    }

    #[test]
    fn batch_occupancy_and_per_store_accounting() {
        let st = ServeStats::new(&[("alpha", 2), ("beta", 1)]);
        let prune = PruneStats {
            items: 6,
            sketch_rejected: 1,
            early_terminated: 2,
            words_streamed: 40,
            words_total: 96,
        };
        st.record_batch(
            3,
            &[
                (StoreId(0), RequestKind::Recall, Duration::from_millis(1)),
                (StoreId(0), RequestKind::Recall, Duration::from_millis(3)),
                (StoreId(1), RequestKind::Factorize, Duration::from_millis(9)),
            ],
            &[
                (
                    StoreId(0),
                    StoreWork {
                        timings: vec![(0, 0.001), (1, 0.002)],
                        prune,
                    },
                ),
                (
                    StoreId(1),
                    StoreWork {
                        timings: vec![(0, 0.004)],
                        prune,
                    },
                ),
            ],
        );
        st.record_batch(
            1,
            &[(StoreId(0), RequestKind::RecallTopK, Duration::from_millis(2))],
            &[(
                StoreId(0),
                StoreWork {
                    timings: vec![(0, 0.004)],
                    prune,
                },
            )],
        );
        st.record_rejected();
        st.record_expired(StoreId(0), 2);
        let s = st.snapshot();
        // engine-wide aggregates merge across stores
        assert_eq!(s.prune.items, 18);
        assert_eq!(s.prune.words_streamed, 120);
        assert!(s.cache.is_none());
        assert_eq!(s.completed, 4);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch - 2.0).abs() < 1e-12);
        assert_eq!(s.max_batch, 3);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.expired, 2);
        // concatenated shard vector: alpha's 2 shards then beta's 1
        assert_eq!(s.shards.len(), 3);
        assert_eq!(s.shards[0].scans, 2);
        assert!((s.shards[0].busy_s - 0.005).abs() < 1e-12);
        assert_eq!(s.shards[1].scans, 1);
        assert_eq!(s.shards[2].scans, 1);
        assert_eq!(s.recall.unwrap().n, 2);
        assert_eq!(s.topk.unwrap().n, 1);
        assert_eq!(s.factorize.unwrap().n, 1);
        // per-store sections
        assert_eq!(s.stores.len(), 2);
        assert_eq!(s.stores[0].name, "alpha");
        assert_eq!(s.stores[0].completed, 3);
        assert_eq!(s.stores[0].prune.items, 12);
        assert_eq!(s.stores[0].latency.unwrap().n, 3);
        assert_eq!(s.stores[0].expired_dropped, 2);
        assert_eq!(s.stores[1].name, "beta");
        assert_eq!(s.stores[1].completed, 1);
        assert_eq!(s.stores[1].prune.items, 6);
        assert_eq!(s.stores[1].shards.len(), 1);
        assert!((s.stores[1].shards[0].busy_s - 0.004).abs() < 1e-12);
    }

    #[test]
    fn overload_counters_attribute_per_store() {
        let st = ServeStats::new(&[("a", 1), ("b", 1)]);
        st.record_tenant_rejected(StoreId(0));
        st.record_tenant_rejected(StoreId(0));
        st.record_degraded(StoreId(1), 3);
        st.record_internal(StoreId(1), 4);
        st.record_expired(StoreId(1), 5);
        // out-of-range ids must not panic (defensive, like latencies)
        st.record_tenant_rejected(StoreId(9));
        st.record_degraded(StoreId(9), 1);
        st.record_internal(StoreId(9), 1);
        let s = st.snapshot();
        assert_eq!(s.stores[0].rejected_tenant, 2);
        assert_eq!(s.stores[0].degraded, 0);
        assert_eq!(s.stores[1].degraded, 3);
        assert_eq!(s.stores[1].internal, 4);
        assert_eq!(s.stores[1].expired_dropped, 5);
        assert_eq!(s.rejected_tenant, 2);
        assert_eq!(s.degraded, 3);
        assert_eq!(s.internal, 4);
        assert_eq!(s.expired, 5);
    }

    #[test]
    fn latencies_for_unknown_store_ids_still_count_globally() {
        // defensive: a latency tagged with an out-of-range store id must
        // not panic and must still reach the per-class estimators
        let st = ServeStats::new(&[("only", 1)]);
        st.record_batch(
            1,
            &[(StoreId(9), RequestKind::Recall, Duration::from_millis(1))],
            &[],
        );
        let s = st.snapshot();
        assert_eq!(s.completed, 1);
        assert_eq!(s.stores[0].completed, 0);
    }
}
