//! Serving metrics: per-class latency, per-shard busy time, batch
//! occupancy, admission outcomes, and throughput.
//!
//! All counters live behind one mutex and are updated once per batch (not
//! per request), so the metrics path stays off the kernel hot loops.

use super::cache::CacheCounters;
use super::RequestKind;
use crate::util::stats::percentile;
use crate::vsa::PruneStats;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Latency distribution summary (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub n: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

impl LatencySummary {
    /// Summarize a sample of latencies; `None` when empty.
    pub fn of(xs: &[f64]) -> Option<LatencySummary> {
        if xs.is_empty() {
            return None;
        }
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(LatencySummary {
            n: s.len(),
            mean_s: s.iter().sum::<f64>() / s.len() as f64,
            p50_s: percentile(&s, 0.50),
            p99_s: percentile(&s, 0.99),
            max_s: s[s.len() - 1],
        })
    }
}

/// Per-shard accumulated scan work.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ShardStat {
    /// Batched scans this shard participated in.
    pub scans: u64,
    /// Total seconds a worker spent scanning this shard.
    pub busy_s: f64,
}

#[derive(Debug, Default)]
struct StatsInner {
    recall_lat_s: Vec<f64>,
    topk_lat_s: Vec<f64>,
    factorize_lat_s: Vec<f64>,
    batch_sizes: Vec<usize>,
    rejected: u64,
    expired: u64,
    unsupported: u64,
    shards: Vec<ShardStat>,
    prune: PruneStats,
}

/// Shared, thread-safe metrics sink for one engine.
#[derive(Debug)]
pub struct ServeStats {
    inner: Mutex<StatsInner>,
    started: Instant,
}

impl ServeStats {
    pub fn new(n_shards: usize) -> ServeStats {
        ServeStats {
            inner: Mutex::new(StatsInner {
                shards: vec![ShardStat::default(); n_shards],
                ..StatsInner::default()
            }),
            started: Instant::now(),
        }
    }

    /// Record one executed micro-batch: occupancy, per-request latencies
    /// (queue wait + execution — cache hits included), per-shard scan
    /// timings, and the batch's merged scan [`PruneStats`].
    pub fn record_batch(
        &self,
        executed: usize,
        latencies: &[(RequestKind, Duration)],
        shard_timings: &[(usize, f64)],
        prune: &PruneStats,
    ) {
        let mut g = self.inner.lock().expect("stats poisoned");
        if executed > 0 {
            g.batch_sizes.push(executed);
        }
        g.prune.merge(prune);
        for &(kind, lat) in latencies {
            let secs = lat.as_secs_f64();
            match kind {
                RequestKind::Recall => g.recall_lat_s.push(secs),
                RequestKind::RecallTopK => g.topk_lat_s.push(secs),
                RequestKind::Factorize => g.factorize_lat_s.push(secs),
            }
        }
        for &(s, busy) in shard_timings {
            if let Some(st) = g.shards.get_mut(s) {
                st.scans += 1;
                st.busy_s += busy;
            }
        }
    }

    pub fn record_rejected(&self) {
        self.inner.lock().expect("stats poisoned").rejected += 1;
    }

    pub fn record_expired(&self, n: u64) {
        self.inner.lock().expect("stats poisoned").expired += n;
    }

    /// Requests refused without execution: unsupported kind or
    /// dimension mismatch.
    pub fn record_unsupported(&self, n: u64) {
        self.inner.lock().expect("stats poisoned").unsupported += n;
    }

    /// Snapshot every metric (cheap; clones the latency vectors).
    pub fn snapshot(&self) -> StatsSnapshot {
        let g = self.inner.lock().expect("stats poisoned");
        let completed =
            (g.recall_lat_s.len() + g.topk_lat_s.len() + g.factorize_lat_s.len()) as u64;
        let batches = g.batch_sizes.len() as u64;
        let occupancy: u64 = g.batch_sizes.iter().map(|&b| b as u64).sum();
        let elapsed = self.started.elapsed().as_secs_f64();
        StatsSnapshot {
            completed,
            rejected: g.rejected,
            expired: g.expired,
            unsupported: g.unsupported,
            batches,
            mean_batch: if batches > 0 {
                occupancy as f64 / batches as f64
            } else {
                0.0
            },
            max_batch: g.batch_sizes.iter().copied().max().unwrap_or(0),
            qps: if elapsed > 0.0 {
                completed as f64 / elapsed
            } else {
                0.0
            },
            recall: LatencySummary::of(&g.recall_lat_s),
            topk: LatencySummary::of(&g.topk_lat_s),
            factorize: LatencySummary::of(&g.factorize_lat_s),
            shards: g.shards.clone(),
            prune: g.prune,
            cache: None,
        }
    }
}

/// Point-in-time view of an engine's metrics.
#[derive(Debug, Clone)]
pub struct StatsSnapshot {
    pub completed: u64,
    pub rejected: u64,
    pub expired: u64,
    pub unsupported: u64,
    pub batches: u64,
    /// Mean requests per executed micro-batch (batch occupancy).
    pub mean_batch: f64,
    pub max_batch: usize,
    /// Completed requests per second since engine start.
    pub qps: f64,
    pub recall: Option<LatencySummary>,
    pub topk: Option<LatencySummary>,
    pub factorize: Option<LatencySummary>,
    pub shards: Vec<ShardStat>,
    /// Merged bound-pruned scan telemetry across every executed batch.
    pub prune: PruneStats,
    /// Response-cache counters; `None` when the engine runs uncached
    /// (filled by [`super::engine::ServeEngine::stats`], not by
    /// [`ServeStats::snapshot`]).
    pub cache: Option<CacheCounters>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = LatencySummary::of(&xs).unwrap();
        assert_eq!(s.n, 100);
        assert!((s.p50_s - 50.5).abs() < 1e-9);
        assert!(s.p99_s > 98.0 && s.p99_s <= 100.0);
        assert_eq!(s.max_s, 100.0);
        assert!(LatencySummary::of(&[]).is_none());
    }

    #[test]
    fn batch_occupancy_and_shard_accounting() {
        let st = ServeStats::new(2);
        let prune = PruneStats {
            items: 6,
            sketch_rejected: 1,
            early_terminated: 2,
            words_streamed: 40,
            words_total: 96,
        };
        st.record_batch(
            3,
            &[
                (RequestKind::Recall, Duration::from_millis(1)),
                (RequestKind::Recall, Duration::from_millis(3)),
                (RequestKind::Factorize, Duration::from_millis(9)),
            ],
            &[(0, 0.001), (1, 0.002)],
            &prune,
        );
        st.record_batch(
            1,
            &[(RequestKind::RecallTopK, Duration::from_millis(2))],
            &[(0, 0.004)],
            &prune,
        );
        st.record_rejected();
        st.record_expired(2);
        let s = st.snapshot();
        assert_eq!(s.prune.items, 12);
        assert_eq!(s.prune.words_streamed, 80);
        assert!(s.cache.is_none());
        assert_eq!(s.completed, 4);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch - 2.0).abs() < 1e-12);
        assert_eq!(s.max_batch, 3);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.expired, 2);
        assert_eq!(s.shards[0].scans, 2);
        assert!((s.shards[0].busy_s - 0.005).abs() < 1e-12);
        assert_eq!(s.shards[1].scans, 1);
        assert_eq!(s.recall.unwrap().n, 2);
        assert_eq!(s.topk.unwrap().n, 1);
        assert_eq!(s.factorize.unwrap().n, 1);
    }
}
