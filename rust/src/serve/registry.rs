//! Store registry: N named stores behind one serving engine.
//!
//! The paper's system-level findings (Sec. V–VI) are about *heterogeneous*
//! symbolic workloads: different codebook shapes, resonator
//! configurations, and sparsity profiles whose memory-bound scans only
//! amortize when batching is workload-aware. A single engine therefore
//! serves several [`Store`]s — each its own sharded cleanup codebook,
//! optional resonator, response cache, and sketch/prune configuration —
//! and every [`super::ServeRequest`] names the store it targets with a
//! [`StoreId`]. Batch formation groups by `(store, request class)` so one
//! batched kernel call never mixes stores (and hence never mixes
//! dimensions), and stats/caches stay attributable per store.
//!
//! [`StoreRegistry`] is immutable once the engine starts: registration
//! happens up front, the engine takes ownership, and workers read it
//! lock-free through the shared `Arc`.

use super::cache::{CacheConfig, ResponseCache};
use super::engine::EngineConfig;
use super::shard::ShardedCleanup;
use std::fmt;

use crate::vsa::{BinaryCodebook, Resonator};

/// Identifier of a registered store: its index in registration order.
/// `StoreId::DEFAULT` (store 0) is what the single-store convenience
/// constructors route to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StoreId(pub usize);

impl StoreId {
    /// The first registered store — the target of every single-store
    /// convenience constructor ([`super::ServeRequest::recall`] etc.).
    pub const DEFAULT: StoreId = StoreId(0);

    pub fn index(self) -> usize {
        self.0
    }
}

impl fmt::Display for StoreId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "store#{}", self.0)
    }
}

/// Per-store sizing and policy knobs, applied at registration.
#[derive(Debug, Clone, Copy)]
pub struct StoreSpec {
    /// Codebook shards in this store's cleanup memory.
    pub shards: usize,
    /// Sketch sidecar width for this store's shards (`None` = per-dim
    /// default, `Some(0)` disables the sidecars).
    pub sketch_bits: Option<usize>,
    /// This store's response-cache entry budget; 0 disables its cache.
    pub cache_capacity: usize,
    /// This store's response-cache lock shards.
    pub cache_shards: usize,
    /// Deficit-round-robin scheduling weight: per scheduler round, this
    /// store pops up to `weight` tickets before the rotation advances
    /// (relative share under contention; idle stores cost nothing).
    pub weight: u32,
    /// Per-store admission quota: at most this many of this store's
    /// tickets may occupy the queue at once; the overflow is refused with
    /// [`super::ServeError::TenantOverloaded`] while other stores keep
    /// admitting. `None` = no tenant-local cap (only the global queue
    /// capacity applies, as before multi-tenant isolation).
    pub quota: Option<usize>,
    /// Degraded-mode *enter* threshold: when this store's queue lane
    /// holds at least this many waiting tickets at batch-formation time,
    /// the batcher serves the store degraded — top-k capped at
    /// `degrade_k`, factorize shed with
    /// [`super::ServeError::TenantOverloaded`]. The store stays degraded
    /// until the lane drains below the *exit* threshold (`degrade_exit`,
    /// default `(enter / 2).max(1)`) — hysteresis, so a lane hovering at
    /// the boundary doesn't flap between degraded and full service.
    /// `None` disables degradation.
    pub degrade_depth: Option<usize>,
    /// Degraded-mode *exit* threshold override: the store leaves degraded
    /// mode when its lane depth drops *below* this value. `None` derives
    /// `(degrade_depth / 2).max(1)`; values are clamped into
    /// `1..=degrade_depth`. See [`Hysteresis`].
    pub degrade_exit: Option<usize>,
    /// Top-k cap while degraded (responses arrive wrapped in
    /// [`super::ServeResponse::Degraded`] so the truncation is explicit).
    pub degrade_k: usize,
}

impl Default for StoreSpec {
    fn default() -> Self {
        let cache = CacheConfig::default();
        StoreSpec {
            shards: 4,
            sketch_bits: None,
            cache_capacity: cache.capacity,
            cache_shards: cache.shards,
            weight: 1,
            quota: None,
            degrade_depth: None,
            degrade_exit: None,
            degrade_k: 1,
        }
    }
}

impl StoreSpec {
    /// Derive a spec from the engine-level knobs — what the single-store
    /// wrappers use, so `EngineConfig { shards, sketch_bits, cache_* }`
    /// keeps meaning exactly what it did before multi-store routing.
    pub fn from_engine(cfg: &EngineConfig) -> StoreSpec {
        StoreSpec {
            shards: cfg.shards,
            sketch_bits: cfg.sketch_bits,
            cache_capacity: cfg.cache_capacity,
            cache_shards: cfg.cache_shards,
            ..StoreSpec::default()
        }
    }

    /// The degraded-mode threshold pair this spec configures, or `None`
    /// when degradation is disabled.
    pub fn degrade_hysteresis(&self) -> Option<Hysteresis> {
        self.degrade_depth.map(|enter| match self.degrade_exit {
            Some(exit) => Hysteresis::with_exit(enter, exit),
            None => Hysteresis::new(enter),
        })
    }
}

/// Degraded-mode hysteresis state machine: enter at `depth >= enter`,
/// leave only once `depth < exit` (with `exit <= enter`), so a lane
/// oscillating around a single threshold cannot flap the store between
/// `Degraded` and full-k responses on every batch.
///
/// The machine itself is pure — `next(currently_degraded, depth)`
/// returns the successor state — so the batcher can keep the persistent
/// bit wherever it likes (the engine holds one `AtomicBool` per store)
/// and this type stays trivially unit-testable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hysteresis {
    /// Enter degraded mode at lane depth ≥ `enter`.
    pub enter: usize,
    /// Leave degraded mode at lane depth < `exit`.
    pub exit: usize,
}

impl Hysteresis {
    /// Default exit threshold: half the enter depth (at least 1), per
    /// the usual hysteresis rule of thumb — the backlog must genuinely
    /// drain, not momentarily dip, before full service resumes.
    pub fn new(enter: usize) -> Hysteresis {
        let enter = enter.max(1);
        Hysteresis {
            enter,
            exit: (enter / 2).max(1),
        }
    }

    /// Explicit exit threshold, clamped into `1..=enter`.
    pub fn with_exit(enter: usize, exit: usize) -> Hysteresis {
        let enter = enter.max(1);
        Hysteresis {
            enter,
            exit: exit.clamp(1, enter),
        }
    }

    /// Successor state given the current state and the observed lane
    /// depth.
    pub fn next(&self, degraded: bool, depth: usize) -> bool {
        if degraded {
            depth >= self.exit
        } else {
            depth >= self.enter
        }
    }
}

/// One registered store: a sharded cleanup codebook, an optional
/// resonator for factorize requests, and its own response cache.
pub struct Store {
    id: StoreId,
    name: String,
    cleanup: ShardedCleanup,
    resonator: Option<Resonator>,
    cache: Option<ResponseCache>,
    spec: StoreSpec,
}

impl Store {
    pub fn id(&self) -> StoreId {
        self.id
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn cleanup(&self) -> &ShardedCleanup {
        &self.cleanup
    }

    pub fn resonator(&self) -> Option<&Resonator> {
        self.resonator.as_ref()
    }

    pub fn cache(&self) -> Option<&ResponseCache> {
        self.cache.as_ref()
    }

    pub fn spec(&self) -> &StoreSpec {
        &self.spec
    }

    /// Hypervector dimension of this store's cleanup memory.
    pub fn dim(&self) -> usize {
        self.cleanup.dim()
    }

    /// Items in this store's cleanup memory.
    pub fn len(&self) -> usize {
        self.cleanup.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cleanup.is_empty()
    }

    pub fn n_shards(&self) -> usize {
        self.cleanup.n_shards()
    }

    /// Scene dimension factorize requests against this store must carry
    /// (`None` when the store has no resonator).
    pub fn fact_dim(&self) -> Option<usize> {
        self.resonator.as_ref().map(|r| r.codebooks()[0].dim())
    }
}

impl fmt::Debug for Store {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Store")
            .field("id", &self.id)
            .field("name", &self.name)
            .field("dim", &self.dim())
            .field("items", &self.len())
            .field("shards", &self.n_shards())
            .field("resonator", &self.resonator.is_some())
            .field("cache", &self.cache.is_some())
            .finish()
    }
}

/// The engine's store table. Built up front via [`StoreRegistry::register`],
/// then owned (immutably) by the running engine.
#[derive(Debug, Default)]
pub struct StoreRegistry {
    stores: Vec<Store>,
}

impl StoreRegistry {
    pub fn new() -> StoreRegistry {
        StoreRegistry { stores: Vec::new() }
    }

    /// Registry with exactly one store named `"default"` — the
    /// single-store constructors' path ([`super::ServeEngine::start`]).
    pub fn single(
        codebook: &BinaryCodebook,
        resonator: Option<Resonator>,
        spec: StoreSpec,
    ) -> StoreRegistry {
        let mut r = StoreRegistry::new();
        r.register("default", codebook, resonator, spec);
        r
    }

    /// Shard `codebook` per `spec`, build its cache, and assign the next
    /// [`StoreId`]. Store names must be unique (routing and reporting key
    /// on them).
    pub fn register(
        &mut self,
        name: &str,
        codebook: &BinaryCodebook,
        resonator: Option<Resonator>,
        spec: StoreSpec,
    ) -> StoreId {
        assert!(
            self.by_name(name).is_none(),
            "store name '{name}' already registered"
        );
        let id = StoreId(self.stores.len());
        let cleanup =
            ShardedCleanup::partition_sketched(codebook, spec.shards.max(1), spec.sketch_bits);
        let cache = (spec.cache_capacity > 0).then(|| {
            ResponseCache::for_store(
                CacheConfig {
                    capacity: spec.cache_capacity,
                    shards: spec.cache_shards.max(1),
                },
                id,
            )
        });
        self.stores.push(Store {
            id,
            name: name.to_string(),
            cleanup,
            resonator,
            cache,
            spec,
        });
        id
    }

    /// Number of registered stores.
    pub fn len(&self) -> usize {
        self.stores.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stores.is_empty()
    }

    /// All stores, in [`StoreId`] order.
    pub fn stores(&self) -> &[Store] {
        &self.stores
    }

    /// Look a store up by id; `None` for ids this registry never issued
    /// (the engine answers those requests with
    /// [`super::ServeError::UnknownStore`] instead of panicking).
    pub fn store_by_id(&self, id: StoreId) -> Option<&Store> {
        self.stores.get(id.0)
    }

    /// Look a store's id up by its registration name.
    pub fn by_name(&self, name: &str) -> Option<StoreId> {
        self.stores.iter().find(|s| s.name == name).map(|s| s.id)
    }

    /// Registered ids, in order.
    pub fn ids(&self) -> impl Iterator<Item = StoreId> + '_ {
        (0..self.stores.len()).map(StoreId)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::vsa::RealCodebook;

    fn codebook(seed: u64, items: usize, dim: usize) -> BinaryCodebook {
        let mut rng = Rng::new(seed);
        BinaryCodebook::random(&mut rng, items, dim)
    }

    #[test]
    fn register_assigns_sequential_ids_and_lookups_work() {
        let mut reg = StoreRegistry::new();
        let a = reg.register("alpha", &codebook(1, 16, 512), None, StoreSpec::default());
        let b = reg.register(
            "beta",
            &codebook(2, 24, 1024),
            None,
            StoreSpec {
                shards: 2,
                cache_capacity: 0,
                ..StoreSpec::default()
            },
        );
        assert_eq!(a, StoreId(0));
        assert_eq!(b, StoreId(1));
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.by_name("beta"), Some(b));
        assert_eq!(reg.by_name("gamma"), None);
        let beta = reg.store_by_id(b).unwrap();
        assert_eq!(beta.name(), "beta");
        assert_eq!(beta.dim(), 1024);
        assert_eq!(beta.len(), 24);
        assert_eq!(beta.n_shards(), 2);
        assert!(beta.cache().is_none(), "capacity 0 disables the cache");
        assert!(reg.store_by_id(StoreId(0)).unwrap().cache().is_some());
        assert!(reg.store_by_id(StoreId(7)).is_none(), "unknown ids are None");
        assert_eq!(reg.ids().collect::<Vec<_>>(), vec![a, b]);
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn duplicate_names_are_refused() {
        let mut reg = StoreRegistry::new();
        reg.register("dup", &codebook(3, 8, 256), None, StoreSpec::default());
        reg.register("dup", &codebook(4, 8, 256), None, StoreSpec::default());
    }

    #[test]
    fn single_wraps_one_default_store() {
        let mut rng = Rng::new(5);
        let cb = codebook(5, 12, 512);
        let res = Resonator::new(
            (0..2)
                .map(|_| RealCodebook::random_bipolar(&mut rng, 4, 256))
                .collect(),
            10,
        );
        let reg = StoreRegistry::single(&cb, Some(res), StoreSpec::default());
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.by_name("default"), Some(StoreId::DEFAULT));
        let s = reg.store_by_id(StoreId::DEFAULT).unwrap();
        assert_eq!(s.fact_dim(), Some(256));
    }

    #[test]
    fn hysteresis_thresholds_derive_and_clamp() {
        assert_eq!(Hysteresis::new(8), Hysteresis { enter: 8, exit: 4 });
        // exit never reaches 0: depth-1 enter still needs depth 0 to exit
        assert_eq!(Hysteresis::new(1), Hysteresis { enter: 1, exit: 1 });
        assert_eq!(Hysteresis::new(0), Hysteresis { enter: 1, exit: 1 });
        // explicit exit clamps into 1..=enter
        assert_eq!(Hysteresis::with_exit(4, 0), Hysteresis { enter: 4, exit: 1 });
        assert_eq!(Hysteresis::with_exit(4, 9), Hysteresis { enter: 4, exit: 4 });
        let spec = StoreSpec {
            degrade_depth: Some(6),
            ..StoreSpec::default()
        };
        assert_eq!(
            spec.degrade_hysteresis(),
            Some(Hysteresis { enter: 6, exit: 3 })
        );
        let spec = StoreSpec {
            degrade_depth: Some(6),
            degrade_exit: Some(2),
            ..StoreSpec::default()
        };
        assert_eq!(
            spec.degrade_hysteresis(),
            Some(Hysteresis { enter: 6, exit: 2 })
        );
        assert_eq!(StoreSpec::default().degrade_hysteresis(), None);
    }

    #[test]
    fn hysteresis_state_machine_enters_holds_and_exits() {
        let h = Hysteresis::new(4); // enter at ≥4, exit below 2
        let mut deg = false;
        for (depth, expect) in [
            (3, false), // below enter: stays healthy
            (4, true),  // crosses enter
            (3, true),  // dips below enter but not below exit: holds
            (2, true),  // still ≥ exit: holds
            (1, false), // below exit: recovers
            (3, false), // healthy again; below enter stays healthy
            (5, true),  // re-enters
        ] {
            deg = h.next(deg, depth);
            assert_eq!(deg, expect, "depth {depth}");
        }
    }

    #[test]
    fn hysteresis_does_not_flap_at_the_boundary() {
        // A lane oscillating one ticket around the old single threshold
        // (depth 4 ↔ 3) flips exactly once under hysteresis, never per
        // observation.
        let h = Hysteresis::new(4);
        let mut deg = false;
        let mut transitions = 0;
        for depth in [4, 3, 4, 3, 4, 3, 4, 3] {
            let next = h.next(deg, depth);
            if next != deg {
                transitions += 1;
            }
            deg = next;
        }
        assert_eq!(transitions, 1, "one enter transition, zero exits");
        assert!(deg, "still degraded while hovering above exit");
    }
}
