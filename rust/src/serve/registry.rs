//! Store registry: N named, **live-mutable** stores behind one engine.
//!
//! The paper's system-level findings (Sec. V–VI) are about *heterogeneous*
//! symbolic workloads: different codebook shapes, resonator
//! configurations, and sparsity profiles whose memory-bound scans only
//! amortize when batching is workload-aware. A single engine therefore
//! serves several stores — each its own sharded cleanup codebook,
//! optional resonator, response cache, and sketch/prune configuration —
//! and every [`super::ServeRequest`] names the store it targets with a
//! [`StoreId`]. Batch formation groups by `(store, request class)` so one
//! batched kernel call never mixes stores (and hence never mixes
//! dimensions), and stats/caches stay attributable per store.
//!
//! # Epoch-based snapshot swap
//!
//! Stores mutate *under live traffic* — item insert/delete, store
//! create/drop — without ever breaking the bit-exactness contract. The
//! mechanism is RCU-style snapshot swapping:
//!
//! - Every store version is an immutable [`StoreSnapshot`] (master
//!   codebook + sharded cleanup with sketch sidecars + resonator +
//!   spec) tagged with a monotonically increasing per-store **epoch**.
//! - A mutation rebuilds the full snapshot from the mutated item list
//!   and publishes it atomically by swapping the slot's `Arc` under the
//!   registry write lock; the epoch increments with every publish.
//! - Readers ([`StoreRegistry::live`]) clone the `Arc` under the read
//!   lock and then scan lock-free: an in-flight batch keeps the snapshot
//!   it was sealed against even if the store mutates or drops mid-batch,
//!   so its answers are exactly the sealed epoch's sequential oracle.
//! - Dropping a store tombstones its slot (`snapshot = None`). Ids are
//!   **never reused**; a dropped id answers
//!   [`super::ServeError::UnknownStore`] forever. Names of dropped
//!   stores may be reused by later [`StoreRegistry::create_store`]
//!   calls (the new store gets a fresh id and epoch 0).
//! - The response cache folds the serving epoch into every key
//!   (see [`super::cache`]), so a stale-epoch hit is structurally
//!   impossible — no explicit invalidation walk is needed.
//!
//! Mutations hold the write lock while they rebuild (cost is one
//! re-partition of the store's items — O(items·dim/64) — which is the
//! price of never publishing a half-built snapshot); the serve hot path
//! only ever takes the read lock for an `Arc` clone.

use super::cache::{CacheConfig, ResponseCache};
use super::engine::EngineConfig;
use super::shard::ShardedCleanup;
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock, RwLockReadGuard};

use crate::vsa::ca90;
use crate::vsa::hypervector::{FOLD_BITS, FOLD_WORDS};
use crate::vsa::{BinaryCodebook, BinaryHV, Resonator};

/// Identifier of a registered store: its slot index in creation order.
/// Slots are never reused, so a `StoreId` names the same store for the
/// engine's whole lifetime — after [`StoreRegistry::drop_store`] it
/// names a tombstone and is refused with
/// [`super::ServeError::UnknownStore`].
/// `StoreId::DEFAULT` (store 0) is what the single-store convenience
/// constructors route to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StoreId(pub usize);

impl StoreId {
    /// The first registered store — the target of every single-store
    /// convenience constructor ([`super::ServeRequest::recall`] etc.).
    pub const DEFAULT: StoreId = StoreId(0);

    pub fn index(self) -> usize {
        self.0
    }
}

impl fmt::Display for StoreId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "store#{}", self.0)
    }
}

/// Per-store sizing and policy knobs, applied at registration.
#[derive(Debug, Clone, Copy)]
pub struct StoreSpec {
    /// Codebook shards in this store's cleanup memory.
    pub shards: usize,
    /// Sketch sidecar width for this store's shards (`None` = per-dim
    /// default, `Some(0)` disables the sidecars).
    pub sketch_bits: Option<usize>,
    /// Hierarchical sketch-cascade coarse level width in bits for this
    /// store's shards (`--sketch-cascade` serve knob). The coarse level
    /// orders and bulk-rejects the scan tail before the full sketch
    /// runs; rejections land in `PruneStats::coarse_rejected`. `None`
    /// disables the cascade; ignored when the sketch itself is disabled
    /// or no wider than the coarse level.
    pub sketch_cascade: Option<usize>,
    /// This store's response-cache entry budget; 0 disables its cache.
    pub cache_capacity: usize,
    /// This store's response-cache lock shards.
    pub cache_shards: usize,
    /// Deficit-round-robin scheduling weight: per scheduler round, this
    /// store pops up to `weight` tickets before the rotation advances
    /// (relative share under contention; idle stores cost nothing).
    /// When the lane holds high-priority tickets at refill time the
    /// effective refill is boosted (see [`super::queue`]), so priority
    /// buys cross-tenant share, not just intra-lane ordering.
    pub weight: u32,
    /// Per-store admission quota: at most this many of this store's
    /// tickets may occupy the queue at once; the overflow is refused with
    /// [`super::ServeError::TenantOverloaded`] while other stores keep
    /// admitting. `None` = no tenant-local cap (only the global queue
    /// capacity applies, as before multi-tenant isolation).
    pub quota: Option<usize>,
    /// Degraded-mode *enter* threshold: when this store's queue lane
    /// holds at least this many waiting tickets at batch-formation time,
    /// the batcher serves the store degraded — top-k capped at
    /// `degrade_k`, factorize shed with
    /// [`super::ServeError::TenantOverloaded`]. The store stays degraded
    /// until the lane drains below the *exit* threshold (`degrade_exit`,
    /// default `(enter / 2).max(1)`) — hysteresis, so a lane hovering at
    /// the boundary doesn't flap between degraded and full service.
    /// `None` disables degradation.
    pub degrade_depth: Option<usize>,
    /// Degraded-mode *exit* threshold override: the store leaves degraded
    /// mode when its lane depth drops *below* this value. `None` derives
    /// `(degrade_depth / 2).max(1)`; values are clamped into
    /// `1..=degrade_depth`. See [`Hysteresis`].
    pub degrade_exit: Option<usize>,
    /// Top-k cap while degraded (responses arrive wrapped in
    /// [`super::ServeResponse::Degraded`] so the truncation is explicit).
    pub degrade_k: usize,
}

impl Default for StoreSpec {
    fn default() -> Self {
        let cache = CacheConfig::default();
        StoreSpec {
            shards: 4,
            sketch_bits: None,
            sketch_cascade: None,
            cache_capacity: cache.capacity,
            cache_shards: cache.shards,
            weight: 1,
            quota: None,
            degrade_depth: None,
            degrade_exit: None,
            degrade_k: 1,
        }
    }
}

impl StoreSpec {
    /// Derive a spec from the engine-level knobs — what the single-store
    /// wrappers use, so `EngineConfig { shards, sketch_bits, cache_* }`
    /// keeps meaning exactly what it did before multi-store routing.
    pub fn from_engine(cfg: &EngineConfig) -> StoreSpec {
        StoreSpec {
            shards: cfg.shards,
            sketch_bits: cfg.sketch_bits,
            cache_capacity: cfg.cache_capacity,
            cache_shards: cfg.cache_shards,
            ..StoreSpec::default()
        }
    }

    /// The degraded-mode threshold pair this spec configures, or `None`
    /// when degradation is disabled.
    pub fn degrade_hysteresis(&self) -> Option<Hysteresis> {
        self.degrade_depth.map(|enter| match self.degrade_exit {
            Some(exit) => Hysteresis::with_exit(enter, exit),
            None => Hysteresis::new(enter),
        })
    }
}

/// Degraded-mode hysteresis state machine: enter at `depth >= enter`,
/// leave only once `depth < exit` (with `exit <= enter`), so a lane
/// oscillating around a single threshold cannot flap the store between
/// `Degraded` and full-k responses on every batch.
///
/// The machine itself is pure — `next(currently_degraded, depth)`
/// returns the successor state — so the persistent bit can live wherever
/// the caller likes (the registry holds one `AtomicBool` per store slot,
/// stepped via [`StoreRegistry::degrade_step`]) and this type stays
/// trivially unit-testable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hysteresis {
    /// Enter degraded mode at lane depth ≥ `enter`.
    pub enter: usize,
    /// Leave degraded mode at lane depth < `exit`.
    pub exit: usize,
}

impl Hysteresis {
    /// Default exit threshold: half the enter depth (at least 1), per
    /// the usual hysteresis rule of thumb — the backlog must genuinely
    /// drain, not momentarily dip, before full service resumes.
    pub fn new(enter: usize) -> Hysteresis {
        let enter = enter.max(1);
        Hysteresis {
            enter,
            exit: (enter / 2).max(1),
        }
    }

    /// Explicit exit threshold, clamped into `1..=enter`.
    pub fn with_exit(enter: usize, exit: usize) -> Hysteresis {
        let enter = enter.max(1);
        Hysteresis {
            enter,
            exit: exit.clamp(1, enter),
        }
    }

    /// Successor state given the current state and the observed lane
    /// depth.
    pub fn next(&self, degraded: bool, depth: usize) -> bool {
        if degraded {
            depth >= self.exit
        } else {
            depth >= self.enter
        }
    }
}

/// Why a serve-time registry mutation was refused. Mutations never
/// panic the engine: every refusal is a typed error the management
/// caller handles, while serve traffic keeps flowing against the
/// still-published snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MutateError {
    /// The id was never issued or names a dropped store.
    UnknownStore,
    /// A live store already owns this name.
    DuplicateName,
    /// The inserted item's dimension differs from the store's.
    DimensionMismatch,
    /// Delete index is out of range for the current snapshot.
    BadIndex,
    /// Deleting this item would leave the store empty (empty codebooks
    /// cannot be sharded or scanned; drop the store instead).
    WouldEmpty,
    /// The store is CA-90 (seeds-only) backed and the inserted item is
    /// not a CA-90 expansion of its own first fold — it cannot be
    /// stored as a seed without changing its bits, which would break
    /// the bit-exactness contract.
    IncompressibleItem,
}

impl fmt::Display for MutateError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MutateError::UnknownStore => write!(f, "unknown or dropped store"),
            MutateError::DuplicateName => write!(f, "a live store already owns this name"),
            MutateError::DimensionMismatch => write!(f, "item dimension differs from the store's"),
            MutateError::BadIndex => write!(f, "item index out of range"),
            MutateError::WouldEmpty => write!(f, "delete would leave the store empty"),
            MutateError::IncompressibleItem => write!(
                f,
                "item is not a CA-90 expansion of its first fold (seeds-only store)"
            ),
        }
    }
}

impl std::error::Error for MutateError {}

/// One immutable published version of a store: the master item list,
/// the sharded cleanup memory (with sketch sidecars) built from it, the
/// resonator, and the spec — all frozen at publish time and tagged with
/// the epoch that published them. Workers hold these behind `Arc`: a
/// batch sealed against epoch `e` scans exactly epoch `e`'s items no
/// matter what mutates concurrently.
pub struct StoreSnapshot {
    id: StoreId,
    epoch: u64,
    name: String,
    codebook: BinaryCodebook,
    cleanup: ShardedCleanup,
    resonator: Option<Resonator>,
    spec: StoreSpec,
}

impl StoreSnapshot {
    fn build(
        id: StoreId,
        epoch: u64,
        name: String,
        codebook: BinaryCodebook,
        resonator: Option<Resonator>,
        spec: StoreSpec,
    ) -> StoreSnapshot {
        let mut cleanup =
            ShardedCleanup::partition_sketched(&codebook, spec.shards.max(1), spec.sketch_bits);
        if let Some(bits) = spec.sketch_cascade {
            cleanup.enable_cascade(bits);
        }
        StoreSnapshot {
            id,
            epoch,
            name,
            codebook,
            cleanup,
            resonator,
            spec,
        }
    }

    pub fn id(&self) -> StoreId {
        self.id
    }

    /// The epoch that published this snapshot: 0 at store creation,
    /// +1 per mutation, strictly monotonic per store.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// The master (unsharded) item list this snapshot was built from —
    /// what mutations rebuild from, and what per-epoch oracles replay.
    pub fn codebook(&self) -> &BinaryCodebook {
        &self.codebook
    }

    pub fn cleanup(&self) -> &ShardedCleanup {
        &self.cleanup
    }

    pub fn resonator(&self) -> Option<&Resonator> {
        self.resonator.as_ref()
    }

    pub fn spec(&self) -> &StoreSpec {
        &self.spec
    }

    /// Hypervector dimension of this store's cleanup memory.
    pub fn dim(&self) -> usize {
        self.cleanup.dim()
    }

    /// Items in this store's cleanup memory.
    pub fn len(&self) -> usize {
        self.cleanup.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cleanup.is_empty()
    }

    pub fn n_shards(&self) -> usize {
        self.cleanup.n_shards()
    }

    /// Scene dimension factorize requests against this store must carry
    /// (`None` when the store has no resonator).
    pub fn fact_dim(&self) -> Option<usize> {
        self.resonator.as_ref().map(|r| r.codebooks()[0].dim())
    }

    /// Row-storage backing of the serving shards (`"ram"` or `"ca90"`).
    pub fn backing_name(&self) -> &'static str {
        self.cleanup.backing_name()
    }

    /// Resident bytes of the serving rows across all shards: full rows
    /// (ram) or 512-bit seed folds only (ca90).
    pub fn row_resident_bytes(&self) -> usize {
        self.cleanup.row_resident_bytes()
    }

    /// Resident bytes of the shards' sketch sidecars, cascade coarse
    /// levels included.
    pub fn sketch_resident_bytes(&self) -> usize {
        self.cleanup.sketch_resident_bytes()
    }

    /// Resident bytes of the master (unsharded) codebook — the copy
    /// mutations rebuild from and per-epoch oracles replay.
    pub fn master_resident_bytes(&self) -> usize {
        self.codebook.resident_bytes()
    }

    /// Total resident bytes for this snapshot: serving shards (rows +
    /// sketch sidecars) plus the master copy.
    pub fn resident_bytes(&self) -> usize {
        self.row_resident_bytes() + self.sketch_resident_bytes() + self.master_resident_bytes()
    }
}

impl fmt::Debug for StoreSnapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("StoreSnapshot")
            .field("id", &self.id)
            .field("epoch", &self.epoch)
            .field("name", &self.name)
            .field("dim", &self.dim())
            .field("items", &self.len())
            .field("shards", &self.n_shards())
            .field("resonator", &self.resonator.is_some())
            .finish()
    }
}

/// One store slot: the currently published snapshot (or `None` once
/// dropped — the tombstone that keeps ids from ever being reused), the
/// response cache that persists across the store's epochs (epoch-keyed
/// entries from old snapshots structurally never hit and age out FIFO),
/// and the persistent degraded-mode bit.
#[derive(Debug)]
struct StoreSlot {
    name: String,
    spec: StoreSpec,
    cache: Option<Arc<ResponseCache>>,
    snapshot: Option<Arc<StoreSnapshot>>,
    /// Epoch of the latest snapshot ever published in this slot —
    /// survives the tombstone so [`StoreRegistry::epoch_of`] stays
    /// answerable (and monotonicity checkable) after a drop.
    epoch: u64,
    degraded: AtomicBool,
}

/// The engine's store table: slots behind one `RwLock`. Reads (the
/// serve hot path) take the read lock just long enough to clone an
/// `Arc`; mutations rebuild and swap snapshots under the write lock.
/// Construction-time registration still happens through `&mut self`
/// ([`StoreRegistry::register`]); everything after engine start goes
/// through the `&self` mutation API.
#[derive(Debug, Default)]
pub struct StoreRegistry {
    slots: RwLock<Vec<StoreSlot>>,
}

impl StoreRegistry {
    pub fn new() -> StoreRegistry {
        StoreRegistry {
            slots: RwLock::new(Vec::new()),
        }
    }

    /// Registry with exactly one store named `"default"` — the
    /// single-store constructors' path ([`super::ServeEngine::start`]).
    pub fn single(
        codebook: &BinaryCodebook,
        resonator: Option<Resonator>,
        spec: StoreSpec,
    ) -> StoreRegistry {
        let mut r = StoreRegistry::new();
        r.register("default", codebook, resonator, spec);
        r
    }

    fn read(&self) -> RwLockReadGuard<'_, Vec<StoreSlot>> {
        self.slots.read().unwrap_or_else(|p| p.into_inner())
    }

    fn make_slot(
        id: StoreId,
        name: &str,
        codebook: &BinaryCodebook,
        resonator: Option<Resonator>,
        spec: StoreSpec,
    ) -> StoreSlot {
        let snapshot = Arc::new(StoreSnapshot::build(
            id,
            0,
            name.to_string(),
            codebook.clone(),
            resonator,
            spec,
        ));
        let cache = (spec.cache_capacity > 0).then(|| {
            Arc::new(ResponseCache::for_store(
                CacheConfig {
                    capacity: spec.cache_capacity,
                    shards: spec.cache_shards.max(1),
                },
                id,
            ))
        });
        StoreSlot {
            name: name.to_string(),
            spec,
            cache,
            snapshot: Some(snapshot),
            epoch: 0,
            degraded: AtomicBool::new(false),
        }
    }

    /// Construction-time registration: shard `codebook` per `spec`,
    /// build its cache, and assign the next [`StoreId`] at epoch 0.
    /// Live store names must be unique (routing and reporting key on
    /// them); a duplicate panics — use [`Self::create_store`] for the
    /// fallible serve-time path.
    pub fn register(
        &mut self,
        name: &str,
        codebook: &BinaryCodebook,
        resonator: Option<Resonator>,
        spec: StoreSpec,
    ) -> StoreId {
        assert!(
            self.by_name(name).is_none(),
            "store name '{name}' already registered"
        );
        let slots = self.slots.get_mut().unwrap_or_else(|p| p.into_inner());
        let id = StoreId(slots.len());
        slots.push(Self::make_slot(id, name, codebook, resonator, spec));
        id
    }

    /// Serve-time store creation (hot-swap): a brand-new slot at epoch 0
    /// with a fresh never-reused id, published atomically. Refuses names
    /// owned by a *live* store; dropped stores' names are reusable.
    pub fn create_store(
        &self,
        name: &str,
        codebook: &BinaryCodebook,
        resonator: Option<Resonator>,
        spec: StoreSpec,
    ) -> Result<StoreId, MutateError> {
        let mut slots = self.slots.write().unwrap_or_else(|p| p.into_inner());
        if slots
            .iter()
            .any(|s| s.snapshot.is_some() && s.name == name)
        {
            return Err(MutateError::DuplicateName);
        }
        let id = StoreId(slots.len());
        slots.push(Self::make_slot(id, name, codebook, resonator, spec));
        Ok(id)
    }

    /// Serve-time store drop: tombstones the slot. In-flight batches
    /// sealed against the last snapshot finish against it (they hold the
    /// `Arc`); everything admitted or executed afterwards answers
    /// [`super::ServeError::UnknownStore`]. The id is never reused.
    pub fn drop_store(&self, id: StoreId) -> Result<(), MutateError> {
        let mut slots = self.slots.write().unwrap_or_else(|p| p.into_inner());
        let slot = slots.get_mut(id.0).ok_or(MutateError::UnknownStore)?;
        if slot.snapshot.take().is_none() {
            return Err(MutateError::UnknownStore);
        }
        Ok(())
    }

    /// Serve-time item insert: rebuilds the snapshot with the item
    /// appended (its index is the old `len()`) and publishes it at
    /// `epoch + 1`. Returns the new epoch.
    pub fn insert_item(&self, id: StoreId, item: BinaryHV) -> Result<u64, MutateError> {
        self.mutate_items(id, |items, dim| {
            if item.dim() != dim {
                return Err(MutateError::DimensionMismatch);
            }
            items.push(item);
            Ok(())
        })
    }

    /// Serve-time item delete by index (indices shift down past the
    /// hole, exactly like `Vec::remove`). Refuses to empty the store —
    /// an empty codebook cannot be sharded or scanned; [`Self::drop_store`]
    /// is the way to retire a store. Returns the new epoch.
    pub fn delete_item(&self, id: StoreId, index: usize) -> Result<u64, MutateError> {
        self.mutate_items(id, |items, _dim| {
            if index >= items.len() {
                return Err(MutateError::BadIndex);
            }
            if items.len() == 1 {
                return Err(MutateError::WouldEmpty);
            }
            items.remove(index);
            Ok(())
        })
    }

    /// Shared mutation path: clone the live snapshot's items, apply the
    /// edit, rebuild, and publish at `epoch + 1` — all under the write
    /// lock, so two racing mutations serialize and each publishes a
    /// distinct epoch.
    ///
    /// Seeds-only (ca90) stores materialize their rows for the edit and
    /// re-compress afterwards — every row (including the edit's inserts)
    /// must regenerate exactly from its first fold or the mutation is
    /// refused with [`MutateError::IncompressibleItem`], keeping the
    /// backing lossless. The transient materialization costs one full
    /// row set, the same order as the snapshot rebuild itself.
    fn mutate_items(
        &self,
        id: StoreId,
        edit: impl FnOnce(&mut Vec<BinaryHV>, usize) -> Result<(), MutateError>,
    ) -> Result<u64, MutateError> {
        let mut slots = self.slots.write().unwrap_or_else(|p| p.into_inner());
        let slot = slots.get_mut(id.0).ok_or(MutateError::UnknownStore)?;
        let current = slot.snapshot.as_ref().ok_or(MutateError::UnknownStore)?;
        let dim = current.dim();
        let ca90_backed = current.codebook().is_ca90();
        let mut items = if ca90_backed {
            (0..current.codebook().len())
                .map(|i| current.codebook().materialize_item(i))
                .collect()
        } else {
            current.codebook().items().to_vec()
        };
        edit(&mut items, dim)?;
        let codebook = if ca90_backed {
            let mut seeds = Vec::with_capacity(items.len());
            for it in &items {
                let seed = it.words()[..FOLD_WORDS].to_vec();
                if ca90::expand_vector(&seed, FOLD_BITS, dim) != *it {
                    return Err(MutateError::IncompressibleItem);
                }
                seeds.push(seed);
            }
            BinaryCodebook::ca90_from_seeds(&seeds, dim, None)
        } else {
            BinaryCodebook::from_items_sketched(dim, items, None)
        };
        let epoch = slot.epoch + 1;
        let resonator = current.resonator.clone();
        let next = StoreSnapshot::build(id, epoch, slot.name.clone(), codebook, resonator, slot.spec);
        slot.snapshot = Some(Arc::new(next));
        slot.epoch = epoch;
        Ok(epoch)
    }

    /// Slots ever issued (live + tombstoned) — the upper bound on
    /// `StoreId` indices.
    pub fn len(&self) -> usize {
        self.read().len()
    }

    pub fn is_empty(&self) -> bool {
        self.read().is_empty()
    }

    /// The serve hot path's seal: atomically resolve a store id to its
    /// currently published snapshot and cache. `None` for ids never
    /// issued or dropped (the engine answers those with
    /// [`super::ServeError::UnknownStore`] instead of panicking).
    #[allow(clippy::type_complexity)]
    pub fn live(
        &self,
        id: StoreId,
    ) -> Option<(Arc<StoreSnapshot>, Option<Arc<ResponseCache>>)> {
        let slots = self.read();
        let slot = slots.get(id.0)?;
        let snap = slot.snapshot.as_ref()?.clone();
        Some((snap, slot.cache.clone()))
    }

    /// The currently published snapshot for `id`, if live.
    pub fn snapshot_of(&self, id: StoreId) -> Option<Arc<StoreSnapshot>> {
        self.read().get(id.0)?.snapshot.clone()
    }

    /// The response cache for `id`'s slot (present even after a drop, so
    /// late counter reads don't race the tombstone).
    pub fn cache_of(&self, id: StoreId) -> Option<Arc<ResponseCache>> {
        self.read().get(id.0)?.cache.clone()
    }

    /// The latest epoch ever published in `id`'s slot — `Some` even for
    /// tombstones (the epoch the store died at); `None` only for ids
    /// never issued.
    pub fn epoch_of(&self, id: StoreId) -> Option<u64> {
        self.read().get(id.0).map(|s| s.epoch)
    }

    /// Whether `id` currently has a published snapshot.
    pub fn is_live(&self, id: StoreId) -> bool {
        self.read()
            .get(id.0)
            .is_some_and(|s| s.snapshot.is_some())
    }

    /// All live snapshots, in [`StoreId`] order.
    pub fn store_views(&self) -> Vec<Arc<StoreSnapshot>> {
        self.read()
            .iter()
            .filter_map(|s| s.snapshot.clone())
            .collect()
    }

    /// Look a **live** store's id up by name (dropped stores release
    /// their names).
    pub fn by_name(&self, name: &str) -> Option<StoreId> {
        self.read()
            .iter()
            .position(|s| s.snapshot.is_some() && s.name == name)
            .map(StoreId)
    }

    /// Every id ever issued, in order (including tombstones).
    pub fn ids(&self) -> Vec<StoreId> {
        (0..self.len()).map(StoreId).collect()
    }

    /// Ids with a currently published snapshot, in order.
    pub fn live_ids(&self) -> Vec<StoreId> {
        self.read()
            .iter()
            .enumerate()
            .filter(|(_, s)| s.snapshot.is_some())
            .map(|(i, _)| StoreId(i))
            .collect()
    }

    /// Step `id`'s persistent degraded-mode bit through `h` at the
    /// observed lane `depth`; returns the successor state. Tombstoned or
    /// unknown ids report healthy (their tickets answer `UnknownStore`
    /// before degradation matters).
    pub fn degrade_step(&self, id: StoreId, h: Hysteresis, depth: usize) -> bool {
        let slots = self.read();
        let Some(slot) = slots.get(id.0) else {
            return false;
        };
        if slot.snapshot.is_none() {
            return false;
        }
        let next = h.next(slot.degraded.load(Ordering::Relaxed), depth);
        slot.degraded.store(next, Ordering::Relaxed);
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::vsa::{CleanupMemory, RealCodebook};

    fn codebook(seed: u64, items: usize, dim: usize) -> BinaryCodebook {
        let mut rng = Rng::new(seed);
        BinaryCodebook::random(&mut rng, items, dim)
    }

    #[test]
    fn register_assigns_sequential_ids_and_lookups_work() {
        let mut reg = StoreRegistry::new();
        let a = reg.register("alpha", &codebook(1, 16, 512), None, StoreSpec::default());
        let b = reg.register(
            "beta",
            &codebook(2, 24, 1024),
            None,
            StoreSpec {
                shards: 2,
                cache_capacity: 0,
                ..StoreSpec::default()
            },
        );
        assert_eq!(a, StoreId(0));
        assert_eq!(b, StoreId(1));
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.by_name("beta"), Some(b));
        assert_eq!(reg.by_name("gamma"), None);
        let beta = reg.snapshot_of(b).unwrap();
        assert_eq!(beta.name(), "beta");
        assert_eq!(beta.epoch(), 0);
        assert_eq!(beta.dim(), 1024);
        assert_eq!(beta.len(), 24);
        assert_eq!(beta.n_shards(), 2);
        assert!(reg.cache_of(b).is_none(), "capacity 0 disables the cache");
        assert!(reg.cache_of(a).is_some());
        assert!(reg.snapshot_of(StoreId(7)).is_none(), "unknown ids are None");
        assert!(reg.live(StoreId(7)).is_none());
        assert_eq!(reg.ids(), vec![a, b]);
        assert_eq!(reg.live_ids(), vec![a, b]);
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn duplicate_names_are_refused() {
        let mut reg = StoreRegistry::new();
        reg.register("dup", &codebook(3, 8, 256), None, StoreSpec::default());
        reg.register("dup", &codebook(4, 8, 256), None, StoreSpec::default());
    }

    #[test]
    fn single_wraps_one_default_store() {
        let mut rng = Rng::new(5);
        let cb = codebook(5, 12, 512);
        let res = Resonator::new(
            (0..2)
                .map(|_| RealCodebook::random_bipolar(&mut rng, 4, 256))
                .collect(),
            10,
        );
        let reg = StoreRegistry::single(&cb, Some(res), StoreSpec::default());
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.by_name("default"), Some(StoreId::DEFAULT));
        let s = reg.snapshot_of(StoreId::DEFAULT).unwrap();
        assert_eq!(s.fact_dim(), Some(256));
    }

    #[test]
    fn mutations_publish_monotonic_epochs_and_bit_exact_snapshots() {
        let mut rng = Rng::new(9);
        let cb = codebook(9, 8, 512);
        let mut reg = StoreRegistry::new();
        let id = reg.register("m", &cb, None, StoreSpec { shards: 3, ..StoreSpec::default() });
        assert_eq!(reg.epoch_of(id), Some(0));

        // insert: epoch 1, the new item lands at the old len
        let item = BinaryHV::random(&mut rng, 512);
        assert_eq!(reg.insert_item(id, item.clone()), Ok(1));
        let snap = reg.snapshot_of(id).unwrap();
        assert_eq!(snap.epoch(), 1);
        assert_eq!(snap.len(), 9);
        assert_eq!(snap.codebook().item(8), &item);

        // the rebuilt sharded scan is bit-identical to a sequential
        // oracle over the same mutated item list
        let oracle = CleanupMemory::new(snap.codebook().clone());
        let queries: Vec<BinaryHV> = (0..12).map(|_| BinaryHV::random(&mut rng, 512)).collect();
        let sharded = snap.cleanup();
        let (got, _, _) = sharded.recall_batch_stats(&queries, 2);
        for (q, query) in queries.iter().enumerate() {
            assert_eq!(got[q], oracle.recall(query), "query {q}");
        }

        // delete: epoch 2, indices shift down
        let survivor = snap.codebook().item(1).clone();
        assert_eq!(reg.delete_item(id, 0), Ok(2));
        let snap2 = reg.snapshot_of(id).unwrap();
        assert_eq!(snap2.epoch(), 2);
        assert_eq!(snap2.len(), 8);
        assert_eq!(snap2.codebook().item(0), &survivor);

        // the epoch-1 snapshot is untouched — what an in-flight batch
        // sealed against it keeps scanning
        assert_eq!(snap.len(), 9);
        assert_eq!(snap.epoch(), 1);

        // refusals leave the epoch alone
        assert_eq!(
            reg.insert_item(id, BinaryHV::zeros(256)),
            Err(MutateError::DimensionMismatch)
        );
        assert_eq!(reg.delete_item(id, 99), Err(MutateError::BadIndex));
        assert_eq!(reg.epoch_of(id), Some(2));
    }

    #[test]
    fn delete_refuses_to_empty_a_store() {
        let mut reg = StoreRegistry::new();
        let id = reg.register("solo", &codebook(11, 1, 256), None, StoreSpec::default());
        assert_eq!(reg.delete_item(id, 0), Err(MutateError::WouldEmpty));
        assert!(reg.is_live(id));
        assert_eq!(reg.epoch_of(id), Some(0));
    }

    #[test]
    fn drop_tombstones_and_ids_are_never_reused() {
        let mut reg = StoreRegistry::new();
        let a = reg.register("a", &codebook(21, 8, 256), None, StoreSpec::default());
        let b = reg.register("b", &codebook(22, 8, 256), None, StoreSpec::default());
        reg.insert_item(b, BinaryHV::zeros(256)).unwrap();
        // a batch already holding b's snapshot keeps it across the drop
        let sealed = reg.snapshot_of(b).unwrap();
        assert_eq!(reg.drop_store(b), Ok(()));
        assert!(!reg.is_live(b));
        assert!(reg.live(b).is_none());
        assert!(reg.snapshot_of(b).is_none());
        assert_eq!(reg.epoch_of(b), Some(1), "death epoch stays readable");
        assert_eq!(reg.drop_store(b), Err(MutateError::UnknownStore));
        assert_eq!(
            reg.insert_item(b, BinaryHV::zeros(256)),
            Err(MutateError::UnknownStore)
        );
        assert_eq!(sealed.len(), 9, "sealed snapshot outlives the drop");

        // name is reusable, id is not: the replacement gets a fresh slot
        let b2 = reg.create_store("b", &codebook(23, 4, 256), None, StoreSpec::default());
        let b2 = b2.unwrap();
        assert_eq!(b2, StoreId(2), "tombstoned slot is never recycled");
        assert_eq!(reg.by_name("b"), Some(b2));
        assert_eq!(reg.snapshot_of(b2).unwrap().epoch(), 0);
        assert_eq!(reg.live_ids(), vec![a, b2]);
        assert_eq!(reg.ids().len(), 3);

        // live duplicate names are still refused at serve time
        assert_eq!(
            reg.create_store("a", &codebook(24, 4, 256), None, StoreSpec::default())
                .unwrap_err(),
            MutateError::DuplicateName
        );
    }

    #[test]
    fn concurrent_mutations_serialize_into_distinct_epochs() {
        let mut reg = StoreRegistry::new();
        let id = reg.register("c", &codebook(31, 4, 256), None, StoreSpec::default());
        let reg = std::sync::Arc::new(reg);
        let mut handles = Vec::new();
        for t in 0..4 {
            let reg = reg.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                let mut epochs = Vec::new();
                for _ in 0..8 {
                    let e = reg.insert_item(id, BinaryHV::random(&mut rng, 256)).unwrap();
                    epochs.push(e);
                }
                epochs
            }));
        }
        let mut all: Vec<u64> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (1..=32).collect::<Vec<u64>>(), "every publish got a distinct epoch");
        assert_eq!(reg.epoch_of(id), Some(32));
        assert_eq!(reg.snapshot_of(id).unwrap().len(), 4 + 32);
    }

    #[test]
    fn ca90_store_mutations_stay_seeds_only() {
        let mut rng = Rng::new(51);
        let seeds: Vec<Vec<u64>> = (0..10)
            .map(|_| (0..8).map(|_| rng.next_u64()).collect())
            .collect();
        let cb = BinaryCodebook::ca90_from_seeds(&seeds, 1024, None);
        let mut reg = StoreRegistry::new();
        let id = reg.register(
            "compressed",
            &cb,
            None,
            StoreSpec { shards: 2, ..StoreSpec::default() },
        );
        let snap = reg.snapshot_of(id).unwrap();
        assert_eq!(snap.backing_name(), "ca90");
        assert!(
            snap.row_resident_bytes() < 10 * 1024 / 8,
            "shards must hold seeds, not rows"
        );
        // an expansion of a fresh seed is compressible and admitted
        let seed: Vec<u64> = (0..8).map(|_| rng.next_u64()).collect();
        let item = crate::vsa::ca90::expand_vector(&seed, 512, 1024);
        assert_eq!(reg.insert_item(id, item.clone()), Ok(1));
        let snap = reg.snapshot_of(id).unwrap();
        assert_eq!(snap.len(), 11);
        assert!(snap.codebook().is_ca90(), "backing survives the rebuild");
        assert_eq!(snap.codebook().materialize_item(10), item);
        // an arbitrary vector cannot be stored as a seed losslessly
        assert_eq!(
            reg.insert_item(id, BinaryHV::random(&mut rng, 1024)),
            Err(MutateError::IncompressibleItem)
        );
        assert_eq!(reg.epoch_of(id), Some(1), "refusal leaves the epoch alone");
        // delete keeps the backing too
        assert_eq!(reg.delete_item(id, 0), Ok(2));
        assert!(reg.snapshot_of(id).unwrap().codebook().is_ca90());
    }

    #[test]
    fn spec_cascade_applies_to_snapshot_shards_and_survives_mutation() {
        let cb = codebook(52, 40, 8192);
        let mut reg = StoreRegistry::new();
        let id = reg.register(
            "cascaded",
            &cb,
            None,
            StoreSpec {
                shards: 2,
                sketch_cascade: Some(128),
                ..StoreSpec::default()
            },
        );
        let snap = reg.snapshot_of(id).unwrap();
        for s in 0..snap.cleanup().n_shards() {
            assert_eq!(
                snap.cleanup().store().shard(s).sketch().unwrap().coarse_bits(),
                128,
                "shard {s}"
            );
        }
        let no_casc = StoreSnapshot::build(
            StoreId(9),
            0,
            "plain".into(),
            cb.clone(),
            None,
            StoreSpec { shards: 2, ..StoreSpec::default() },
        );
        assert!(
            snap.sketch_resident_bytes() > no_casc.sketch_resident_bytes(),
            "coarse level adds resident sidecar bytes"
        );
        // cascade config rides the spec through mutation rebuilds
        let mut rng = Rng::new(53);
        reg.insert_item(id, BinaryHV::random(&mut rng, 8192)).unwrap();
        let snap = reg.snapshot_of(id).unwrap();
        assert_eq!(
            snap.cleanup().store().shard(0).sketch().unwrap().coarse_bits(),
            128
        );
    }

    #[test]
    fn degrade_step_is_persistent_per_slot() {
        let mut reg = StoreRegistry::new();
        let id = reg.register("d", &codebook(41, 4, 256), None, StoreSpec::default());
        let h = Hysteresis::new(4); // enter ≥4, exit <2
        assert!(!reg.degrade_step(id, h, 3));
        assert!(reg.degrade_step(id, h, 4), "crosses enter");
        assert!(reg.degrade_step(id, h, 3), "holds between exit and enter");
        assert!(!reg.degrade_step(id, h, 1), "drains below exit");
        assert!(!reg.degrade_step(StoreId(9), h, 100), "unknown ids report healthy");
        reg.drop_store(id).unwrap();
        assert!(!reg.degrade_step(id, h, 100), "tombstones report healthy");
    }

    #[test]
    fn hysteresis_thresholds_derive_and_clamp() {
        assert_eq!(Hysteresis::new(8), Hysteresis { enter: 8, exit: 4 });
        // exit never reaches 0: depth-1 enter still needs depth 0 to exit
        assert_eq!(Hysteresis::new(1), Hysteresis { enter: 1, exit: 1 });
        assert_eq!(Hysteresis::new(0), Hysteresis { enter: 1, exit: 1 });
        // explicit exit clamps into 1..=enter
        assert_eq!(Hysteresis::with_exit(4, 0), Hysteresis { enter: 4, exit: 1 });
        assert_eq!(Hysteresis::with_exit(4, 9), Hysteresis { enter: 4, exit: 4 });
        let spec = StoreSpec {
            degrade_depth: Some(6),
            ..StoreSpec::default()
        };
        assert_eq!(
            spec.degrade_hysteresis(),
            Some(Hysteresis { enter: 6, exit: 3 })
        );
        let spec = StoreSpec {
            degrade_depth: Some(6),
            degrade_exit: Some(2),
            ..StoreSpec::default()
        };
        assert_eq!(
            spec.degrade_hysteresis(),
            Some(Hysteresis { enter: 6, exit: 2 })
        );
        assert_eq!(StoreSpec::default().degrade_hysteresis(), None);
    }

    #[test]
    fn hysteresis_state_machine_enters_holds_and_exits() {
        let h = Hysteresis::new(4); // enter at ≥4, exit below 2
        let mut deg = false;
        for (depth, expect) in [
            (3, false), // below enter: stays healthy
            (4, true),  // crosses enter
            (3, true),  // dips below enter but not below exit: holds
            (2, true),  // still ≥ exit: holds
            (1, false), // below exit: recovers
            (3, false), // healthy again; below enter stays healthy
            (5, true),  // re-enters
        ] {
            deg = h.next(deg, depth);
            assert_eq!(deg, expect, "depth {depth}");
        }
    }

    #[test]
    fn hysteresis_does_not_flap_at_the_boundary() {
        // A lane oscillating one ticket around the old single threshold
        // (depth 4 ↔ 3) flips exactly once under hysteresis, never per
        // observation.
        let h = Hysteresis::new(4);
        let mut deg = false;
        let mut transitions = 0;
        for depth in [4, 3, 4, 3, 4, 3, 4, 3] {
            let next = h.next(deg, depth);
            if next != deg {
                transitions += 1;
            }
            deg = next;
        }
        assert_eq!(transitions, 1, "one enter transition, zero exits");
        assert!(deg, "still degraded while hovering above exit");
    }
}
