//! The serving engine: persistent worker event loops behind a blocking
//! `submit()` client API, serving every store in a [`StoreRegistry`].
//!
//! Clients (any thread) enqueue tickets through the bounded admission
//! queue; `workers` threads each run gather → execute forever, coalescing
//! concurrent requests into micro-batches that execution splits per
//! `(store, request class)`. Admission validates the request's store id
//! up front (unknown ids are refused with [`ServeError::UnknownStore`]
//! before they ever occupy queue capacity), then applies two-level
//! backpressure: global capacity ([`ServeError::Overloaded`]) and the
//! target store's own lane quota ([`ServeError::TenantOverloaded`]) — a
//! flooding tenant sheds its *own* traffic while other stores' lanes stay
//! admittable, and the queue's deficit-round-robin pop keeps service
//! shares proportional to store weights.
//!
//! Shutdown comes in two grades, and both answer every admitted ticket —
//! no waiter is ever left to spin out its own timeout:
//! [`ServeEngine::shutdown`] is graceful (close the queue, let the
//! workers *execute* the backlog, join), while `Drop` and
//! [`ServeEngine::shutdown_now`] abort (close the queue, drain the
//! backlog, fill each drained ticket with [`ServeError::ShuttingDown`],
//! join — counted in [`StatsSnapshot::shed_shutdown`]).
//!
//! Async completion has two shapes: per-ticket polling via
//! [`PendingResponse::try_wait`], and the completion-queue path
//! ([`ServeEngine::submit_with_completion`]) where finished outcomes are
//! delivered to a caller-owned [`CompletionQueue`] tagged with a
//! caller-chosen id — one blocking consumer harvests any number of
//! in-flight requests without polling. The TCP front-end's connection
//! writer threads ([`super::net`]) are built on it.
//!
//! Worker panics are contained: `execute` runs under `catch_unwind`, a
//! poisoned batch's still-unanswered tickets are filled with
//! [`ServeError::Internal`], the worker's scratch is rebuilt, and the
//! loop continues — one bad batch (or one injected fault) never takes
//! the engine down.
//!
//! Stores are live-mutable while the engine serves: [`ServeEngine::insert_item`],
//! [`ServeEngine::delete_item`], [`ServeEngine::create_store`], and
//! [`ServeEngine::drop_store`] delegate to the registry's epoch-based
//! snapshot swap. In-flight batches finish against the snapshot they were
//! sealed on; a ticket admitted for a store that is dropped before its
//! batch executes is answered [`ServeError::UnknownStore`] at execute
//! time (the admit-vs-drop race is answered, never a panic). Creating a
//! store also grows the stats table ([`ServeStats::register_store`]) and
//! opens its queue lane ([`AdmissionQueue::set_lane`]) so observability
//! and fair scheduling cover it from its first request.

use super::batcher::{self, BatchPolicy, ExecCtx, WorkerScratch};
use super::cache::CacheConfig;
use super::faults::{FaultConfig, FaultPlan};
use super::queue::{AdmissionQueue, CompletionQueue, LaneSpec, Priority, ResponseSlot, Ticket};
use super::registry::{MutateError, StoreId, StoreRegistry, StoreSpec};
use super::stats::{ServeStats, StatsSnapshot, StoreMemory};
use super::trace::{StageMarks, TraceEvent, TraceRing};
use super::{RequestKind, ServeError, ServeRequest, ServeResponse};
use crate::vsa::{BinaryCodebook, BinaryHV, Resonator};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Engine sizing and policy knobs. The store-shaped fields (`shards`,
/// `sketch_bits`, `cache_capacity`, `cache_shards`) are the spec applied
/// to the single store the [`ServeEngine::start`] wrapper registers (and
/// the default [`StoreSpec::from_engine`] pulls for registry callers).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker event-loop threads (each gathers and executes whole
    /// micro-batches).
    pub workers: usize,
    /// Codebook shards per store (single-store wrapper / spec default).
    pub shards: usize,
    /// Scoped scan threads *per worker* fanning out across shards
    /// (1 = each worker scans its batch serially, shard by shard).
    pub scan_threads: usize,
    /// Max requests coalesced into one micro-batch.
    pub max_batch: usize,
    /// How long a worker holds the batch window open for stragglers.
    pub max_delay: Duration,
    /// Admission queue bound (reject-on-full backpressure).
    pub queue_capacity: usize,
    /// Deadline applied by [`ServeEngine::submit`].
    pub default_deadline: Duration,
    /// Explicit sketch width (bits) for the shards' prefilter sidecars;
    /// `None` keeps the per-dimension default, `Some(0)` disables the
    /// sidecars (incremental bounds still prune). `--sketch-bits`.
    pub sketch_bits: Option<usize>,
    /// Per-store response-cache entry budget; 0 disables the cache.
    /// `--cache`.
    pub cache_capacity: usize,
    /// Response-cache lock shards. `--cache-shards`.
    pub cache_shards: usize,
    /// Fault-injection plan applied at the engine's injection points;
    /// `None` (the default) injects nothing. `--faults`.
    pub faults: Option<FaultConfig>,
    /// Capacity of the trace-event ring buffer (drop-oldest on
    /// overflow); `None` (the default) disables event tracing — the
    /// always-on stage-latency decomposition in [`StatsSnapshot`] is
    /// unaffected. `--trace` / `--trace-capacity` / `NSCOG_TRACE`.
    pub trace_capacity: Option<usize>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        let cache = CacheConfig::default();
        EngineConfig {
            workers: 2,
            shards: 4,
            scan_threads: 1,
            max_batch: 32,
            max_delay: Duration::from_micros(200),
            queue_capacity: 1024,
            default_deadline: Duration::from_secs(5),
            sketch_bits: None,
            cache_capacity: cache.capacity,
            cache_shards: cache.shards,
            faults: None,
            trace_capacity: None,
        }
    }
}

struct Shared {
    queue: AdmissionQueue,
    registry: StoreRegistry,
    stats: ServeStats,
    policy: BatchPolicy,
    scan_threads: usize,
    faults: Option<FaultPlan>,
    /// Trace-event ring, when `EngineConfig::trace_capacity` asked for one.
    trace: Option<TraceRing>,
}

/// Handle to an in-flight asynchronous submission.
pub struct PendingResponse {
    slot: ResponseSlot,
    enqueued: Instant,
}

impl PendingResponse {
    /// Block until the engine answers.
    pub fn wait(self) -> Result<ServeResponse, ServeError> {
        self.slot.wait()
    }

    /// Block until the engine answers; also return the request's total
    /// latency (enqueue → worker fill), for open-loop accounting.
    pub fn wait_with_latency(self) -> (Result<ServeResponse, ServeError>, Duration) {
        let (outcome, completed) = self.slot.wait_timed();
        (outcome, completed.duration_since(self.enqueued))
    }

    /// Non-blocking poll: `Ok((outcome, latency))` once the engine has
    /// answered, `Err(self)` while the request is still in flight (the
    /// handle is returned so the caller can poll again or fall back to a
    /// blocking wait). This is the open-loop load generator's harvest
    /// path and the first step of the async client API.
    pub fn try_wait(self) -> Result<(Result<ServeResponse, ServeError>, Duration), PendingResponse> {
        match self.slot.try_take() {
            Some((outcome, completed)) => Ok((outcome, completed.duration_since(self.enqueued))),
            None => Err(self),
        }
    }

    /// Bounded-blocking poll: wait up to `timeout` for the answer, then
    /// hand the handle back (`Err(self)`) if the engine still hasn't
    /// filled it.
    pub fn wait_timeout(
        self,
        timeout: Duration,
    ) -> Result<(Result<ServeResponse, ServeError>, Duration), PendingResponse> {
        match self.slot.wait_until(Instant::now() + timeout) {
            Some((outcome, completed)) => Ok((outcome, completed.duration_since(self.enqueued))),
            None => Err(self),
        }
    }
}

/// A running serving engine. Cheap to share by reference across client
/// threads (`submit` takes `&self`).
pub struct ServeEngine {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    cfg: EngineConfig,
}

impl ServeEngine {
    /// Single-store convenience: register `codebook` (and the optional
    /// `resonator`) as store 0 under the config's store knobs, then start
    /// serving. Behavior is bit-identical to the pre-registry engine;
    /// requests built with [`ServeRequest::recall`] and friends route
    /// here. `Err` only if the OS refuses to spawn a worker thread.
    pub fn start(
        codebook: &BinaryCodebook,
        resonator: Option<Resonator>,
        cfg: EngineConfig,
    ) -> std::io::Result<ServeEngine> {
        let registry = StoreRegistry::single(codebook, resonator, StoreSpec::from_engine(&cfg));
        Self::start_registry(registry, cfg)
    }

    /// Take ownership of a prepared [`StoreRegistry`], spawn the worker
    /// loops, and start serving all of its stores behind one queue. Each
    /// store gets its own queue lane, weighted and quota-capped by its
    /// [`StoreSpec`] (`quota: None` means the lane is bounded only by the
    /// global capacity — the pre-quota behavior).
    ///
    /// `Err` if the OS refuses to spawn a worker thread; any workers
    /// already spawned are shut down (queue closed, threads joined)
    /// before the error is returned, so a partial failure leaks nothing.
    pub fn start_registry(
        registry: StoreRegistry,
        cfg: EngineConfig,
    ) -> std::io::Result<ServeEngine> {
        assert!(cfg.workers >= 1, "engine needs at least one worker");
        assert!(
            !registry.is_empty(),
            "engine needs at least one registered store"
        );
        let views = registry.store_views();
        let store_shapes: Vec<(&str, usize)> =
            views.iter().map(|s| (s.name(), s.n_shards())).collect();
        let stats = ServeStats::new(&store_shapes);
        let lanes: Vec<LaneSpec> = views
            .iter()
            .map(|s| LaneSpec {
                weight: s.spec().weight.max(1),
                quota: s.spec().quota.unwrap_or(cfg.queue_capacity),
            })
            .collect();
        drop(views);
        let shared = Arc::new(Shared {
            queue: AdmissionQueue::with_lanes(cfg.queue_capacity, &lanes),
            registry,
            stats,
            policy: BatchPolicy {
                max_batch: cfg.max_batch.max(1),
                max_delay: cfg.max_delay,
            },
            scan_threads: cfg.scan_threads.max(1),
            faults: cfg.faults.map(FaultPlan::new),
            trace: cfg.trace_capacity.map(TraceRing::new),
        });
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let sh = Arc::clone(&shared);
            match std::thread::Builder::new()
                .name(format!("nscog-serve-{w}"))
                .spawn(move || worker_loop(&sh))
            {
                Ok(h) => workers.push(h),
                Err(e) => {
                    shared.queue.close();
                    for h in workers {
                        let _ = h.join();
                    }
                    return Err(e);
                }
            }
        }
        Ok(ServeEngine {
            shared,
            workers,
            cfg,
        })
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// The engine's store table: `registry().store_views()` for every
    /// live store's current snapshot, `registry().snapshot_of(id)` for
    /// one. (The old single-store `store()` accessor is gone — with
    /// several stores behind the engine it had no honest meaning.)
    pub fn registry(&self) -> &StoreRegistry {
        &self.shared.registry
    }

    /// Hot-create a store while serving: a fresh never-reused id at
    /// epoch 0, with its own stats section and queue lane, admittable
    /// the moment this returns. Refuses names owned by a live store.
    pub fn create_store(
        &self,
        name: &str,
        codebook: &BinaryCodebook,
        resonator: Option<Resonator>,
        spec: StoreSpec,
    ) -> Result<StoreId, MutateError> {
        let id = self
            .shared
            .registry
            .create_store(name, codebook, resonator, spec)?;
        let shards = self
            .shared
            .registry
            .snapshot_of(id)
            .map(|s| s.n_shards())
            .unwrap_or(1);
        // Grow the stats table and the lane config to cover the new
        // slot. Stats sections and registry slots are both append-only
        // and id-ordered, so the new section lands at `id.index()`.
        self.shared.stats.register_store(name, shards);
        self.shared.queue.set_lane(
            id,
            LaneSpec {
                weight: spec.weight.max(1),
                quota: spec.quota.unwrap_or(self.cfg.queue_capacity),
            },
        );
        Ok(id)
    }

    /// Hot-drop a store: tombstones its registry slot. Already-admitted
    /// tickets are answered [`ServeError::UnknownStore`] when their
    /// batch executes; in-flight batches sealed before the drop finish
    /// against the snapshot they hold. The id is never reused, so the
    /// store's final stats/cache counters stay readable (its section
    /// reports `live: false`).
    pub fn drop_store(&self, id: StoreId) -> Result<(), MutateError> {
        self.shared.registry.drop_store(id)
    }

    /// Live item insert: publishes the store's next epoch with `item`
    /// appended (its index is the pre-insert `len()`). Returns the new
    /// epoch. Batches already sealed keep their old snapshot; the
    /// epoch-keyed cache makes stale hits structurally impossible.
    pub fn insert_item(&self, id: StoreId, item: BinaryHV) -> Result<u64, MutateError> {
        self.shared.registry.insert_item(id, item)
    }

    /// Live item delete by index (`Vec::remove` semantics — later
    /// indices shift down). Returns the new epoch. Refuses to empty the
    /// store; [`Self::drop_store`] is the way to retire one.
    pub fn delete_item(&self, id: StoreId, index: usize) -> Result<u64, MutateError> {
        self.shared.registry.delete_item(id, index)
    }

    /// The store's current epoch (`Some(0)` until its first mutation;
    /// also `Some` for dropped stores — the tombstone keeps the final
    /// epoch); `None` only for never-issued ids.
    pub fn store_epoch(&self, id: StoreId) -> Option<u64> {
        self.shared.registry.epoch_of(id)
    }

    /// The live fault-injection plan, when the config carried one. Chaos
    /// tests retune its probabilities mid-run (`set_probs`) to force a
    /// fault deterministically and then turn it back off.
    pub fn faults(&self) -> Option<&FaultPlan> {
        self.shared.faults.as_ref()
    }

    /// Blocking submit with default priority and deadline.
    pub fn submit(&self, request: ServeRequest) -> Result<ServeResponse, ServeError> {
        self.submit_with(request, Priority::Normal, self.cfg.default_deadline)
    }

    /// Blocking submit with explicit priority and relative deadline.
    pub fn submit_with(
        &self,
        request: ServeRequest,
        priority: Priority,
        deadline: Duration,
    ) -> Result<ServeResponse, ServeError> {
        self.submit_async(request, priority, deadline)?.wait()
    }

    /// Non-blocking enqueue: admission control runs immediately (so
    /// `Overloaded`/`TenantOverloaded`/`ShuttingDown`/`UnknownStore`
    /// surface here), execution is awaited through the returned
    /// [`PendingResponse`]. This is the open-loop load generator's entry
    /// point.
    pub fn submit_async(
        &self,
        request: ServeRequest,
        priority: Priority,
        deadline: Duration,
    ) -> Result<PendingResponse, ServeError> {
        if !self.shared.registry.is_live(request.store) {
            self.shared.stats.record_unsupported(1);
            return Err(ServeError::UnknownStore);
        }
        if let Some(f) = &self.shared.faults {
            if f.should_reject_admission() {
                // injected admission flake, indistinguishable from a
                // full queue to the caller
                self.shared.stats.record_rejected();
                return Err(ServeError::Overloaded);
            }
        }
        let store = request.store;
        let slot = ResponseSlot::new();
        let now = Instant::now();
        let ticket = Ticket {
            request,
            priority,
            slot: slot.clone(),
            enqueued: now,
            deadline: now + deadline,
            marks: StageMarks::new(now),
        };
        match self.shared.queue.push(ticket) {
            Ok(()) => Ok(PendingResponse {
                slot,
                enqueued: now,
            }),
            Err((_, why)) => {
                let err = why.to_serve_error();
                if err == ServeError::TenantOverloaded {
                    self.shared.stats.record_tenant_rejected(store);
                } else {
                    self.shared.stats.record_rejected();
                }
                Err(err)
            }
        }
    }

    /// Completion-queue submit — the polling-free half of the async API.
    /// Admission control runs synchronously (refusals come back as
    /// `Err`, exactly like [`ServeEngine::submit_async`], and push
    /// *nothing* to the queue — the caller answers those itself); an
    /// admitted request's outcome is later delivered to `cq` as a
    /// [`super::queue::Completion`] tagged `tag`, whatever terminates it
    /// (worker fill, deadline expiry, contained panic, abort shutdown).
    /// One consumer blocking on `cq.pop_blocking()` therefore harvests
    /// any number of in-flight requests — the connection writer threads
    /// in [`super::net`] run exactly this loop.
    pub fn submit_with_completion(
        &self,
        request: ServeRequest,
        priority: Priority,
        deadline: Duration,
        cq: &CompletionQueue,
        tag: u64,
    ) -> Result<(), ServeError> {
        self.submit_with_completion_wire(request, priority, deadline, cq, tag, Duration::ZERO)
    }

    /// [`ServeEngine::submit_with_completion`] for wire-borne requests:
    /// `net_in` is the socket read + frame decode span the network
    /// front-end measured *before* this call. It rides the ticket's
    /// [`StageMarks`] into the per-class `net_in` stage lane, so the
    /// inbound wire hop shows up in the stage decomposition next to
    /// queue/batch/kernel/fill (in-process callers pass zero and are
    /// skipped by the lane).
    pub fn submit_with_completion_wire(
        &self,
        request: ServeRequest,
        priority: Priority,
        deadline: Duration,
        cq: &CompletionQueue,
        tag: u64,
        net_in: Duration,
    ) -> Result<(), ServeError> {
        if !self.shared.registry.is_live(request.store) {
            self.shared.stats.record_unsupported(1);
            return Err(ServeError::UnknownStore);
        }
        if let Some(f) = &self.shared.faults {
            if f.should_reject_admission() {
                self.shared.stats.record_rejected();
                return Err(ServeError::Overloaded);
            }
        }
        let store = request.store;
        let now = Instant::now();
        let mut marks = StageMarks::new(now);
        if !net_in.is_zero() {
            marks.mark_net_in(net_in.as_secs_f64());
        }
        let ticket = Ticket {
            request,
            priority,
            slot: ResponseSlot::with_completion(cq.clone(), tag),
            enqueued: now,
            deadline: now + deadline,
            marks,
        };
        match self.shared.queue.push(ticket) {
            Ok(()) => Ok(()),
            Err((_, why)) => {
                let err = why.to_serve_error();
                if err == ServeError::TenantOverloaded {
                    self.shared.stats.record_tenant_rejected(store);
                } else {
                    self.shared.stats.record_rejected();
                }
                Err(err)
            }
        }
    }

    /// Record the encode + socket-write span of one wire response into
    /// the per-class / per-store `net_out` stage lane (the network
    /// front-end's connection writers call this after each framed write
    /// completes — responses are accounted before they are written, so
    /// the outbound hop cannot ride the batch accounting).
    pub fn record_net_out(&self, store: StoreId, kind: RequestKind, dur: Duration) {
        self.shared.stats.record_net_out(store, kind, dur.as_secs_f64());
    }

    /// Metrics snapshot, including per-store response-cache counters for
    /// every store that runs one (and their engine-wide sum), each
    /// store's current epoch and liveness, resident-memory telemetry of
    /// each live store's snapshot (row payload, sketch sidecars, master
    /// codebook, and the `ram`/`ca90` backing), plus the live
    /// queue-depth and per-lane deficit gauges. Dropped stores keep
    /// their section — final counters stay readable — marked
    /// `live: false` (their `memory` is `None`: the snapshot is gone).
    pub fn stats(&self) -> StatsSnapshot {
        let mut snap = self.shared.stats.snapshot();
        let mut total = super::cache::CacheCounters::default();
        let mut any_cache = false;
        for (i, section) in snap.stores.iter_mut().enumerate() {
            let id = StoreId(i);
            section.cache = self.shared.registry.cache_of(id).map(|c| c.counters());
            section.epoch = self.shared.registry.epoch_of(id).unwrap_or(0);
            section.live = self.shared.registry.is_live(id);
            section.memory = self.shared.registry.snapshot_of(id).map(|s| StoreMemory {
                backing: s.backing_name(),
                row_bytes: s.row_resident_bytes(),
                sketch_bytes: s.sketch_resident_bytes(),
                master_bytes: s.master_resident_bytes(),
            });
            if let Some(c) = &section.cache {
                total.merge(c);
                any_cache = true;
            }
        }
        snap.cache = any_cache.then_some(total);
        let (depth, lanes) = self.shared.queue.gauges();
        snap.queue_depth = depth;
        snap.lanes = lanes;
        snap
    }

    /// The trace ring's current contents (oldest first) and its exact
    /// dropped-events count; `None` when the engine was started without
    /// [`EngineConfig::trace_capacity`].
    pub fn trace_snapshot(&self) -> Option<(Vec<TraceEvent>, u64)> {
        self.shared.trace.as_ref().map(|r| r.snapshot())
    }

    /// Configured trace-ring capacity, when tracing is on.
    pub fn trace_capacity(&self) -> Option<usize> {
        self.shared.trace.as_ref().map(|r| r.capacity())
    }

    /// Graceful shutdown: stop admissions, let the workers *execute*
    /// every already-admitted ticket, join. Every waiter gets a real
    /// outcome.
    pub fn shutdown(mut self) {
        self.shutdown_in_place();
    }

    /// Abort shutdown: stop admissions, drain the backlog without
    /// executing it — each drained ticket is answered
    /// [`ServeError::ShuttingDown`] immediately (counted in
    /// [`StatsSnapshot::shed_shutdown`]) — then join the workers.
    /// Tickets a worker had already popped still finish and keep their
    /// real outcome (slot fills are first-write-wins). This is also
    /// what `Drop` runs, so leaking an engine mid-chaos can never leave
    /// a `wait_timeout` caller spinning against an unfilled slot.
    pub fn shutdown_now(mut self) {
        self.abort_in_place();
    }

    fn shutdown_in_place(&mut self) {
        self.shared.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }

    fn abort_in_place(&mut self) {
        self.shared.queue.close();
        let mut shed = 0u64;
        for t in self.shared.queue.drain_all() {
            if t.slot.fill(Err(ServeError::ShuttingDown)) {
                shed += 1;
            }
        }
        if shed > 0 {
            self.shared.stats.record_shed_shutdown(shed);
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        self.abort_in_place();
    }
}

fn worker_loop(sh: &Shared) {
    let mut scratch = WorkerScratch::new();
    while let Some(batch) = batcher::gather(&sh.queue, &sh.policy, &sh.stats) {
        // Keep a handle on every ticket's slot before execution consumes
        // the batch, so a panicking batch can still be answered.
        let slots: Vec<(ResponseSlot, StoreId)> = batch
            .iter()
            .map(|t| (t.slot.clone(), t.request.store))
            .collect();
        let ctx = ExecCtx {
            registry: &sh.registry,
            stats: &sh.stats,
            scan_threads: sh.scan_threads,
            queue: Some(&sh.queue),
            trace: sh.trace.as_ref(),
            faults: sh.faults.as_ref(),
        };
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            batcher::execute(batch, &ctx, &mut scratch);
        }));
        if outcome.is_err() {
            // Containment — the in-place respawn: answer whatever the
            // poisoned batch left unanswered, rebuild the scratch (its
            // resonator buffers may have been mid-update when the panic
            // unwound through them), and keep serving.
            for (slot, store) in slots {
                if slot.fill(Err(ServeError::Internal)) {
                    sh.stats.record_internal(store, 1);
                }
            }
            scratch = WorkerScratch::new();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::registry::StoreId;
    use super::*;
    use crate::util::Rng;
    use crate::vsa::{BinaryHV, CleanupMemory};

    fn engine(cfg: EngineConfig, seed: u64) -> (ServeEngine, CleanupMemory) {
        let mut rng = Rng::new(seed);
        let cb = BinaryCodebook::random(&mut rng, 32, 1024);
        let cm = CleanupMemory::new(cb.clone());
        let eng = ServeEngine::start(&cb, None, cfg).expect("spawn serve workers");
        (eng, cm)
    }

    #[test]
    fn submit_round_trip_matches_oracle() {
        let (eng, cm) = engine(EngineConfig::default(), 1);
        let mut rng = Rng::new(2);
        for i in 0..8 {
            let q = BinaryHV::random(&mut rng, 1024);
            let got = eng.submit(ServeRequest::recall(q.clone())).unwrap();
            let (index, cosine) = cm.recall(&q);
            assert_eq!(got, ServeResponse::Recall { index, cosine }, "req {i}");
        }
        let snap = eng.stats();
        assert_eq!(snap.completed, 8);
        assert_eq!(snap.rejected, 0);
        assert_eq!(snap.stores.len(), 1, "single-store wrapper registers store 0");
        assert_eq!(snap.stores[0].completed, 8);
        // live stores carry resident-memory telemetry from the registry
        let mem = snap.stores[0].memory.expect("live store reports memory");
        assert_eq!(mem.backing, "ram");
        assert_eq!(mem.row_bytes, 32 * 1024 / 8, "sharded rows: 32 items x 1024 bits");
        assert!(mem.master_bytes >= 32 * 1024 / 8, "master codebook holds the rows too");
        assert_eq!(
            mem.total_bytes(),
            mem.row_bytes + mem.sketch_bytes + mem.master_bytes
        );
        eng.shutdown();
    }

    #[test]
    fn repeated_submits_hit_the_cache_with_identical_responses() {
        let (eng, cm) = engine(EngineConfig::default(), 9);
        let mut rng = Rng::new(10);
        let q = BinaryHV::random(&mut rng, 1024);
        let first = eng.submit(ServeRequest::recall(q.clone())).unwrap();
        let second = eng.submit(ServeRequest::recall(q.clone())).unwrap();
        assert_eq!(first, second);
        let (index, cosine) = cm.recall(&q);
        assert_eq!(first, ServeResponse::Recall { index, cosine });
        let snap = eng.stats();
        let cache = snap.cache.expect("default engine config enables the cache");
        assert_eq!(cache.hits, 1);
        assert_eq!(cache.misses, 1);
        assert_eq!(snap.stores[0].cache.unwrap().hits, 1);
        assert_eq!(snap.completed, 2);
        eng.shutdown();
    }

    #[test]
    fn cache_can_be_disabled() {
        let (eng, _) = engine(
            EngineConfig {
                cache_capacity: 0,
                ..EngineConfig::default()
            },
            11,
        );
        let mut rng = Rng::new(12);
        let q = BinaryHV::random(&mut rng, 1024);
        for _ in 0..2 {
            eng.submit(ServeRequest::recall(q.clone())).unwrap();
        }
        let snap = eng.stats();
        assert!(snap.cache.is_none());
        assert!(snap.stores[0].cache.is_none());
        eng.shutdown();
    }

    #[test]
    fn factorize_without_resonator_is_unsupported() {
        let (eng, _) = engine(EngineConfig::default(), 3);
        let got = eng.submit(ServeRequest::factorize(crate::vsa::RealHV::zeros(64)));
        assert_eq!(got, Err(ServeError::Unsupported));
    }

    #[test]
    fn unknown_store_is_refused_at_admission() {
        let (eng, _) = engine(EngineConfig::default(), 13);
        let got = eng.submit(ServeRequest::recall_on(StoreId(3), BinaryHV::zeros(1024)));
        assert_eq!(got, Err(ServeError::UnknownStore));
        let snap = eng.stats();
        assert_eq!(snap.unsupported, 1);
        assert_eq!(snap.completed, 0, "refused before reaching a worker");
        // the engine keeps serving valid store ids afterwards
        assert!(eng
            .submit(ServeRequest::recall(BinaryHV::zeros(1024)))
            .is_ok());
        eng.shutdown();
    }

    #[test]
    fn try_wait_polls_and_hands_the_handle_back() {
        // deterministic slot-level check: an unfilled pending response
        // returns itself, a filled one returns the outcome exactly once
        let slot = ResponseSlot::new();
        let p = PendingResponse {
            slot: slot.clone(),
            enqueued: Instant::now(),
        };
        let p = p.try_wait().expect_err("unfilled handle comes back");
        let p = p
            .wait_timeout(Duration::from_millis(5))
            .expect_err("timeout hands the handle back too");
        slot.fill(Err(ServeError::Overloaded));
        let (outcome, _lat) = p.try_wait().expect("filled handle resolves");
        assert_eq!(outcome, Err(ServeError::Overloaded));

        // end-to-end: poll a real submission to completion
        let (eng, cm) = engine(EngineConfig::default(), 15);
        let mut rng = Rng::new(16);
        let q = BinaryHV::random(&mut rng, 1024);
        let mut pending = eng
            .submit_async(
                ServeRequest::recall(q.clone()),
                Priority::Normal,
                Duration::from_secs(5),
            )
            .unwrap();
        let outcome = loop {
            match pending.try_wait() {
                Ok((outcome, _lat)) => break outcome,
                Err(p) => {
                    pending = p;
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
        };
        let (index, cosine) = cm.recall(&q);
        assert_eq!(outcome, Ok(ServeResponse::Recall { index, cosine }));
        eng.shutdown();
    }

    #[test]
    fn traced_engine_records_events_and_layers_gauges() {
        let (eng, _) = engine(
            EngineConfig {
                trace_capacity: Some(64),
                ..EngineConfig::default()
            },
            25,
        );
        assert_eq!(eng.trace_capacity(), Some(64));
        let mut rng = Rng::new(26);
        for _ in 0..6 {
            let q = BinaryHV::random(&mut rng, 1024);
            eng.submit(ServeRequest::recall(q)).unwrap();
        }
        let (events, dropped) = eng.trace_snapshot().expect("tracing is on");
        assert_eq!(dropped, 0, "capacity 64 holds 6 events");
        assert_eq!(events.len(), 6, "one trace event per completed response");
        for e in &events {
            // engine-path tickets carry the full lifecycle: queue wait,
            // batch wait, kernel bracket, fill — all bounded by e2e
            assert!(e.stages.queue_s > 0.0, "pop mark stamped by the queue");
            assert!(e.stages.sum() <= e.total_s + 1e-9);
        }
        let snap = eng.stats();
        assert_eq!(snap.lanes.len(), 1, "one gauge per store lane");
        assert_eq!(snap.queue_depth, 0, "drained after blocking submits");
        let stage_n: u64 = snap.stages.iter().map(|s| s.n).sum();
        assert_eq!(stage_n, 6, "stage breakdowns saw every response");
        // an untraced engine answers None but still decomposes stages
        let (untraced, _) = engine(EngineConfig::default(), 27);
        assert!(untraced.trace_snapshot().is_none());
        untraced.shutdown();
        eng.shutdown();
    }

    #[test]
    fn zero_deadline_requests_expire_not_execute() {
        let (eng, _) = engine(EngineConfig::default(), 4);
        let got = eng.submit_with(
            ServeRequest::recall(BinaryHV::zeros(1024)),
            Priority::Normal,
            Duration::from_secs(0),
        );
        assert_eq!(got, Err(ServeError::DeadlineExceeded));
        assert_eq!(eng.stats().expired, 1);
    }

    #[test]
    fn submit_after_shutdown_is_rejected() {
        let (eng, _) = engine(EngineConfig::default(), 5);
        eng.shared.queue.close();
        let got = eng.submit(ServeRequest::recall(BinaryHV::zeros(1024)));
        assert_eq!(got, Err(ServeError::ShuttingDown));
    }

    #[test]
    fn drop_joins_workers() {
        let (eng, _) = engine(EngineConfig::default(), 6);
        drop(eng); // must not hang
    }

    #[test]
    fn completion_queue_harvests_every_submission_without_polling() {
        let (eng, cm) = engine(EngineConfig::default(), 41);
        let mut rng = Rng::new(42);
        let cq = CompletionQueue::new();
        let queries: Vec<BinaryHV> = (0..12).map(|_| BinaryHV::random(&mut rng, 1024)).collect();
        for (i, q) in queries.iter().enumerate() {
            eng.submit_with_completion(
                ServeRequest::recall(q.clone()),
                Priority::Normal,
                Duration::from_secs(5),
                &cq,
                i as u64,
            )
            .unwrap();
        }
        // one consumer, zero polling: exactly 12 completions arrive,
        // each tagged, each bit-exact for its own query
        let mut seen = vec![false; queries.len()];
        for _ in 0..queries.len() {
            let c = cq.pop_blocking().expect("completion for every admitted ticket");
            let tag = c.tag as usize;
            assert!(!std::mem::replace(&mut seen[tag], true), "tag {tag} delivered twice");
            let (index, cosine) = cm.recall(&queries[tag]);
            assert_eq!(c.outcome, Ok(ServeResponse::Recall { index, cosine }));
            assert!(c.completed >= c.enqueued);
        }
        assert!(cq.is_empty(), "no phantom completions");
        // admission refusals surface synchronously and push nothing
        let err = eng.submit_with_completion(
            ServeRequest::recall_on(StoreId(9), BinaryHV::zeros(1024)),
            Priority::Normal,
            Duration::from_secs(5),
            &cq,
            99,
        );
        assert_eq!(err, Err(ServeError::UnknownStore));
        assert!(cq.is_empty());
        eng.shutdown();
    }

    #[test]
    fn abort_shutdown_terminates_the_backlog_with_shutting_down() {
        // one worker pinned in an injected 300ms kernel delay while a
        // backlog queues behind it: shutdown_now must answer the whole
        // backlog with ShuttingDown immediately instead of executing it
        // (or leaving the waiters to spin out their own timeouts)
        let (eng, _) = engine(
            EngineConfig {
                workers: 1,
                max_batch: 1,
                max_delay: Duration::from_micros(50),
                cache_capacity: 0,
                faults: Some(FaultConfig {
                    seed: 3,
                    kernel_delay_prob: 1.0,
                    kernel_delay: Duration::from_millis(300),
                    ..FaultConfig::default()
                }),
                ..EngineConfig::default()
            },
            43,
        );
        let mut rng = Rng::new(44);
        let mut pending = Vec::new();
        for _ in 0..6 {
            let q = BinaryHV::random(&mut rng, 1024);
            pending.push(
                eng.submit_async(ServeRequest::recall(q), Priority::Normal, Duration::from_secs(30))
                    .unwrap(),
            );
        }
        std::thread::sleep(Duration::from_millis(40));
        let t0 = Instant::now();
        eng.shutdown_now();
        // every handle resolves: the popped head ticket(s) finished for
        // real, the drained rest got ShuttingDown — nothing hangs for
        // its 30s deadline
        let mut shed = 0;
        for p in pending {
            match p.wait() {
                Err(ServeError::ShuttingDown) => shed += 1,
                Ok(_) => {}
                other => panic!("unexpected abort outcome {other:?}"),
            }
        }
        assert!(shed >= 1, "abort must shed the queued backlog");
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "abort shutdown answers waiters promptly"
        );
    }

    #[test]
    fn injected_admission_rejections_surface_as_overloaded() {
        let (eng, _) = engine(
            EngineConfig {
                faults: Some(FaultConfig {
                    seed: 5,
                    admit_reject_prob: 1.0,
                    ..FaultConfig::default()
                }),
                ..EngineConfig::default()
            },
            17,
        );
        let got = eng.submit(ServeRequest::recall(BinaryHV::zeros(1024)));
        assert_eq!(got, Err(ServeError::Overloaded));
        assert_eq!(eng.stats().rejected, 1);
        // turn the fault off: service resumes untouched
        eng.faults().unwrap().set_probs(0.0, 0.0, 0.0);
        assert!(eng
            .submit(ServeRequest::recall(BinaryHV::zeros(1024)))
            .is_ok());
        eng.shutdown();
    }

    #[test]
    fn injected_worker_panic_is_contained_and_engine_keeps_serving() {
        let (eng, cm) = engine(
            EngineConfig {
                workers: 1, // one worker: the panic and the respawn are the same thread's loop
                faults: Some(FaultConfig {
                    seed: 9,
                    panic_prob: 1.0,
                    ..FaultConfig::default()
                }),
                ..EngineConfig::default()
            },
            19,
        );
        let mut rng = Rng::new(20);
        let q = BinaryHV::random(&mut rng, 1024);
        // every batch panics: the request is answered with Internal, not lost
        let got = eng.submit(ServeRequest::recall(q.clone()));
        assert_eq!(got, Err(ServeError::Internal));
        // flip the fault off: the SAME engine (worker respawned in place)
        // serves correct answers again
        eng.faults().unwrap().set_probs(0.0, 0.0, 0.0);
        let got = eng.submit(ServeRequest::recall(q.clone())).unwrap();
        let (index, cosine) = cm.recall(&q);
        assert_eq!(got, ServeResponse::Recall { index, cosine });
        let snap = eng.stats();
        assert_eq!(snap.internal, 1);
        assert_eq!(snap.stores[0].internal, 1);
        assert_eq!(snap.completed, 1);
        eng.shutdown();
    }

    #[test]
    fn tenant_quota_rejections_are_attributed_to_the_flooding_store() {
        let mut rng = Rng::new(23);
        let cb = BinaryCodebook::random(&mut rng, 16, 512);
        let mut registry = StoreRegistry::new();
        let a = registry.register("calm", &cb, None, StoreSpec {
            shards: 1,
            cache_capacity: 0,
            ..StoreSpec::default()
        });
        let b = registry.register("flooder", &cb, None, StoreSpec {
            shards: 1,
            cache_capacity: 0,
            quota: Some(1),
            ..StoreSpec::default()
        });
        // one worker, pinned inside an injected 200ms kernel delay while
        // we flood, so the burst below races nothing: the lane really is
        // full when each rejected submit arrives
        let eng = ServeEngine::start_registry(
            registry,
            EngineConfig {
                workers: 1,
                max_delay: Duration::from_micros(100),
                cache_capacity: 0,
                faults: Some(FaultConfig {
                    seed: 1,
                    kernel_delay_prob: 1.0,
                    kernel_delay: Duration::from_millis(200),
                    ..FaultConfig::default()
                }),
                ..EngineConfig::default()
            },
        )
        .expect("spawn serve workers");
        // occupy the worker: it pops this ticket, closes its tiny batch
        // window, and sleeps in the injected delay
        let busy = eng
            .submit_async(
                ServeRequest::recall_on(a, BinaryHV::zeros(512)),
                Priority::Normal,
                Duration::from_secs(5),
            )
            .unwrap();
        std::thread::sleep(Duration::from_millis(40));
        // flood store b: quota 1 admits exactly one, sheds the rest
        // tenant-locally
        let mut tenant_rejects = 0;
        let mut pending = Vec::new();
        for _ in 0..8 {
            match eng.submit_async(
                ServeRequest::recall_on(b, BinaryHV::zeros(512)),
                Priority::Normal,
                Duration::from_secs(5),
            ) {
                Ok(p) => pending.push(p),
                Err(ServeError::TenantOverloaded) => tenant_rejects += 1,
                Err(e) => panic!("unexpected error {e:?}"),
            }
        }
        assert_eq!(tenant_rejects, 7, "quota-1 lane admits 1 of a burst of 8");
        // the calm store admits fine while the flooder's lane is full
        let calm_pending = eng
            .submit_async(
                ServeRequest::recall_on(a, BinaryHV::zeros(512)),
                Priority::Normal,
                Duration::from_secs(5),
            )
            .expect("calm store unaffected by flooder's quota");
        eng.faults().unwrap().set_probs(0.0, 0.0, 0.0);
        assert!(matches!(
            calm_pending.wait(),
            Ok(ServeResponse::Recall { .. })
        ));
        let _ = busy.wait();
        for p in pending {
            assert!(p.wait().is_ok());
        }
        let snap = eng.stats();
        assert_eq!(snap.rejected_tenant, 7);
        assert_eq!(snap.stores[b.index()].rejected_tenant, 7);
        assert_eq!(snap.stores[a.index()].rejected_tenant, 0);
        assert_eq!(snap.rejected, 0, "no global-capacity rejections here");
        eng.shutdown();
    }

    #[test]
    fn stores_created_at_runtime_serve_and_drop_answers_unknown() {
        let mut rng = Rng::new(31);
        let cb = BinaryCodebook::random(&mut rng, 32, 1024);
        let eng = ServeEngine::start(&cb, None, EngineConfig::default()).unwrap();
        // hot-create a second store with a different shape
        let cb2 = BinaryCodebook::random(&mut rng, 12, 256);
        let cm2 = CleanupMemory::new(cb2.clone());
        let hot = eng
            .create_store("hot", &cb2, None, StoreSpec {
                shards: 2,
                cache_capacity: 0,
                ..StoreSpec::default()
            })
            .unwrap();
        assert_eq!(hot, StoreId(1));
        let q = BinaryHV::random(&mut rng, 256);
        let got = eng.submit(ServeRequest::recall_on(hot, q.clone())).unwrap();
        let (index, cosine) = cm2.recall(&q);
        assert_eq!(got, ServeResponse::Recall { index, cosine });
        // duplicate live names are refused; mutations bump the epoch
        assert_eq!(
            eng.create_store("hot", &cb2, None, StoreSpec::default()),
            Err(MutateError::DuplicateName)
        );
        assert_eq!(
            eng.insert_item(hot, BinaryHV::random(&mut rng, 256)).unwrap(),
            1
        );
        assert_eq!(eng.store_epoch(hot), Some(1));
        let snap = eng.stats();
        assert_eq!(snap.stores.len(), 2, "runtime store got its own section");
        assert_eq!(snap.stores[1].name, "hot");
        assert_eq!(snap.stores[1].epoch, 1);
        assert!(snap.stores[1].live);
        assert_eq!(snap.stores[1].completed, 1);
        assert_eq!(snap.lanes.len(), 2, "runtime store got its own lane gauge");
        // drop: admission refuses the id, the boot store is unaffected
        eng.drop_store(hot).unwrap();
        assert_eq!(
            eng.submit(ServeRequest::recall_on(hot, BinaryHV::zeros(256))),
            Err(ServeError::UnknownStore)
        );
        assert!(eng.submit(ServeRequest::recall(BinaryHV::zeros(1024))).is_ok());
        let snap = eng.stats();
        assert!(!snap.stores[1].live, "tombstoned section keeps final counters");
        assert_eq!(snap.stores[1].completed, 1);
        assert!(
            snap.stores[1].memory.is_none(),
            "dropped store's snapshot is gone, so no resident bytes"
        );
        eng.shutdown();
    }

    #[test]
    fn store_dropped_after_admission_is_answered_at_execute_time() {
        // The admit-vs-drop race, end to end: a ticket validated while
        // its store was live executes after the drop. It must resolve to
        // `UnknownStore` — not a panic, not an answer from freed state.
        let mut rng = Rng::new(35);
        let cb = BinaryCodebook::random(&mut rng, 16, 512);
        let mut registry = StoreRegistry::new();
        let a = registry.register("keep", &cb, None, StoreSpec {
            shards: 1,
            cache_capacity: 0,
            ..StoreSpec::default()
        });
        let b = registry.register("doomed", &cb, None, StoreSpec {
            shards: 1,
            cache_capacity: 0,
            ..StoreSpec::default()
        });
        let eng = ServeEngine::start_registry(
            registry,
            EngineConfig {
                workers: 1,
                max_delay: Duration::from_micros(100),
                cache_capacity: 0,
                faults: Some(FaultConfig {
                    seed: 1,
                    kernel_delay_prob: 1.0,
                    kernel_delay: Duration::from_millis(200),
                    ..FaultConfig::default()
                }),
                ..EngineConfig::default()
            },
        )
        .expect("spawn serve workers");
        // pin the single worker inside the injected kernel delay
        let busy = eng
            .submit_async(
                ServeRequest::recall_on(a, BinaryHV::zeros(512)),
                Priority::Normal,
                Duration::from_secs(5),
            )
            .unwrap();
        std::thread::sleep(Duration::from_millis(40));
        // admitted while b is live...
        let doomed = eng
            .submit_async(
                ServeRequest::recall_on(b, BinaryHV::zeros(512)),
                Priority::Normal,
                Duration::from_secs(5),
            )
            .expect("b is live at admission");
        // ...but gone before the worker's next batch seals
        eng.drop_store(b).unwrap();
        eng.faults().unwrap().set_probs(0.0, 0.0, 0.0);
        assert!(busy.wait().is_ok());
        assert_eq!(doomed.wait(), Err(ServeError::UnknownStore));
        // the engine keeps serving the surviving store
        assert!(eng
            .submit(ServeRequest::recall_on(a, BinaryHV::zeros(512)))
            .is_ok());
        eng.shutdown();
    }

    #[test]
    fn in_flight_batches_keep_their_sealed_epoch_under_mutation() {
        let mut rng = Rng::new(37);
        let cb = BinaryCodebook::random(&mut rng, 32, 1024);
        let cm_old = CleanupMemory::new(cb.clone());
        let eng = ServeEngine::start(
            &cb,
            None,
            EngineConfig {
                workers: 1,
                cache_capacity: 0,
                max_delay: Duration::from_micros(100),
                trace_capacity: Some(16),
                faults: Some(FaultConfig {
                    seed: 1,
                    kernel_delay_prob: 1.0,
                    kernel_delay: Duration::from_millis(250),
                    ..FaultConfig::default()
                }),
                ..EngineConfig::default()
            },
        )
        .expect("spawn serve workers");
        let q = BinaryHV::random(&mut rng, 1024);
        let pending = eng
            .submit_async(
                ServeRequest::recall(q.clone()),
                Priority::Normal,
                Duration::from_secs(5),
            )
            .unwrap();
        // the worker seals the batch at epoch 0, then sleeps in the
        // injected delay; this mutation publishes epoch 1 mid-flight
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(eng.insert_item(StoreId::DEFAULT, q.clone()).unwrap(), 1);
        let got = pending.wait();
        // the in-flight batch answered from its sealed epoch-0 snapshot:
        // the exact-match item inserted mid-flight is not in its answer
        let (index, cosine) = cm_old.recall(&q);
        assert!(cosine < 1.0, "setup: the epoch-0 answer is no exact match");
        assert_eq!(got, Ok(ServeResponse::Recall { index, cosine }));
        eng.faults().unwrap().set_probs(0.0, 0.0, 0.0);
        // a request admitted after the swap sees epoch 1 and the item
        let got2 = eng.submit(ServeRequest::recall(q.clone())).unwrap();
        assert_eq!(got2, ServeResponse::Recall { index: 32, cosine: 1.0 });
        // epochs surface in stats and in the trace events, which carry
        // the epoch their batch was sealed on
        let snap = eng.stats();
        assert_eq!(snap.stores[0].epoch, 1);
        let (events, _) = eng.trace_snapshot().expect("tracing is on");
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].epoch, 0, "in-flight answer tagged its sealed epoch");
        assert_eq!(events[1].epoch, 1, "post-swap answer tagged the new epoch");
        eng.shutdown();
    }
}
