//! The serving engine: persistent worker event loops behind a blocking
//! `submit()` client API.
//!
//! Clients (any thread) enqueue tickets through the bounded admission
//! queue; `workers` threads each run gather → execute forever, coalescing
//! concurrent requests into micro-batches. Shutdown closes the queue,
//! drains every already-admitted ticket (no waiter is ever left hanging),
//! and joins the workers; `Drop` does the same if `shutdown()` was never
//! called.

use super::batcher::{self, BatchPolicy, WorkerScratch};
use super::cache::{CacheConfig, ResponseCache};
use super::queue::{AdmissionQueue, Priority, ResponseSlot, Ticket};
use super::shard::ShardedCleanup;
use super::stats::{ServeStats, StatsSnapshot};
use super::{ServeError, ServeRequest, ServeResponse};
use crate::vsa::{BinaryCodebook, Resonator};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Engine sizing and policy knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker event-loop threads (each gathers and executes whole
    /// micro-batches).
    pub workers: usize,
    /// Codebook shards in the cleanup store.
    pub shards: usize,
    /// Scoped scan threads *per worker* fanning out across shards
    /// (1 = each worker scans its batch serially, shard by shard).
    pub scan_threads: usize,
    /// Max requests coalesced into one micro-batch.
    pub max_batch: usize,
    /// How long a worker holds the batch window open for stragglers.
    pub max_delay: Duration,
    /// Admission queue bound (reject-on-full backpressure).
    pub queue_capacity: usize,
    /// Deadline applied by [`ServeEngine::submit`].
    pub default_deadline: Duration,
    /// Explicit sketch width (bits) for the shards' prefilter sidecars;
    /// `None` keeps the per-dimension default, `Some(0)` disables the
    /// sidecars (incremental bounds still prune). `--sketch-bits`.
    pub sketch_bits: Option<usize>,
    /// Response-cache entry budget; 0 disables the cache. `--cache`.
    pub cache_capacity: usize,
    /// Response-cache lock shards. `--cache-shards`.
    pub cache_shards: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        let cache = CacheConfig::default();
        EngineConfig {
            workers: 2,
            shards: 4,
            scan_threads: 1,
            max_batch: 32,
            max_delay: Duration::from_micros(200),
            queue_capacity: 1024,
            default_deadline: Duration::from_secs(5),
            sketch_bits: None,
            cache_capacity: cache.capacity,
            cache_shards: cache.shards,
        }
    }
}

struct Shared {
    queue: AdmissionQueue,
    store: ShardedCleanup,
    resonator: Option<Resonator>,
    cache: Option<ResponseCache>,
    stats: ServeStats,
    policy: BatchPolicy,
    scan_threads: usize,
}

/// Handle to an in-flight asynchronous submission.
pub struct PendingResponse {
    slot: ResponseSlot,
    enqueued: Instant,
}

impl PendingResponse {
    /// Block until the engine answers.
    pub fn wait(self) -> Result<ServeResponse, ServeError> {
        self.slot.wait()
    }

    /// Block until the engine answers; also return the request's total
    /// latency (enqueue → worker fill), for open-loop accounting.
    pub fn wait_with_latency(self) -> (Result<ServeResponse, ServeError>, Duration) {
        let (outcome, completed) = self.slot.wait_timed();
        (outcome, completed.duration_since(self.enqueued))
    }
}

/// A running serving engine. Cheap to share by reference across client
/// threads (`submit` takes `&self`).
pub struct ServeEngine {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    cfg: EngineConfig,
}

impl ServeEngine {
    /// Shard `codebook`, spawn the worker loops, and start serving.
    /// `resonator` is optional: engines without one answer factorize
    /// requests with [`ServeError::Unsupported`].
    pub fn start(
        codebook: &BinaryCodebook,
        resonator: Option<Resonator>,
        cfg: EngineConfig,
    ) -> ServeEngine {
        assert!(cfg.workers >= 1, "engine needs at least one worker");
        let store = ShardedCleanup::partition_sketched(codebook, cfg.shards.max(1), cfg.sketch_bits);
        let stats = ServeStats::new(store.n_shards());
        let cache = (cfg.cache_capacity > 0).then(|| {
            ResponseCache::new(CacheConfig {
                capacity: cfg.cache_capacity,
                shards: cfg.cache_shards.max(1),
            })
        });
        let shared = Arc::new(Shared {
            queue: AdmissionQueue::new(cfg.queue_capacity),
            store,
            resonator,
            cache,
            stats,
            policy: BatchPolicy {
                max_batch: cfg.max_batch.max(1),
                max_delay: cfg.max_delay,
            },
            scan_threads: cfg.scan_threads.max(1),
        });
        let workers = (0..cfg.workers)
            .map(|w| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("nscog-serve-{w}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("failed to spawn serve worker")
            })
            .collect();
        ServeEngine {
            shared,
            workers,
            cfg,
        }
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    pub fn store(&self) -> &ShardedCleanup {
        &self.shared.store
    }

    /// Blocking submit with default priority and deadline.
    pub fn submit(&self, request: ServeRequest) -> Result<ServeResponse, ServeError> {
        self.submit_with(request, Priority::Normal, self.cfg.default_deadline)
    }

    /// Blocking submit with explicit priority and relative deadline.
    pub fn submit_with(
        &self,
        request: ServeRequest,
        priority: Priority,
        deadline: Duration,
    ) -> Result<ServeResponse, ServeError> {
        self.submit_async(request, priority, deadline)?.wait()
    }

    /// Non-blocking enqueue: admission control runs immediately (so
    /// `Overloaded`/`ShuttingDown` surface here), execution is awaited
    /// through the returned [`PendingResponse`]. This is the open-loop
    /// load generator's entry point.
    pub fn submit_async(
        &self,
        request: ServeRequest,
        priority: Priority,
        deadline: Duration,
    ) -> Result<PendingResponse, ServeError> {
        let slot = ResponseSlot::new();
        let now = Instant::now();
        let ticket = Ticket {
            request,
            priority,
            slot: slot.clone(),
            enqueued: now,
            deadline: now + deadline,
        };
        match self.shared.queue.push(ticket) {
            Ok(()) => Ok(PendingResponse {
                slot,
                enqueued: now,
            }),
            Err((_, why)) => {
                self.shared.stats.record_rejected();
                Err(why.to_serve_error())
            }
        }
    }

    /// Metrics snapshot, including response-cache counters when a cache
    /// is configured.
    pub fn stats(&self) -> StatsSnapshot {
        let mut snap = self.shared.stats.snapshot();
        snap.cache = self.shared.cache.as_ref().map(|c| c.counters());
        snap
    }

    /// Stop admissions, drain already-admitted tickets, join workers.
    pub fn shutdown(mut self) {
        self.shutdown_in_place();
    }

    fn shutdown_in_place(&mut self) {
        self.shared.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        self.shutdown_in_place();
    }
}

fn worker_loop(sh: &Shared) {
    let mut scratch = WorkerScratch::new();
    while let Some(batch) = batcher::gather(&sh.queue, &sh.policy) {
        batcher::execute(
            batch,
            &sh.store,
            sh.resonator.as_ref(),
            sh.cache.as_ref(),
            &mut scratch,
            &sh.stats,
            sh.scan_threads,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::vsa::{BinaryHV, CleanupMemory};

    fn engine(cfg: EngineConfig, seed: u64) -> (ServeEngine, CleanupMemory) {
        let mut rng = Rng::new(seed);
        let cb = BinaryCodebook::random(&mut rng, 32, 1024);
        let cm = CleanupMemory::new(cb.clone());
        (ServeEngine::start(&cb, None, cfg), cm)
    }

    #[test]
    fn submit_round_trip_matches_oracle() {
        let (eng, cm) = engine(EngineConfig::default(), 1);
        let mut rng = Rng::new(2);
        for i in 0..8 {
            let q = BinaryHV::random(&mut rng, 1024);
            let got = eng.submit(ServeRequest::Recall { query: q.clone() }).unwrap();
            let (index, cosine) = cm.recall(&q);
            assert_eq!(got, ServeResponse::Recall { index, cosine }, "req {i}");
        }
        let snap = eng.stats();
        assert_eq!(snap.completed, 8);
        assert_eq!(snap.rejected, 0);
        eng.shutdown();
    }

    #[test]
    fn repeated_submits_hit_the_cache_with_identical_responses() {
        let (eng, cm) = engine(EngineConfig::default(), 9);
        let mut rng = Rng::new(10);
        let q = BinaryHV::random(&mut rng, 1024);
        let first = eng
            .submit(ServeRequest::Recall { query: q.clone() })
            .unwrap();
        let second = eng
            .submit(ServeRequest::Recall { query: q.clone() })
            .unwrap();
        assert_eq!(first, second);
        let (index, cosine) = cm.recall(&q);
        assert_eq!(first, ServeResponse::Recall { index, cosine });
        let snap = eng.stats();
        let cache = snap.cache.expect("default engine config enables the cache");
        assert_eq!(cache.hits, 1);
        assert_eq!(cache.misses, 1);
        assert_eq!(snap.completed, 2);
        eng.shutdown();
    }

    #[test]
    fn cache_can_be_disabled() {
        let (eng, _) = engine(
            EngineConfig {
                cache_capacity: 0,
                ..EngineConfig::default()
            },
            11,
        );
        let mut rng = Rng::new(12);
        let q = BinaryHV::random(&mut rng, 1024);
        for _ in 0..2 {
            eng.submit(ServeRequest::Recall { query: q.clone() }).unwrap();
        }
        assert!(eng.stats().cache.is_none());
        eng.shutdown();
    }

    #[test]
    fn factorize_without_resonator_is_unsupported() {
        let (eng, _) = engine(EngineConfig::default(), 3);
        let got = eng.submit(ServeRequest::Factorize {
            scene: crate::vsa::RealHV::zeros(64),
        });
        assert_eq!(got, Err(ServeError::Unsupported));
    }

    #[test]
    fn zero_deadline_requests_expire_not_execute() {
        let (eng, _) = engine(EngineConfig::default(), 4);
        let got = eng.submit_with(
            ServeRequest::Recall {
                query: BinaryHV::zeros(1024),
            },
            Priority::Normal,
            Duration::from_secs(0),
        );
        assert_eq!(got, Err(ServeError::DeadlineExceeded));
        assert_eq!(eng.stats().expired, 1);
    }

    #[test]
    fn submit_after_shutdown_is_rejected() {
        let (eng, _) = engine(EngineConfig::default(), 5);
        eng.shared.queue.close();
        let got = eng.submit(ServeRequest::Recall {
            query: BinaryHV::zeros(1024),
        });
        assert_eq!(got, Err(ServeError::ShuttingDown));
    }

    #[test]
    fn drop_joins_workers() {
        let (eng, _) = engine(EngineConfig::default(), 6);
        drop(eng); // must not hang
    }
}
