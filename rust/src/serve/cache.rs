//! Bounded, sharded per-store response caches for the serving engine.
//!
//! Production recall traffic repeats: the same noisy percept or symbol is
//! looked up again and again (the reuse the paper's Sec. VI co-design
//! exploits). Each registered store slot owns one cache that persists
//! across the store's epochs; it sits at
//! batch-formation time in [`super::batcher::execute`]: a hit fills the
//! ticket's response slot immediately and the request never reaches a
//! kernel, so repeated queries cost a hash fold instead of an item-memory
//! scan.
//!
//! Keys are **exact**: shard selection and hash-bucket placement use a
//! 64-bit fold of the query words mixed with the request class, `k`,
//! the target [`StoreId`], and the store **epoch** that computed the
//! response, but every probe verifies full word-for-word query equality
//! (plus class, `k`, store, and epoch) before serving — a fold collision
//! degrades to a miss-like walk of a (nearly always single-entry)
//! bucket, never to a wrong response. Responses are therefore
//! bit-identical to what the kernels would have produced, and entries
//! can never be served across differing `k`, request class, store, or
//! epoch: even if two stores' caches were accidentally swapped, the
//! store id baked into every key would turn each probe into a miss
//! instead of a cross-tenant answer, and a store mutation (which bumps
//! the epoch — see [`super::registry`]) makes every older entry
//! structurally unreachable, so stale hits are impossible without any
//! invalidation walk; dead epochs' entries simply age out of the FIFO.
//! `serve-bench`'s per-store oracle verification covers the whole path.
//! Factorize requests are not cached (real-valued scenes have no exact
//! equality story under f32 noise).
//!
//! Eviction is per-shard FIFO: each shard holds at most
//! `capacity / shards` entries and evicts its oldest insertion when full
//! — bounded memory, no per-hit bookkeeping on the hot path.

use super::registry::StoreId;
use super::{RequestOp, ServeRequest, ServeResponse};
use crate::vsa::BinaryHV;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Cache sizing knobs (`--cache`, `--cache-shards`).
#[derive(Debug, Clone, Copy)]
pub struct CacheConfig {
    /// Total entry budget across shards; 0 disables the cache.
    pub capacity: usize,
    /// Lock shards (concurrent workers probe disjoint shards).
    pub shards: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            capacity: 4096,
            shards: 8,
        }
    }
}

/// Monotonic counters, snapshotted into
/// [`super::stats::StoreSnapshot::cache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheCounters {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    /// Entries currently resident.
    pub entries: u64,
}

impl CacheCounters {
    /// Hit fraction over all cacheable probes.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total > 0 {
            self.hits as f64 / total as f64
        } else {
            0.0
        }
    }

    /// Element-wise sum — the engine-wide aggregate across store caches.
    pub fn merge(&mut self, other: &CacheCounters) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.inserts += other.inserts;
        self.evictions += other.evictions;
        self.entries += other.entries;
    }
}

/// Request-class tag folded into the key so recall and top-k entries can
/// never alias.
const CLASS_RECALL: u8 = 1;
const CLASS_TOPK: u8 = 2;

/// 64-bit fold of the query words, seeded by class, `k`, store id, and
/// store epoch (splitmix-style multiply-xor mixing; deterministic
/// across runs and platforms).
fn fold_query(words: &[u64], class: u8, k: usize, store: StoreId, epoch: u64) -> u64 {
    let mut h = 0x9e37_79b9_7f4a_7c15u64
        ^ (class as u64).wrapping_mul(0xff51_afd7_ed55_8ccd)
        ^ (k as u64).wrapping_mul(0xc4ce_b9fe_1a85_ec53)
        ^ (store.index() as u64).wrapping_mul(0x2545_f491_4f6c_dd1d)
        ^ epoch.wrapping_mul(0x9e6c_63d0_876a_68b5);
    for &w in words {
        h ^= w;
        h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
        h ^= h >> 33;
    }
    h
}

/// One resident entry: the full key material for exact verification plus
/// the response to replay.
#[derive(Debug)]
struct Entry {
    store: StoreId,
    class: u8,
    k: usize,
    epoch: u64,
    query: BinaryHV,
    response: ServeResponse,
}

impl Entry {
    fn matches(&self, store: StoreId, class: u8, k: usize, epoch: u64, query: &BinaryHV) -> bool {
        self.store == store
            && self.class == class
            && self.k == k
            && self.epoch == epoch
            && &self.query == query
    }
}

#[derive(Debug, Default)]
struct ShardState {
    /// fold → entries with that fold (collisions walk the bucket).
    map: HashMap<u64, Vec<Entry>>,
    /// Insertion order of folds, for FIFO eviction.
    fifo: VecDeque<u64>,
    len: usize,
}

/// The cache proper: one per registered store. Shared by reference
/// across workers; each operation locks exactly one shard.
#[derive(Debug)]
pub struct ResponseCache {
    /// The store this cache serves — the default key scope for the
    /// hot-path probes that carry only a query.
    store: StoreId,
    shards: Vec<Mutex<ShardState>>,
    per_shard_capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    inserts: AtomicU64,
    evictions: AtomicU64,
}

/// Store/class/k/words view of a cacheable request; `None` for factorize.
fn key_parts(request: &ServeRequest) -> Option<(StoreId, u8, usize, &BinaryHV)> {
    match &request.op {
        RequestOp::Recall { query } => Some((request.store, CLASS_RECALL, 0, query)),
        RequestOp::RecallTopK { query, k } => Some((request.store, CLASS_TOPK, *k, query)),
        RequestOp::Factorize { .. } => None,
    }
}

impl ResponseCache {
    /// Cache scoped to [`StoreId::DEFAULT`] — single-store callers.
    pub fn new(cfg: CacheConfig) -> ResponseCache {
        Self::for_store(cfg, StoreId::DEFAULT)
    }

    /// Cache scoped to `store`: hot-path probes fold that store id into
    /// every key.
    pub fn for_store(cfg: CacheConfig, store: StoreId) -> ResponseCache {
        let shards = cfg.shards.max(1);
        // round the budget DOWN per shard (min 1) so total residency
        // never exceeds the configured capacity (unless capacity < shards)
        let per_shard_capacity = (cfg.capacity / shards).max(1);
        ResponseCache {
            store,
            shards: (0..shards).map(|_| Mutex::new(ShardState::default())).collect(),
            per_shard_capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// The store this cache is scoped to.
    pub fn store(&self) -> StoreId {
        self.store
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Effective total entry budget (the configured capacity rounded
    /// down to a multiple of the shard count, min one per shard).
    pub fn capacity(&self) -> usize {
        self.per_shard_capacity * self.shards.len()
    }

    fn shard_of(&self, fold: u64) -> &Mutex<ShardState> {
        &self.shards[(fold % self.shards.len() as u64) as usize]
    }

    /// Look up a response for `request`, keyed by the request's own
    /// store id at serving epoch `epoch`. Counts a hit or miss for
    /// cacheable classes; factorize requests return `None` uncounted.
    pub fn get(&self, request: &ServeRequest, epoch: u64) -> Option<ServeResponse> {
        let (store, class, k, query) = key_parts(request)?;
        self.lookup(store, class, k, epoch, query)
    }

    /// Probe for a cached recall response against this cache's own store
    /// at the sealed `epoch` (the batcher's hot-path entry; avoids
    /// materializing a `ServeRequest`).
    pub fn get_recall(&self, query: &BinaryHV, epoch: u64) -> Option<ServeResponse> {
        self.lookup(self.store, CLASS_RECALL, 0, epoch, query)
    }

    /// Probe for a cached top-`k` response at exactly this `k`, against
    /// this cache's own store at the sealed `epoch`.
    pub fn get_topk(&self, query: &BinaryHV, k: usize, epoch: u64) -> Option<ServeResponse> {
        self.lookup(self.store, CLASS_TOPK, k, epoch, query)
    }

    // Lock poisoning: a worker that panics mid-probe must not brick the
    // shard for every later request — entries are verified on read, so a
    // recovered guard can at worst miss, never serve a wrong answer.
    fn lookup(
        &self,
        store: StoreId,
        class: u8,
        k: usize,
        epoch: u64,
        query: &BinaryHV,
    ) -> Option<ServeResponse> {
        let fold = fold_query(query.words(), class, k, store, epoch);
        let g = self.shard_of(fold).lock().unwrap_or_else(|p| p.into_inner());
        let found = g
            .map
            .get(&fold)
            .and_then(|bucket| bucket.iter().find(|e| e.matches(store, class, k, epoch, query)))
            .map(|e| e.response.clone());
        drop(g);
        match found {
            Some(r) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(r)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert a response computed at `epoch` (no-op for factorize or
    /// when the exact key is already resident). Evicts the shard's
    /// oldest insertion when the shard is at capacity.
    pub fn put(&self, request: &ServeRequest, response: &ServeResponse, epoch: u64) {
        let Some((store, class, k, query)) = key_parts(request) else {
            return;
        };
        self.insert_parts(store, class, k, epoch, query.clone(), response);
    }

    /// [`Self::put`] taking ownership of the request, so hot-path callers
    /// that already own the query pay no extra clone.
    pub fn insert(&self, request: ServeRequest, response: &ServeResponse, epoch: u64) {
        let store = request.store;
        match request.op {
            RequestOp::Recall { query } => {
                self.insert_parts(store, CLASS_RECALL, 0, epoch, query, response)
            }
            RequestOp::RecallTopK { query, k } => {
                self.insert_parts(store, CLASS_TOPK, k, epoch, query, response)
            }
            RequestOp::Factorize { .. } => {}
        }
    }

    fn insert_parts(
        &self,
        store: StoreId,
        class: u8,
        k: usize,
        epoch: u64,
        query: BinaryHV,
        response: &ServeResponse,
    ) {
        let fold = fold_query(query.words(), class, k, store, epoch);
        let mut g = self.shard_of(fold).lock().unwrap_or_else(|p| p.into_inner());
        let st = &mut *g;
        if let Some(bucket) = st.map.get(&fold) {
            if bucket.iter().any(|e| e.matches(store, class, k, epoch, &query)) {
                return;
            }
        }
        if st.len >= self.per_shard_capacity {
            if let Some(old_fold) = st.fifo.pop_front() {
                if let Some(bucket) = st.map.get_mut(&old_fold) {
                    if !bucket.is_empty() {
                        bucket.remove(0);
                        st.len -= 1;
                        self.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                    if bucket.is_empty() {
                        st.map.remove(&old_fold);
                    }
                }
            }
        }
        st.map.entry(fold).or_default().push(Entry {
            store,
            class,
            k,
            epoch,
            query,
            response: response.clone(),
        });
        st.fifo.push_back(fold);
        st.len += 1;
        self.inserts.fetch_add(1, Ordering::Relaxed);
    }

    /// Entries currently resident across all shards.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap_or_else(|p| p.into_inner()).len)
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot the counters.
    pub fn counters(&self) -> CacheCounters {
        CacheCounters {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.len() as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn recall_req(q: &BinaryHV) -> ServeRequest {
        ServeRequest::recall(q.clone())
    }

    fn topk_req(q: &BinaryHV, k: usize) -> ServeRequest {
        ServeRequest::recall_topk(q.clone(), k)
    }

    #[test]
    fn hit_replays_exact_response_and_respects_class_and_k() {
        let cache = ResponseCache::new(CacheConfig::default());
        let mut rng = Rng::new(1);
        let q = BinaryHV::random(&mut rng, 512);
        let recall_resp = ServeResponse::Recall {
            index: 3,
            cosine: 0.75,
        };
        let topk2 = ServeResponse::RecallTopK {
            hits: vec![(3, 0.75), (1, 0.5)],
        };
        assert_eq!(cache.get(&recall_req(&q), 0), None);
        cache.put(&recall_req(&q), &recall_resp, 0);
        assert_eq!(cache.get(&recall_req(&q), 0), Some(recall_resp.clone()));
        // same query, different class or k: never cross-served
        assert_eq!(cache.get(&topk_req(&q, 2), 0), None);
        cache.put(&topk_req(&q, 2), &topk2, 0);
        assert_eq!(cache.get(&topk_req(&q, 2), 0), Some(topk2));
        assert_eq!(cache.get(&topk_req(&q, 3), 0), None);
        // different query, same class: miss
        let q2 = BinaryHV::random(&mut rng, 512);
        assert_eq!(cache.get(&recall_req(&q2), 0), None);
        let c = cache.counters();
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 4);
        assert_eq!(c.inserts, 2);
        assert_eq!(c.entries, 2);
        assert!((c.hit_rate() - 2.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn entries_are_scoped_to_their_store_id() {
        // one cache per store is the engine's layout; even so, the store
        // id is part of every key, so a request tagged with a different
        // store can never be served another tenant's entry
        let cache = ResponseCache::for_store(CacheConfig::default(), StoreId(0));
        let mut rng = Rng::new(7);
        let q = BinaryHV::random(&mut rng, 512);
        let resp = ServeResponse::Recall {
            index: 5,
            cosine: 0.9,
        };
        cache.put(&ServeRequest::recall_on(StoreId(0), q.clone()), &resp, 0);
        assert_eq!(
            cache.get(&ServeRequest::recall_on(StoreId(0), q.clone()), 0),
            Some(resp.clone())
        );
        assert_eq!(
            cache.get(&ServeRequest::recall_on(StoreId(1), q.clone()), 0),
            None,
            "same query under a different store id must miss"
        );
        // hot-path probes are scoped to the cache's own store
        assert_eq!(cache.get_recall(&q, 0), Some(resp));
        let other = ResponseCache::for_store(CacheConfig::default(), StoreId(1));
        assert_eq!(other.get_recall(&q, 0), None);
    }

    #[test]
    fn entries_are_scoped_to_their_epoch() {
        // a store mutation bumps the serving epoch; every entry cached
        // under the old epoch must become structurally unreachable —
        // that IS the invalidation mechanism (no walk, no flag)
        let cache = ResponseCache::new(CacheConfig::default());
        let mut rng = Rng::new(17);
        let q = BinaryHV::random(&mut rng, 512);
        let old = ServeResponse::Recall {
            index: 2,
            cosine: 0.8,
        };
        let new = ServeResponse::Recall {
            index: 9,
            cosine: 0.95,
        };
        cache.put(&recall_req(&q), &old, 0);
        assert_eq!(cache.get(&recall_req(&q), 0), Some(old.clone()));
        // epoch bumped: the old entry never hits again
        assert_eq!(cache.get(&recall_req(&q), 1), None);
        assert_eq!(cache.get_recall(&q, 1), None);
        cache.put(&recall_req(&q), &new, 1);
        assert_eq!(cache.get(&recall_req(&q), 1), Some(new));
        // both epochs resident until FIFO ages the dead one out
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.get(&recall_req(&q), 0), Some(old));
    }

    #[test]
    fn duplicate_puts_are_idempotent() {
        let cache = ResponseCache::new(CacheConfig {
            capacity: 8,
            shards: 2,
        });
        let mut rng = Rng::new(2);
        let q = BinaryHV::random(&mut rng, 256);
        let resp = ServeResponse::Recall {
            index: 1,
            cosine: 0.5,
        };
        cache.put(&recall_req(&q), &resp, 0);
        cache.put(&recall_req(&q), &resp, 0);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.counters().inserts, 1);
    }

    #[test]
    fn factorize_is_never_cached() {
        let cache = ResponseCache::new(CacheConfig::default());
        let req = ServeRequest::factorize(crate::vsa::RealHV::zeros(64));
        assert_eq!(cache.get(&req, 0), None);
        cache.put(
            &req,
            &ServeResponse::Factorize {
                indices: vec![0],
                iterations: 1,
                converged: true,
            },
            0,
        );
        assert!(cache.is_empty());
        let c = cache.counters();
        assert_eq!(c.hits + c.misses + c.inserts, 0);
    }

    #[test]
    fn bounded_fifo_eviction() {
        let cache = ResponseCache::new(CacheConfig {
            capacity: 4,
            shards: 1,
        });
        let mut rng = Rng::new(3);
        let qs: Vec<BinaryHV> = (0..6).map(|_| BinaryHV::random(&mut rng, 256)).collect();
        for (i, q) in qs.iter().enumerate() {
            cache.put(
                &recall_req(q),
                &ServeResponse::Recall {
                    index: i,
                    cosine: 1.0,
                },
                0,
            );
        }
        let c = cache.counters();
        assert_eq!(c.inserts, 6);
        assert_eq!(c.evictions, 2);
        assert_eq!(c.entries, 4);
        // oldest two evicted, newest four resident
        assert_eq!(cache.get(&recall_req(&qs[0]), 0), None);
        assert_eq!(cache.get(&recall_req(&qs[1]), 0), None);
        for (i, q) in qs.iter().enumerate().skip(2) {
            assert_eq!(
                cache.get(&recall_req(q), 0),
                Some(ServeResponse::Recall {
                    index: i,
                    cosine: 1.0
                }),
                "entry {i} should be resident"
            );
        }
    }

    #[test]
    fn fold_separates_classes_k_stores_and_epochs() {
        let words = [0x1234u64, 0xdeadbeefu64];
        let a = fold_query(&words, CLASS_RECALL, 0, StoreId(0), 0);
        let b = fold_query(&words, CLASS_TOPK, 0, StoreId(0), 0);
        let c = fold_query(&words, CLASS_TOPK, 1, StoreId(0), 0);
        let d = fold_query(&words, CLASS_RECALL, 0, StoreId(1), 0);
        let e = fold_query(&words, CLASS_RECALL, 0, StoreId(0), 1);
        assert_ne!(a, b);
        assert_ne!(b, c);
        assert_ne!(a, d, "store id must perturb the fold");
        assert_ne!(a, e, "epoch must perturb the fold");
        assert_ne!(d, e);
        // deterministic
        assert_eq!(a, fold_query(&words, CLASS_RECALL, 0, StoreId(0), 0));
    }
}
