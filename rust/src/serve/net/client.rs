//! Blocking TCP client for the serving wire, with a retry/backoff
//! `call` wrapper and pipelined `send`/`recv` halves.
//!
//! Request ids are allocated once per logical request and **reused
//! verbatim across retries**: every serve operation is a pure read
//! against an epoch-stamped snapshot, so re-submitting the same id after
//! a reconnect is idempotent by construction — the worst case is the
//! engine computing the same bit-exact answer twice, never a duplicated
//! side effect. A response frame carrying a protocol-level error code
//! (or an undecodable frame) surfaces as `io::ErrorKind::InvalidData`;
//! engine-level refusals ([`ServeError`]) are a normal `Ok(Err(e))`
//! return — the connection stays healthy.
//!
//! The read deadline (`read_timeout`) bounds every `recv`, so a dead or
//! wedged server can never hang the caller; `call` then tears the
//! connection down, sleeps an exponentially growing backoff, reconnects,
//! and retries up to `retries` times.

use super::super::queue::Priority;
use super::super::{ServeError, ServeRequest, ServeResponse};
use super::frame::{self, Frame};
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Blocking wire client. Not thread-safe by design (one connection, one
/// in-order byte stream); spawn one per client thread.
#[derive(Debug)]
pub struct NetClient {
    addr: SocketAddr,
    stream: Option<TcpStream>,
    buf: Vec<u8>,
    next_id: u64,
    /// Per-`recv` deadline; a server that stops answering yields
    /// `TimedOut` instead of a hang.
    pub read_timeout: Duration,
    /// Extra attempts `call` makes after the first failure.
    pub retries: u32,
    /// Base backoff slept before the first retry; doubles per attempt,
    /// capped at 500ms.
    pub backoff: Duration,
}

impl NetClient {
    /// Resolve and connect. `addr` may be anything `ToSocketAddrs`
    /// accepts (a `SocketAddr`, `"127.0.0.1:7070"`, ...).
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<NetClient> {
        let addr = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidInput, "no address resolved"))?;
        let mut c = NetClient {
            addr,
            stream: None,
            buf: Vec::new(),
            next_id: 1,
            read_timeout: Duration::from_secs(5),
            retries: 3,
            backoff: Duration::from_millis(10),
        };
        c.ensure()?;
        Ok(c)
    }

    fn ensure(&mut self) -> io::Result<&mut TcpStream> {
        if self.stream.is_none() {
            let s = TcpStream::connect(self.addr)?;
            let _ = s.set_nodelay(true);
            s.set_read_timeout(Some(self.read_timeout))?;
            s.set_write_timeout(Some(self.read_timeout))?;
            self.buf.clear(); // stale bytes belong to the dead stream
            self.stream = Some(s);
        }
        Ok(self.stream.as_mut().expect("just ensured"))
    }

    /// Drop the connection; the next operation reconnects.
    pub fn disconnect(&mut self) {
        self.stream = None;
        self.buf.clear();
    }

    /// Pipelined send: write one request frame, return its id. Pair
    /// with [`NetClient::recv`]; responses may arrive out of order.
    pub fn send(
        &mut self,
        request: &ServeRequest,
        priority: Priority,
        deadline_us: u64,
    ) -> io::Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        self.send_with_id(id, request, priority, deadline_us)?;
        Ok(id)
    }

    fn send_with_id(
        &mut self,
        id: u64,
        request: &ServeRequest,
        priority: Priority,
        deadline_us: u64,
    ) -> io::Result<()> {
        let bytes = frame::encode_request(id, deadline_us, priority, request);
        let s = self.ensure()?;
        s.write_all(&bytes)
    }

    /// Receive the next response or error frame: `(id, outcome)`.
    /// Protocol-level failures (undecodable frame, protocol error code,
    /// unexpected frame type, EOF mid-stream) are `io::Error`s and drop
    /// the connection; engine refusals are `Ok((id, Err(serve_error)))`.
    pub fn recv(&mut self) -> io::Result<(u64, Result<ServeResponse, ServeError>)> {
        let mut tmp = [0u8; 4096];
        loop {
            match frame::decode_from(&self.buf) {
                Ok(Some((f, used))) => {
                    self.buf.drain(..used);
                    match f {
                        Frame::Response { id, response } => return Ok((id, Ok(response))),
                        Frame::Error { id, code } => match frame::code_to_error(code) {
                            Some(e) => return Ok((id, Err(e))),
                            None => {
                                self.disconnect();
                                return Err(io::Error::new(
                                    io::ErrorKind::InvalidData,
                                    format!("server closed the connection: protocol error code {code}"),
                                ));
                            }
                        },
                        Frame::Request(_) => {
                            self.disconnect();
                            return Err(io::Error::new(
                                io::ErrorKind::InvalidData,
                                "server sent a request frame",
                            ));
                        }
                    }
                }
                Ok(None) => {
                    let s = self.ensure()?;
                    match s.read(&mut tmp) {
                        Ok(0) => {
                            self.disconnect();
                            return Err(io::Error::new(
                                io::ErrorKind::UnexpectedEof,
                                "server closed the connection",
                            ));
                        }
                        Ok(n) => self.buf.extend_from_slice(&tmp[..n]),
                        Err(e)
                            if matches!(
                                e.kind(),
                                io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
                            ) =>
                        {
                            self.disconnect();
                            return Err(io::Error::new(
                                io::ErrorKind::TimedOut,
                                "no response within the read deadline",
                            ));
                        }
                        Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                        Err(e) => {
                            self.disconnect();
                            return Err(e);
                        }
                    }
                }
                Err(we) => {
                    self.disconnect();
                    return Err(io::Error::new(io::ErrorKind::InvalidData, we.to_string()));
                }
            }
        }
    }

    /// Blocking round trip at normal priority with the server-default
    /// deadline. See [`NetClient::call_with`].
    pub fn call(
        &mut self,
        request: &ServeRequest,
    ) -> io::Result<Result<ServeResponse, ServeError>> {
        self.call_with(request, Priority::Normal, 0)
    }

    /// Blocking round trip with retry/backoff: send, await the matching
    /// id, and on transport failure reconnect and re-send the SAME id
    /// (idempotent — serve ops are pure reads) up to `retries` extra
    /// attempts with exponential backoff. `deadline_us = 0` asks for the
    /// server's default admission deadline.
    pub fn call_with(
        &mut self,
        request: &ServeRequest,
        priority: Priority,
        deadline_us: u64,
    ) -> io::Result<Result<ServeResponse, ServeError>> {
        let id = self.next_id;
        self.next_id += 1;
        let mut last_err: Option<io::Error> = None;
        for attempt in 0..=self.retries {
            if attempt > 0 {
                let exp = attempt.saturating_sub(1).min(16);
                let delay = self
                    .backoff
                    .saturating_mul(1u32 << exp)
                    .min(Duration::from_millis(500));
                std::thread::sleep(delay);
            }
            match self.attempt(id, request, priority, deadline_us) {
                Ok(outcome) => return Ok(outcome),
                Err(e) => {
                    self.disconnect();
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.expect("at least one attempt ran"))
    }

    fn attempt(
        &mut self,
        id: u64,
        request: &ServeRequest,
        priority: Priority,
        deadline_us: u64,
    ) -> io::Result<Result<ServeResponse, ServeError>> {
        self.send_with_id(id, request, priority, deadline_us)?;
        loop {
            let (rid, outcome) = self.recv()?;
            if rid == id {
                return Ok(outcome);
            }
            // a stale response from an earlier pipelined send on this
            // stream; drop it and keep waiting for ours
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::engine::{EngineConfig, ServeEngine};
    use super::super::super::ServeRequest;
    use super::super::server::{NetConfig, NetServer};
    use super::*;
    use crate::util::Rng;
    use crate::vsa::{BinaryCodebook, BinaryHV, CleanupMemory};
    use std::sync::Arc;

    fn start_pair(seed: u64) -> (Arc<ServeEngine>, CleanupMemory, NetServer) {
        let mut rng = Rng::new(seed);
        let cb = BinaryCodebook::random(&mut rng, 32, 1024);
        let cm = CleanupMemory::new(cb.clone());
        let eng =
            Arc::new(ServeEngine::start(&cb, None, EngineConfig::default()).expect("workers"));
        let srv =
            NetServer::start(Arc::clone(&eng), "127.0.0.1:0", NetConfig::default()).unwrap();
        (eng, cm, srv)
    }

    #[test]
    fn pipelined_sends_harvest_by_id() {
        let (eng, cm, srv) = start_pair(201);
        let mut client = NetClient::connect(srv.addr()).unwrap();
        let mut rng = Rng::new(202);
        let queries: Vec<BinaryHV> = (0..8).map(|_| BinaryHV::random(&mut rng, 1024)).collect();
        let ids: Vec<u64> = queries
            .iter()
            .map(|q| {
                client
                    .send(&ServeRequest::recall(q.clone()), Priority::Normal, 0)
                    .unwrap()
            })
            .collect();
        let mut got = std::collections::BTreeMap::new();
        for _ in 0..queries.len() {
            let (id, outcome) = client.recv().unwrap();
            got.insert(id, outcome.unwrap());
        }
        for (id, q) in ids.iter().zip(&queries) {
            let (index, cosine) = cm.recall(q);
            assert_eq!(
                got[id],
                super::super::super::ServeResponse::Recall { index, cosine }
            );
        }
        srv.shutdown();
        if let Ok(e) = Arc::try_unwrap(eng) {
            e.shutdown();
        }
    }

    #[test]
    fn call_against_a_dead_server_fails_after_bounded_retries() {
        // bind and immediately shut a server to learn a dead port
        let (eng, _, srv) = start_pair(203);
        let addr = srv.addr();
        srv.shutdown();
        if let Ok(e) = Arc::try_unwrap(eng) {
            e.shutdown();
        }
        let mut client = match NetClient::connect(addr) {
            Ok(c) => c,      // raced a TIME_WAIT accept; calls still fail
            Err(_) => return, // refused outright — the property held
        };
        client.retries = 1;
        client.backoff = Duration::from_millis(1);
        client.read_timeout = Duration::from_millis(200);
        let err = client
            .call(&ServeRequest::recall(BinaryHV::zeros(1024)))
            .expect_err("no server behind the port");
        assert!(
            matches!(
                err.kind(),
                io::ErrorKind::ConnectionRefused
                    | io::ErrorKind::ConnectionReset
                    | io::ErrorKind::UnexpectedEof
                    | io::ErrorKind::TimedOut
                    | io::ErrorKind::BrokenPipe
            ),
            "unexpected error kind: {err:?}"
        );
    }
}
