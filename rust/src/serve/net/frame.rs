//! Length-prefixed binary frame codec for the TCP serving wire.
//!
//! Every frame is an 8-byte header followed by a payload:
//!
//! ```text
//! offset  size  field
//!      0     2  magic  "NS"
//!      2     1  version (currently 1)
//!      3     1  frame type: 1=request, 2=response, 3=error
//!      4     4  payload length, u32 LE, capped at MAX_FRAME_LEN
//! ```
//!
//! All multi-byte integers are little-endian. Floats travel as raw IEEE
//! bit patterns (`f32::to_bits` / `f64::to_bits`), so a decoded response
//! is bit-identical to what the engine produced — the oracle comparison
//! in the chaos scenarios is exact equality, not an epsilon.
//!
//! Request payload:
//! ```text
//! id u64 · deadline_us u64 (0 = server default) · priority u8 (0=normal,
//! 1=high) · store u32 · op u8 (0=recall, 1=topk [+ k u32], 2=factorize)
//! · payload: binary query = dim u32 + dim/64 words u64
//!            factorize scene = dim u32 + dim floats f32
//! ```
//!
//! Response payload:
//! ```text
//! id u64 · degraded-depth u8 (count of Degraded wrappers) · kind u8 ·
//!   0=recall:    index u64 + cosine f64
//!   1=topk:      n u32 + n × (index u64, score f64)
//!   2=factorize: n u32 + n × index u64 + iterations u64 + converged u8
//! ```
//!
//! Error payload: `id u64 · code u8` — see [`error_code`] for the
//! [`ServeError`] mapping (codes 1–8) and the protocol-level codes
//! ([`CODE_MALFORMED`], [`CODE_OVERSIZED`], [`CODE_BAD_VERSION`]) a
//! server answers just before closing an unsynchronizable connection.
//!
//! Decoding is *total*: every read is bounds-checked, every length field
//! is validated against the bytes actually present **before** any
//! allocation sized by it, trailing bytes are refused, and dimension
//! invariants (`dim > 0`, `dim % 64 == 0` for binary queries) are
//! checked before [`BinaryHV::from_words`] so its asserts are
//! unreachable from the wire. Malicious input yields a [`WireError`],
//! never a panic and never a partially-decoded value.

use super::super::queue::Priority;
use super::super::registry::StoreId;
use super::super::{RequestOp, ServeError, ServeRequest, ServeResponse};
use crate::vsa::{BinaryHV, RealHV};
use std::fmt;

pub const MAGIC: [u8; 2] = *b"NS";
pub const VERSION: u8 = 1;
pub const HEADER_LEN: usize = 8;
/// Hard cap on a frame's payload length (16 MiB). An oversized header is
/// refused before any payload byte is read or buffered, so a hostile
/// length field cannot balloon server memory.
pub const MAX_FRAME_LEN: usize = 1 << 24;

/// Protocol-level error codes (connection-fatal; the stream can no
/// longer be framed). [`ServeError`] codes are 1–8, see [`error_code`].
pub const CODE_MALFORMED: u8 = 100;
pub const CODE_OVERSIZED: u8 = 101;
pub const CODE_BAD_VERSION: u8 = 102;

/// Frame type discriminant (header byte 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameType {
    Request = 1,
    Response = 2,
    Error = 3,
}

impl FrameType {
    fn from_u8(b: u8) -> Option<FrameType> {
        match b {
            1 => Some(FrameType::Request),
            2 => Some(FrameType::Response),
            3 => Some(FrameType::Error),
            _ => None,
        }
    }
}

/// Why a header or payload failed to decode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireError {
    /// Header bytes 0–1 are not `"NS"` — the stream is not speaking this
    /// protocol (or framing desynchronized).
    BadMagic,
    /// Unknown protocol version byte.
    BadVersion(u8),
    /// Unknown frame-type byte.
    UnknownType(u8),
    /// Declared payload length exceeds [`MAX_FRAME_LEN`].
    Oversized(usize),
    /// Payload ended before a field it declared.
    Truncated,
    /// Payload has bytes left over after the last declared field — a
    /// partial decode is never silently accepted.
    Trailing,
    /// A field's value violates an invariant (bad op/kind/priority byte,
    /// bad dimension, word count mismatch).
    BadPayload(&'static str),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::BadMagic => write!(f, "bad frame magic"),
            WireError::BadVersion(v) => write!(f, "unsupported protocol version {v}"),
            WireError::UnknownType(t) => write!(f, "unknown frame type {t}"),
            WireError::Oversized(n) => write!(f, "frame payload {n} exceeds cap {MAX_FRAME_LEN}"),
            WireError::Truncated => write!(f, "payload truncated"),
            WireError::Trailing => write!(f, "payload has trailing bytes"),
            WireError::BadPayload(why) => write!(f, "bad payload: {why}"),
        }
    }
}

impl std::error::Error for WireError {}

impl WireError {
    /// The error-frame code a server answers with before closing the
    /// connection this error made unframeable.
    pub fn code(&self) -> u8 {
        match self {
            WireError::Oversized(_) => CODE_OVERSIZED,
            WireError::BadVersion(_) => CODE_BAD_VERSION,
            _ => CODE_MALFORMED,
        }
    }
}

/// A decoded request frame: wire id, client deadline (µs; 0 = server
/// default), priority, and the engine-ready [`ServeRequest`].
#[derive(Debug, Clone, PartialEq)]
pub struct RequestFrame {
    pub id: u64,
    pub deadline_us: u64,
    pub priority: Priority,
    pub request: ServeRequest,
}

/// Any decoded frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Request(RequestFrame),
    Response { id: u64, response: ServeResponse },
    Error { id: u64, code: u8 },
}

/// [`ServeError`] → wire error code (1–8, stable across versions).
pub fn error_code(e: ServeError) -> u8 {
    match e {
        ServeError::Overloaded => 1,
        ServeError::DeadlineExceeded => 2,
        ServeError::ShuttingDown => 3,
        ServeError::Unsupported => 4,
        ServeError::InvalidDimension => 5,
        ServeError::UnknownStore => 6,
        ServeError::TenantOverloaded => 7,
        ServeError::Internal => 8,
    }
}

/// Wire error code → [`ServeError`]; `None` for protocol-level codes
/// (the connection is closing, there is no per-request error).
pub fn code_to_error(code: u8) -> Option<ServeError> {
    match code {
        1 => Some(ServeError::Overloaded),
        2 => Some(ServeError::DeadlineExceeded),
        3 => Some(ServeError::ShuttingDown),
        4 => Some(ServeError::Unsupported),
        5 => Some(ServeError::InvalidDimension),
        6 => Some(ServeError::UnknownStore),
        7 => Some(ServeError::TenantOverloaded),
        8 => Some(ServeError::Internal),
        _ => None,
    }
}

/// Parse the fixed 8-byte header into `(frame type, payload length)`.
pub fn parse_header(h: &[u8; HEADER_LEN]) -> Result<(FrameType, usize), WireError> {
    if h[0..2] != MAGIC {
        return Err(WireError::BadMagic);
    }
    if h[2] != VERSION {
        return Err(WireError::BadVersion(h[2]));
    }
    let ft = FrameType::from_u8(h[3]).ok_or(WireError::UnknownType(h[3]))?;
    let len = u32::from_le_bytes([h[4], h[5], h[6], h[7]]) as usize;
    if len > MAX_FRAME_LEN {
        return Err(WireError::Oversized(len));
    }
    Ok((ft, len))
}

// ---------------------------------------------------------------------
// encoding

fn header(ft: FrameType, payload_len: usize) -> [u8; HEADER_LEN] {
    assert!(payload_len <= MAX_FRAME_LEN, "frame payload over cap");
    let len = (payload_len as u32).to_le_bytes();
    [MAGIC[0], MAGIC[1], VERSION, ft as u8, len[0], len[1], len[2], len[3]]
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn framed(ft: FrameType, payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&header(ft, payload.len()));
    out.extend_from_slice(&payload);
    out
}

/// Encode a complete request frame (header + payload).
pub fn encode_request(
    id: u64,
    deadline_us: u64,
    priority: Priority,
    request: &ServeRequest,
) -> Vec<u8> {
    let mut p = Vec::with_capacity(32);
    put_u64(&mut p, id);
    put_u64(&mut p, deadline_us);
    p.push(match priority {
        Priority::Normal => 0,
        Priority::High => 1,
    });
    put_u32(&mut p, request.store.index() as u32);
    match &request.op {
        RequestOp::Recall { query } => {
            p.push(0);
            put_binary(&mut p, query);
        }
        RequestOp::RecallTopK { query, k } => {
            p.push(1);
            put_u32(&mut p, *k as u32);
            put_binary(&mut p, query);
        }
        RequestOp::Factorize { scene } => {
            p.push(2);
            put_u32(&mut p, scene.dim() as u32);
            for &x in scene.as_slice() {
                put_u32(&mut p, x.to_bits());
            }
        }
    }
    framed(FrameType::Request, p)
}

fn put_binary(out: &mut Vec<u8>, hv: &BinaryHV) {
    put_u32(out, hv.dim() as u32);
    for &w in hv.words() {
        put_u64(out, w);
    }
}

/// Encode a complete response frame (header + payload).
pub fn encode_response(id: u64, response: &ServeResponse) -> Vec<u8> {
    // Peel Degraded wrappers into a depth count so the inner answer
    // encodes flat and the client rewraps losslessly.
    let mut depth = 0u8;
    let mut inner = response;
    while let ServeResponse::Degraded { inner: boxed } = inner {
        depth = depth.saturating_add(1);
        inner = boxed;
    }
    let mut p = Vec::with_capacity(32);
    put_u64(&mut p, id);
    p.push(depth);
    match inner {
        ServeResponse::Recall { index, cosine } => {
            p.push(0);
            put_u64(&mut p, *index as u64);
            put_u64(&mut p, cosine.to_bits());
        }
        ServeResponse::RecallTopK { hits } => {
            p.push(1);
            put_u32(&mut p, hits.len() as u32);
            for &(index, score) in hits {
                put_u64(&mut p, index as u64);
                put_u64(&mut p, score.to_bits());
            }
        }
        ServeResponse::Factorize {
            indices,
            iterations,
            converged,
        } => {
            p.push(2);
            put_u32(&mut p, indices.len() as u32);
            for &i in indices {
                put_u64(&mut p, i as u64);
            }
            put_u64(&mut p, *iterations as u64);
            p.push(u8::from(*converged));
        }
        ServeResponse::Degraded { .. } => unreachable!("wrappers peeled above"),
    }
    framed(FrameType::Response, p)
}

/// Encode a complete error frame (header + payload).
pub fn encode_error(id: u64, code: u8) -> Vec<u8> {
    let mut p = Vec::with_capacity(9);
    put_u64(&mut p, id);
    p.push(code);
    framed(FrameType::Error, p)
}

// ---------------------------------------------------------------------
// decoding

/// Bounds-checked payload cursor: every read either yields bytes that
/// exist or `Err(Truncated)` — indexing can never panic.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, i: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
        ]))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Refuse trailing bytes: the payload must be exactly its fields.
    fn finish(&self) -> Result<(), WireError> {
        if self.remaining() != 0 {
            return Err(WireError::Trailing);
        }
        Ok(())
    }
}

fn take_binary(cur: &mut Cur<'_>) -> Result<BinaryHV, WireError> {
    let dim = cur.u32()? as usize;
    if dim == 0 || dim % 64 != 0 {
        return Err(WireError::BadPayload("binary dim must be a positive multiple of 64"));
    }
    let n_words = dim / 64;
    // length check precedes the allocation, so a hostile dim field can
    // not reserve more memory than the payload actually carries
    if cur.remaining() < n_words * 8 {
        return Err(WireError::Truncated);
    }
    let mut words = Vec::with_capacity(n_words);
    for _ in 0..n_words {
        words.push(cur.u64()?);
    }
    Ok(BinaryHV::from_words(dim, words))
}

/// Decode one payload of the given type. Total: any input yields
/// `Ok(frame)` or a [`WireError`], never a panic.
pub fn decode_payload(ft: FrameType, payload: &[u8]) -> Result<Frame, WireError> {
    let mut cur = Cur::new(payload);
    let frame = match ft {
        FrameType::Request => {
            let id = cur.u64()?;
            let deadline_us = cur.u64()?;
            let priority = match cur.u8()? {
                0 => Priority::Normal,
                1 => Priority::High,
                _ => return Err(WireError::BadPayload("bad priority byte")),
            };
            let store = StoreId(cur.u32()? as usize);
            let op = match cur.u8()? {
                0 => RequestOp::Recall {
                    query: take_binary(&mut cur)?,
                },
                1 => {
                    let k = cur.u32()? as usize;
                    RequestOp::RecallTopK {
                        query: take_binary(&mut cur)?,
                        k,
                    }
                }
                2 => {
                    let dim = cur.u32()? as usize;
                    if cur.remaining() < dim * 4 {
                        return Err(WireError::Truncated);
                    }
                    let mut data = Vec::with_capacity(dim);
                    for _ in 0..dim {
                        data.push(f32::from_bits(cur.u32()?));
                    }
                    RequestOp::Factorize {
                        scene: RealHV::from_vec(data),
                    }
                }
                _ => return Err(WireError::BadPayload("bad op byte")),
            };
            Frame::Request(RequestFrame {
                id,
                deadline_us,
                priority,
                request: ServeRequest { store, op },
            })
        }
        FrameType::Response => {
            let id = cur.u64()?;
            let depth = cur.u8()?;
            let mut response = match cur.u8()? {
                0 => ServeResponse::Recall {
                    index: cur.u64()? as usize,
                    cosine: cur.f64()?,
                },
                1 => {
                    let n = cur.u32()? as usize;
                    if cur.remaining() < n * 16 {
                        return Err(WireError::Truncated);
                    }
                    let mut hits = Vec::with_capacity(n);
                    for _ in 0..n {
                        let index = cur.u64()? as usize;
                        let score = cur.f64()?;
                        hits.push((index, score));
                    }
                    ServeResponse::RecallTopK { hits }
                }
                2 => {
                    let n = cur.u32()? as usize;
                    if cur.remaining() < n * 8 {
                        return Err(WireError::Truncated);
                    }
                    let mut indices = Vec::with_capacity(n);
                    for _ in 0..n {
                        indices.push(cur.u64()? as usize);
                    }
                    let iterations = cur.u64()? as usize;
                    let converged = match cur.u8()? {
                        0 => false,
                        1 => true,
                        _ => return Err(WireError::BadPayload("bad converged byte")),
                    };
                    ServeResponse::Factorize {
                        indices,
                        iterations,
                        converged,
                    }
                }
                _ => return Err(WireError::BadPayload("bad response kind byte")),
            };
            for _ in 0..depth {
                response = ServeResponse::Degraded {
                    inner: Box::new(response),
                };
            }
            Frame::Response { id, response }
        }
        FrameType::Error => {
            let id = cur.u64()?;
            let code = cur.u8()?;
            Frame::Error { id, code }
        }
    };
    cur.finish()?;
    Ok(frame)
}

/// Decode one complete frame from the front of `buf`: `Ok(Some((frame,
/// consumed)))` when a whole frame is present, `Ok(None)` when more
/// bytes are needed, `Err` on a protocol violation. This is the shared
/// incremental entry point for the server reader and the client.
pub fn decode_from(buf: &[u8]) -> Result<Option<(Frame, usize)>, WireError> {
    if buf.len() < HEADER_LEN {
        return Ok(None);
    }
    let mut h = [0u8; HEADER_LEN];
    h.copy_from_slice(&buf[..HEADER_LEN]);
    let (ft, len) = parse_header(&h)?;
    if buf.len() < HEADER_LEN + len {
        return Ok(None);
    }
    let frame = decode_payload(ft, &buf[HEADER_LEN..HEADER_LEN + len])?;
    Ok(Some((frame, HEADER_LEN + len)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::Rng;

    fn arb_request(rng: &mut Rng) -> (u64, u64, Priority, ServeRequest) {
        let id = rng.next_u64();
        let deadline_us = if rng.below(4) == 0 { 0u64 } else { rng.below(10_000_000) as u64 };
        let priority = if rng.below(2) == 0 { Priority::Normal } else { Priority::High };
        let store = StoreId(rng.below(8) as usize);
        let dim = 64 * (1 + rng.below(8) as usize);
        let op = match rng.below(3) {
            0 => RequestOp::Recall {
                query: crate::vsa::BinaryHV::random(rng, dim),
            },
            1 => RequestOp::RecallTopK {
                query: crate::vsa::BinaryHV::random(rng, dim),
                k: 1 + rng.below(16) as usize,
            },
            _ => {
                let n = 1 + rng.below(64) as usize;
                let data: Vec<f32> = (0..n).map(|_| (rng.f64() * 4.0 - 2.0) as f32).collect();
                RequestOp::Factorize {
                    scene: RealHV::from_vec(data),
                }
            }
        };
        (id, deadline_us, priority, ServeRequest { store, op })
    }

    fn arb_response(rng: &mut Rng) -> (u64, ServeResponse) {
        let id = rng.next_u64();
        let base = match rng.below(3) {
            0 => ServeResponse::Recall {
                index: rng.below(1 << 20) as usize,
                cosine: rng.f64() * 2.0 - 1.0,
            },
            1 => {
                let n = rng.below(12) as usize;
                ServeResponse::RecallTopK {
                    hits: (0..n)
                        .map(|_| (rng.below(1 << 16) as usize, rng.f64()))
                        .collect(),
                }
            }
            _ => ServeResponse::Factorize {
                indices: (0..1 + rng.below(5) as usize)
                    .map(|_| rng.below(64) as usize)
                    .collect(),
                iterations: rng.below(100) as usize,
                converged: rng.below(2) == 0,
            },
        };
        let resp = match rng.below(4) {
            0 => ServeResponse::Degraded { inner: Box::new(base) },
            _ => base,
        };
        (id, resp)
    }

    #[test]
    fn request_roundtrip_is_exact() {
        forall(0x9e01, 200, arb_request, |(id, dl, pr, req)| {
            let bytes = encode_request(*id, *dl, *pr, req);
            match decode_from(&bytes) {
                Ok(Some((Frame::Request(f), used))) => {
                    used == bytes.len()
                        && f.id == *id
                        && f.deadline_us == *dl
                        && f.priority == *pr
                        && f.request == *req
                }
                _ => false,
            }
        });
    }

    #[test]
    fn response_roundtrip_is_bit_exact() {
        forall(0x9e02, 200, arb_response, |(id, resp)| {
            let bytes = encode_response(*id, resp);
            match decode_from(&bytes) {
                Ok(Some((Frame::Response { id: rid, response }, used))) => {
                    used == bytes.len() && rid == *id && response == *resp
                }
                _ => false,
            }
        });
    }

    #[test]
    fn error_frames_roundtrip_and_codes_map_back() {
        for e in [
            ServeError::Overloaded,
            ServeError::DeadlineExceeded,
            ServeError::ShuttingDown,
            ServeError::Unsupported,
            ServeError::InvalidDimension,
            ServeError::UnknownStore,
            ServeError::TenantOverloaded,
            ServeError::Internal,
        ] {
            let code = error_code(e);
            assert_eq!(code_to_error(code), Some(e));
            let bytes = encode_error(7, code);
            assert_eq!(
                decode_from(&bytes).unwrap().unwrap().0,
                Frame::Error { id: 7, code }
            );
        }
        assert_eq!(code_to_error(CODE_MALFORMED), None);
        assert_eq!(code_to_error(0), None);
    }

    #[test]
    fn truncated_prefixes_never_decode_partially() {
        // every strict prefix of a valid frame either asks for more
        // bytes (incomplete) or fails typed — never Ok(Some) early
        forall(0x9e03, 60, arb_request, |(id, dl, pr, req)| {
            let bytes = encode_request(*id, *dl, *pr, req);
            (0..bytes.len()).all(|cut| matches!(decode_from(&bytes[..cut]), Ok(None)))
        });
        // a payload cut short relative to its header is Truncated, not
        // a partial value (header claims the full length; feed less
        // through decode_payload directly)
        let bytes = encode_request(1, 0, Priority::Normal, &ServeRequest::recall(
            crate::vsa::BinaryHV::zeros(64),
        ));
        let payload = &bytes[HEADER_LEN..];
        for cut in 0..payload.len() {
            let got = decode_payload(FrameType::Request, &payload[..cut]);
            assert!(
                matches!(got, Err(WireError::Truncated) | Err(WireError::BadPayload(_))),
                "cut {cut} must refuse, got {got:?}"
            );
        }
    }

    #[test]
    fn garbage_bytes_never_panic_and_never_yield_requests() {
        forall(
            0x9e04,
            300,
            |rng| {
                let n = rng.below(96) as usize;
                (0..n).map(|_| rng.below(256) as u8).collect::<Vec<u8>>()
            },
            |bytes| {
                // any outcome but a panic is acceptable for random bytes;
                // decode_from must stay total
                let _ = decode_from(bytes);
                true
            },
        );
        // garbage behind a *valid* request header must be refused, not
        // half-decoded into a request
        forall(
            0x9e05,
            200,
            |rng| {
                let n = rng.below(64) as usize;
                let mut bytes = Vec::with_capacity(HEADER_LEN + n);
                bytes.extend_from_slice(&header(FrameType::Request, n));
                for _ in 0..n {
                    bytes.push(rng.below(256) as u8);
                }
                bytes
            },
            |bytes| match decode_from(bytes) {
                Err(_) => true,
                // astronomically unlikely (random bytes forming a valid
                // request), but structurally possible at tiny sizes only
                // if every field validates — in which case decode is a
                // full, exact parse, which is fine too
                Ok(Some((Frame::Request(_), used))) => *used == bytes.len(),
                _ => false,
            },
        );
    }

    #[test]
    fn header_validation_rejects_each_field() {
        let good = header(FrameType::Request, 4);
        assert!(parse_header(&good).is_ok());
        let mut bad = good;
        bad[0] = b'X';
        assert_eq!(parse_header(&bad), Err(WireError::BadMagic));
        let mut bad = good;
        bad[2] = 9;
        assert_eq!(parse_header(&bad), Err(WireError::BadVersion(9)));
        assert_eq!(WireError::BadVersion(9).code(), CODE_BAD_VERSION);
        let mut bad = good;
        bad[3] = 77;
        assert_eq!(parse_header(&bad), Err(WireError::UnknownType(77)));
        let mut bad = good;
        bad[4..8].copy_from_slice(&((MAX_FRAME_LEN as u32) + 1).to_le_bytes());
        assert_eq!(parse_header(&bad), Err(WireError::Oversized(MAX_FRAME_LEN + 1)));
        assert_eq!(WireError::Oversized(0).code(), CODE_OVERSIZED);
        assert_eq!(WireError::Truncated.code(), CODE_MALFORMED);
    }

    #[test]
    fn hostile_length_fields_fail_before_allocating() {
        // a binary query claiming dim 2^31 inside a 32-byte payload:
        // the remaining-bytes check fires before any Vec::with_capacity
        let mut p = Vec::new();
        put_u64(&mut p, 1); // id
        put_u64(&mut p, 0); // deadline
        p.push(0); // priority
        put_u32(&mut p, 0); // store
        p.push(0); // recall
        put_u32(&mut p, 1u32 << 31); // hostile dim (multiple of 64)
        p.extend_from_slice(&[0u8; 8]); // one word, not 2^31/64
        assert_eq!(
            decode_payload(FrameType::Request, &p),
            Err(WireError::Truncated)
        );
        // same for a topk response claiming 2^30 hits
        let mut p = Vec::new();
        put_u64(&mut p, 1);
        p.push(0); // depth
        p.push(1); // topk
        put_u32(&mut p, 1u32 << 30);
        assert_eq!(
            decode_payload(FrameType::Response, &p),
            Err(WireError::Truncated)
        );
    }

    #[test]
    fn trailing_bytes_are_refused() {
        let mut bytes = encode_error(3, 1);
        // grow the declared payload and append a stray byte
        let n = (bytes.len() - HEADER_LEN + 1) as u32;
        bytes[4..8].copy_from_slice(&n.to_le_bytes());
        bytes.push(0xAB);
        assert_eq!(decode_from(&bytes), Err(WireError::Trailing));
    }

    #[test]
    fn zero_and_misaligned_dims_are_bad_payload() {
        for dim in [0u32, 63, 65, 100] {
            let mut p = Vec::new();
            put_u64(&mut p, 1);
            put_u64(&mut p, 0);
            p.push(0);
            put_u32(&mut p, 0);
            p.push(0);
            put_u32(&mut p, dim);
            assert!(
                matches!(
                    decode_payload(FrameType::Request, &p),
                    Err(WireError::BadPayload(_))
                ),
                "dim {dim} must be refused before BinaryHV::from_words"
            );
        }
    }
}
