//! `serve/net/` — the std-only TCP front-end over the serving engine.
//!
//! The paper's system-level critique (complex flow control, limited
//! scalability) applies doubly once requests cross a network: the wire
//! must preserve the engine's correctness contract (every response
//! bit-identical to the sequential oracle) *and* its backpressure
//! discipline (a full admission lane refuses with an error frame instead
//! of buffering unboundedly), while surviving the network's own failure
//! modes — slow writers, half-open peers, mid-frame disconnects, and
//! garbage bytes.
//!
//! - [`frame`]: the length-prefixed binary codec. An 8-byte header
//!   (magic `"NS"`, version, frame type, payload length capped at
//!   [`frame::MAX_FRAME_LEN`]) fronts request / response / error
//!   payloads that decode straight into [`super::ServeRequest`] /
//!   [`super::ServeResponse`]. Decoding is total: truncated, oversized,
//!   or garbage input is refused with a typed [`frame::WireError`] —
//!   never a panic, never a partial decode (property-tested).
//! - [`server`]: [`NetServer`] — an accept loop plus one reader and one
//!   writer thread per connection. The reader decodes frames and submits
//!   through [`super::engine::ServeEngine::submit_with_completion`]; the
//!   writer harvests the connection's [`super::queue::CompletionQueue`]
//!   and writes response/error frames. Per-connection robustness: a
//!   mid-frame stall beyond `read_timeout` is a slow-loris peer, an
//!   idle gap beyond `idle_timeout` is a half-open peer — both are
//!   reaped (socket shut, completion queue closed, counted). Admission
//!   refusals and the per-connection in-flight cap answer error frames
//!   immediately — connection backpressure is the lane's backpressure.
//!   Shutdown drains: in-flight tickets are answered before the socket
//!   closes (bounded by `drain_timeout`).
//! - [`client`]: [`NetClient`] — a blocking client with pipelined
//!   `send`/`recv` halves and a retrying `call` wrapper (exponential
//!   backoff, reconnect, and the *same* request id across attempts:
//!   every serve op is a pure read, so retries are idempotent by
//!   construction).
//!
//! Everything here is `std::net` + threads — no external dependencies,
//! matching the repo's vendored-only rule.

pub mod client;
pub mod frame;
pub mod server;

pub use client::NetClient;
pub use server::{NetConfig, NetCounters, NetServer};
