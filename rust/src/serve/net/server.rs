//! TCP server: accept loop + per-connection reader/writer thread pairs
//! over the serving engine's completion-queue async path.
//!
//! Per connection, the reader thread accumulates bytes, decodes frames
//! incrementally ([`frame::decode_from`]), and submits each request via
//! [`ServeEngine::submit_with_completion`] tagged with its wire id; the
//! writer thread blocks on the connection's own [`CompletionQueue`] and
//! writes response/error frames as the engine finishes them — responses
//! may leave out of request order, ids are the correlation.
//!
//! Robustness contract (the tentpole):
//!
//! - **Backpressure, not buffering.** An admission refusal
//!   (`Overloaded`/`TenantOverloaded`) or the per-connection
//!   `max_inflight` cap answers an error frame immediately. The server
//!   never queues requests itself — the bounded admission queue is the
//!   only queue, so a flooding connection is shed by the same lane
//!   discipline as an in-process flooder.
//! - **Slow-loris reaping.** A peer stalled *mid-frame* longer than
//!   `read_timeout` is reaped (counted in `slowloris_reaped`); a peer
//!   idle *between* frames longer than `idle_timeout` is a half-open
//!   carcass and reaped too (`halfopen_reaped`). Reaping shuts the
//!   socket and closes the completion queue; completions for tickets
//!   still in flight are dropped harmlessly
//!   ([`CompletionQueue::push`] on a closed queue returns `false`).
//! - **Protocol errors answer then close.** Undecodable input (bad
//!   magic/version/type, oversized length, malformed payload) gets one
//!   error frame with the protocol code ([`WireError::code`]) and the
//!   connection closes — after a framing error the stream cannot be
//!   resynchronized. Write-side stalls are bounded by `write_timeout`.
//! - **Drain, don't wedge.** A clean EOF (and server shutdown) waits up
//!   to `drain_timeout` for in-flight tickets to finish and their
//!   responses to flush before closing, so a well-behaved client that
//!   half-closes after its last request still gets every answer.

use super::super::engine::ServeEngine;
use super::super::queue::CompletionQueue;
use super::super::registry::StoreId;
use super::super::{RequestKind, ServeError};
use super::frame::{self, Frame, RequestFrame};
use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Per-connection routing of in-flight wire ids to the `(store, class)`
/// their request targeted. The reader inserts before submit, the writer
/// removes at completion — so the encode + write span of each response
/// can be attributed to the right `net_out` stage lane even for error
/// outcomes (which carry no response payload to infer the class from).
type TagRoutes = Mutex<HashMap<u64, (StoreId, RequestKind)>>;

/// Read-poll quantum: reader threads wake at this cadence to check the
/// stall clocks and the server stop flag, so reap latency is bounded by
/// the configured deadline plus one quantum.
const POLL: Duration = Duration::from_millis(25);

/// Connection-level policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct NetConfig {
    /// Max mid-frame stall before a connection is reaped as slow-loris.
    pub read_timeout: Duration,
    /// Max between-frames idle before a connection is reaped as
    /// half-open (a peer that vanished without FIN never trips TCP's
    /// own timers at this timescale — this deadline is the bound).
    pub idle_timeout: Duration,
    /// Per-write-call stall cap (slow *reader* peers).
    pub write_timeout: Duration,
    /// Per-connection in-flight request cap; excess requests are
    /// refused with an `Overloaded` error frame (backpressure).
    pub max_inflight: usize,
    /// How long a closing connection waits for in-flight tickets to
    /// finish and flush before giving up.
    pub drain_timeout: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            read_timeout: Duration::from_secs(2),
            idle_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(2),
            max_inflight: 256,
            drain_timeout: Duration::from_secs(5),
        }
    }
}

/// Wire-level counters (all monotonic), snapshot into [`NetCounters`].
#[derive(Debug, Default)]
pub struct NetStats {
    accepted: AtomicU64,
    frames_in: AtomicU64,
    frames_out: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
    protocol_errors: AtomicU64,
    refused: AtomicU64,
    slowloris_reaped: AtomicU64,
    halfopen_reaped: AtomicU64,
    disconnects: AtomicU64,
}

/// A point-in-time copy of [`NetStats`], for reports and invariants.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetCounters {
    pub accepted: u64,
    pub frames_in: u64,
    pub frames_out: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Undecodable frames answered with a protocol error frame.
    pub protocol_errors: u64,
    /// Requests refused at the wire (`max_inflight` cap) or by
    /// admission (`Overloaded`/`TenantOverloaded`) — each got its
    /// error frame.
    pub refused: u64,
    pub slowloris_reaped: u64,
    pub halfopen_reaped: u64,
    /// Connections that died mid-write/mid-read without a clean EOF.
    pub disconnects: u64,
}

impl NetStats {
    fn snapshot(&self) -> NetCounters {
        NetCounters {
            accepted: self.accepted.load(Ordering::Relaxed),
            frames_in: self.frames_in.load(Ordering::Relaxed),
            frames_out: self.frames_out.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
            refused: self.refused.load(Ordering::Relaxed),
            slowloris_reaped: self.slowloris_reaped.load(Ordering::Relaxed),
            halfopen_reaped: self.halfopen_reaped.load(Ordering::Relaxed),
            disconnects: self.disconnects.load(Ordering::Relaxed),
        }
    }

    fn bump(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }
}

/// A running TCP front-end. Holds the engine alive through its `Arc`;
/// shut the server down before shutting the engine down.
pub struct NetServer {
    addr: SocketAddr,
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral test port)
    /// and start accepting. Each accepted connection gets a reader
    /// thread (this function's spawned accept loop spawns them) and a
    /// writer thread consuming that connection's completion queue.
    pub fn start(engine: Arc<ServeEngine>, addr: &str, cfg: NetConfig) -> io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stats = Arc::new(NetStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stats = Arc::clone(&stats);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("nscog-net-accept".into())
                .spawn(move || accept_loop(listener, engine, cfg, stats, stop))?
        };
        Ok(NetServer {
            addr: local,
            stats,
            stop,
            accept: Some(accept),
        })
    }

    /// The bound address (resolves the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn counters(&self) -> NetCounters {
        self.stats.snapshot()
    }

    /// Stop accepting, drain and join every connection, join the accept
    /// loop. Connections get their in-flight responses flushed (bounded
    /// by their `drain_timeout`).
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // wake the blocking accept with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(
    listener: TcpListener,
    engine: Arc<ServeEngine>,
    cfg: NetConfig,
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
        };
        if stop.load(Ordering::SeqCst) {
            break; // the shutdown wake-up connection, or a race with it
        }
        NetStats::bump(&stats.accepted, 1);
        let spawned = {
            let engine = Arc::clone(&engine);
            let stats = Arc::clone(&stats);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("nscog-net-conn".into())
                .spawn(move || serve_conn(stream, engine, cfg, stats, stop))
        };
        match spawned {
            Ok(h) => conns.push(h),
            Err(_) => {} // stream dropped: refused by closing
        }
        // join connections that already finished so a long-lived server
        // doesn't accumulate handles
        let mut i = 0;
        while i < conns.len() {
            if conns[i].is_finished() {
                let _ = conns.swap_remove(i).join();
            } else {
                i += 1;
            }
        }
    }
    for h in conns {
        let _ = h.join();
    }
}

/// How the reader decided the connection should end.
enum Teardown {
    /// Clean EOF or server stop: wait for in-flight tickets, flush, close.
    Drain,
    /// Reaped or errored: close now; undelivered completions drop.
    Abort,
}

fn serve_conn(
    stream: TcpStream,
    engine: Arc<ServeEngine>,
    cfg: NetConfig,
    stats: Arc<NetStats>,
    stop: Arc<AtomicBool>,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(POLL));
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => {
            NetStats::bump(&stats.disconnects, 1);
            return;
        }
    };
    let _ = write_half.set_write_timeout(Some(cfg.write_timeout));
    let wr = Arc::new(Mutex::new(write_half));
    let cq = CompletionQueue::new();
    let inflight = Arc::new(AtomicUsize::new(0));
    let tags: Arc<TagRoutes> = Arc::new(Mutex::new(HashMap::new()));

    let writer = {
        let cq = cq.clone();
        let wr = Arc::clone(&wr);
        let stats = Arc::clone(&stats);
        let inflight = Arc::clone(&inflight);
        let engine = Arc::clone(&engine);
        let tags = Arc::clone(&tags);
        std::thread::Builder::new()
            .name("nscog-net-writer".into())
            .spawn(move || writer_loop(cq, wr, stats, inflight, engine, tags))
    };
    let writer = match writer {
        Ok(h) => h,
        Err(_) => {
            NetStats::bump(&stats.disconnects, 1);
            return;
        }
    };

    let teardown = reader_loop(&stream, &engine, &cfg, &stats, &stop, &wr, &cq, &inflight, &tags);
    match teardown {
        Teardown::Drain => {
            // bounded wait for the engine to finish what this connection
            // still has in flight; the writer is flushing as they land
            let deadline = Instant::now() + cfg.drain_timeout;
            while inflight.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(1));
            }
            cq.close();
            let _ = writer.join();
            let _ = stream.shutdown(Shutdown::Both);
        }
        Teardown::Abort => {
            cq.close();
            let _ = stream.shutdown(Shutdown::Both);
            let _ = writer.join();
        }
    }
}

fn writer_loop(
    cq: CompletionQueue,
    wr: Arc<Mutex<TcpStream>>,
    stats: Arc<NetStats>,
    inflight: Arc<AtomicUsize>,
    engine: Arc<ServeEngine>,
    tags: Arc<TagRoutes>,
) {
    while let Some(c) = cq.pop_blocking() {
        inflight.fetch_sub(1, Ordering::SeqCst);
        let routed = tags
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .remove(&c.tag);
        // bracket the outbound hop: response encode + socket write
        let t0 = Instant::now();
        let bytes = match &c.outcome {
            Ok(resp) => frame::encode_response(c.tag, resp),
            Err(e) => frame::encode_error(c.tag, frame::error_code(*e)),
        };
        let wrote = write_frame(&wr, &bytes, &stats);
        if wrote {
            if let Some((store, kind)) = routed {
                engine.record_net_out(store, kind, t0.elapsed());
            }
        } else {
            // peer unwritable: stop flushing; the reader will observe
            // the dead socket and abort the connection
            break;
        }
    }
}

/// Write one whole frame under the connection's write lock (frames from
/// the writer thread and the reader's refusal path never interleave).
fn write_frame(wr: &Mutex<TcpStream>, bytes: &[u8], stats: &NetStats) -> bool {
    let mut w = wr.lock().unwrap_or_else(|p| p.into_inner());
    match w.write_all(bytes) {
        Ok(()) => {
            NetStats::bump(&stats.frames_out, 1);
            NetStats::bump(&stats.bytes_out, bytes.len() as u64);
            true
        }
        Err(_) => {
            NetStats::bump(&stats.disconnects, 1);
            false
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn reader_loop(
    stream: &TcpStream,
    engine: &ServeEngine,
    cfg: &NetConfig,
    stats: &NetStats,
    stop: &AtomicBool,
    wr: &Mutex<TcpStream>,
    cq: &CompletionQueue,
    inflight: &AtomicUsize,
    tags: &TagRoutes,
) -> Teardown {
    let mut stream = stream;
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 4096];
    let mut last_progress = Instant::now();
    // When the first bytes of the frame currently being accumulated
    // arrived — origin of the inbound wire span (socket accumulation +
    // decode) attributed to that frame's request. `None` while the
    // buffer sits empty between frames.
    let mut frame_t0: Option<Instant> = None;
    loop {
        if stop.load(Ordering::SeqCst) {
            return Teardown::Drain;
        }
        match stream.read(&mut tmp) {
            Ok(0) => return Teardown::Drain, // clean EOF / half-close
            Ok(n) => {
                NetStats::bump(&stats.bytes_in, n as u64);
                last_progress = Instant::now();
                if buf.is_empty() {
                    frame_t0 = Some(last_progress);
                }
                buf.extend_from_slice(&tmp[..n]);
                loop {
                    match frame::decode_from(&buf) {
                        Ok(None) => break, // need more bytes
                        Ok(Some((f, used))) => {
                            buf.drain(..used);
                            NetStats::bump(&stats.frames_in, 1);
                            let net_in = frame_t0
                                .map(|t| t.elapsed())
                                .unwrap_or(Duration::ZERO);
                            // pipelined frames left in the buffer start
                            // their span at this decode boundary
                            frame_t0 = (!buf.is_empty()).then(Instant::now);
                            if !handle_frame(f, engine, cfg, stats, wr, cq, inflight, tags, net_in)
                            {
                                return Teardown::Abort;
                            }
                        }
                        Err(we) => {
                            // the stream cannot be re-framed after this:
                            // answer the protocol error and close
                            NetStats::bump(&stats.protocol_errors, 1);
                            let _ = write_frame(wr, &frame::encode_error(0, we.code()), stats);
                            return Teardown::Abort;
                        }
                    }
                }
            }
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                let stalled = last_progress.elapsed();
                if !buf.is_empty() && stalled >= cfg.read_timeout {
                    NetStats::bump(&stats.slowloris_reaped, 1);
                    return Teardown::Abort;
                }
                // a connection awaiting responses is not half-open: the
                // peer is quiet because it is blocked on *us*
                if buf.is_empty()
                    && inflight.load(Ordering::SeqCst) == 0
                    && stalled >= cfg.idle_timeout
                {
                    NetStats::bump(&stats.halfopen_reaped, 1);
                    return Teardown::Abort;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => {
                NetStats::bump(&stats.disconnects, 1);
                return Teardown::Abort;
            }
        }
    }
}

/// Handle one decoded frame; `false` aborts the connection.
#[allow(clippy::too_many_arguments)]
fn handle_frame(
    f: Frame,
    engine: &ServeEngine,
    cfg: &NetConfig,
    stats: &NetStats,
    wr: &Mutex<TcpStream>,
    cq: &CompletionQueue,
    inflight: &AtomicUsize,
    tags: &TagRoutes,
    net_in: Duration,
) -> bool {
    let req = match f {
        Frame::Request(r) => r,
        // a client has no business sending response/error frames; the
        // stream is suspect, treat like any other protocol violation
        Frame::Response { .. } | Frame::Error { .. } => {
            NetStats::bump(&stats.protocol_errors, 1);
            let _ = write_frame(wr, &frame::encode_error(0, frame::CODE_MALFORMED), stats);
            return false;
        }
    };
    let RequestFrame {
        id,
        deadline_us,
        priority,
        request,
    } = req;
    // connection backpressure: the wire cap refuses before admission
    // ever sees the request, exactly like a full lane would
    if inflight.load(Ordering::SeqCst) >= cfg.max_inflight {
        NetStats::bump(&stats.refused, 1);
        return write_frame(
            wr,
            &frame::encode_error(id, frame::error_code(ServeError::Overloaded)),
            stats,
        );
    }
    // satellite: the client's deadline rides the wire; 0 means "server
    // default" (the engine config's submit deadline)
    let deadline = if deadline_us == 0 {
        engine.config().default_deadline
    } else {
        Duration::from_micros(deadline_us)
    };
    inflight.fetch_add(1, Ordering::SeqCst);
    let route = (request.store, request.kind());
    tags.lock()
        .unwrap_or_else(|p| p.into_inner())
        .insert(id, route);
    match engine.submit_with_completion_wire(request, priority, deadline, cq, id, net_in) {
        Ok(()) => true,
        Err(e) => {
            tags.lock().unwrap_or_else(|p| p.into_inner()).remove(&id);
            inflight.fetch_sub(1, Ordering::SeqCst);
            if matches!(e, ServeError::Overloaded | ServeError::TenantOverloaded) {
                NetStats::bump(&stats.refused, 1);
            }
            write_frame(wr, &frame::encode_error(id, frame::error_code(e)), stats)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::engine::{EngineConfig, ServeEngine};
    use super::super::super::{ServeRequest, ServeResponse};
    use super::super::client::NetClient;
    use super::*;
    use crate::util::Rng;
    use crate::vsa::{BinaryCodebook, BinaryHV, CleanupMemory};

    fn start_engine(seed: u64) -> (Arc<ServeEngine>, CleanupMemory) {
        let mut rng = Rng::new(seed);
        let cb = BinaryCodebook::random(&mut rng, 32, 1024);
        let cm = CleanupMemory::new(cb.clone());
        let eng = ServeEngine::start(&cb, None, EngineConfig::default()).expect("spawn workers");
        (Arc::new(eng), cm)
    }

    fn quick_cfg() -> NetConfig {
        NetConfig {
            read_timeout: Duration::from_millis(120),
            idle_timeout: Duration::from_millis(200),
            ..NetConfig::default()
        }
    }

    #[test]
    fn networked_responses_are_bit_exact() {
        let (eng, cm) = start_engine(101);
        let srv = NetServer::start(Arc::clone(&eng), "127.0.0.1:0", NetConfig::default()).unwrap();
        let mut client = NetClient::connect(srv.addr()).unwrap();
        let mut rng = Rng::new(102);
        for _ in 0..16 {
            let q = BinaryHV::random(&mut rng, 1024);
            let got = client
                .call(&ServeRequest::recall(q.clone()))
                .expect("wire call")
                .expect("served");
            let (index, cosine) = cm.recall(&q);
            assert_eq!(got, ServeResponse::Recall { index, cosine });
        }
        let c = srv.counters();
        assert_eq!(c.accepted, 1);
        assert_eq!(c.frames_in, 16);
        assert_eq!(c.frames_out, 16);
        assert_eq!(c.protocol_errors, 0);
        srv.shutdown();
        // after the writer joined, every wire request shows up in the
        // net stage lanes: 16 inbound read+decode spans, 16 outbound
        // encode+write spans, attributed to the recall class
        let snap = eng.stats();
        let recall = &snap.stages[RequestKind::Recall.index()];
        let net_in = recall.net_in.expect("wire requests record net_in");
        assert_eq!(net_in.n, 16);
        assert!(net_in.mean_s > 0.0);
        let net_out = recall.net_out.expect("flushed responses record net_out");
        assert_eq!(net_out.n, 16);
        assert!(net_out.mean_s > 0.0);
        // the per-store mirror saw the same wire traffic
        let st = &snap.stores[0].stages[RequestKind::Recall.index()];
        assert_eq!(st.net_in.unwrap().n, 16);
        assert_eq!(st.net_out.unwrap().n, 16);
        if let Ok(e) = Arc::try_unwrap(eng) {
            e.shutdown();
        }
    }

    #[test]
    fn garbage_is_answered_with_a_protocol_error_then_closed() {
        let (eng, _) = start_engine(103);
        let srv = NetServer::start(Arc::clone(&eng), "127.0.0.1:0", quick_cfg()).unwrap();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        s.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        let mut resp = Vec::new();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let _ = s.read_to_end(&mut resp); // server closes after the error frame
        let (f, used) = frame::decode_from(&resp).unwrap().expect("one error frame");
        assert_eq!(used, resp.len());
        match f {
            Frame::Error { id, code } => {
                assert_eq!(id, 0);
                assert_eq!(code, frame::CODE_MALFORMED);
            }
            other => panic!("expected protocol error frame, got {other:?}"),
        }
        assert_eq!(srv.counters().protocol_errors, 1);
        srv.shutdown();
        if let Ok(e) = Arc::try_unwrap(eng) {
            e.shutdown();
        }
    }

    #[test]
    fn half_open_connections_are_reaped_within_the_idle_deadline() {
        let (eng, _) = start_engine(105);
        let cfg = quick_cfg();
        let srv = NetServer::start(Arc::clone(&eng), "127.0.0.1:0", cfg).unwrap();
        // connect, send nothing: a half-open carcass
        let s = TcpStream::connect(srv.addr()).unwrap();
        let t0 = Instant::now();
        let deadline = t0 + Duration::from_secs(5);
        while srv.counters().halfopen_reaped == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(srv.counters().halfopen_reaped, 1, "idle peer must be reaped");
        drop(s);
        srv.shutdown();
        if let Ok(e) = Arc::try_unwrap(eng) {
            e.shutdown();
        }
    }

    #[test]
    fn slow_loris_mid_frame_stall_is_reaped_and_victims_keep_serving() {
        let (eng, cm) = start_engine(107);
        let srv = NetServer::start(Arc::clone(&eng), "127.0.0.1:0", quick_cfg()).unwrap();
        // attacker: a valid header promising 64 bytes, then silence
        let mut attacker = TcpStream::connect(srv.addr()).unwrap();
        let mut partial = frame::encode_request(
            1,
            0,
            super::super::super::queue::Priority::Normal,
            &ServeRequest::recall(BinaryHV::zeros(1024)),
        );
        partial.truncate(frame::HEADER_LEN + 3);
        attacker.write_all(&partial).unwrap();
        // victim on its own connection: full service while the attacker
        // stalls
        let mut victim = NetClient::connect(srv.addr()).unwrap();
        let mut rng = Rng::new(108);
        let q = BinaryHV::random(&mut rng, 1024);
        let got = victim.call(&ServeRequest::recall(q.clone())).unwrap().unwrap();
        let (index, cosine) = cm.recall(&q);
        assert_eq!(got, ServeResponse::Recall { index, cosine });
        let deadline = Instant::now() + Duration::from_secs(5);
        while srv.counters().slowloris_reaped == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(srv.counters().slowloris_reaped, 1, "stalled writer must be reaped");
        drop(attacker);
        srv.shutdown();
        if let Ok(e) = Arc::try_unwrap(eng) {
            e.shutdown();
        }
    }
}
