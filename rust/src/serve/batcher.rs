//! Dynamic micro-batching: coalesce concurrent requests into batched
//! kernel calls, grouped by `(store, request class)`.
//!
//! A worker blocks for the first ticket, then holds the batch window open
//! for up to `max_delay` (or until `max_batch` tickets arrive) before
//! executing. Batch formation is deadline-aware: a ticket that expired
//! while queued is answered ([`ServeError::DeadlineExceeded`]) and
//! dropped at pop time, before it can consume batch capacity or kernel
//! work. The gathered batch may mix stores; execution splits it by
//! target store and request class, and each `(store, class)` group runs
//! as ONE batched call — `ShardedCleanup::recall_batch_stats`,
//! `recall_topk_batch_stats`, or `Resonator::factorize_batch_with` over
//! the worker's per-store reused [`ResonatorScratch`] — so item-memory
//! rows stream once per group instead of once per request (the paper's
//! batching remedy for the memory-bound cleanup scan), and a batched
//! kernel call never mixes stores — and therefore never mixes dimensions
//! or codebooks. Each store's configured [`super::cache::ResponseCache`] is consulted
//! first: repeated queries bypass the kernels entirely (see
//! [`super::cache`]).
//!
//! Snapshot sealing: each distinct store id in the batch is resolved
//! against the registry exactly ONCE ([`StoreRegistry::live`]), at
//! classification time, into the epoch-stamped
//! [`StoreSnapshot`](super::registry::StoreSnapshot) the whole batch
//! scans. Concurrent mutations publish new snapshots for *later*
//! batches; this batch keeps its sealed snapshot alive through the
//! `Arc`, so every answer it produces is consistent with exactly one
//! epoch. A store dropped between admission and execution fails the
//! seal and its tickets are answered [`ServeError::UnknownStore`] —
//! never a panic, never a read of freed state. Cache probes and inserts
//! carry the sealed epoch, so a hit can never resurface an earlier
//! epoch's answer (see [`super::cache`]).
//!
//! Graceful degradation: a store whose queue lane is backlogged past its
//! [`super::registry::StoreSpec::degrade_depth`] *enter* threshold is
//! served degraded for the batch — top-k requests are answered at
//! `degrade_k` (wrapped in [`ServeResponse::Degraded`] so the truncation
//! is explicit, and never cached), factorize requests are shed with
//! [`ServeError::TenantOverloaded`]. The probe steps the
//! [`super::registry::Hysteresis`] state machine through the persistent
//! per-slot bit owned by the registry
//! ([`StoreRegistry::degrade_step`]): once entered, a store stays
//! degraded until its lane drains below the *exit* threshold
//! (`degrade_exit`, default half of enter), so service doesn't flap
//! when the depth hovers at the boundary. Cache hits still serve full
//! answers (they cost no kernel work). Degradation is per store: one
//! tenant's backlog never degrades another's responses.
//!
//! Observability: every ticket carries [`StageMarks`]; the batcher
//! stamps seal at window close and the kernel bracket per `(store,
//! class)` group call, then folds each response's [`StageSample`] and
//! the group's measured [`KernelWork`] into [`ServeStats`] — and into
//! the [`TraceRing`] when tracing is enabled.

use super::cache::ResponseCache;
use super::faults::FaultPlan;
use super::queue::{AdmissionQueue, ResponseSlot, Ticket};
use super::registry::{StoreId, StoreRegistry, StoreSnapshot};
use super::stats::{ServeStats, StoreWork};
use super::trace::{KernelWork, StageMarks, StageSample, TraceEvent, TraceRing};
use super::{RequestKind, RequestOp, ServeError, ServeRequest, ServeResponse};
use crate::vsa::{RealHV, Resonator, ResonatorScratch};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batch formation policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Hard cap on tickets per micro-batch.
    pub max_batch: usize,
    /// How long to hold the window open after the first ticket.
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            max_delay: Duration::from_micros(200),
        }
    }
}

/// Answer an expired ticket without executing it (stats first, then the
/// fill, so the woken client observes metrics including its request).
fn drop_expired(t: Ticket, stats: &ServeStats) {
    stats.record_expired(t.request.store, 1);
    t.slot.fill(Err(ServeError::DeadlineExceeded));
}

/// Gather one micro-batch: block for the first *live* ticket, then fill
/// the window. Tickets that expired while queued are answered
/// (`DeadlineExceeded`) and dropped here — they consume neither batch
/// capacity nor a batch window. `None` once the queue is closed and
/// drained.
pub fn gather(queue: &AdmissionQueue, policy: &BatchPolicy, stats: &ServeStats) -> Option<Vec<Ticket>> {
    let max_batch = policy.max_batch.max(1);
    let mut batch = Vec::with_capacity(max_batch);
    let first = loop {
        let t = queue.pop_blocking()?;
        if t.expired(Instant::now()) {
            drop_expired(t, stats);
            continue;
        }
        break t;
    };
    batch.push(first);
    // Fast drain during shutdown: once the queue is closed no new
    // tickets can arrive, so holding the window open for `max_delay`
    // only delays the remaining backlog. Sweep what is already queued
    // (pop_until with an elapsed deadline) and execute immediately.
    if max_batch > 1 {
        let window_end = if queue.is_closed() {
            Instant::now()
        } else {
            Instant::now() + policy.max_delay
        };
        while batch.len() < max_batch {
            match queue.pop_until(window_end) {
                Some(t) if t.expired(Instant::now()) => drop_expired(t, stats),
                Some(t) => batch.push(t),
                None => break,
            }
        }
    }
    // The batch window just closed: stamp the seal mark on every
    // gathered ticket (the pop mark was stamped by the queue).
    let sealed = Instant::now();
    for t in &mut batch {
        t.marks.sealed = Some(sealed);
    }
    Some(batch)
}

/// Per-worker reusable buffers: one resonator estimate set + scratch per
/// store (stores have independent resonator shapes), allocated lazily on
/// the first factorize request routed to that store on this worker and
/// reused for every later batch.
pub struct WorkerScratch {
    resonator_bufs: BTreeMap<StoreId, (Vec<RealHV>, ResonatorScratch)>,
}

impl WorkerScratch {
    pub fn new() -> WorkerScratch {
        WorkerScratch {
            resonator_bufs: BTreeMap::new(),
        }
    }

    fn bufs(&mut self, store: StoreId, res: &Resonator) -> &mut (Vec<RealHV>, ResonatorScratch) {
        self.resonator_bufs.entry(store).or_insert_with(|| {
            let d = res.codebooks()[0].dim();
            (
                vec![RealHV::zeros(d); res.n_factors()],
                res.make_scratch(),
            )
        })
    }
}

impl Default for WorkerScratch {
    fn default() -> Self {
        WorkerScratch::new()
    }
}

/// Everything [`execute`] needs besides the batch itself. Bundled so the
/// engine's worker loop and the direct-execution tests share one
/// signature as the execution path grows knobs.
pub struct ExecCtx<'a> {
    pub registry: &'a StoreRegistry,
    pub stats: &'a ServeStats,
    pub scan_threads: usize,
    /// Queue view for the degraded-mode depth probe (`lane_len`);
    /// `None` disables depth-triggered degradation (tests that execute
    /// batches directly). The [`super::registry::Hysteresis`] memory
    /// lives in the registry slot ([`StoreRegistry::degrade_step`]), so
    /// the probe is persistent across batches and workers.
    pub queue: Option<&'a AdmissionQueue>,
    /// Trace-event ring; `None` (tracing off) costs one branch per
    /// accounted response.
    pub trace: Option<&'a TraceRing>,
    /// Fault-injection plan; `None` injects nothing.
    pub faults: Option<&'a FaultPlan>,
}

impl<'a> ExecCtx<'a> {
    /// Context with no queue probe, no tracing, and no fault plan.
    pub fn plain(registry: &'a StoreRegistry, stats: &'a ServeStats, scan_threads: usize) -> Self {
        ExecCtx {
            registry,
            stats,
            scan_threads,
            queue: None,
            trace: None,
            faults: None,
        }
    }
}

/// One store's slice of a gathered batch, split by request class. The
/// group owns the epoch-stamped snapshot it was sealed against — every
/// kernel call and cache insert below runs on it, so a concurrent
/// mutation (or drop) can never change this batch's answers. Slots
/// carry their ticket's [`StageMarks`] so the kernel bracket can be
/// stamped per `(store, class)` group call.
struct StoreGroup {
    snapshot: Arc<StoreSnapshot>,
    cache: Option<Arc<ResponseCache>>,
    recall_qs: Vec<crate::vsa::BinaryHV>,
    recall_slots: Vec<(ResponseSlot, StageMarks)>,
    topk_qs: Vec<crate::vsa::BinaryHV>,
    /// `(slot, marks, effective k, served degraded)` — k is already
    /// capped when the store is degraded, and degraded answers are
    /// wrapped and never cached.
    topk_slots: Vec<(ResponseSlot, StageMarks, usize, bool)>,
    fact_scenes: Vec<RealHV>,
    fact_slots: Vec<(ResponseSlot, StageMarks)>,
}

impl StoreGroup {
    fn sealed(snapshot: Arc<StoreSnapshot>, cache: Option<Arc<ResponseCache>>) -> StoreGroup {
        StoreGroup {
            snapshot,
            cache,
            recall_qs: Vec::new(),
            recall_slots: Vec::new(),
            topk_qs: Vec::new(),
            topk_slots: Vec::new(),
            fact_scenes: Vec::new(),
            fact_slots: Vec::new(),
        }
    }

    fn executed(&self) -> usize {
        self.recall_qs.len() + self.topk_qs.len() + self.fact_scenes.len()
    }
}

/// Account one completed response: end-to-end latency plus its stage
/// sample for the P² breakdowns, and a [`TraceEvent`] when the ring is
/// on (one `Option` branch when it is not). The accounting instant
/// stands in for the slot-fill time — stats are recorded before fills.
#[allow(clippy::too_many_arguments)]
fn account(
    latencies: &mut Vec<(StoreId, RequestKind, Duration, StageSample)>,
    trace: Option<&TraceRing>,
    store: StoreId,
    epoch: u64,
    kind: RequestKind,
    marks: &StageMarks,
    degraded: bool,
    cache_hit: bool,
) {
    let now = Instant::now();
    let total = now.saturating_duration_since(marks.admit);
    let stages = marks.sample_at(now);
    latencies.push((store, kind, total, stages));
    if let Some(ring) = trace {
        ring.record(TraceEvent {
            seq: 0, // assigned by the ring
            store,
            epoch,
            kind,
            stages,
            total_s: total.as_secs_f64(),
            degraded,
            cache_hit,
        });
    }
}

/// Execute one gathered batch against the registry's stores, record
/// metrics, then fill every slot. Consumes the tickets (query payloads
/// are moved into the batched kernel calls without cloning).
///
/// The batch is first split per target store (unknown store ids are
/// answered with [`ServeError::UnknownStore`] — they normally never get
/// this far because admission validates the id), then per class within
/// each store, so every batched kernel call sees exactly one store's
/// codebook and dimension. When a store has a
/// [`super::cache::ResponseCache`],
/// cacheable tickets are probed at batch-formation time: a hit is
/// answered from the cache and never reaches a kernel call; misses
/// execute batched as before and their responses are inserted for the
/// next repeat. Cache hits count toward completion latencies but not
/// batch occupancy (occupancy measures kernel batching).
///
/// Stats are recorded *before* any slot is filled, so a client woken by
/// its response always observes engine metrics that already include its
/// own request.
pub fn execute(batch: Vec<Ticket>, ctx: &ExecCtx<'_>, scratch: &mut WorkerScratch) {
    // Fault injection: a planned worker panic fires before any slot is
    // answered, so containment (engine worker loop) owns the whole
    // batch's outcome.
    if let Some(f) = ctx.faults {
        if f.should_panic() {
            panic!("injected worker panic (fault plan)");
        }
    }

    let registry = ctx.registry;
    let stats = ctx.stats;
    let now = Instant::now();
    // The seal: each distinct store id resolves against the registry
    // exactly once per batch, pinning the epoch-stamped snapshot (and
    // its cache handle) every ticket for that store will use. The
    // degraded-mode depth probe (`lane_len` → `degrade_step`) runs
    // inside the same seal closure, so the degrade decision and the
    // snapshot it gates are taken at one point in time — a mutation
    // landing mid-batch can't pair a fresh snapshot's epoch (and its
    // cascade prune tallies, which count the sealed epoch's items)
    // with a depth probe taken against the previous item set, or vice
    // versa. A store dropped since admission resolves to `None` here —
    // its tickets are answered `UnknownStore` below, uniformly for the
    // whole batch.
    type Sealed = Option<(Arc<StoreSnapshot>, Option<Arc<ResponseCache>>, bool)>;
    let mut sealed: BTreeMap<StoreId, Sealed> = BTreeMap::new();
    let mut groups: BTreeMap<StoreId, StoreGroup> = BTreeMap::new();
    let mut expired_by: BTreeMap<StoreId, u64> = BTreeMap::new();
    let mut degraded_by: BTreeMap<StoreId, u64> = BTreeMap::new();
    let mut unsupported = 0u64;
    let mut latencies: Vec<(StoreId, RequestKind, Duration, StageSample)> =
        Vec::with_capacity(batch.len());
    // (slot, outcome) pairs, filled only after all metrics are recorded
    let mut fills: Vec<(ResponseSlot, Result<ServeResponse, ServeError>)> =
        Vec::with_capacity(batch.len());

    for t in batch {
        if t.expired(now) {
            *expired_by.entry(t.request.store).or_default() += 1;
            fills.push((t.slot, Err(ServeError::DeadlineExceeded)));
            continue;
        }
        let ServeRequest { store: store_id, op } = t.request;
        let (store, cache_arc, degraded) = match sealed.entry(store_id).or_insert_with(|| {
            registry.live(store_id).map(|(s, c)| {
                // Depth-probed once per store per batch, under the same
                // seal as the snapshot: degradation is a batch-formation
                // decision, not a per-ticket race. Persistent per-slot
                // bit in the registry: enter at `h.enter`, leave only
                // once the lane drains below `h.exit` — no flapping
                // while the depth hovers at the threshold.
                let degraded = match (s.spec().degrade_hysteresis(), ctx.queue) {
                    (Some(h), Some(q)) => {
                        registry.degrade_step(store_id, h, q.lane_len(store_id))
                    }
                    _ => false,
                };
                (s, c, degraded)
            })
        }) {
            Some((s, c, d)) => (Arc::clone(s), c.clone(), *d),
            None => {
                fills.push((t.slot, Err(ServeError::UnknownStore)));
                unsupported += 1;
                continue;
            }
        };
        let epoch = store.epoch();
        let cache = cache_arc.as_deref();
        match op {
            RequestOp::Recall { query } => {
                if query.dim() != store.dim() {
                    fills.push((t.slot, Err(ServeError::InvalidDimension)));
                    unsupported += 1;
                } else if let Some(resp) = cache.and_then(|c| c.get_recall(&query, epoch)) {
                    account(
                        &mut latencies,
                        ctx.trace,
                        store_id,
                        epoch,
                        RequestKind::Recall,
                        &t.marks,
                        false,
                        true,
                    );
                    fills.push((t.slot, Ok(resp)));
                } else {
                    let g = groups
                        .entry(store_id)
                        .or_insert_with(|| StoreGroup::sealed(Arc::clone(&store), cache_arc.clone()));
                    g.recall_qs.push(query);
                    g.recall_slots.push((t.slot, t.marks));
                }
            }
            RequestOp::RecallTopK { query, k } => {
                if query.dim() != store.dim() {
                    fills.push((t.slot, Err(ServeError::InvalidDimension)));
                    unsupported += 1;
                } else if let Some(resp) = cache.and_then(|c| c.get_topk(&query, k, epoch)) {
                    // a full-k hit costs no kernel work, so degraded
                    // stores still serve it undegraded
                    account(
                        &mut latencies,
                        ctx.trace,
                        store_id,
                        epoch,
                        RequestKind::RecallTopK,
                        &t.marks,
                        false,
                        true,
                    );
                    fills.push((t.slot, Ok(resp)));
                } else {
                    let (k_eff, deg) = if degraded && k > store.spec().degrade_k.max(1) {
                        *degraded_by.entry(store_id).or_default() += 1;
                        (store.spec().degrade_k.max(1), true)
                    } else {
                        (k, false)
                    };
                    let g = groups
                        .entry(store_id)
                        .or_insert_with(|| StoreGroup::sealed(Arc::clone(&store), cache_arc.clone()));
                    g.topk_qs.push(query);
                    g.topk_slots.push((t.slot, t.marks, k_eff, deg));
                }
            }
            RequestOp::Factorize { scene } => match store.resonator() {
                None => {
                    fills.push((t.slot, Err(ServeError::Unsupported)));
                    unsupported += 1;
                }
                Some(res) if scene.dim() != res.codebooks()[0].dim() => {
                    fills.push((t.slot, Err(ServeError::InvalidDimension)));
                    unsupported += 1;
                }
                Some(_) if degraded => {
                    // shed the expensive class while backlogged — the
                    // tenant-local error tells the caller to back off
                    *degraded_by.entry(store_id).or_default() += 1;
                    fills.push((t.slot, Err(ServeError::TenantOverloaded)));
                }
                Some(_) => {
                    let g = groups
                        .entry(store_id)
                        .or_insert_with(|| StoreGroup::sealed(Arc::clone(&store), cache_arc.clone()));
                    g.fact_scenes.push(scene);
                    g.fact_slots.push((t.slot, t.marks));
                }
            },
        }
    }

    let executed: usize = groups.values().map(StoreGroup::executed).sum();
    let mut store_work: Vec<(StoreId, StoreWork)> = Vec::with_capacity(groups.len());

    // Fault injection: artificial kernel latency ahead of the dispatches.
    if executed > 0 {
        if let Some(d) = ctx.faults.and_then(|f| f.kernel_delay()) {
            std::thread::sleep(d);
        }
    }

    for (store_id, group) in groups {
        // No registry re-resolution here: the group owns the snapshot it
        // was sealed against, so a drop or mutation that landed after
        // classification cannot change (or panic) this dispatch.
        let StoreGroup {
            snapshot: store,
            cache,
            recall_qs,
            recall_slots,
            topk_qs,
            topk_slots,
            fact_scenes,
            fact_slots,
        } = group;
        let epoch = store.epoch();
        let cache = cache.as_deref();
        let mut work = StoreWork::default();

        if !recall_qs.is_empty() {
            let n_q = recall_qs.len() as u64;
            let kstart = Instant::now();
            let (results, timings, scan_prune) = store
                .cleanup()
                .recall_batch_stats(&recall_qs, ctx.scan_threads);
            let kend = Instant::now();
            work.timings.extend(timings);
            // Measured roofline inputs: the pruned scan streamed
            // `words_streamed` u64 item words (XOR + popcount +
            // accumulate ≈ 3 ops/word) plus each query row once; each
            // answer writes an (index, cosine) pair.
            work.measured[RequestKind::Recall.index()].merge(&KernelWork {
                calls: 1,
                elapsed_s: kend.saturating_duration_since(kstart).as_secs_f64(),
                flops: 3 * scan_prune.words_streamed,
                bytes_read: 8 * scan_prune.words_streamed + n_q * (store.dim() as u64 / 8),
                bytes_written: n_q * 16,
            });
            work.prune.merge(&scan_prune);
            for (((slot, mut marks), (index, cosine)), query) in
                recall_slots.into_iter().zip(results).zip(recall_qs)
            {
                marks.mark_kernel(kstart, kend);
                let resp = ServeResponse::Recall { index, cosine };
                if let Some(c) = cache {
                    c.insert(ServeRequest::recall_on(store_id, query), &resp, epoch);
                }
                account(
                    &mut latencies,
                    ctx.trace,
                    store_id,
                    epoch,
                    RequestKind::Recall,
                    &marks,
                    false,
                    false,
                );
                fills.push((slot, Ok(resp)));
            }
        }

        if !topk_qs.is_empty() {
            // One scan at the group's largest k; per-ticket answers are
            // prefixes of it (top-k is prefix-stable in k — see
            // `BinaryCodebook::top_k`). Cache entries are keyed at each
            // ticket's own k, so a hit can never leak a different k's
            // answer.
            let k_max = topk_slots
                .iter()
                .map(|&(_, _, k, _)| k)
                .max()
                .unwrap_or(0);
            let n_q = topk_qs.len() as u64;
            let kstart = Instant::now();
            let (results, timings, scan_prune) =
                store
                    .cleanup()
                    .recall_topk_batch_stats(&topk_qs, k_max, ctx.scan_threads);
            let kend = Instant::now();
            work.timings.extend(timings);
            work.measured[RequestKind::RecallTopK.index()].merge(&KernelWork {
                calls: 1,
                elapsed_s: kend.saturating_duration_since(kstart).as_secs_f64(),
                flops: 3 * scan_prune.words_streamed,
                bytes_read: 8 * scan_prune.words_streamed + n_q * (store.dim() as u64 / 8),
                bytes_written: n_q * k_max as u64 * 16,
            });
            work.prune.merge(&scan_prune);
            for (((slot, mut marks, k, deg), mut hits), query) in
                topk_slots.into_iter().zip(results).zip(topk_qs)
            {
                marks.mark_kernel(kstart, kend);
                hits.truncate(k);
                let resp = ServeResponse::RecallTopK { hits };
                let resp = if deg {
                    // degraded answers are marked and never inserted:
                    // a cached entry must always be the full-k truth
                    ServeResponse::Degraded {
                        inner: Box::new(resp),
                    }
                } else {
                    if let Some(c) = cache {
                        c.insert(ServeRequest::recall_topk_on(store_id, query, k), &resp, epoch);
                    }
                    resp
                };
                account(
                    &mut latencies,
                    ctx.trace,
                    store_id,
                    epoch,
                    RequestKind::RecallTopK,
                    &marks,
                    deg,
                    false,
                );
                fills.push((slot, Ok(resp)));
            }
        }

        if !fact_scenes.is_empty() {
            let res = store
                .resonator()
                .expect("factorize tickets imply their sealed snapshot has a resonator");
            let (estimates, rscratch) = scratch.bufs(store_id, res);
            let decode_before = *rscratch.prune_stats();
            let kstart = Instant::now();
            let results = res.factorize_batch_with(&fact_scenes, estimates, rscratch);
            let kend = Instant::now();
            // attribute this batch's pruned per-factor index decodes to
            // the store's telemetry (the scratch accumulates across
            // batches; real decodes count f32 elements where the binary
            // scans count words, but streamed and total stay in matching
            // units per scan)
            work.prune
                .merge(&rscratch.prune_stats().delta_since(&decode_before));
            // Modelled roofline inputs for the resonator sweeps: per
            // converged iteration each factor's codebook (len × dim f32
            // elements) is streamed for the projection and again for the
            // reconstruction, ≈ 2 MACs per element each pass.
            let total_iters: u64 = results.iter().map(|r| r.iterations as u64).sum();
            let shape: u64 = res
                .codebooks()
                .iter()
                .map(|c| (c.len() * c.dim()) as u64)
                .sum();
            work.measured[RequestKind::Factorize.index()].merge(&KernelWork {
                calls: 1,
                elapsed_s: kend.saturating_duration_since(kstart).as_secs_f64(),
                flops: total_iters * 4 * shape,
                bytes_read: total_iters * 8 * shape,
                bytes_written: (results.len() as u64) * res.n_factors() as u64 * 8,
            });
            for ((slot, mut marks), r) in fact_slots.into_iter().zip(results) {
                marks.mark_kernel(kstart, kend);
                account(
                    &mut latencies,
                    ctx.trace,
                    store_id,
                    epoch,
                    RequestKind::Factorize,
                    &marks,
                    false,
                    false,
                );
                fills.push((
                    slot,
                    Ok(ServeResponse::Factorize {
                        indices: r.indices,
                        iterations: r.iterations,
                        converged: r.converged,
                    }),
                ));
            }
        }

        store_work.push((store_id, work));
    }

    for (&store, &n) in &expired_by {
        stats.record_expired(store, n);
    }
    for (&store, &n) in &degraded_by {
        stats.record_degraded(store, n);
    }
    if unsupported > 0 {
        stats.record_unsupported(unsupported);
    }
    stats.record_batch(executed, &latencies, &store_work);
    for (slot, outcome) in fills {
        slot.fill(outcome);
    }
}

#[cfg(test)]
mod tests {
    use super::super::queue::{LaneSpec, Priority};
    use super::super::registry::StoreSpec;
    use super::*;
    use crate::util::Rng;
    use crate::vsa::{BinaryCodebook, BinaryHV, CleanupMemory, RealCodebook};

    fn uncached_spec(shards: usize) -> StoreSpec {
        StoreSpec {
            shards,
            cache_capacity: 0,
            ..StoreSpec::default()
        }
    }

    fn single_registry(seed: u64) -> (BinaryCodebook, StoreRegistry) {
        let mut rng = Rng::new(seed);
        let cb = BinaryCodebook::random(&mut rng, 24, 512);
        let registry = StoreRegistry::single(&cb, None, uncached_spec(3));
        (cb, registry)
    }

    fn stats_for(registry: &StoreRegistry) -> ServeStats {
        let views = registry.store_views();
        let names: Vec<(&str, usize)> = views.iter().map(|s| (s.name(), s.n_shards())).collect();
        ServeStats::new(&names)
    }

    fn ticket(request: ServeRequest, deadline: Duration) -> (Ticket, ResponseSlot) {
        let slot = ResponseSlot::new();
        let now = Instant::now();
        (
            Ticket {
                request,
                priority: Priority::Normal,
                slot: slot.clone(),
                enqueued: now,
                deadline: now + deadline,
                marks: StageMarks::new(now),
            },
            slot,
        )
    }

    #[test]
    fn gather_respects_max_batch() {
        let q = AdmissionQueue::new(16);
        let stats = ServeStats::new(&[("only", 1)]);
        for i in 0..5 {
            let (t, _slot) = ticket(
                ServeRequest::recall_topk(BinaryHV::zeros(64), i),
                Duration::from_secs(1),
            );
            q.push(t).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 3,
            max_delay: Duration::from_millis(5),
        };
        let batch = gather(&q, &policy, &stats).unwrap();
        assert_eq!(batch.len(), 3);
        let rest = gather(&q, &policy, &stats).unwrap();
        assert_eq!(rest.len(), 2);
    }

    #[test]
    fn gather_drops_expired_tickets_without_consuming_batch_slots() {
        let q = AdmissionQueue::new(16);
        let stats = ServeStats::new(&[("only", 1)]);
        // two already-expired tickets ahead of three live ones
        let mut expired_slots = Vec::new();
        for i in 0..2 {
            let (t, s) = ticket(
                ServeRequest::recall_topk(BinaryHV::zeros(64), i),
                Duration::from_secs(0),
            );
            expired_slots.push(s);
            q.push(t).unwrap();
        }
        for i in 10..13 {
            let (t, _s) = ticket(
                ServeRequest::recall_topk(BinaryHV::zeros(64), i),
                Duration::from_secs(5),
            );
            q.push(t).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 3,
            max_delay: Duration::from_millis(5),
        };
        let batch = gather(&q, &policy, &stats).unwrap();
        assert_eq!(batch.len(), 3, "expired tickets must not occupy the batch");
        for s in expired_slots {
            assert_eq!(s.wait(), Err(ServeError::DeadlineExceeded));
        }
        let snap = stats.snapshot();
        assert_eq!(snap.expired, 2);
        assert_eq!(snap.stores[0].expired_dropped, 2);
    }

    #[test]
    fn execute_mixed_batch_matches_oracles() {
        let mut rng = Rng::new(1);
        let cb = BinaryCodebook::random(&mut rng, 24, 512);
        let cm = CleanupMemory::new(cb.clone());
        let mut rng = Rng::new(2);
        let res = Resonator::new(
            (0..3)
                .map(|_| RealCodebook::random_bipolar(&mut rng, 6, 512))
                .collect(),
            40,
        );
        let mut registry = StoreRegistry::new();
        registry.register("default", &cb, Some(res.clone()), uncached_spec(3));
        let scene = res.compose(&[1, 4, 2]);
        let q1 = BinaryHV::random(&mut rng, 512);
        let q2 = BinaryHV::random(&mut rng, 512);

        let (t1, s1) = ticket(ServeRequest::recall(q1.clone()), Duration::from_secs(5));
        let (t2, s2) = ticket(
            ServeRequest::recall_topk(q2.clone(), 3),
            Duration::from_secs(5),
        );
        let (t3, s3) = ticket(ServeRequest::factorize(scene.clone()), Duration::from_secs(5));
        let stats = stats_for(&registry);
        let mut scratch = WorkerScratch::new();
        execute(
            vec![t1, t2, t3],
            &ExecCtx::plain(&registry, &stats, 1),
            &mut scratch,
        );
        let (idx, cos) = cm.recall(&q1);
        assert_eq!(s1.wait(), Ok(ServeResponse::Recall { index: idx, cosine: cos }));
        assert_eq!(
            s2.wait(),
            Ok(ServeResponse::RecallTopK {
                hits: cm.recall_topk(&q2, 3)
            })
        );
        let oracle = res.factorize(&scene);
        assert_eq!(
            s3.wait(),
            Ok(ServeResponse::Factorize {
                indices: oracle.indices,
                iterations: oracle.iterations,
                converged: oracle.converged,
            })
        );
        let snap = stats.snapshot();
        assert_eq!(snap.completed, 3);
        assert_eq!(snap.batches, 1);
        assert!((snap.mean_batch - 3.0).abs() < 1e-12);
        assert!(snap.shards.iter().any(|s| s.scans > 0));
        // prune telemetry covers every routed scan in the batch: one
        // recall (24 items) + one top-k (24) + the factorize decode
        // (3 factors x 6 items)
        assert_eq!(snap.prune.items, 24 + 24 + 3 * 6);
        assert_eq!(snap.stores.len(), 1);
        assert_eq!(snap.stores[0].completed, 3);
    }

    #[test]
    fn multi_store_batch_routes_each_ticket_to_its_own_store() {
        // two stores with different dimensions and item counts: one
        // gathered batch containing traffic for both must answer every
        // ticket from its own store's codebook, and attribute scans to
        // the right store's telemetry
        let mut rng = Rng::new(41);
        let cb_a = BinaryCodebook::random(&mut rng, 24, 512);
        let cb_b = BinaryCodebook::random(&mut rng, 40, 1024);
        let cm_a = CleanupMemory::new(cb_a.clone());
        let cm_b = CleanupMemory::new(cb_b.clone());
        let mut registry = StoreRegistry::new();
        let a = registry.register("alpha", &cb_a, None, uncached_spec(2));
        let b = registry.register("beta", &cb_b, None, uncached_spec(3));
        let stats = stats_for(&registry);
        let mut scratch = WorkerScratch::new();

        let qa1 = BinaryHV::random(&mut rng, 512);
        let qa2 = BinaryHV::random(&mut rng, 512);
        let qb1 = BinaryHV::random(&mut rng, 1024);
        let qb2 = BinaryHV::random(&mut rng, 1024);
        let (t1, s1) = ticket(ServeRequest::recall_on(a, qa1.clone()), Duration::from_secs(5));
        let (t2, s2) = ticket(ServeRequest::recall_on(b, qb1.clone()), Duration::from_secs(5));
        let (t3, s3) = ticket(
            ServeRequest::recall_topk_on(a, qa2.clone(), 3),
            Duration::from_secs(5),
        );
        let (t4, s4) = ticket(
            ServeRequest::recall_topk_on(b, qb2.clone(), 5),
            Duration::from_secs(5),
        );
        execute(
            vec![t1, t2, t3, t4],
            &ExecCtx::plain(&registry, &stats, 1),
            &mut scratch,
        );
        let (idx, cos) = cm_a.recall(&qa1);
        assert_eq!(s1.wait(), Ok(ServeResponse::Recall { index: idx, cosine: cos }));
        let (idx, cos) = cm_b.recall(&qb1);
        assert_eq!(s2.wait(), Ok(ServeResponse::Recall { index: idx, cosine: cos }));
        assert_eq!(
            s3.wait(),
            Ok(ServeResponse::RecallTopK {
                hits: cm_a.recall_topk(&qa2, 3)
            })
        );
        assert_eq!(
            s4.wait(),
            Ok(ServeResponse::RecallTopK {
                hits: cm_b.recall_topk(&qb2, 5)
            })
        );
        let snap = stats.snapshot();
        assert_eq!(snap.completed, 4);
        assert_eq!(snap.batches, 1, "one gathered batch, split per store");
        // per-store attribution: each kernel call scanned exactly its
        // own store's items (recall + topk = 2 queries per store), so a
        // store's prune telemetry counts 2 x its item count — proof the
        // groups never mixed stores
        assert_eq!(snap.stores[a.index()].prune.items, 2 * 24);
        assert_eq!(snap.stores[b.index()].prune.items, 2 * 40);
        assert_eq!(snap.stores[a.index()].completed, 2);
        assert_eq!(snap.stores[b.index()].completed, 2);
        assert!(snap.stores[a.index()].shards.iter().all(|s| s.scans > 0));
        assert!(snap.stores[b.index()].shards.iter().all(|s| s.scans > 0));
    }

    #[test]
    fn unknown_store_is_answered_not_panicking() {
        let (_, registry) = single_registry(51);
        let stats = stats_for(&registry);
        let mut scratch = WorkerScratch::new();
        let (t_bad, s_bad) = ticket(
            ServeRequest::recall_on(StoreId(7), BinaryHV::zeros(512)),
            Duration::from_secs(5),
        );
        let (t_ok, s_ok) = ticket(
            ServeRequest::recall(BinaryHV::zeros(512)),
            Duration::from_secs(5),
        );
        execute(
            vec![t_bad, t_ok],
            &ExecCtx::plain(&registry, &stats, 1),
            &mut scratch,
        );
        assert_eq!(s_bad.wait(), Err(ServeError::UnknownStore));
        assert!(s_ok.wait().is_ok(), "good request in same batch still served");
        assert_eq!(stats.snapshot().unsupported, 1);
    }

    #[test]
    fn mixed_k_topk_batch_answers_each_request_at_its_own_k() {
        let (cb, registry) = single_registry(3);
        let cm = CleanupMemory::new(cb);
        let mut rng = Rng::new(4);
        let queries: Vec<BinaryHV> =
            (0..3).map(|_| BinaryHV::random(&mut rng, 512)).collect();
        let ks = [1usize, 5, 2];
        let stats = stats_for(&registry);
        let mut scratch = WorkerScratch::new();
        let mut slots = Vec::new();
        let mut batch = Vec::new();
        for (q, &k) in queries.iter().zip(&ks) {
            let (t, s) = ticket(ServeRequest::recall_topk(q.clone(), k), Duration::from_secs(5));
            batch.push(t);
            slots.push(s);
        }
        execute(batch, &ExecCtx::plain(&registry, &stats, 1), &mut scratch);
        for ((q, &k), s) in queries.iter().zip(&ks).zip(slots) {
            assert_eq!(
                s.wait(),
                Ok(ServeResponse::RecallTopK {
                    hits: cm.recall_topk(q, k)
                })
            );
        }
    }

    #[test]
    fn cache_hits_bypass_kernels_with_identical_responses() {
        let mut rng = Rng::new(9);
        let cb = BinaryCodebook::random(&mut rng, 24, 512);
        let cm = CleanupMemory::new(cb.clone());
        // cached store this time
        let registry = StoreRegistry::single(&cb, None, StoreSpec {
            shards: 3,
            ..StoreSpec::default()
        });
        let stats = stats_for(&registry);
        let mut scratch = WorkerScratch::new();
        let mut rng = Rng::new(10);
        let q = BinaryHV::random(&mut rng, 512);
        // first pass: misses, computed by the kernels, inserted
        let (t1, s1) = ticket(ServeRequest::recall(q.clone()), Duration::from_secs(5));
        let (t2, s2) = ticket(ServeRequest::recall_topk(q.clone(), 4), Duration::from_secs(5));
        execute(
            vec![t1, t2],
            &ExecCtx::plain(&registry, &stats, 1),
            &mut scratch,
        );
        let first_recall = s1.wait().unwrap();
        let first_topk = s2.wait().unwrap();
        let scans_after_first: u64 = stats.snapshot().shards.iter().map(|s| s.scans).sum();
        // second pass: same query → both served from cache, no new scans
        let (t3, s3) = ticket(ServeRequest::recall(q.clone()), Duration::from_secs(5));
        let (t4, s4) = ticket(ServeRequest::recall_topk(q.clone(), 4), Duration::from_secs(5));
        execute(
            vec![t3, t4],
            &ExecCtx::plain(&registry, &stats, 1),
            &mut scratch,
        );
        assert_eq!(s3.wait().unwrap(), first_recall);
        assert_eq!(s4.wait().unwrap(), first_topk);
        let snap = stats.snapshot();
        let scans_after_second: u64 = snap.shards.iter().map(|s| s.scans).sum();
        assert_eq!(
            scans_after_second, scans_after_first,
            "cache hits must not trigger shard scans"
        );
        assert_eq!(snap.completed, 4, "cache hits still count as completed");
        assert_eq!(snap.batches, 1, "all-hit batches don't count toward occupancy");
        let c = registry.cache_of(StoreId::DEFAULT).unwrap().counters();
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 2);
        // a different k is a miss, answered by the kernels at its own k
        let (t5, s5) = ticket(ServeRequest::recall_topk(q.clone(), 2), Duration::from_secs(5));
        execute(
            vec![t5],
            &ExecCtx::plain(&registry, &stats, 1),
            &mut scratch,
        );
        assert_eq!(
            s5.wait(),
            Ok(ServeResponse::RecallTopK {
                hits: cm.recall_topk(&q, 2)
            })
        );
        let c = registry.cache_of(StoreId::DEFAULT).unwrap().counters();
        assert_eq!(c.hits, 2, "k=2 probe must not hit the k=4 entry");
    }

    #[test]
    fn dimension_mismatch_is_refused_not_panicking() {
        let (_, registry) = single_registry(7); // dim 512
        let stats = stats_for(&registry);
        let mut scratch = WorkerScratch::new();
        let (t_bad, s_bad) = ticket(
            ServeRequest::recall(BinaryHV::zeros(64)), // wrong dimension
            Duration::from_secs(5),
        );
        let (t_ok, s_ok) = ticket(
            ServeRequest::recall(BinaryHV::zeros(512)),
            Duration::from_secs(5),
        );
        execute(
            vec![t_bad, t_ok],
            &ExecCtx::plain(&registry, &stats, 1),
            &mut scratch,
        );
        assert_eq!(s_bad.wait(), Err(ServeError::InvalidDimension));
        assert!(s_ok.wait().is_ok(), "good request in same batch still served");
        assert_eq!(stats.snapshot().unsupported, 1);
    }

    #[test]
    fn expired_and_unsupported_are_answered_not_executed() {
        let (_, registry) = single_registry(5);
        let stats = stats_for(&registry);
        let mut scratch = WorkerScratch::new();
        let (t_expired, s_expired) = ticket(
            ServeRequest::recall(BinaryHV::zeros(512)),
            Duration::from_secs(0),
        );
        let (t_fact, s_fact) = ticket(
            ServeRequest::factorize(crate::vsa::RealHV::zeros(64)),
            Duration::from_secs(5),
        );
        execute(
            vec![t_expired, t_fact],
            &ExecCtx::plain(&registry, &stats, 1),
            &mut scratch,
        );
        assert_eq!(s_expired.wait(), Err(ServeError::DeadlineExceeded));
        assert_eq!(s_fact.wait(), Err(ServeError::Unsupported));
        let snap = stats.snapshot();
        assert_eq!(snap.completed, 0);
        assert_eq!(snap.expired, 1);
        assert_eq!(snap.stores[0].expired_dropped, 1);
        assert_eq!(snap.unsupported, 1);
        assert_eq!(snap.batches, 0, "empty batches don't count toward occupancy");
    }

    #[test]
    fn degraded_store_caps_topk_and_sheds_factorize() {
        let mut rng = Rng::new(21);
        let cb = BinaryCodebook::random(&mut rng, 24, 512);
        let cm = CleanupMemory::new(cb.clone());
        let res = Resonator::new(
            (0..2)
                .map(|_| RealCodebook::random_bipolar(&mut rng, 4, 256))
                .collect(),
            20,
        );
        let registry = StoreRegistry::single(
            &cb,
            Some(res.clone()),
            StoreSpec {
                shards: 2,
                cache_capacity: 0,
                degrade_depth: Some(2),
                degrade_k: 2,
                ..StoreSpec::default()
            },
        );
        let stats = stats_for(&registry);
        let mut scratch = WorkerScratch::new();

        // backlog the store's lane past the threshold so the depth probe
        // trips (these fillers stay queued; we execute a batch directly)
        let q = AdmissionQueue::with_lanes(16, &[LaneSpec { weight: 1, quota: 16 }]);
        for i in 0..3 {
            let (t, _s) = ticket(
                ServeRequest::recall_topk(BinaryHV::zeros(512), i + 1),
                Duration::from_secs(5),
            );
            q.push(t).unwrap();
        }

        let query = BinaryHV::random(&mut rng, 512);
        let scene = res.compose(&[1, 3]);
        let (t_topk, s_topk) = ticket(
            ServeRequest::recall_topk(query.clone(), 5),
            Duration::from_secs(5),
        );
        let (t_fact, s_fact) = ticket(ServeRequest::factorize(scene), Duration::from_secs(5));
        let ctx = ExecCtx {
            registry: &registry,
            stats: &stats,
            scan_threads: 1,
            queue: Some(&q),
            trace: None,
            faults: None,
        };
        execute(vec![t_topk, t_fact], &ctx, &mut scratch);

        // top-k served degraded: truncated to degrade_k, wrapped, and
        // bit-exact w.r.t. the oracle's prefix (prefix-stability)
        match s_topk.wait() {
            Ok(ServeResponse::Degraded { inner }) => {
                assert_eq!(
                    *inner,
                    ServeResponse::RecallTopK {
                        hits: cm.recall_topk(&query, 2)
                    }
                );
            }
            other => panic!("expected degraded top-k, got {other:?}"),
        }
        // factorize shed with the tenant-local error
        assert_eq!(s_fact.wait(), Err(ServeError::TenantOverloaded));
        let snap = stats.snapshot();
        assert_eq!(snap.stores[0].degraded, 2);
        assert_eq!(snap.degraded, 2);

        // drain the lane below the threshold: service returns to full
        while q.pop_until(Instant::now()).is_some() {}
        let (t_full, s_full) = ticket(
            ServeRequest::recall_topk(query.clone(), 5),
            Duration::from_secs(5),
        );
        execute(vec![t_full], &ctx, &mut scratch);
        assert_eq!(
            s_full.wait(),
            Ok(ServeResponse::RecallTopK {
                hits: cm.recall_topk(&query, 5)
            })
        );
    }

    #[test]
    fn trace_ring_records_stage_decomposed_events() {
        let (_, registry) = single_registry(61);
        let stats = stats_for(&registry);
        let mut scratch = WorkerScratch::new();
        let ring = TraceRing::new(8);
        let mut rng = Rng::new(62);
        let q1 = BinaryHV::random(&mut rng, 512);
        let q2 = BinaryHV::random(&mut rng, 512);
        let (t1, s1) = ticket(ServeRequest::recall(q1), Duration::from_secs(5));
        let (t2, s2) = ticket(ServeRequest::recall_topk(q2, 3), Duration::from_secs(5));
        let mut ctx = ExecCtx::plain(&registry, &stats, 1);
        ctx.trace = Some(&ring);
        execute(vec![t1, t2], &ctx, &mut scratch);
        assert!(s1.wait().is_ok());
        assert!(s2.wait().is_ok());
        let (events, dropped) = ring.snapshot();
        assert_eq!(dropped, 0);
        assert_eq!(events.len(), 2, "one event per completed response");
        for e in &events {
            assert!(!e.cache_hit);
            assert!(!e.degraded);
            assert!(e.stages.kernel_s > 0.0, "kernel bracket stamped");
            assert!(e.stages.sum() <= e.total_s + 1e-9, "stage sums bounded by e2e");
        }
        // the measured kernel work behind those events surfaces per class
        let snap = stats.snapshot();
        assert_eq!(snap.kernel_work[RequestKind::Recall.index()].calls, 1);
        assert_eq!(snap.kernel_work[RequestKind::RecallTopK.index()].calls, 1);
        assert!(snap.kernel_work[RequestKind::Recall.index()].flops > 0);
        assert!(snap.stores[0].kernel_work[RequestKind::Recall.index()].bytes_read > 0);
    }

    #[test]
    fn persistent_hysteresis_holds_degraded_until_lane_drains() {
        let mut rng = Rng::new(71);
        let cb = BinaryCodebook::random(&mut rng, 24, 512);
        let registry = StoreRegistry::single(
            &cb,
            None,
            StoreSpec {
                shards: 2,
                cache_capacity: 0,
                degrade_depth: Some(4), // exit defaults to 2
                degrade_k: 1,
                ..StoreSpec::default()
            },
        );
        let stats = stats_for(&registry);
        let mut scratch = WorkerScratch::new();
        let q = AdmissionQueue::with_lanes(16, &[LaneSpec { weight: 1, quota: 16 }]);
        for i in 0..4 {
            let (t, _s) = ticket(
                ServeRequest::recall_topk(BinaryHV::zeros(512), i + 1),
                Duration::from_secs(5),
            );
            q.push(t).unwrap();
        }
        let ctx = ExecCtx {
            registry: &registry,
            stats: &stats,
            scan_threads: 1,
            queue: Some(&q),
            trace: None,
            faults: None,
        };
        let query = BinaryHV::random(&mut rng, 512);
        let mut served_degraded = |ctx: &ExecCtx<'_>, scratch: &mut WorkerScratch| {
            let (t, s) = ticket(
                ServeRequest::recall_topk(query.clone(), 3),
                Duration::from_secs(5),
            );
            execute(vec![t], ctx, scratch);
            matches!(s.wait(), Ok(ServeResponse::Degraded { .. }))
        };
        // depth 4 hits the enter threshold: degraded mode engages (the
        // persistent bit lives in the registry's store slot)
        assert!(served_degraded(&ctx, &mut scratch));
        // drain to depth 3 — below enter but above exit. A stateless
        // probe would restore full service here; the registry's
        // persistent bit holds degraded until the backlog really drains.
        q.pop_until(Instant::now()).unwrap();
        assert!(served_degraded(&ctx, &mut scratch));
        // drain below exit (depth 1 < 2): full service resumes
        q.pop_until(Instant::now()).unwrap();
        q.pop_until(Instant::now()).unwrap();
        assert!(!served_degraded(&ctx, &mut scratch));
    }

    #[test]
    fn injected_kernel_delay_slows_but_does_not_change_answers() {
        let (cb, registry) = single_registry(33);
        let cm = CleanupMemory::new(cb);
        let stats = stats_for(&registry);
        let mut scratch = WorkerScratch::new();
        let plan = FaultPlan::new(super::super::faults::FaultConfig {
            seed: 3,
            kernel_delay_prob: 1.0,
            kernel_delay: Duration::from_millis(5),
            ..Default::default()
        });
        let mut rng = Rng::new(34);
        let query = BinaryHV::random(&mut rng, 512);
        let (t, s) = ticket(ServeRequest::recall(query.clone()), Duration::from_secs(5));
        let ctx = ExecCtx {
            registry: &registry,
            stats: &stats,
            scan_threads: 1,
            queue: None,
            trace: None,
            faults: Some(&plan),
        };
        let t0 = Instant::now();
        execute(vec![t], &ctx, &mut scratch);
        assert!(t0.elapsed() >= Duration::from_millis(5), "delay injected");
        let (idx, cos) = cm.recall(&query);
        assert_eq!(s.wait(), Ok(ServeResponse::Recall { index: idx, cosine: cos }));
        assert_eq!(plan.injected().2, 1, "one delayed dispatch counted");
    }

    #[test]
    fn store_dropped_between_admission_and_execution_is_answered_unknown() {
        // The admit-vs-drop race: a ticket validated at submit time can
        // outlive its store. Execution must answer `UnknownStore` from
        // the failed seal — never panic, never scan a freed snapshot —
        // and other stores' tickets in the same batch still serve.
        let mut rng = Rng::new(81);
        let cb_a = BinaryCodebook::random(&mut rng, 24, 512);
        let cb_b = BinaryCodebook::random(&mut rng, 16, 512);
        let cm_a = CleanupMemory::new(cb_a.clone());
        let mut registry = StoreRegistry::new();
        let a = registry.register("keep", &cb_a, None, uncached_spec(2));
        let b = registry.register("doomed", &cb_b, None, uncached_spec(2));
        let stats = stats_for(&registry);
        let mut scratch = WorkerScratch::new();
        let qa = BinaryHV::random(&mut rng, 512);
        let (t_a, s_a) = ticket(ServeRequest::recall_on(a, qa.clone()), Duration::from_secs(5));
        let (t_b, s_b) = ticket(
            ServeRequest::recall_on(b, BinaryHV::random(&mut rng, 512)),
            Duration::from_secs(5),
        );
        // the store disappears while the tickets sit in the batch window
        registry.drop_store(b).unwrap();
        execute(
            vec![t_b, t_a],
            &ExecCtx::plain(&registry, &stats, 1),
            &mut scratch,
        );
        assert_eq!(s_b.wait(), Err(ServeError::UnknownStore));
        let (idx, cos) = cm_a.recall(&qa);
        assert_eq!(s_a.wait(), Ok(ServeResponse::Recall { index: idx, cosine: cos }));
        assert_eq!(stats.snapshot().unsupported, 1);
    }

    #[test]
    fn cache_entries_from_old_epochs_never_serve_after_mutation() {
        let mut rng = Rng::new(91);
        let cb = BinaryCodebook::random(&mut rng, 24, 512);
        let registry = StoreRegistry::single(&cb, None, StoreSpec {
            shards: 3,
            ..StoreSpec::default()
        });
        let stats = stats_for(&registry);
        let mut scratch = WorkerScratch::new();
        let q = BinaryHV::random(&mut rng, 512);
        let mut run = |scratch: &mut WorkerScratch| {
            let (t, s) = ticket(ServeRequest::recall(q.clone()), Duration::from_secs(5));
            execute(vec![t], &ExecCtx::plain(&registry, &stats, 1), scratch);
            s.wait().unwrap()
        };
        // epoch 0: computed and cached, then served from the cache
        let first = run(&mut scratch);
        assert_eq!(run(&mut scratch), first);
        let c = registry.cache_of(StoreId::DEFAULT).unwrap();
        assert_eq!(c.counters().hits, 1);
        // mutate: insert the query itself, which beats every original
        registry.insert_item(StoreId::DEFAULT, q.clone()).unwrap();
        // the epoch-0 entry is structurally unreachable at epoch 1: the
        // kernels recompute against the new snapshot and find the item
        let third = run(&mut scratch);
        let snap = registry.snapshot_of(StoreId::DEFAULT).unwrap();
        let (idx, cos) = CleanupMemory::new(snap.codebook().clone()).recall(&q);
        assert_eq!(idx, 24, "inserted item wins the post-mutation recall");
        assert_eq!(third, ServeResponse::Recall { index: idx, cosine: cos });
        let counters = c.counters();
        assert_eq!(counters.hits, 1, "epoch-0 entry must not serve epoch 1");
        assert_eq!(counters.misses, 2);
    }
}
