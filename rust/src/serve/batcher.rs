//! Dynamic micro-batching: coalesce concurrent requests into single
//! batched-kernel calls.
//!
//! A worker blocks for the first ticket, then holds the batch window open
//! for up to `max_delay` (or until `max_batch` tickets arrive) before
//! executing. The batch is split by request class and each class runs as
//! ONE batched call — `ShardedCleanup::recall_batch_stats`,
//! `recall_topk_batch_stats`, or `Resonator::factorize_batch_with` over
//! the worker's reused [`ResonatorScratch`] — so item-memory rows stream
//! once per batch instead of once per request (the paper's batching
//! remedy for the memory-bound cleanup scan). A configured
//! [`ResponseCache`] is consulted first: repeated queries bypass the
//! kernels entirely (see [`super::cache`]).

use super::cache::ResponseCache;
use super::queue::{AdmissionQueue, ResponseSlot, Ticket};
use super::shard::ShardedCleanup;
use super::stats::ServeStats;
use super::{RequestKind, ServeError, ServeRequest, ServeResponse};
use crate::vsa::{PruneStats, RealHV, Resonator, ResonatorScratch};
use std::time::{Duration, Instant};

/// Batch formation policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Hard cap on tickets per micro-batch.
    pub max_batch: usize,
    /// How long to hold the window open after the first ticket.
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            max_delay: Duration::from_micros(200),
        }
    }
}

/// Gather one micro-batch: block for the first ticket, then fill the
/// window. `None` once the queue is closed and drained.
pub fn gather(queue: &AdmissionQueue, policy: &BatchPolicy) -> Option<Vec<Ticket>> {
    let first = queue.pop_blocking()?;
    let max_batch = policy.max_batch.max(1);
    let mut batch = Vec::with_capacity(max_batch);
    batch.push(first);
    if max_batch > 1 {
        let window_end = Instant::now() + policy.max_delay;
        while batch.len() < max_batch {
            match queue.pop_until(window_end) {
                Some(t) => batch.push(t),
                None => break,
            }
        }
    }
    Some(batch)
}

/// Per-worker reusable buffers: one resonator estimate set + scratch,
/// allocated lazily on the first factorize request and reused for every
/// later batch on this worker.
pub struct WorkerScratch {
    resonator_bufs: Option<(Vec<RealHV>, ResonatorScratch)>,
}

impl WorkerScratch {
    pub fn new() -> WorkerScratch {
        WorkerScratch {
            resonator_bufs: None,
        }
    }

    fn bufs(&mut self, res: &Resonator) -> &mut (Vec<RealHV>, ResonatorScratch) {
        self.resonator_bufs.get_or_insert_with(|| {
            let d = res.codebooks()[0].dim();
            (
                vec![RealHV::zeros(d); res.n_factors()],
                res.make_scratch(),
            )
        })
    }
}

impl Default for WorkerScratch {
    fn default() -> Self {
        WorkerScratch::new()
    }
}

/// Execute one gathered batch against the store, record metrics, then
/// fill every slot. Consumes the tickets (query payloads are moved into
/// the batched kernel calls without cloning).
///
/// When a [`ResponseCache`] is configured, cacheable tickets are probed
/// at batch-formation time: a hit is answered from the cache and never
/// reaches a kernel call; misses execute batched as before and their
/// responses are inserted for the next repeat. Cache hits count toward
/// completion latencies but not batch occupancy (occupancy measures
/// kernel batching).
///
/// Stats are recorded *before* any slot is filled, so a client woken by
/// its response always observes engine metrics that already include its
/// own request.
pub fn execute(
    batch: Vec<Ticket>,
    store: &ShardedCleanup,
    resonator: Option<&Resonator>,
    cache: Option<&ResponseCache>,
    scratch: &mut WorkerScratch,
    stats: &ServeStats,
    scan_threads: usize,
) {
    let now = Instant::now();
    let mut recall_qs = Vec::new();
    let mut recall_slots: Vec<(ResponseSlot, Instant)> = Vec::new();
    let mut topk_qs = Vec::new();
    let mut topk_slots: Vec<(ResponseSlot, Instant, usize)> = Vec::new();
    let mut fact_scenes = Vec::new();
    let mut fact_slots: Vec<(ResponseSlot, Instant)> = Vec::new();
    let mut expired = 0u64;
    let mut unsupported = 0u64;
    let mut latencies: Vec<(RequestKind, Duration)> = Vec::with_capacity(batch.len());
    // (slot, outcome) pairs, filled only after all metrics are recorded
    let mut fills: Vec<(ResponseSlot, Result<ServeResponse, ServeError>)> =
        Vec::with_capacity(batch.len());

    for t in batch {
        if t.expired(now) {
            fills.push((t.slot, Err(ServeError::DeadlineExceeded)));
            expired += 1;
            continue;
        }
        match t.request {
            ServeRequest::Recall { query } => {
                if query.dim() != store.dim() {
                    fills.push((t.slot, Err(ServeError::InvalidDimension)));
                    unsupported += 1;
                } else if let Some(resp) = cache.and_then(|c| c.get_recall(&query)) {
                    latencies.push((RequestKind::Recall, t.enqueued.elapsed()));
                    fills.push((t.slot, Ok(resp)));
                } else {
                    recall_qs.push(query);
                    recall_slots.push((t.slot, t.enqueued));
                }
            }
            ServeRequest::RecallTopK { query, k } => {
                if query.dim() != store.dim() {
                    fills.push((t.slot, Err(ServeError::InvalidDimension)));
                    unsupported += 1;
                } else if let Some(resp) = cache.and_then(|c| c.get_topk(&query, k)) {
                    latencies.push((RequestKind::RecallTopK, t.enqueued.elapsed()));
                    fills.push((t.slot, Ok(resp)));
                } else {
                    topk_qs.push(query);
                    topk_slots.push((t.slot, t.enqueued, k));
                }
            }
            ServeRequest::Factorize { scene } => match resonator {
                None => {
                    fills.push((t.slot, Err(ServeError::Unsupported)));
                    unsupported += 1;
                }
                Some(res) if scene.dim() != res.codebooks()[0].dim() => {
                    fills.push((t.slot, Err(ServeError::InvalidDimension)));
                    unsupported += 1;
                }
                Some(_) => {
                    fact_scenes.push(scene);
                    fact_slots.push((t.slot, t.enqueued));
                }
            },
        }
    }

    let executed = recall_qs.len() + topk_qs.len() + fact_scenes.len();
    let mut shard_timings: Vec<(usize, f64)> = Vec::new();
    let mut prune = PruneStats::default();

    if !recall_qs.is_empty() {
        let (results, timings, scan_prune) = store.recall_batch_stats(&recall_qs, scan_threads);
        shard_timings.extend(timings);
        prune.merge(&scan_prune);
        for (((slot, enqueued), (index, cosine)), query) in
            recall_slots.into_iter().zip(results).zip(recall_qs)
        {
            let resp = ServeResponse::Recall { index, cosine };
            if let Some(c) = cache {
                c.insert(ServeRequest::Recall { query }, &resp);
            }
            latencies.push((RequestKind::Recall, enqueued.elapsed()));
            fills.push((slot, Ok(resp)));
        }
    }

    if !topk_qs.is_empty() {
        // One scan at the batch's largest k; per-ticket answers are
        // prefixes of it (top-k is prefix-stable in k — see
        // `BinaryCodebook::top_k`). Cache entries are keyed at each
        // ticket's own k, so a hit can never leak a different k's answer.
        let k_max = topk_slots.iter().map(|&(_, _, k)| k).max().unwrap_or(0);
        let (results, timings, scan_prune) =
            store.recall_topk_batch_stats(&topk_qs, k_max, scan_threads);
        shard_timings.extend(timings);
        prune.merge(&scan_prune);
        for (((slot, enqueued, k), mut hits), query) in
            topk_slots.into_iter().zip(results).zip(topk_qs)
        {
            hits.truncate(k);
            let resp = ServeResponse::RecallTopK { hits };
            if let Some(c) = cache {
                c.insert(ServeRequest::RecallTopK { query, k }, &resp);
            }
            latencies.push((RequestKind::RecallTopK, enqueued.elapsed()));
            fills.push((slot, Ok(resp)));
        }
    }

    if !fact_scenes.is_empty() {
        let res = resonator.expect("factorize tickets imply a resonator");
        let (estimates, rscratch) = scratch.bufs(res);
        let decode_before = *rscratch.prune_stats();
        let results = res.factorize_batch_with(&fact_scenes, estimates, rscratch);
        // attribute this batch's pruned per-factor index decodes to the
        // batch telemetry (the scratch accumulates across batches; real
        // decodes count f32 elements where the binary scans count words,
        // but streamed and total stay in matching units per scan)
        prune.merge(&rscratch.prune_stats().delta_since(&decode_before));
        for ((slot, enqueued), r) in fact_slots.into_iter().zip(results) {
            latencies.push((RequestKind::Factorize, enqueued.elapsed()));
            fills.push((
                slot,
                Ok(ServeResponse::Factorize {
                    indices: r.indices,
                    iterations: r.iterations,
                    converged: r.converged,
                }),
            ));
        }
    }

    if expired > 0 {
        stats.record_expired(expired);
    }
    if unsupported > 0 {
        stats.record_unsupported(unsupported);
    }
    stats.record_batch(executed, &latencies, &shard_timings, &prune);
    for (slot, outcome) in fills {
        slot.fill(outcome);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::queue::Priority;
    use crate::util::Rng;
    use crate::vsa::{BinaryCodebook, BinaryHV, CleanupMemory, RealCodebook};

    fn make_store(seed: u64) -> (BinaryCodebook, ShardedCleanup) {
        let mut rng = Rng::new(seed);
        let cb = BinaryCodebook::random(&mut rng, 24, 512);
        let sharded = ShardedCleanup::partition(&cb, 3);
        (cb, sharded)
    }

    fn ticket(request: ServeRequest, deadline: Duration) -> (Ticket, ResponseSlot) {
        let slot = ResponseSlot::new();
        let now = Instant::now();
        (
            Ticket {
                request,
                priority: Priority::Normal,
                slot: slot.clone(),
                enqueued: now,
                deadline: now + deadline,
            },
            slot,
        )
    }

    #[test]
    fn gather_respects_max_batch() {
        let q = AdmissionQueue::new(16);
        for i in 0..5 {
            let (t, _slot) = ticket(
                ServeRequest::RecallTopK {
                    query: BinaryHV::zeros(64),
                    k: i,
                },
                Duration::from_secs(1),
            );
            q.push(t).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 3,
            max_delay: Duration::from_millis(5),
        };
        let batch = gather(&q, &policy).unwrap();
        assert_eq!(batch.len(), 3);
        let rest = gather(&q, &policy).unwrap();
        assert_eq!(rest.len(), 2);
    }

    #[test]
    fn execute_mixed_batch_matches_oracles() {
        let (cb, store) = make_store(1);
        let cm = CleanupMemory::new(cb.clone());
        let mut rng = Rng::new(2);
        let res = Resonator::new(
            (0..3)
                .map(|_| RealCodebook::random_bipolar(&mut rng, 6, 512))
                .collect(),
            40,
        );
        let scene = res.compose(&[1, 4, 2]);
        let q1 = BinaryHV::random(&mut rng, 512);
        let q2 = BinaryHV::random(&mut rng, 512);

        let (t1, s1) = ticket(ServeRequest::Recall { query: q1.clone() }, Duration::from_secs(5));
        let (t2, s2) = ticket(
            ServeRequest::RecallTopK {
                query: q2.clone(),
                k: 3,
            },
            Duration::from_secs(5),
        );
        let (t3, s3) = ticket(
            ServeRequest::Factorize {
                scene: scene.clone(),
            },
            Duration::from_secs(5),
        );
        let stats = ServeStats::new(store.n_shards());
        let mut scratch = WorkerScratch::new();
        execute(
            vec![t1, t2, t3],
            &store,
            Some(&res),
            None,
            &mut scratch,
            &stats,
            1,
        );
        let (idx, cos) = cm.recall(&q1);
        assert_eq!(s1.wait(), Ok(ServeResponse::Recall { index: idx, cosine: cos }));
        assert_eq!(
            s2.wait(),
            Ok(ServeResponse::RecallTopK {
                hits: cm.recall_topk(&q2, 3)
            })
        );
        let oracle = res.factorize(&scene);
        assert_eq!(
            s3.wait(),
            Ok(ServeResponse::Factorize {
                indices: oracle.indices,
                iterations: oracle.iterations,
                converged: oracle.converged,
            })
        );
        let snap = stats.snapshot();
        assert_eq!(snap.completed, 3);
        assert_eq!(snap.batches, 1);
        assert!((snap.mean_batch - 3.0).abs() < 1e-12);
        assert!(snap.shards.iter().any(|s| s.scans > 0));
        // prune telemetry covers every routed scan in the batch: one
        // recall (24 items) + one top-k (24) + the factorize decode
        // (3 factors x 6 items)
        assert_eq!(snap.prune.items, 24 + 24 + 3 * 6);
    }

    #[test]
    fn mixed_k_topk_batch_answers_each_request_at_its_own_k() {
        let (cb, store) = make_store(3);
        let cm = CleanupMemory::new(cb);
        let mut rng = Rng::new(4);
        let queries: Vec<BinaryHV> =
            (0..3).map(|_| BinaryHV::random(&mut rng, 512)).collect();
        let ks = [1usize, 5, 2];
        let stats = ServeStats::new(store.n_shards());
        let mut scratch = WorkerScratch::new();
        let mut slots = Vec::new();
        let mut batch = Vec::new();
        for (q, &k) in queries.iter().zip(&ks) {
            let (t, s) = ticket(
                ServeRequest::RecallTopK {
                    query: q.clone(),
                    k,
                },
                Duration::from_secs(5),
            );
            batch.push(t);
            slots.push(s);
        }
        execute(batch, &store, None, None, &mut scratch, &stats, 1);
        for ((q, &k), s) in queries.iter().zip(&ks).zip(slots) {
            assert_eq!(
                s.wait(),
                Ok(ServeResponse::RecallTopK {
                    hits: cm.recall_topk(q, k)
                })
            );
        }
    }

    #[test]
    fn cache_hits_bypass_kernels_with_identical_responses() {
        use super::super::cache::{CacheConfig, ResponseCache};
        let (cb, store) = make_store(9);
        let cm = CleanupMemory::new(cb);
        let cache = ResponseCache::new(CacheConfig::default());
        let stats = ServeStats::new(store.n_shards());
        let mut scratch = WorkerScratch::new();
        let mut rng = Rng::new(10);
        let q = BinaryHV::random(&mut rng, 512);
        // first pass: misses, computed by the kernels, inserted
        let (t1, s1) = ticket(ServeRequest::Recall { query: q.clone() }, Duration::from_secs(5));
        let (t2, s2) = ticket(
            ServeRequest::RecallTopK { query: q.clone(), k: 4 },
            Duration::from_secs(5),
        );
        execute(vec![t1, t2], &store, None, Some(&cache), &mut scratch, &stats, 1);
        let first_recall = s1.wait().unwrap();
        let first_topk = s2.wait().unwrap();
        let scans_after_first: u64 = stats.snapshot().shards.iter().map(|s| s.scans).sum();
        // second pass: same query → both served from cache, no new scans
        let (t3, s3) = ticket(ServeRequest::Recall { query: q.clone() }, Duration::from_secs(5));
        let (t4, s4) = ticket(
            ServeRequest::RecallTopK { query: q.clone(), k: 4 },
            Duration::from_secs(5),
        );
        execute(vec![t3, t4], &store, None, Some(&cache), &mut scratch, &stats, 1);
        assert_eq!(s3.wait().unwrap(), first_recall);
        assert_eq!(s4.wait().unwrap(), first_topk);
        let snap = stats.snapshot();
        let scans_after_second: u64 = snap.shards.iter().map(|s| s.scans).sum();
        assert_eq!(
            scans_after_second, scans_after_first,
            "cache hits must not trigger shard scans"
        );
        assert_eq!(snap.completed, 4, "cache hits still count as completed");
        assert_eq!(snap.batches, 1, "all-hit batches don't count toward occupancy");
        let c = cache.counters();
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 2);
        // a different k is a miss, answered by the kernels at its own k
        let (t5, s5) = ticket(
            ServeRequest::RecallTopK { query: q.clone(), k: 2 },
            Duration::from_secs(5),
        );
        execute(vec![t5], &store, None, Some(&cache), &mut scratch, &stats, 1);
        assert_eq!(
            s5.wait(),
            Ok(ServeResponse::RecallTopK {
                hits: cm.recall_topk(&q, 2)
            })
        );
        assert_eq!(cache.counters().hits, 2, "k=2 probe must not hit the k=4 entry");
    }

    #[test]
    fn dimension_mismatch_is_refused_not_panicking() {
        let (_, store) = make_store(7); // dim 512
        let stats = ServeStats::new(store.n_shards());
        let mut scratch = WorkerScratch::new();
        let (t_bad, s_bad) = ticket(
            ServeRequest::Recall {
                query: BinaryHV::zeros(64), // wrong dimension
            },
            Duration::from_secs(5),
        );
        let (t_ok, s_ok) = ticket(
            ServeRequest::Recall {
                query: BinaryHV::zeros(512),
            },
            Duration::from_secs(5),
        );
        execute(vec![t_bad, t_ok], &store, None, None, &mut scratch, &stats, 1);
        assert_eq!(s_bad.wait(), Err(ServeError::InvalidDimension));
        assert!(s_ok.wait().is_ok(), "good request in same batch still served");
        assert_eq!(stats.snapshot().unsupported, 1);
    }

    #[test]
    fn expired_and_unsupported_are_answered_not_executed() {
        let (_, store) = make_store(5);
        let stats = ServeStats::new(store.n_shards());
        let mut scratch = WorkerScratch::new();
        let (t_expired, s_expired) = ticket(
            ServeRequest::Recall {
                query: BinaryHV::zeros(512),
            },
            Duration::from_secs(0),
        );
        let (t_fact, s_fact) = ticket(
            ServeRequest::Factorize {
                scene: crate::vsa::RealHV::zeros(64),
            },
            Duration::from_secs(5),
        );
        execute(vec![t_expired, t_fact], &store, None, None, &mut scratch, &stats, 1);
        assert_eq!(s_expired.wait(), Err(ServeError::DeadlineExceeded));
        assert_eq!(s_fact.wait(), Err(ServeError::Unsupported));
        let snap = stats.snapshot();
        assert_eq!(snap.completed, 0);
        assert_eq!(snap.expired, 1);
        assert_eq!(snap.unsupported, 1);
        assert_eq!(snap.batches, 0, "empty batches don't count toward occupancy");
    }
}
