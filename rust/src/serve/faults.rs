//! Deterministic fault-injection harness for the serve engine.
//!
//! Robustness claims are only as good as the failures you can reproduce.
//! This module injects three failure classes the chaos scenarios in
//! [`super::loadgen`] and the containment tests lean on:
//!
//! - **artificial kernel latency** — a sleep before a batch's kernel
//!   dispatch, simulating a slow store / cold memory;
//! - **forced admission rejections** — a request refused at submit time
//!   as if the queue were full, simulating admission-control flakes;
//! - **worker-thread panics** — a panic raised inside batch execution,
//!   exercising the engine's containment path (the poisoned batch is
//!   answered with [`super::ServeError::Internal`] and the worker
//!   respawns).
//!
//! Decisions are driven by a seeded [`crate::util::Rng`] behind a mutex,
//! so a run is reproducible from its seed (exactly, with one worker;
//! aggregate-deterministically with several — the *number* of injections
//! over N decisions concentrates tightly, only their interleaving moves).
//! The probability knobs are runtime-adjustable, so a test can force a
//! panic on the next batch (`p = 1.0`), then lower it to zero and verify
//! the engine still serves.

use crate::util::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Fault-injection knobs. `FaultConfig::default()` injects nothing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Seed for the decision stream.
    pub seed: u64,
    /// Probability a `submit` is refused at admission (as
    /// [`super::ServeError::Overloaded`]) before touching the queue.
    pub admit_reject_prob: f64,
    /// Probability an executed batch panics its worker.
    pub panic_prob: f64,
    /// Probability a batch's kernel dispatch is delayed by
    /// `kernel_delay`.
    pub kernel_delay_prob: f64,
    /// Injected latency per delayed dispatch.
    pub kernel_delay: Duration,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            seed: 0,
            admit_reject_prob: 0.0,
            panic_prob: 0.0,
            kernel_delay_prob: 0.0,
            kernel_delay: Duration::ZERO,
        }
    }
}

#[derive(Debug)]
struct FaultState {
    rng: Rng,
    cfg: FaultConfig,
}

/// Shared decision engine the serve engine consults at its injection
/// points. All methods take `&self`; counters are atomics so the stats
/// path never blocks on the decision lock.
#[derive(Debug)]
pub struct FaultPlan {
    state: Mutex<FaultState>,
    injected_rejects: AtomicU64,
    injected_panics: AtomicU64,
    injected_delays: AtomicU64,
}

impl FaultPlan {
    pub fn new(cfg: FaultConfig) -> FaultPlan {
        FaultPlan {
            state: Mutex::new(FaultState {
                rng: Rng::new(cfg.seed),
                cfg,
            }),
            injected_rejects: AtomicU64::new(0),
            injected_panics: AtomicU64::new(0),
            injected_delays: AtomicU64::new(0),
        }
    }

    fn roll(&self, pick: impl Fn(&FaultConfig) -> f64) -> bool {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        let p = pick(&st.cfg);
        // p == 0 must not consume randomness: disabled fault classes
        // leave the decision stream of the enabled ones untouched.
        p > 0.0 && st.rng.chance(p)
    }

    /// Should this submission be refused at admission?
    pub fn should_reject_admission(&self) -> bool {
        let hit = self.roll(|c| c.admit_reject_prob);
        if hit {
            self.injected_rejects.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Should the worker panic on this batch?
    pub fn should_panic(&self) -> bool {
        let hit = self.roll(|c| c.panic_prob);
        if hit {
            self.injected_panics.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Delay to impose before this batch's kernel dispatch, if any.
    pub fn kernel_delay(&self) -> Option<Duration> {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        let (p, d) = (st.cfg.kernel_delay_prob, st.cfg.kernel_delay);
        if p > 0.0 && !d.is_zero() && st.rng.chance(p) {
            drop(st);
            self.injected_delays.fetch_add(1, Ordering::Relaxed);
            Some(d)
        } else {
            None
        }
    }

    /// Retune the probabilities of a live plan (tests flip a fault on,
    /// observe it, then flip it off). The seed is not re-applied; the
    /// decision stream continues.
    pub fn set_probs(&self, admit_reject: f64, panic_p: f64, kernel_delay_p: f64) {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        st.cfg.admit_reject_prob = admit_reject;
        st.cfg.panic_prob = panic_p;
        st.cfg.kernel_delay_prob = kernel_delay_p;
    }

    /// (forced admission rejections, worker panics, delayed dispatches)
    /// injected so far.
    pub fn injected(&self) -> (u64, u64, u64) {
        (
            self.injected_rejects.load(Ordering::Relaxed),
            self.injected_panics.load(Ordering::Relaxed),
            self.injected_delays.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_injects_nothing() {
        let plan = FaultPlan::new(FaultConfig::default());
        for _ in 0..100 {
            assert!(!plan.should_reject_admission());
            assert!(!plan.should_panic());
            assert!(plan.kernel_delay().is_none());
        }
        assert_eq!(plan.injected(), (0, 0, 0));
    }

    #[test]
    fn decisions_are_reproducible_from_seed() {
        let cfg = FaultConfig {
            seed: 42,
            admit_reject_prob: 0.3,
            panic_prob: 0.2,
            kernel_delay_prob: 0.1,
            kernel_delay: Duration::from_micros(50),
            ..FaultConfig::default()
        };
        let a = FaultPlan::new(cfg);
        let b = FaultPlan::new(cfg);
        for _ in 0..200 {
            assert_eq!(a.should_reject_admission(), b.should_reject_admission());
            assert_eq!(a.should_panic(), b.should_panic());
            assert_eq!(a.kernel_delay(), b.kernel_delay());
        }
        assert_eq!(a.injected(), b.injected());
    }

    #[test]
    fn probability_one_always_fires_and_counts() {
        let plan = FaultPlan::new(FaultConfig {
            seed: 7,
            panic_prob: 1.0,
            ..FaultConfig::default()
        });
        for _ in 0..10 {
            assert!(plan.should_panic());
        }
        assert_eq!(plan.injected(), (0, 10, 0));
        // retune to zero: the fault stops firing
        plan.set_probs(0.0, 0.0, 0.0);
        assert!(!plan.should_panic());
        assert_eq!(plan.injected(), (0, 10, 0));
    }

    #[test]
    fn injection_rate_tracks_probability() {
        let plan = FaultPlan::new(FaultConfig {
            seed: 11,
            admit_reject_prob: 0.25,
            ..FaultConfig::default()
        });
        let n = 10_000;
        let hits = (0..n).filter(|_| plan.should_reject_admission()).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.02, "rate {rate}");
    }
}
