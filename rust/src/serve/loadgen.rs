//! Synthetic (multi-tenant) load generation and the `nscog serve-bench`
//! report.
//!
//! A [`Fixture`] deterministically generates an NVSA-style request mix —
//! noisy cleanup recalls, top-k recalls, and resonator factorizations —
//! over one or more stores (each its own codebook shape, resonator
//! configuration, popularity weight, and repeat fraction: the
//! heterogeneous-workload shape of the paper's Sec. V–VI findings), plus
//! the per-store sequential unbatched oracle every engine response is
//! checked against. Two generator shapes drive the engine:
//!
//! - **closed loop**: `clients` threads submit back-to-back (each new
//!   request waits for the previous response) — measures saturated
//!   throughput and is what forms large micro-batches;
//! - **open loop**: arrivals follow a fixed-rate schedule regardless of
//!   completions (the production-realistic shape) — measures latency
//!   under a target offered load, including queueing delay. Completions
//!   are harvested non-blocking between arrivals via
//!   [`PendingResponse::try_wait`], so a slow response never stalls the
//!   sender threads.
//!
//! `run_bench` compares both against the unbatched single-thread baseline
//! and emits `BENCH_serve.json` (path override: `NSCOG_SERVE_JSON`) with
//! one per-store block per registered store. With `--wire` the same
//! engine additionally serves a closed-loop pass over real TCP sockets
//! ([`super::net`]): `clients` [`NetClient`] threads against a
//! [`NetServer`] on an ephemeral loopback port, every framed response
//! oracle-checked bit-exactly, socket counters folded into the JSON's
//! `"wire"` block — the wire-vs-in-process delta is the front-end's
//! measured overhead.
//!
//! Chaos scenarios (`--chaos
//! flood|deadline|panic|churn|slowloris|halfopen|disconnect|garbage`)
//! run on a **separate** engine instance after the clean passes, so the
//! bit-exactness numbers above are never polluted by injected failures.
//! Each scenario checks a fairness invariant (a misbehaving tenant's
//! damage stays tenant-local) and a liveness invariant (the engine still
//! answers correctly once the chaos stops), reported in the JSON's
//! `"chaos"` block. The churn scenario additionally keeps a per-epoch
//! oracle ledger: while live item inserts/deletes and store create/drops
//! race the traffic, every `Ok` answer must be bit-exact for *some*
//! snapshot epoch the request could have been sealed against — a
//! wrong-epoch answer (e.g. a stale cache hit) fails the run. The four
//! network scenarios put a misbehaving *peer* in front of the TCP
//! front-end — a mid-frame staller, a silent half-open carcass, a
//! mid-stream disconnector, a garbage-byte speaker — while victim
//! clients run the schedule over real sockets: the peer must be reaped
//! or refused per the wire contract, every victim answer must stay
//! bit-exact, and the `completed + refused + expired == offered`
//! accounting must hold exactly (the `"chaos"` block's `"net"` ledger).
//!
//! With `--trace` the clean engine also runs its per-request stage
//! tracer: the final ring-buffer dump, the per-class stage-latency
//! decompositions, and the measured-roofline placement of each request
//! class (live FLOP/byte counters from [`super::trace::KernelWork`]
//! against the calibrated [`Platform::host`] roofline, next to the
//! analytical [`crate::profiler::roofline::place`] point for the same
//! op shape) are written to `BENCH_serve_trace.json` (path override:
//! `--trace-json`, then `NSCOG_SERVE_TRACE_JSON`).

use super::engine::{EngineConfig, PendingResponse, ServeEngine};
use super::faults::FaultConfig;
use super::net::{frame, NetClient, NetConfig, NetCounters, NetServer};
use super::queue::{LaneGauge, Priority};
use super::registry::{StoreId, StoreRegistry, StoreSpec};
use super::stats::{LatencySummary, StageSummary, StatsSnapshot, StoreMemory};
use super::trace::{KernelWork, TraceEvent};
use super::{RequestKind, RequestOp, ServeError, ServeRequest, ServeResponse};
use crate::platform::Platform;
use crate::profiler::roofline::{self, RooflinePoint};
use crate::profiler::taxonomy::{OpCategory, PhaseKind};
use crate::profiler::trace::Trace;
use crate::util::bench::Table;
use crate::util::Rng;
use crate::vsa::{BinaryCodebook, BinaryHV, CleanupMemory, RealCodebook, Resonator};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Default trace-ring capacity (events) when `--trace` is on and no
/// `--trace-capacity` is given.
pub const DEFAULT_TRACE_CAPACITY: usize = 4096;

/// Relative request-class weights.
#[derive(Debug, Clone, Copy)]
pub struct LoadMix {
    pub recall: u32,
    pub topk: u32,
    pub factorize: u32,
}

impl LoadMix {
    fn total(&self) -> u32 {
        self.recall + self.topk + self.factorize
    }
}

/// Row-storage mode for a bench store's master codebook.
///
/// `Ram` keeps every row materialized (the default, bandwidth-bound
/// scans); `Ca90` keeps only per-item CA-90 seeds and regenerates rows
/// chunk-by-chunk inside the scan loop (capacity-bound stores, ~dim/512
/// less resident row memory). `--store-backing`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StoreBacking {
    #[default]
    Ram,
    Ca90,
}

impl StoreBacking {
    /// Stable lowercase label, matching `BinaryCodebook::backing_name`.
    pub fn name(&self) -> &'static str {
        match self {
            StoreBacking::Ram => "ram",
            StoreBacking::Ca90 => "ca90",
        }
    }

    /// Parse a `--store-backing` flag value.
    pub fn parse(s: &str) -> Option<StoreBacking> {
        match s {
            "ram" => Some(StoreBacking::Ram),
            "ca90" => Some(StoreBacking::Ca90),
            _ => None,
        }
    }
}

/// One tenant store's shape and traffic profile.
#[derive(Debug, Clone)]
pub struct StoreProfile {
    /// Registration name (`s0`, `s1`, … by convention).
    pub name: String,
    /// Cleanup-memory items / hypervector dimension.
    pub items: usize,
    pub dim: usize,
    /// `k` for this store's top-k recall requests.
    pub topk_k: usize,
    /// Resonator shape: factors × items-per-factor × dimension, max iters.
    pub fact_factors: usize,
    pub fact_items: usize,
    pub fact_dim: usize,
    pub fact_iters: usize,
    /// Relative popularity weight in the request schedule (skewed tenant
    /// traffic; stores with weight 0 are treated as weight 1).
    pub weight: u32,
    /// Fraction of this store's requests that repeat one of its earlier
    /// cacheable requests verbatim (production recall traffic repeats;
    /// this is what the response cache monetizes). 0 disables repeats.
    pub repeat_frac: f64,
    /// Per-store sketch sidecar width override (`None` = engine default).
    pub sketch_bits: Option<usize>,
    /// Per-store admission quota (max queued tickets for this store's
    /// lane); `None` bounds the lane only by global queue capacity.
    /// `--store-quotas`.
    pub quota: Option<usize>,
    /// Row-storage mode for the master codebook (`--store-backing`).
    /// `Ca90` requires `dim` to be a positive multiple of 512.
    pub backing: StoreBacking,
    /// Coarse cascade prefix width in bits for sketched scans
    /// (`--sketch-cascade`); `None` keeps the single-level sketch.
    pub sketch_cascade: Option<usize>,
}

/// Fixture sizing (per-store problem shapes + shared request schedule).
#[derive(Debug, Clone)]
pub struct FixtureConfig {
    /// One profile per store, [`StoreId`] order.
    pub stores: Vec<StoreProfile>,
    /// Fraction of bits flipped on recall queries (all stores).
    pub noise_frac: f64,
    /// Total requests across all stores, and their class mix.
    pub requests: usize,
    pub mix: LoadMix,
    pub seed: u64,
}

/// One store's built state: codebook, oracle cleanup memory, resonator.
pub struct StoreFixture {
    pub profile: StoreProfile,
    pub codebook: BinaryCodebook,
    pub cleanup: CleanupMemory,
    pub resonator: Resonator,
}

/// Deterministic workload: stores, request schedule, and oracle inputs.
pub struct Fixture {
    pub stores: Vec<StoreFixture>,
    pub requests: Vec<ServeRequest>,
    pub cfg: FixtureConfig,
}

impl Fixture {
    /// Build every store and a request schedule, all derived from
    /// `cfg.seed`: stores are built in order, then each scheduled request
    /// first picks its store by popularity weight, then rolls that
    /// store's repeat fraction, then the class mix.
    pub fn build(cfg: FixtureConfig) -> Fixture {
        assert!(!cfg.stores.is_empty(), "fixture needs at least one store");
        assert!(cfg.mix.total() > 0, "empty request mix");
        let mut rng = Rng::new(cfg.seed);
        let stores: Vec<StoreFixture> = cfg
            .stores
            .iter()
            .map(|p| {
                let codebook = match p.backing {
                    StoreBacking::Ram => BinaryCodebook::random(&mut rng, p.items, p.dim),
                    // seeds-only rows: draw one FOLD_BITS seed per item and
                    // let the scan loop rematerialize rows on demand
                    StoreBacking::Ca90 => {
                        let seeds: Vec<Vec<u64>> = (0..p.items)
                            .map(|_| (0..crate::vsa::hypervector::FOLD_WORDS).map(|_| rng.next_u64()).collect())
                            .collect();
                        BinaryCodebook::ca90_from_seeds(&seeds, p.dim, None)
                    }
                };
                let resonator = Resonator::new(
                    (0..p.fact_factors)
                        .map(|_| RealCodebook::random_bipolar(&mut rng, p.fact_items, p.fact_dim))
                        .collect(),
                    p.fact_iters,
                );
                StoreFixture {
                    cleanup: CleanupMemory::new(codebook.clone()),
                    codebook,
                    resonator,
                    profile: p.clone(),
                }
            })
            .collect();
        let weight_of = |p: &StoreProfile| p.weight.max(1) as usize;
        let weight_total: usize = cfg.stores.iter().map(weight_of).sum();
        let mut requests: Vec<ServeRequest> = Vec::with_capacity(cfg.requests);
        // per-store indices of earlier cacheable (recall / top-k)
        // requests — repeats never cross stores
        let mut repeatable: Vec<Vec<usize>> = vec![Vec::new(); stores.len()];
        for _ in 0..cfg.requests {
            // pick the store by popularity weight (skewed tenants)
            let mut roll = rng.below(weight_total);
            let mut si = stores.len() - 1;
            for (i, p) in cfg.stores.iter().enumerate() {
                let w = weight_of(p);
                if roll < w {
                    si = i;
                    break;
                }
                roll -= w;
            }
            let store_id = StoreId(si);
            let sf = &stores[si];
            let p = &sf.profile;
            let repeat_threshold = (p.repeat_frac.clamp(0.0, 1.0) * 1e6) as usize;
            if repeat_threshold > 0
                && !repeatable[si].is_empty()
                && rng.below(1_000_000) < repeat_threshold
            {
                let src = repeatable[si][rng.below(repeatable[si].len())];
                let repeat = requests[src].clone();
                repeatable[si].push(requests.len());
                requests.push(repeat);
                continue;
            }
            let roll = rng.below(cfg.mix.total() as usize) as u32;
            if roll < cfg.mix.recall + cfg.mix.topk {
                repeatable[si].push(requests.len());
                let flips = (p.dim as f64 * cfg.noise_frac) as usize;
                // materialize (not `.item()`): ca90 stores hold seeds only
                let mut query = sf.codebook.materialize_item(rng.below(p.items));
                for i in rng.sample_indices(p.dim, flips) {
                    query.set(i, !query.get(i));
                }
                if roll < cfg.mix.recall {
                    requests.push(ServeRequest::recall_on(store_id, query));
                } else {
                    requests.push(ServeRequest::recall_topk_on(store_id, query, p.topk_k));
                }
            } else {
                let truth: Vec<usize> = (0..p.fact_factors)
                    .map(|_| rng.below(p.fact_items))
                    .collect();
                requests.push(ServeRequest::factorize_on(
                    store_id,
                    sf.resonator.compose(&truth),
                ));
            }
        }
        Fixture {
            stores,
            requests,
            cfg,
        }
    }

    /// Register every store with the engine-level spec defaults
    /// (per-store `sketch_bits` overrides applied) — what `run_bench`
    /// and the e2e tests hand to [`ServeEngine::start_registry`].
    pub fn registry(&self, engine: &EngineConfig) -> StoreRegistry {
        let mut reg = StoreRegistry::new();
        for sf in &self.stores {
            let spec = StoreSpec {
                shards: engine.shards,
                sketch_bits: sf.profile.sketch_bits.or(engine.sketch_bits),
                cache_capacity: engine.cache_capacity,
                cache_shards: engine.cache_shards,
                // popularity doubles as the DRR service share: hotter
                // tenants earn proportionally more pops under backlog
                weight: sf.profile.weight.max(1),
                quota: sf.profile.quota,
                sketch_cascade: sf.profile.sketch_cascade,
                ..StoreSpec::default()
            };
            reg.register(
                &sf.profile.name,
                &sf.codebook,
                Some(sf.resonator.clone()),
                spec,
            );
        }
        reg
    }

    /// Answer one request with its store's sequential, unbatched,
    /// unsharded kernels — the correctness oracle and the baseline's
    /// inner loop.
    pub fn oracle_answer(&self, req: &ServeRequest) -> ServeResponse {
        let sf = &self.stores[req.store.index()];
        match &req.op {
            RequestOp::Recall { query } => {
                let (index, cosine) = sf.cleanup.recall(query);
                ServeResponse::Recall { index, cosine }
            }
            RequestOp::RecallTopK { query, k } => ServeResponse::RecallTopK {
                hits: sf.cleanup.recall_topk(query, *k),
            },
            RequestOp::Factorize { scene } => {
                let r = sf.resonator.factorize(scene);
                ServeResponse::Factorize {
                    indices: r.indices,
                    iterations: r.iterations,
                    converged: r.converged,
                }
            }
        }
    }

    /// Sequential oracle for the whole schedule (untimed convenience).
    pub fn oracle(&self) -> Vec<ServeResponse> {
        self.requests.iter().map(|r| self.oracle_answer(r)).collect()
    }

    /// Run the whole schedule sequentially (the unbatched single-thread
    /// baseline): responses, per-request latencies, and wall time.
    pub fn baseline_run(&self) -> (Vec<ServeResponse>, Vec<f64>, f64) {
        let t0 = Instant::now();
        let mut responses = Vec::with_capacity(self.requests.len());
        let mut latencies = Vec::with_capacity(self.requests.len());
        for req in &self.requests {
            let s = Instant::now();
            responses.push(self.oracle_answer(req));
            latencies.push(s.elapsed().as_secs_f64());
        }
        (responses, latencies, t0.elapsed().as_secs_f64())
    }
}

/// Outcome of one generator run against an engine.
#[derive(Debug)]
pub struct LoadReport {
    pub wall_s: f64,
    /// Per-request end-to-end latency (seconds), request order.
    pub latencies_s: Vec<f64>,
    pub outcomes: Vec<Result<ServeResponse, ServeError>>,
    pub ok: usize,
    pub rejected: usize,
    /// Tenant-local quota rejections ([`ServeError::TenantOverloaded`]).
    pub rejected_tenant: usize,
    pub expired: usize,
    /// Contained worker panics ([`ServeError::Internal`]).
    pub internal: usize,
    /// Ok responses served degraded (`ServeResponse::Degraded`) — each
    /// verified as a truth-prefix of its oracle answer, not an exact
    /// match.
    pub degraded: usize,
    /// Ok responses that differ from the sequential oracle (must be 0).
    pub mismatches: usize,
}

impl LoadReport {
    fn assemble(
        wall_s: f64,
        mut tagged: Vec<(usize, Result<ServeResponse, ServeError>, f64)>,
        oracle: &[ServeResponse],
    ) -> LoadReport {
        tagged.sort_by_key(|&(i, _, _)| i);
        let mut latencies_s = Vec::with_capacity(tagged.len());
        let mut outcomes = Vec::with_capacity(tagged.len());
        let (mut ok, mut rejected, mut rejected_tenant, mut expired) = (0, 0, 0, 0);
        let (mut internal, mut degraded, mut mismatches) = (0, 0, 0);
        for (i, outcome, lat) in tagged {
            match &outcome {
                // a degraded answer is honest about its truncation: it
                // must be a prefix of the full-k oracle answer (top-k is
                // prefix-stable in k), anything else is a mismatch
                Ok(ServeResponse::Degraded { inner }) => {
                    ok += 1;
                    degraded += 1;
                    let prefix_exact = match (&**inner, &oracle[i]) {
                        (
                            ServeResponse::RecallTopK { hits },
                            ServeResponse::RecallTopK { hits: full },
                        ) => hits.len() <= full.len() && full[..hits.len()] == hits[..],
                        _ => false,
                    };
                    if !prefix_exact {
                        mismatches += 1;
                    }
                }
                Ok(resp) => {
                    ok += 1;
                    if resp != &oracle[i] {
                        mismatches += 1;
                    }
                }
                Err(ServeError::Overloaded) | Err(ServeError::ShuttingDown) => rejected += 1,
                Err(ServeError::TenantOverloaded) => rejected_tenant += 1,
                Err(ServeError::DeadlineExceeded) => expired += 1,
                Err(ServeError::Internal) => internal += 1,
                // the fixture never generates these, so any of them means
                // the engine under test is misconfigured — flag it
                Err(ServeError::Unsupported)
                | Err(ServeError::InvalidDimension)
                | Err(ServeError::UnknownStore) => mismatches += 1,
            }
            latencies_s.push(lat);
            outcomes.push(outcome);
        }
        LoadReport {
            wall_s,
            latencies_s,
            outcomes,
            ok,
            rejected,
            rejected_tenant,
            expired,
            internal,
            degraded,
            mismatches,
        }
    }

    /// Completed-request throughput.
    pub fn qps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.ok as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Latency summary over successful requests only.
    pub fn latency(&self) -> Option<LatencySummary> {
        let ok_lats: Vec<f64> = self
            .outcomes
            .iter()
            .zip(&self.latencies_s)
            .filter(|(o, _)| o.is_ok())
            .map(|(_, &l)| l)
            .collect();
        LatencySummary::of(&ok_lats)
    }
}

/// Closed loop: `clients` threads each submit their share of the schedule
/// back-to-back. Request `i` goes to client `i % clients`, preserving a
/// deterministic assignment. `oracle` is the per-request expected
/// response set ([`Fixture::oracle`] / `baseline_run`) — precomputed by
/// the caller so one oracle pass can serve several generator runs.
pub fn run_closed_loop(
    engine: &ServeEngine,
    fixture: &Fixture,
    clients: usize,
    oracle: &[ServeResponse],
) -> LoadReport {
    let requests = &fixture.requests;
    assert_eq!(oracle.len(), requests.len());
    let clients = clients.clamp(1, requests.len().max(1));
    let t0 = Instant::now();
    let tagged: Vec<(usize, Result<ServeResponse, ServeError>, f64)> =
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..clients)
                .map(|c| {
                    s.spawn(move || {
                        let mut out = Vec::new();
                        for (i, req) in requests.iter().enumerate() {
                            if i % clients != c {
                                continue;
                            }
                            let start = Instant::now();
                            let outcome = engine.submit(req.clone());
                            out.push((i, outcome, start.elapsed().as_secs_f64()));
                        }
                        out
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("load client panicked"))
                .collect()
        });
    LoadReport::assemble(t0.elapsed().as_secs_f64(), tagged, oracle)
}

/// Drain every pending entry that has already completed, without
/// blocking, via [`PendingResponse::try_wait`]; unfinished handles are
/// kept pending.
fn harvest_completed(
    pending: &mut Vec<(usize, PendingResponse)>,
    done: &mut Vec<(usize, Result<ServeResponse, ServeError>, f64)>,
) {
    let mut still = Vec::with_capacity(pending.len());
    for (i, p) in pending.drain(..) {
        match p.try_wait() {
            Ok((outcome, lat)) => done.push((i, outcome, lat.as_secs_f64())),
            Err(p) => still.push((i, p)),
        }
    }
    *pending = still;
}

/// Open loop: arrivals paced at `rate_qps` from a shared schedule,
/// dispatched non-blocking by `senders` threads; completions are
/// harvested non-blocking between arrivals (the `try_wait` poll), with a
/// final blocking drain after the schedule is exhausted — so slow
/// completions never stall later arrivals. Latency is measured enqueue →
/// worker-fill (queueing included). `oracle` as in [`run_closed_loop`].
pub fn run_open_loop(
    engine: &ServeEngine,
    fixture: &Fixture,
    rate_qps: f64,
    senders: usize,
    oracle: &[ServeResponse],
) -> LoadReport {
    assert!(rate_qps > 0.0);
    let requests = &fixture.requests;
    assert_eq!(oracle.len(), requests.len());
    let senders = senders.clamp(1, requests.len().max(1));
    let interval = Duration::from_secs_f64(1.0 / rate_qps);
    let next = AtomicUsize::new(0);
    // small lead so every sender thread is running before arrival 0
    let epoch = Instant::now() + Duration::from_millis(10);
    let deadline = engine.config().default_deadline;
    let t0 = Instant::now();
    let tagged: Vec<(usize, Result<ServeResponse, ServeError>, f64)> =
        std::thread::scope(|s| {
            let next = &next;
            let handles: Vec<_> = (0..senders)
                .map(|_| {
                    s.spawn(move || {
                        let mut pending: Vec<(usize, PendingResponse)> = Vec::new();
                        let mut done = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= requests.len() {
                                break;
                            }
                            let scheduled = epoch + interval.mul_f64(i as f64);
                            let now = Instant::now();
                            if scheduled > now {
                                std::thread::sleep(scheduled - now);
                            }
                            match engine.submit_async(
                                requests[i].clone(),
                                Priority::Normal,
                                deadline,
                            ) {
                                Ok(p) => pending.push((i, p)),
                                Err(e) => done.push((i, Err(e), 0.0)),
                            }
                            harvest_completed(&mut pending, &mut done);
                        }
                        // blocking drain of whatever is still in flight
                        for (i, p) in pending {
                            let (outcome, lat) = p.wait_with_latency();
                            done.push((i, outcome, lat.as_secs_f64()));
                        }
                        done
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("load sender panicked"))
                .collect()
        });
    LoadReport::assemble(t0.elapsed().as_secs_f64(), tagged, oracle)
}

/// Everything `nscog serve-bench` needs for one run.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub fixture: FixtureConfig,
    pub engine: EngineConfig,
    /// Closed-loop client threads.
    pub clients: usize,
    /// Open-loop offered rate; `None` skips the open-loop pass.
    pub open_loop_qps: Option<f64>,
    /// Run an extra closed-loop pass over real TCP sockets (`--wire`):
    /// the same engine behind a [`NetServer`] on an ephemeral loopback
    /// port, `clients` [`NetClient`] threads, every framed response
    /// verified bit-exactly against the oracle.
    pub wire: bool,
    /// Chaos scenario to run after the clean passes, on its own engine.
    pub chaos: Option<ChaosScenario>,
    /// Churn scenario mutation rate, ops/second (`--churn-rate`).
    pub churn_rate: f64,
    /// Churn scenario mutation count (`--churn-ops`).
    pub churn_ops: usize,
    pub json_path: Option<String>,
    /// Run the clean engine with the per-request stage tracer on
    /// (`--trace` / `NSCOG_TRACE=1`) and emit `BENCH_serve_trace.json`.
    pub trace: bool,
    /// Trace-ring capacity in events (`--trace-capacity`); beyond it the
    /// ring drops oldest and counts the drops.
    pub trace_capacity: usize,
    /// Trace JSON path override (`--trace-json`); then
    /// `NSCOG_SERVE_TRACE_JSON`, then `BENCH_serve_trace.json`.
    pub trace_json_path: Option<String>,
}

impl BenchOpts {
    /// CI smoke shape: bounded requests, deterministic seed, small enough
    /// to finish in a few seconds even unoptimized.
    pub fn smoke() -> BenchOpts {
        BenchOpts {
            fixture: FixtureConfig {
                stores: vec![StoreProfile {
                    name: "default".into(),
                    items: 96,
                    dim: 2048,
                    topk_k: 3,
                    fact_factors: 3,
                    fact_items: 8,
                    fact_dim: 512,
                    fact_iters: 30,
                    weight: 1,
                    repeat_frac: 0.25,
                    sketch_bits: None,
                    quota: None,
                    backing: StoreBacking::Ram,
                    sketch_cascade: None,
                }],
                noise_frac: 0.2,
                requests: 400,
                mix: LoadMix {
                    recall: 6,
                    topk: 1,
                    factorize: 1,
                },
                seed: 2024,
            },
            engine: EngineConfig {
                workers: 2,
                shards: 4,
                scan_threads: 1,
                max_batch: 16,
                max_delay: Duration::from_micros(300),
                queue_capacity: 512,
                default_deadline: Duration::from_secs(30),
                ..EngineConfig::default()
            },
            clients: 8,
            open_loop_qps: None,
            wire: false,
            chaos: None,
            churn_rate: 150.0,
            churn_ops: 60,
            json_path: None,
            trace: false,
            trace_capacity: DEFAULT_TRACE_CAPACITY,
            trace_json_path: None,
        }
    }

    /// Default standalone-bench shape: paper-scale cleanup memory
    /// (120×8192, the Tab. VII REACT/MULT store) and more load.
    pub fn standard() -> BenchOpts {
        BenchOpts {
            fixture: FixtureConfig {
                stores: vec![StoreProfile {
                    name: "default".into(),
                    items: 120,
                    dim: 8192,
                    topk_k: 5,
                    fact_factors: 3,
                    fact_items: 10,
                    fact_dim: 1024,
                    fact_iters: 60,
                    weight: 1,
                    repeat_frac: 0.25,
                    sketch_bits: None,
                    quota: None,
                    backing: StoreBacking::Ram,
                    sketch_cascade: None,
                }],
                noise_frac: 0.2,
                requests: 2000,
                mix: LoadMix {
                    recall: 6,
                    topk: 1,
                    factorize: 1,
                },
                seed: 2024,
            },
            engine: EngineConfig::default(),
            clients: 16,
            open_loop_qps: None,
            wire: false,
            chaos: None,
            churn_rate: 150.0,
            churn_ops: 60,
            json_path: None,
            trace: false,
            trace_capacity: DEFAULT_TRACE_CAPACITY,
            trace_json_path: None,
        }
    }

    /// Expand the fixture to `n` stores (the `--stores N` knob): store
    /// `i` derives from the base profile with dims alternating base /
    /// 2×base (heterogeneous tenants) and popularity halving per store
    /// (skewed mix: store 0 is the hottest tenant; weights are capped at
    /// 64×, so beyond 7 stores the hottest tenants plateau rather than
    /// grow unboundedly skewed). Per-store dim / item / sketch / weight
    /// / repeat overrides can then be layered on by the caller.
    pub fn with_stores(&mut self, n: usize) {
        let n = n.max(1);
        let base = self.fixture.stores[0].clone();
        self.fixture.stores = (0..n)
            .map(|i| StoreProfile {
                name: format!("s{i}"),
                dim: base.dim << (i % 2),
                weight: 1u32 << (n - 1 - i).min(6),
                ..base.clone()
            })
            .collect();
    }
}

/// Chaos scenario selector (`--chaos`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosScenario {
    /// One tenant offers several times its admission quota while the
    /// others run closed-loop: the flooder must shed tenant-locally, the
    /// victims must keep completing bit-exactly.
    Flood,
    /// Every other request arrives already past its deadline amid live
    /// traffic: the dead ones must expire, the live ones must complete.
    DeadlineStorm,
    /// Workers panic on a fifth of their batches: every poisoned request
    /// is answered `Internal`, nothing hangs, and the engine serves
    /// bit-exactly once the fault is switched off.
    PanicStorm,
    /// Live item inserts/deletes and store creates/drops race the
    /// traffic: every answer must be bit-exact for an epoch its request
    /// could have been sealed against, dropped stores must answer
    /// `UnknownStore` (never garbage), epochs must grow strictly
    /// monotonically, and surviving stores must probe bit-exactly after
    /// the churn stops.
    Churn,
    /// A peer stalls mid-frame (valid header, payload never finishes)
    /// while victim clients run the schedule over real sockets: the
    /// staller must be reaped within the read deadline and the victims
    /// must complete bit-exactly.
    Slowloris,
    /// A peer connects and then goes silent forever (no FIN): it must be
    /// reaped within the idle deadline without touching the victims.
    HalfOpen,
    /// A peer repeatedly sends whole or partial request frames and drops
    /// the connection without reading answers: stranded completions must
    /// be discarded harmlessly, victims unaffected.
    Disconnect,
    /// A peer speaks non-protocol bytes: each attempt must be answered
    /// with exactly one protocol error frame and closed — never a panic,
    /// never a partial decode — while victims keep serving.
    Garbage,
}

impl ChaosScenario {
    pub fn parse(s: &str) -> Option<ChaosScenario> {
        match s {
            "flood" => Some(ChaosScenario::Flood),
            "deadline" => Some(ChaosScenario::DeadlineStorm),
            "panic" => Some(ChaosScenario::PanicStorm),
            "churn" => Some(ChaosScenario::Churn),
            "slowloris" => Some(ChaosScenario::Slowloris),
            "halfopen" => Some(ChaosScenario::HalfOpen),
            "disconnect" => Some(ChaosScenario::Disconnect),
            "garbage" => Some(ChaosScenario::Garbage),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ChaosScenario::Flood => "flood",
            ChaosScenario::DeadlineStorm => "deadline",
            ChaosScenario::PanicStorm => "panic",
            ChaosScenario::Churn => "churn",
            ChaosScenario::Slowloris => "slowloris",
            ChaosScenario::HalfOpen => "halfopen",
            ChaosScenario::Disconnect => "disconnect",
            ChaosScenario::Garbage => "garbage",
        }
    }
}

/// One store's ledger across a chaos scenario.
#[derive(Debug, Clone, Default)]
pub struct ChaosStoreOutcome {
    pub name: String,
    /// Whether this store was the scenario's misbehaving tenant.
    pub flooder: bool,
    pub offered: usize,
    pub completed: usize,
    pub rejected: usize,
    pub rejected_tenant: usize,
    pub expired: usize,
    pub internal: usize,
    pub degraded: usize,
    pub mismatches: usize,
}

/// Chaos verdict: per-store ledgers plus the two invariants every
/// scenario must uphold.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    pub scenario: ChaosScenario,
    pub stores: Vec<ChaosStoreOutcome>,
    /// The misbehavior stayed tenant-local / casualty-exact: well-behaved
    /// traffic completed (≥90%, bit-exactly) and only the intended
    /// victims of the scenario paid for it.
    pub fairness_pass: bool,
    /// With the chaos switched off, every store answered a fresh request
    /// bit-exactly on the same (never restarted) engine.
    pub liveness_pass: bool,
    /// The churn scenario's mutation/epoch ledger; `None` for every
    /// other scenario.
    pub churn: Option<ChurnReport>,
    /// The network scenarios' wire ledger; `None` for in-process chaos.
    pub net: Option<NetChaosReport>,
}

/// The churn scenario's ledger: what was mutated, how every response
/// verified against its epoch window, and the post-churn probe verdict.
#[derive(Debug, Clone, Default)]
pub struct ChurnReport {
    /// Mutations applied (`--churn-ops`).
    pub ops: usize,
    pub inserts: usize,
    pub deletes: usize,
    pub creates: usize,
    pub drops: usize,
    /// Mutations the engine refused. The driver is the only mutator and
    /// checks its own mirror first, so any refusal is an engine bug —
    /// must be 0.
    pub op_failures: usize,
    /// `Ok` responses that were bit-exact for *no* epoch in the
    /// request's seal window — the tentpole invariant; must be 0. A
    /// stale (pre-mutation) cache hit would land here.
    pub wrong_epoch: usize,
    /// `UnknownStore` answers for stores that really were dropped (the
    /// legal admit-vs-drop race outcome).
    pub unknown_ok: usize,
    /// `UnknownStore` (or other refusals) for live stores — must be 0.
    pub unknown_bad: usize,
    /// `Internal` answers. Churn injects no faults, so a contained
    /// worker panic here is a mutation race bug — must be 0.
    pub panics: usize,
    /// Every observed per-store epoch sequence was strictly monotonic
    /// (driver-returned epochs and client-observed before/after reads).
    pub monotonic: bool,
    /// Surviving stores probed after the churn stopped.
    pub probed: usize,
    /// Every surviving store answered its probe bit-exactly on its final
    /// epoch, and every dropped store answered `UnknownStore`.
    pub probe_pass: bool,
    /// `(name, final epoch)` per issued store slot, dropped included.
    pub final_epochs: Vec<(String, u64)>,
}

/// The network scenarios' wire ledger: victim-side accounting plus the
/// server's reap/refusal counters, and the invariant verdicts.
#[derive(Debug, Clone, Default)]
pub struct NetChaosReport {
    /// Requests the victim clients attempted over the wire.
    pub offered: usize,
    /// Requests answered with a response frame (`Ok`), degraded included.
    pub completed: usize,
    /// Engine/wire refusals (`Overloaded`/`TenantOverloaded`/
    /// `ShuttingDown` error frames).
    pub refused: usize,
    /// `DeadlineExceeded` error frames.
    pub expired: usize,
    /// Victim answers that diverged from the sequential oracle (or
    /// illegal refusals like `UnknownStore`) — must be 0.
    pub mismatches: usize,
    /// Victim calls that failed at the transport after retries — must be
    /// 0: the attacker's damage must never reach another connection.
    pub net_errors: usize,
    /// `completed + refused + expired == offered` held exactly.
    pub accounting_exact: bool,
    /// Server-side reaps (slow-loris + half-open) during the scenario.
    pub reaped: u64,
    /// The scenario's misbehaving peer was caught within the wait bound:
    /// reaped (slowloris/halfopen) or refused with protocol error frames
    /// (garbage); vacuously true for disconnect.
    pub reap_within_deadline: bool,
    /// Undecodable frames answered with a protocol error frame.
    pub protocol_errors: u64,
    /// Connections that died without a clean EOF.
    pub disconnects: u64,
    /// `net_errors == 0 && mismatches == 0`.
    pub victim_clean: bool,
    /// After the attacker stopped, a fresh wire client got a bit-exact
    /// answer from every store with traffic.
    pub probe_pass: bool,
}

/// Classify one outcome into a store's chaos ledger. `oracle == None`
/// skips the bit-exactness check (used for requests whose *expected*
/// outcome is an error, e.g. the deadline storm's dead-on-arrival
/// tickets).
fn chaos_tally(
    out: &mut ChaosStoreOutcome,
    outcome: &Result<ServeResponse, ServeError>,
    oracle: Option<&ServeResponse>,
) {
    match outcome {
        Ok(ServeResponse::Degraded { inner }) => {
            out.completed += 1;
            out.degraded += 1;
            let prefix_exact = matches!(
                (&**inner, oracle),
                (
                    ServeResponse::RecallTopK { hits },
                    Some(ServeResponse::RecallTopK { hits: full }),
                ) if hits.len() <= full.len() && full[..hits.len()] == hits[..]
            );
            if !prefix_exact {
                out.mismatches += 1;
            }
        }
        Ok(resp) => {
            out.completed += 1;
            if let Some(o) = oracle {
                if resp != o {
                    out.mismatches += 1;
                }
            }
        }
        Err(ServeError::Overloaded) | Err(ServeError::ShuttingDown) => out.rejected += 1,
        Err(ServeError::TenantOverloaded) => out.rejected_tenant += 1,
        Err(ServeError::DeadlineExceeded) => out.expired += 1,
        Err(ServeError::Internal) => out.internal += 1,
        Err(ServeError::Unsupported)
        | Err(ServeError::InvalidDimension)
        | Err(ServeError::UnknownStore) => out.mismatches += 1,
    }
}

/// After the chaos stops: one fresh request per store with traffic, each
/// of which must be answered bit-exactly by the same engine.
fn liveness_probe(engine: &ServeEngine, fixture: &Fixture) -> bool {
    let mut probe: Vec<Option<&ServeRequest>> = vec![None; fixture.stores.len()];
    for r in &fixture.requests {
        let si = r.store.index();
        if probe[si].is_none() {
            probe[si] = Some(r);
        }
    }
    probe.iter().flatten().all(|req| {
        matches!(
            engine.submit((*req).clone()),
            Ok(resp) if resp == fixture.oracle_answer(req)
        )
    })
}

fn chaos_outcomes(fixture: &Fixture) -> Vec<ChaosStoreOutcome> {
    fixture
        .stores
        .iter()
        .map(|sf| ChaosStoreOutcome {
            name: sf.profile.name.clone(),
            ..ChaosStoreOutcome::default()
        })
        .collect()
}

/// Run one chaos scenario on a fresh engine built from `fixture`.
pub fn run_chaos(fixture: &Fixture, opts: &BenchOpts, scenario: ChaosScenario) -> ChaosReport {
    match scenario {
        ChaosScenario::Flood => chaos_flood(fixture, opts),
        ChaosScenario::DeadlineStorm => chaos_deadline(fixture, opts),
        ChaosScenario::PanicStorm => chaos_panic(fixture, opts),
        ChaosScenario::Churn => chaos_churn(fixture, opts),
        ChaosScenario::Slowloris
        | ChaosScenario::HalfOpen
        | ChaosScenario::Disconnect
        | ChaosScenario::Garbage => chaos_net(fixture, opts, scenario),
    }
}

/// Single-tenant flood: store 0 (the hottest tenant) offers 4× its
/// schedule fire-and-forget while every other store runs closed-loop.
/// Workers are slowed by an injected per-batch kernel delay so the
/// flooder's backlog is real regardless of host speed; per-store quotas
/// (profile quotas, or capacity/(2·stores) by default) sum to at most
/// half the queue, so a victim's admit can never trip the global
/// capacity check — any victim rejection is a fairness bug, not luck.
fn chaos_flood(fixture: &Fixture, opts: &BenchOpts) -> ChaosReport {
    let n = fixture.stores.len();
    let mut ecfg = opts.engine.clone();
    let capacity = ecfg.queue_capacity.clamp(8, 256);
    ecfg.queue_capacity = capacity;
    ecfg.faults = Some(FaultConfig {
        seed: fixture.cfg.seed,
        kernel_delay_prob: 1.0,
        kernel_delay: Duration::from_millis(2),
        ..FaultConfig::default()
    });
    let mut reg = StoreRegistry::new();
    for sf in &fixture.stores {
        let spec = StoreSpec {
            shards: ecfg.shards,
            sketch_bits: sf.profile.sketch_bits.or(ecfg.sketch_bits),
            // no response cache: a cached flood would drain instantly and
            // prove nothing about admission control
            cache_capacity: 0,
            weight: sf.profile.weight.max(1),
            quota: Some(
                sf.profile
                    .quota
                    .unwrap_or_else(|| (capacity / (2 * n)).max(1)),
            ),
            sketch_cascade: sf.profile.sketch_cascade,
            ..StoreSpec::default()
        };
        reg.register(
            &sf.profile.name,
            &sf.codebook,
            Some(sf.resonator.clone()),
            spec,
        );
    }
    let engine = ServeEngine::start_registry(reg, ecfg).expect("spawn chaos engine workers");
    let mut per_store: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, r) in fixture.requests.iter().enumerate() {
        per_store[r.store.index()].push(i);
    }
    const FLOODER: usize = 0;
    const FLOOD_ROUNDS: usize = 4;
    let deadline = engine.config().default_deadline;
    let eng = &engine;
    let per_store = &per_store;
    let mut stores: Vec<ChaosStoreOutcome> = std::thread::scope(|s| {
        let flood = s.spawn(move || {
            let mut out = ChaosStoreOutcome {
                flooder: true,
                ..ChaosStoreOutcome::default()
            };
            let mut pending = Vec::new();
            for _ in 0..FLOOD_ROUNDS {
                for &i in &per_store[FLOODER] {
                    out.offered += 1;
                    match eng.submit_async(
                        fixture.requests[i].clone(),
                        Priority::Normal,
                        deadline,
                    ) {
                        Ok(p) => pending.push((i, p)),
                        Err(e) => chaos_tally(&mut out, &Err(e), None),
                    }
                }
            }
            // admitted flood tickets still get real answers eventually
            for (i, p) in pending {
                chaos_tally(
                    &mut out,
                    &p.wait(),
                    Some(&fixture.oracle_answer(&fixture.requests[i])),
                );
            }
            out
        });
        let victims: Vec<_> = (FLOODER + 1..n)
            .map(|si| {
                s.spawn(move || {
                    let mut out = ChaosStoreOutcome::default();
                    for &i in &per_store[si] {
                        out.offered += 1;
                        let req = &fixture.requests[i];
                        chaos_tally(
                            &mut out,
                            &eng.submit(req.clone()),
                            Some(&fixture.oracle_answer(req)),
                        );
                    }
                    out
                })
            })
            .collect();
        let mut all = vec![flood.join().expect("flooder thread panicked")];
        for v in victims {
            all.push(v.join().expect("victim thread panicked"));
        }
        all
    });
    for (si, out) in stores.iter_mut().enumerate() {
        out.name = fixture.stores[si].profile.name.clone();
    }
    let fairness_pass = stores.iter().enumerate().all(|(si, o)| {
        si == FLOODER
            || (o.rejected == 0
                && o.rejected_tenant == 0
                && o.mismatches == 0
                && o.completed * 10 >= o.offered * 9)
    }) && (n == 1 || stores[FLOODER].rejected_tenant > 0);
    if let Some(f) = eng.faults() {
        f.set_probs(0.0, 0.0, 0.0);
    }
    let liveness_pass = liveness_probe(eng, fixture);
    engine.shutdown();
    ChaosReport {
        scenario: ChaosScenario::Flood,
        stores,
        fairness_pass,
        liveness_pass,
        churn: None,
        net: None,
    }
}

/// Deadline storm: every even-indexed request is submitted already past
/// its deadline (zero relative deadline) while the odd-indexed half runs
/// live. Dead-on-arrival tickets must all expire — at pop time or at
/// execute time, either way without consuming kernel work for answers —
/// and every live request must still complete bit-exactly.
fn chaos_deadline(fixture: &Fixture, opts: &BenchOpts) -> ChaosReport {
    let ecfg = opts.engine.clone();
    let engine =
        ServeEngine::start_registry(fixture.registry(&ecfg), ecfg).expect("spawn chaos engine workers");
    let n = fixture.stores.len();
    let mut stores = chaos_outcomes(fixture);
    let (mut storm_by, mut live_by) = (vec![0usize; n], vec![0usize; n]);
    let mut pending_storm = Vec::new();
    for (i, req) in fixture.requests.iter().enumerate() {
        let si = req.store.index();
        stores[si].offered += 1;
        if i % 2 == 0 {
            storm_by[si] += 1;
            match engine.submit_async(req.clone(), Priority::Normal, Duration::ZERO) {
                Ok(p) => pending_storm.push((si, p)),
                Err(e) => chaos_tally(&mut stores[si], &Err(e), None),
            }
        } else {
            live_by[si] += 1;
            chaos_tally(
                &mut stores[si],
                &engine.submit(req.clone()),
                Some(&fixture.oracle_answer(req)),
            );
        }
    }
    for (si, p) in pending_storm {
        chaos_tally(&mut stores[si], &p.wait(), None);
    }
    // casualty-exact: per store, exactly the storm expired and exactly
    // the live half completed, bit-exactly
    let fairness_pass = stores.iter().enumerate().all(|(si, o)| {
        o.expired == storm_by[si]
            && o.completed == live_by[si]
            && o.mismatches == 0
            && o.rejected == 0
            && o.rejected_tenant == 0
    });
    let liveness_pass = liveness_probe(&engine, fixture);
    engine.shutdown();
    ChaosReport {
        scenario: ChaosScenario::DeadlineStorm,
        stores,
        fairness_pass,
        liveness_pass,
        churn: None,
        net: None,
    }
}

/// Panic storm: a seeded fault plan panics workers on ~20% of batches
/// while the whole schedule runs closed-loop. Every request must be
/// answered — bit-exactly or with `Internal`, never hung or wrong — and
/// once the fault is switched off the same engine must serve bit-exactly
/// again. The default panic hook is silenced for the storm (hundreds of
/// injected backtraces would bury the report) and restored after.
fn chaos_panic(fixture: &Fixture, opts: &BenchOpts) -> ChaosReport {
    let mut ecfg = opts.engine.clone();
    ecfg.faults = Some(FaultConfig {
        seed: fixture.cfg.seed ^ 0x9e37_79b9,
        panic_prob: 0.2,
        ..FaultConfig::default()
    });
    let engine =
        ServeEngine::start_registry(fixture.registry(&ecfg), ecfg).expect("spawn chaos engine workers");
    let oracle = fixture.oracle();
    let hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let report = run_closed_loop(&engine, fixture, opts.clients, &oracle);
    engine.faults().expect("chaos engine has a fault plan").set_probs(0.0, 0.0, 0.0);
    let liveness_pass = liveness_probe(&engine, fixture);
    std::panic::set_hook(hook);
    let mut stores = chaos_outcomes(fixture);
    for ((req, outcome), o) in fixture
        .requests
        .iter()
        .zip(&report.outcomes)
        .zip(&oracle)
    {
        let si = req.store.index();
        stores[si].offered += 1;
        chaos_tally(&mut stores[si], outcome, Some(o));
    }
    // every request answered, none wrongly: completions + contained
    // panics account for the whole offered load
    let fairness_pass = stores
        .iter()
        .all(|o| o.mismatches == 0 && o.completed + o.internal == o.offered);
    engine.shutdown();
    ChaosReport {
        scenario: ChaosScenario::PanicStorm,
        stores,
        fairness_pass,
        liveness_pass,
        churn: None,
        net: None,
    }
}

/// Per-epoch oracle ledger shared between the churn driver and the
/// traffic threads. Insert/delete oracles are recorded *before* the new
/// snapshot publishes, so a client that observes a fresh epoch always
/// finds its oracle; created slots are appended *after* registration, so
/// no client targets a store the engine does not know yet; `dropped` is
/// tombstoned *before* the registry drop, so an `UnknownStore` answer
/// can always be classified as legal or not.
struct ChurnLedger {
    /// `(slot index, epoch)` → that snapshot's sequential oracle.
    oracles: HashMap<(usize, u64), Arc<CleanupMemory>>,
    /// Query dimension per issued slot (slots are append-only — ids are
    /// never reused — and a slot's dimension is immutable).
    dims: Vec<usize>,
    /// Registration name per issued slot.
    names: Vec<String>,
    /// Whether the slot was ever dropped (tombstones stay dropped).
    dropped: Vec<bool>,
}

/// Store churn: one serialized mutation driver applies `--churn-ops`
/// live mutations — item inserts (~40%), item deletes (~30%), store
/// creates (~15%), store drops (~15%; store 0 is the anchor tenant and
/// is never dropped) — at `--churn-rate` ops/s through the engine's
/// mutation API, while `clients` traffic threads hammer the same engine
/// with recall queries against every slot ever issued, dropped ones
/// included.
///
/// Each client reads the target's epoch before submitting (`e0`) and
/// after the response (`e1`); snapshot sealing plus epoch monotonicity
/// guarantee the serving epoch lies in `[e0, e1]`, so an `Ok` answer
/// must be bit-exact for at least one ledger oracle in that window —
/// otherwise it is a wrong-epoch answer and the scenario fails. Fairness
/// = zero wrong-epoch answers, zero `UnknownStore` refusals on live
/// stores, zero contained panics, zero refused mutations, strictly
/// monotonic epochs. Liveness = after the churn stops, every surviving
/// store answers a fresh exact-item probe bit-exactly on its final
/// epoch and every dropped store still answers `UnknownStore`.
fn chaos_churn(fixture: &Fixture, opts: &BenchOpts) -> ChaosReport {
    let ecfg = opts.engine.clone();
    let engine = ServeEngine::start_registry(fixture.registry(&ecfg), ecfg)
        .expect("spawn chaos engine workers");
    let n = fixture.stores.len();
    let ledger = Mutex::new(ChurnLedger {
        oracles: fixture
            .stores
            .iter()
            .enumerate()
            .map(|(si, sf)| ((si, 0u64), Arc::new(sf.cleanup.clone())))
            .collect(),
        dims: fixture.stores.iter().map(|sf| sf.profile.dim).collect(),
        names: fixture.stores.iter().map(|sf| sf.profile.name.clone()).collect(),
        dropped: vec![false; n],
    });
    let done = AtomicBool::new(false);
    let epochs_monotonic = AtomicBool::new(true);
    let churn_ops = opts.churn_ops.max(1);
    let op_gap = Duration::from_secs_f64(1.0 / opts.churn_rate.max(1.0));
    let seed = fixture.cfg.seed;
    let eng = &engine;
    let ledger_ref = &ledger;
    let done_ref = &done;
    let mono_ref = &epochs_monotonic;
    let (mut report, outcomes) = std::thread::scope(|s| {
        let driver = s.spawn(move || {
            let mut rng = Rng::new(seed ^ 0x5eed_c0de);
            // the driver's private mirror of every slot's item list —
            // it is the only mutator, so the mirror is authoritative
            let mut items: Vec<Vec<BinaryHV>> = fixture
                .stores
                .iter()
                .map(|sf| sf.codebook.items().to_vec())
                .collect();
            let mut dims: Vec<usize> = fixture.stores.iter().map(|sf| sf.profile.dim).collect();
            let mut live = vec![true; n];
            let mut epochs = vec![0u64; n];
            let mut r = ChurnReport {
                monotonic: true,
                ..ChurnReport::default()
            };
            for _ in 0..churn_ops {
                std::thread::sleep(op_gap);
                let roll = rng.below(100);
                // store 0 is the anchor tenant: never dropped, so the
                // post-churn probe always has a survivor
                let droppable: Vec<usize> = (1..live.len()).filter(|&i| live[i]).collect();
                if roll < 15 && !droppable.is_empty() {
                    // tombstone the ledger first: by the time the engine
                    // can answer UnknownStore, `dropped` is already true
                    let t = droppable[rng.below(droppable.len())];
                    ledger_ref.lock().unwrap().dropped[t] = true;
                    match eng.drop_store(StoreId(t)) {
                        Ok(()) => {
                            live[t] = false;
                            r.drops += 1;
                        }
                        Err(_) => r.op_failures += 1,
                    }
                } else if (15..30).contains(&roll) {
                    // register first, issue the ledger slot after: no
                    // client targets a slot the ledger has not issued
                    let name = format!("churn{}", r.creates);
                    let dim = dims[0];
                    let fresh: Vec<BinaryHV> =
                        (0..16).map(|_| BinaryHV::random(&mut rng, dim)).collect();
                    let codebook = BinaryCodebook::from_items(dim, fresh.clone());
                    let spec = StoreSpec {
                        shards: eng.config().shards,
                        cache_capacity: eng.config().cache_capacity,
                        cache_shards: eng.config().cache_shards,
                        ..StoreSpec::default()
                    };
                    match eng.create_store(&name, &codebook, None, spec) {
                        Ok(id) => {
                            let mut led = ledger_ref.lock().unwrap();
                            debug_assert_eq!(id.index(), led.dims.len());
                            led.oracles
                                .insert((id.index(), 0), Arc::new(CleanupMemory::new(codebook)));
                            led.dims.push(dim);
                            led.names.push(name);
                            led.dropped.push(false);
                            drop(led);
                            items.push(fresh);
                            dims.push(dim);
                            live.push(true);
                            epochs.push(0);
                            r.creates += 1;
                        }
                        Err(_) => r.op_failures += 1,
                    }
                } else {
                    // insert / delete on a live store: the next epoch's
                    // oracle is in the ledger *before* the swap publishes
                    let targets: Vec<usize> = (0..live.len()).filter(|&i| live[i]).collect();
                    let t = targets[rng.below(targets.len())];
                    let id = StoreId(t);
                    let delete = roll >= 70 && items[t].len() > 1;
                    let expected = epochs[t] + 1;
                    let (next, res) = if delete {
                        let idx = rng.below(items[t].len());
                        let mut next = items[t].clone();
                        next.remove(idx);
                        ledger_ref.lock().unwrap().oracles.insert(
                            (t, expected),
                            Arc::new(CleanupMemory::new(BinaryCodebook::from_items(
                                dims[t],
                                next.clone(),
                            ))),
                        );
                        (next, eng.delete_item(id, idx))
                    } else {
                        let item = BinaryHV::random(&mut rng, dims[t]);
                        let mut next = items[t].clone();
                        next.push(item.clone());
                        ledger_ref.lock().unwrap().oracles.insert(
                            (t, expected),
                            Arc::new(CleanupMemory::new(BinaryCodebook::from_items(
                                dims[t],
                                next.clone(),
                            ))),
                        );
                        (next, eng.insert_item(id, item))
                    };
                    match res {
                        Ok(e) => {
                            if e != expected {
                                r.monotonic = false;
                            }
                            epochs[t] = e;
                            items[t] = next;
                            if delete {
                                r.deletes += 1;
                            } else {
                                r.inserts += 1;
                            }
                        }
                        Err(_) => {
                            r.op_failures += 1;
                            ledger_ref.lock().unwrap().oracles.remove(&(t, expected));
                        }
                    }
                }
                r.ops += 1;
            }
            done_ref.store(true, Ordering::SeqCst);
            r
        });
        let traffic: Vec<_> = (0..opts.clients.max(1))
            .map(|ti| {
                s.spawn(move || {
                    let mut rng = Rng::new(seed ^ (0xACCE55 + ti as u64 * 0x9e37));
                    let mut outs: Vec<ChaosStoreOutcome> = Vec::new();
                    let mut last: Vec<Option<BinaryHV>> = Vec::new();
                    let (mut wrong_epoch, mut unknown_ok, mut unknown_bad, mut panics) =
                        (0usize, 0usize, 0usize, 0usize);
                    loop {
                        // read the stop flag *before* the request so the
                        // final iteration still races the last mutations
                        let finishing = done_ref.load(Ordering::SeqCst);
                        let (si, dim) = {
                            let led = ledger_ref.lock().unwrap();
                            let si = rng.below(led.dims.len());
                            (si, led.dims[si])
                        };
                        while outs.len() <= si {
                            outs.push(ChaosStoreOutcome::default());
                            last.push(None);
                        }
                        // a quarter of the traffic repeats its previous
                        // query per store: under mutation those repeats
                        // are exactly what a stale (epoch-less) cache
                        // would answer wrongly
                        let query = match &last[si] {
                            Some(q) if rng.below(4) == 0 => q.clone(),
                            _ => BinaryHV::random(&mut rng, dim),
                        };
                        last[si] = Some(query.clone());
                        let id = StoreId(si);
                        let e0 = eng.store_epoch(id).unwrap_or(0);
                        outs[si].offered += 1;
                        match eng.submit(ServeRequest::recall_on(id, query.clone())) {
                            Ok(ServeResponse::Recall { index, cosine }) => {
                                outs[si].completed += 1;
                                let e1 = eng.store_epoch(id).unwrap_or(e0);
                                if e1 < e0 {
                                    mono_ref.store(false, Ordering::SeqCst);
                                }
                                let e1 = e1.max(e0);
                                let led = ledger_ref.lock().unwrap();
                                let exact = (e0..=e1).any(|e| {
                                    led.oracles.get(&(si, e)).is_some_and(|o| {
                                        let (oi, oc) = o.recall(&query);
                                        oi == index && oc == cosine
                                    })
                                });
                                drop(led);
                                if !exact {
                                    wrong_epoch += 1;
                                    outs[si].mismatches += 1;
                                }
                            }
                            Ok(_) => {
                                // a recall request answered with anything
                                // but a Recall response is garbage
                                outs[si].completed += 1;
                                wrong_epoch += 1;
                                outs[si].mismatches += 1;
                            }
                            Err(ServeError::UnknownStore) => {
                                if ledger_ref.lock().unwrap().dropped[si] {
                                    unknown_ok += 1;
                                } else {
                                    unknown_bad += 1;
                                    outs[si].mismatches += 1;
                                }
                            }
                            Err(ServeError::Overloaded) | Err(ServeError::ShuttingDown) => {
                                outs[si].rejected += 1;
                            }
                            Err(ServeError::TenantOverloaded) => outs[si].rejected_tenant += 1,
                            Err(ServeError::DeadlineExceeded) => outs[si].expired += 1,
                            Err(ServeError::Internal) => {
                                outs[si].internal += 1;
                                panics += 1;
                            }
                            Err(ServeError::Unsupported) | Err(ServeError::InvalidDimension) => {
                                unknown_bad += 1;
                                outs[si].mismatches += 1;
                            }
                        }
                        if finishing {
                            break;
                        }
                    }
                    (outs, wrong_epoch, unknown_ok, unknown_bad, panics)
                })
            })
            .collect();
        let mut r = driver.join().expect("churn driver panicked");
        let mut merged: Vec<ChaosStoreOutcome> = Vec::new();
        for t in traffic {
            let (outs, we, uo, ub, pa) = t.join().expect("churn traffic thread panicked");
            r.wrong_epoch += we;
            r.unknown_ok += uo;
            r.unknown_bad += ub;
            r.panics += pa;
            for (si, o) in outs.into_iter().enumerate() {
                while merged.len() <= si {
                    merged.push(ChaosStoreOutcome::default());
                }
                let m = &mut merged[si];
                m.offered += o.offered;
                m.completed += o.completed;
                m.rejected += o.rejected;
                m.rejected_tenant += o.rejected_tenant;
                m.expired += o.expired;
                m.internal += o.internal;
                m.degraded += o.degraded;
                m.mismatches += o.mismatches;
            }
        }
        (r, merged)
    });
    // post-churn probes on the same (never restarted) engine
    let led = ledger.into_inner().unwrap();
    let mut prng = Rng::new(seed ^ 0x0b5e_55ed);
    let mut probe_pass = true;
    let mut probed = 0usize;
    let mut final_epochs = Vec::with_capacity(led.dims.len());
    let mut stores = outcomes;
    while stores.len() < led.dims.len() {
        stores.push(ChaosStoreOutcome::default());
    }
    for si in 0..led.dims.len() {
        stores[si].name = led.names[si].clone();
        let id = StoreId(si);
        let final_epoch = engine.store_epoch(id).unwrap_or(0);
        final_epochs.push((led.names[si].clone(), final_epoch));
        if led.dropped[si] {
            // a dropped store keeps answering UnknownStore — not garbage
            let q = BinaryHV::random(&mut prng, led.dims[si]);
            probe_pass &= matches!(
                engine.submit(ServeRequest::recall_on(id, q)),
                Err(ServeError::UnknownStore)
            );
            continue;
        }
        probed += 1;
        match led.oracles.get(&(si, final_epoch)) {
            Some(oracle) => {
                let q = oracle.codebook().item(prng.below(oracle.len())).clone();
                let (index, cosine) = oracle.recall(&q);
                probe_pass &= matches!(
                    engine.submit(ServeRequest::recall_on(id, q)),
                    Ok(ServeResponse::Recall { index: i, cosine: c }) if i == index && c == cosine
                );
            }
            // a live store whose final epoch has no recorded oracle means
            // the engine returned an epoch the driver never issued
            None => probe_pass = false,
        }
    }
    report.monotonic = report.monotonic && epochs_monotonic.load(Ordering::SeqCst);
    report.probed = probed;
    report.probe_pass = probe_pass;
    report.final_epochs = final_epochs;
    let fairness_pass = report.wrong_epoch == 0
        && report.unknown_bad == 0
        && report.panics == 0
        && report.op_failures == 0
        && report.monotonic;
    let liveness_pass = probe_pass && probed >= 1;
    engine.shutdown();
    ChaosReport {
        scenario: ChaosScenario::Churn,
        stores,
        fairness_pass,
        liveness_pass,
        churn: Some(report),
        net: None,
    }
}

/// How long the network-chaos attackers hold their sockets waiting for
/// the server's reap verdict before giving up (generous against CI
/// scheduler noise; the reap itself lands within one deadline + poll
/// quantum on an idle host).
const NET_CHAOS_WAIT: Duration = Duration::from_secs(5);

/// Hold a valid header plus a few payload bytes on the wire, then stall
/// until the server reaps the connection as slow-loris (or the wait
/// bound passes). The socket must stay open through the stall: dropping
/// it early would read as a clean EOF, not a stalled writer.
fn attack_slowloris(addr: SocketAddr, server: &NetServer) {
    let Ok(mut s) = TcpStream::connect(addr) else {
        return;
    };
    let mut partial = frame::encode_request(
        1,
        0,
        Priority::Normal,
        &ServeRequest::recall(BinaryHV::zeros(64)),
    );
    partial.truncate(frame::HEADER_LEN + 3);
    if s.write_all(&partial).is_err() {
        return;
    }
    let deadline = Instant::now() + NET_CHAOS_WAIT;
    while server.counters().slowloris_reaped == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Connect, send nothing, and hold the socket silently until the server
/// reaps it as half-open (or the wait bound passes).
fn attack_halfopen(addr: SocketAddr, server: &NetServer) {
    let Ok(_s) = TcpStream::connect(addr) else {
        return;
    };
    let deadline = Instant::now() + NET_CHAOS_WAIT;
    while server.counters().halfopen_reaped == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Repeatedly send a whole request frame (even rounds — the stranded
/// completion's response write must fail harmlessly) or a partial one
/// (odd rounds — stranded bytes, no ticket) and vanish without reading.
fn attack_disconnect(addr: SocketAddr, req: &ServeRequest) {
    for round in 0..12u64 {
        let Ok(mut s) = TcpStream::connect(addr) else {
            continue;
        };
        let bytes = frame::encode_request(round + 1, 0, Priority::Normal, req);
        let cut = if round % 2 == 0 {
            bytes.len()
        } else {
            frame::HEADER_LEN + 5
        };
        let _ = s.write_all(&bytes[..cut]);
        drop(s);
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Speak non-protocol bytes on a few connections; each must be answered
/// with one protocol error frame and closed (the drained read observes
/// the close — the bytes themselves are checked by the frame codec's
/// property tests and the server's own garbage test).
fn attack_garbage(addr: SocketAddr, seed: u64) {
    let mut rng = Rng::new(seed ^ 0x0bad_bead);
    for _ in 0..4 {
        let Ok(mut s) = TcpStream::connect(addr) else {
            continue;
        };
        let mut junk = vec![0u8; 128];
        for b in junk.iter_mut() {
            *b = rng.next_u64() as u8;
        }
        junk[0] = 0xFF; // never the frame magic: refused on the first header
        if s.write_all(&junk).is_err() {
            continue;
        }
        let _ = s.set_read_timeout(Some(NET_CHAOS_WAIT));
        let mut sink = Vec::new();
        let _ = s.read_to_end(&mut sink);
    }
}

/// Network chaos (`slowloris` / `halfopen` / `disconnect` / `garbage`):
/// a fresh engine behind a [`NetServer`] on an ephemeral loopback port
/// with aggressive reap deadlines, one misbehaving peer thread per
/// scenario, and `clients` victim [`NetClient`] threads running the
/// whole fixture schedule concurrently over real sockets.
///
/// Fairness = the victims never noticed: zero transport errors after
/// retries, zero oracle mismatches, `completed + refused + expired ==
/// offered` exactly, and the attacker was caught (reaped within the
/// wait bound, or refused with protocol error frames). Liveness = after
/// the attacker stopped, a *fresh* wire connection got a bit-exact
/// answer from every store with traffic.
fn chaos_net(fixture: &Fixture, opts: &BenchOpts, scenario: ChaosScenario) -> ChaosReport {
    let ecfg = opts.engine.clone();
    let engine = Arc::new(
        ServeEngine::start_registry(fixture.registry(&ecfg), ecfg)
            .expect("spawn chaos engine workers"),
    );
    // aggressive deadlines so the reap happens inside the scenario; the
    // victims are safe from them: whole frames in one write (never a
    // mid-frame stall) and back-to-back calls with in-flight gating on
    // the idle reap (a connection awaiting responses is never half-open)
    let ncfg = NetConfig {
        read_timeout: Duration::from_millis(150),
        idle_timeout: Duration::from_millis(400),
        ..NetConfig::default()
    };
    let server = NetServer::start(Arc::clone(&engine), "127.0.0.1:0", ncfg)
        .expect("bind chaos net server");
    let addr = server.addr();
    let clients = opts.clients.max(1);
    let seed = fixture.cfg.seed;
    let server_ref = &server;
    let (mut stores, net_errors) = std::thread::scope(|s| {
        let attacker = s.spawn(move || match scenario {
            ChaosScenario::Slowloris => attack_slowloris(addr, server_ref),
            ChaosScenario::HalfOpen => attack_halfopen(addr, server_ref),
            ChaosScenario::Disconnect => attack_disconnect(addr, &fixture.requests[0]),
            ChaosScenario::Garbage => attack_garbage(addr, seed),
            _ => unreachable!("chaos_net only handles the network scenarios"),
        });
        let victims: Vec<_> = (0..clients)
            .map(|ti| {
                s.spawn(move || {
                    let mut outs: Vec<ChaosStoreOutcome> =
                        vec![ChaosStoreOutcome::default(); fixture.stores.len()];
                    let mut errs = 0usize;
                    let mut client = match NetClient::connect(addr) {
                        Ok(c) => c,
                        // an unreachable server fails the whole share
                        Err(_) => {
                            errs = fixture.requests.len().div_ceil(clients);
                            return (outs, errs);
                        }
                    };
                    for (i, req) in fixture.requests.iter().enumerate() {
                        if i % clients != ti {
                            continue;
                        }
                        let si = req.store.index();
                        outs[si].offered += 1;
                        match client.call(req) {
                            Ok(outcome) => chaos_tally(
                                &mut outs[si],
                                &outcome,
                                Some(&fixture.oracle_answer(req)),
                            ),
                            Err(_) => errs += 1,
                        }
                    }
                    (outs, errs)
                })
            })
            .collect();
        let mut merged = chaos_outcomes(fixture);
        let mut errs = 0usize;
        for v in victims {
            let (outs, e) = v.join().expect("victim thread panicked");
            errs += e;
            for (si, o) in outs.into_iter().enumerate() {
                let m = &mut merged[si];
                m.offered += o.offered;
                m.completed += o.completed;
                m.rejected += o.rejected;
                m.rejected_tenant += o.rejected_tenant;
                m.expired += o.expired;
                m.internal += o.internal;
                m.degraded += o.degraded;
                m.mismatches += o.mismatches;
            }
        }
        attacker.join().expect("attacker thread panicked");
        (merged, errs)
    });
    for (si, out) in stores.iter_mut().enumerate() {
        out.name = fixture.stores[si].profile.name.clone();
    }
    let counters = server.counters();
    let offered: usize = stores.iter().map(|s| s.offered).sum();
    let completed: usize = stores.iter().map(|s| s.completed).sum();
    let refused: usize = stores.iter().map(|s| s.rejected + s.rejected_tenant).sum();
    let expired: usize = stores.iter().map(|s| s.expired).sum();
    let mismatches: usize = stores.iter().map(|s| s.mismatches).sum();
    // exact accounting: a net error or contained panic is neither
    // completed nor refused nor expired, so either breaks the equation
    let accounting_exact = completed + refused + expired == offered;
    let victim_clean = net_errors == 0 && mismatches == 0;
    let reap_within_deadline = match scenario {
        ChaosScenario::Slowloris => counters.slowloris_reaped >= 1,
        ChaosScenario::HalfOpen => counters.halfopen_reaped >= 1,
        ChaosScenario::Garbage => counters.protocol_errors >= 1,
        _ => true, // disconnect: vanishing is legal, nothing to reap
    };
    // liveness over the wire: a fresh connection, one request per store
    // with traffic, each bit-exact
    let mut probe_pass = match NetClient::connect(addr) {
        Ok(mut probe) => {
            let mut first: Vec<Option<&ServeRequest>> = vec![None; fixture.stores.len()];
            for r in &fixture.requests {
                let si = r.store.index();
                if first[si].is_none() {
                    first[si] = Some(r);
                }
            }
            first.into_iter().flatten().all(|req| {
                matches!(
                    probe.call(req),
                    Ok(Ok(resp)) if resp == fixture.oracle_answer(req)
                )
            })
        }
        Err(_) => false,
    };
    probe_pass &= offered > 0;
    server.shutdown();
    match Arc::try_unwrap(engine) {
        Ok(e) => e.shutdown(),
        Err(_) => {} // a straggler clone's drop aborts the engine
    }
    let net = NetChaosReport {
        offered,
        completed,
        refused,
        expired,
        mismatches,
        net_errors,
        accounting_exact,
        reaped: counters.slowloris_reaped + counters.halfopen_reaped,
        reap_within_deadline,
        protocol_errors: counters.protocol_errors,
        disconnects: counters.disconnects,
        victim_clean,
        probe_pass,
    };
    ChaosReport {
        scenario,
        stores,
        fairness_pass: victim_clean && accounting_exact && reap_within_deadline,
        liveness_pass: probe_pass,
        churn: None,
        net: Some(net),
    }
}

/// One generator pass, summarized for the report.
#[derive(Debug, Clone)]
pub struct PassSummary {
    pub qps: f64,
    pub latency: Option<LatencySummary>,
    pub ok: usize,
    pub rejected: usize,
    pub rejected_tenant: usize,
    pub expired: usize,
    pub internal: usize,
    pub degraded: usize,
    pub mismatches: usize,
}

impl PassSummary {
    fn of(r: &LoadReport) -> PassSummary {
        PassSummary {
            qps: r.qps(),
            latency: r.latency(),
            ok: r.ok,
            rejected: r.rejected,
            rejected_tenant: r.rejected_tenant,
            expired: r.expired,
            internal: r.internal,
            degraded: r.degraded,
            mismatches: r.mismatches,
        }
    }
}

/// The `--wire` socket pass: the closed-loop summary measured through
/// real TCP framing, plus the server's wire counters. The delta between
/// this pass and the in-process closed loop is the front-end's measured
/// overhead (framing, syscalls, loopback RTT).
#[derive(Debug, Clone)]
pub struct WireSummary {
    pub pass: PassSummary,
    /// Calls that failed at the transport after retries — 0 on a clean
    /// run; these requests are *not* in the pass buckets.
    pub net_errors: usize,
    pub counters: NetCounters,
}

/// Closed-loop pass over real sockets: a [`NetServer`] on an ephemeral
/// loopback port, `clients` [`NetClient`] threads splitting the fixture
/// schedule round-robin, every framed response oracle-checked
/// bit-exactly by the same [`LoadReport`] machinery as the in-process
/// passes.
fn run_wire_pass(
    engine: &Arc<ServeEngine>,
    fixture: &Fixture,
    clients: usize,
    oracle: &[ServeResponse],
) -> WireSummary {
    let server = NetServer::start(Arc::clone(engine), "127.0.0.1:0", NetConfig::default())
        .expect("bind wire bench server");
    let addr = server.addr();
    let clients = clients.clamp(1, fixture.requests.len().max(1));
    let t0 = Instant::now();
    let (tagged, net_errors) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|ti| {
                s.spawn(move || {
                    let mut done: Vec<(usize, Result<ServeResponse, ServeError>, f64)> =
                        Vec::new();
                    let mut errs = 0usize;
                    let mut client = match NetClient::connect(addr) {
                        Ok(c) => c,
                        Err(_) => {
                            errs = fixture.requests.len().div_ceil(clients);
                            return (done, errs);
                        }
                    };
                    for (i, req) in fixture.requests.iter().enumerate() {
                        if i % clients != ti {
                            continue;
                        }
                        let t = Instant::now();
                        match client.call(req) {
                            Ok(outcome) => {
                                done.push((i, outcome, t.elapsed().as_secs_f64()));
                            }
                            Err(_) => errs += 1,
                        }
                    }
                    (done, errs)
                })
            })
            .collect();
        let mut tagged = Vec::new();
        let mut errs = 0usize;
        for h in handles {
            let (d, e) = h.join().expect("wire client thread panicked");
            tagged.extend(d);
            errs += e;
        }
        (tagged, errs)
    });
    let wall = t0.elapsed().as_secs_f64();
    let counters = server.counters();
    server.shutdown();
    let report = LoadReport::assemble(wall, tagged, oracle);
    WireSummary {
        pass: PassSummary::of(&report),
        net_errors,
        counters,
    }
}

/// The trace ring's final dump: everything still buffered when the
/// clean passes finished, plus the drop ledger.
#[derive(Debug, Clone)]
pub struct TraceLog {
    /// Ring capacity the engine ran with.
    pub capacity: usize,
    /// Buffered events, oldest first.
    pub events: Vec<TraceEvent>,
    /// Events overwritten by drop-oldest before the dump.
    pub dropped: u64,
}

/// Full serve-bench result.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub opts: BenchOpts,
    pub baseline_qps: f64,
    pub baseline_latency: Option<LatencySummary>,
    pub closed: PassSummary,
    pub open: Option<(f64, PassSummary)>,
    /// The socket pass, when one ran (`--wire`).
    pub wire: Option<WireSummary>,
    pub stats: StatsSnapshot,
    /// Chaos scenario verdict, when one ran (`--chaos`).
    pub chaos: Option<ChaosReport>,
    /// Ring-buffer dump from the clean engine, when `--trace` ran.
    pub trace: Option<TraceLog>,
}

fn lat_json(l: &Option<LatencySummary>) -> String {
    match l {
        Some(s) => format!(
            "{{\"n\": {}, \"mean_s\": {:e}, \"p50_s\": {:e}, \"p99_s\": {:e}, \"max_s\": {:e}}}",
            s.n, s.mean_s, s.p50_s, s.p99_s, s.max_s
        ),
        None => "null".into(),
    }
}

/// One per-class stage-latency decomposition block. The `net_in` /
/// `net_out` lanes cover only wire-borne requests (PR 10): `null` when
/// every request of the class arrived in-process.
fn stage_json(s: &StageSummary) -> String {
    format!(
        "{{\"kind\": \"{}\", \"n\": {}, \"queue\": {}, \"batch\": {}, \"kernel\": {}, \"fill\": {}, \"net_in\": {}, \"net_out\": {}, \"total\": {}, \"stage_mean_sum_s\": {:e}}}",
        s.kind.label(),
        s.n,
        lat_json(&s.queue),
        lat_json(&s.batch),
        lat_json(&s.kernel),
        lat_json(&s.fill),
        lat_json(&s.net_in),
        lat_json(&s.net_out),
        lat_json(&s.total),
        s.stage_mean_sum_s()
    )
}

fn stages_json(stages: &[StageSummary]) -> String {
    let body: Vec<String> = stages.iter().map(stage_json).collect();
    format!("[{}]", body.join(", "))
}

/// Per-store resident-memory block (PR 10): what the live snapshot
/// actually holds — materialized rows or CA-90 seeds, sketch levels,
/// and the master copy. `null` for stores dropped before the snapshot.
fn memory_json(m: &Option<StoreMemory>) -> String {
    match m {
        Some(m) => format!(
            "{{\"backing\": \"{}\", \"row_bytes\": {}, \"sketch_bytes\": {}, \"master_bytes\": {}, \"total_bytes\": {}}}",
            m.backing,
            m.row_bytes,
            m.sketch_bytes,
            m.master_bytes,
            m.total_bytes()
        ),
        None => "null".into(),
    }
}

/// Queue gauges: global depth plus one block per store lane.
fn queue_json(depth: usize, lanes: &[LaneGauge]) -> String {
    let body: Vec<String> = lanes
        .iter()
        .map(|l| {
            format!(
                "{{\"store\": {}, \"len\": {}, \"high\": {}, \"deficit\": {}, \"weight\": {}, \"quota\": {}}}",
                l.store.index(),
                l.len,
                l.high,
                l.deficit,
                l.weight,
                l.quota
            )
        })
        .collect();
    format!("{{\"depth\": {}, \"lanes\": [{}]}}", depth, body.join(", "))
}

fn roofline_point_json(p: &RooflinePoint) -> String {
    format!(
        "{{\"intensity\": {:e}, \"attained_flops\": {:e}, \"memory_bound\": {}}}",
        p.intensity, p.attained_flops, p.memory_bound
    )
}

/// One request class's roofline block: the raw measured counters, the
/// live placement ([`roofline::place_measured`]), and the analytical
/// placement of the same op shape ([`roofline::place`]) on the same
/// host roofline. Classes with no kernel calls carry `null` verdicts.
fn class_roofline_json(kind: RequestKind, w: &KernelWork, host: &Platform) -> String {
    let (workload, op) = match kind {
        RequestKind::Recall => ("serve:recall", "cleanup_scan"),
        RequestKind::RecallTopK => ("serve:recall_topk", "cleanup_scan_topk"),
        RequestKind::Factorize => ("serve:factorize", "resonator_iters"),
    };
    let (measured, modelled) = if w.calls == 0 {
        ("null".to_string(), "null".to_string())
    } else {
        let m = roofline::place_measured(
            workload,
            PhaseKind::Symbolic,
            w.flops,
            w.bytes(),
            w.elapsed_s,
            host,
        );
        let tr = Trace::single(
            workload,
            op,
            OpCategory::VectorElem,
            PhaseKind::Symbolic,
            w.flops,
            w.bytes_read,
            w.bytes_written,
        );
        let a = roofline::place(&tr, PhaseKind::Symbolic, host);
        (roofline_point_json(&m), roofline_point_json(&a))
    };
    format!(
        "{{\"kind\": \"{}\", \"calls\": {}, \"kernel_elapsed_s\": {:e}, \"flops\": {}, \"bytes_read\": {}, \"bytes_written\": {}, \"intensity\": {:e}, \"measured\": {}, \"modelled\": {}}}",
        kind.label(),
        w.calls,
        w.elapsed_s,
        w.flops,
        w.bytes_read,
        w.bytes_written,
        w.intensity(),
        measured,
        modelled
    )
}

fn roofline_json(work: &[KernelWork; 3], host: &Platform) -> String {
    let body: Vec<String> = RequestKind::ALL
        .iter()
        .map(|&k| class_roofline_json(k, &work[k.index()], host))
        .collect();
    format!("[{}]", body.join(", "))
}

impl BenchReport {
    /// QPS speedup of batched-sharded closed-loop serving over the
    /// unbatched single-thread baseline.
    pub fn speedup_qps(&self) -> f64 {
        if self.baseline_qps > 0.0 {
            self.closed.qps / self.baseline_qps
        } else {
            0.0
        }
    }

    /// Render the result table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(&["pass", "qps", "p50", "p99", "ok", "rej", "exp", "mismatch"]);
        let fmt_lat = |l: &Option<LatencySummary>, f: fn(&LatencySummary) -> f64| {
            l.as_ref()
                .map(|s| crate::util::stats::fmt_time(f(s)))
                .unwrap_or_else(|| "-".into())
        };
        t.row(&[
            "baseline (seq)".into(),
            format!("{:.0}", self.baseline_qps),
            fmt_lat(&self.baseline_latency, |s| s.p50_s),
            fmt_lat(&self.baseline_latency, |s| s.p99_s),
            format!("{}", self.opts.fixture.requests),
            "0".into(),
            "0".into(),
            "0".into(),
        ]);
        let mut pass_row = |name: String, p: &PassSummary| {
            t.row(&[
                name,
                format!("{:.0}", p.qps),
                fmt_lat(&p.latency, |s| s.p50_s),
                fmt_lat(&p.latency, |s| s.p99_s),
                format!("{}", p.ok),
                format!("{}", p.rejected),
                format!("{}", p.expired),
                format!("{}", p.mismatches),
            ]);
        };
        pass_row("closed-loop".into(), &self.closed);
        if let Some((rate, p)) = &self.open {
            pass_row(format!("open-loop @{rate:.0}qps"), p);
        }
        if let Some(w) = &self.wire {
            pass_row("wire (tcp)".into(), &w.pass);
        }
        t
    }

    /// Machine-readable JSON (hand-rolled like `BENCH_hotpath.json`).
    pub fn to_json(&self) -> String {
        let lat = lat_json;
        let pass = |p: &PassSummary| {
            format!(
                "{{\"qps\": {:.3}, \"latency\": {}, \"ok\": {}, \"rejected\": {}, \"rejected_tenant\": {}, \"expired\": {}, \"internal\": {}, \"degraded\": {}, \"mismatches\": {}}}",
                p.qps,
                lat(&p.latency),
                p.ok,
                p.rejected,
                p.rejected_tenant,
                p.expired,
                p.internal,
                p.degraded,
                p.mismatches
            )
        };
        let prune_json = |p: &crate::vsa::PruneStats| {
            format!(
                "{{\"items\": {}, \"coarse_rejected\": {}, \"sketch_rejected\": {}, \"early_terminated\": {}, \"words_streamed\": {}, \"words_total\": {}, \"coarse_reject_rate\": {:.4}, \"sketch_reject_rate\": {:.4}, \"words_frac\": {:.4}}}",
                p.items,
                p.coarse_rejected,
                p.sketch_rejected,
                p.early_terminated,
                p.words_streamed,
                p.words_total,
                p.coarse_reject_rate(),
                p.sketch_reject_rate(),
                p.words_frac()
            )
        };
        let cache_json = |c: &Option<super::cache::CacheCounters>| match c {
            Some(c) => format!(
                "{{\"hits\": {}, \"misses\": {}, \"inserts\": {}, \"evictions\": {}, \"entries\": {}, \"hit_rate\": {:.4}}}",
                c.hits,
                c.misses,
                c.inserts,
                c.evictions,
                c.entries,
                c.hit_rate()
            ),
            None => "null".into(),
        };
        let shards_json = |shards: &[super::stats::ShardStat]| {
            let mut s = String::from("[");
            for (i, sh) in shards.iter().enumerate() {
                if i > 0 {
                    s.push_str(", ");
                }
                s.push_str(&format!(
                    "{{\"scans\": {}, \"busy_s\": {:e}}}",
                    sh.scans, sh.busy_s
                ));
            }
            s.push(']');
            s
        };
        let f = &self.opts.fixture;
        let e = &self.opts.engine;
        let base = &f.stores[0];
        let simd_tier = crate::vsa::kernels::active_tier().name();
        let mut out = String::from("{\n  \"bench\": \"serve\",\n");
        // which kernel code path produced these numbers (PERF.md
        // attribution): the process-wide SIMD dispatch tier
        out.push_str(&format!("  \"simd\": \"{simd_tier}\",\n"));
        out.push_str(&format!("  \"store_count\": {},\n", f.stores.len()));
        // legacy single-store config fields report store 0 (the hottest
        // tenant); the per-store truth is in the "stores" array below
        out.push_str(&format!(
            "  \"config\": {{\"requests\": {}, \"clients\": {}, \"workers\": {}, \"shards\": {}, \"scan_threads\": {}, \"max_batch\": {}, \"max_delay_us\": {}, \"queue_capacity\": {}, \"items\": {}, \"dim\": {}, \"mix\": \"{}:{}:{}\", \"repeat_frac\": {:.3}, \"sketch_bits\": {}, \"backing\": \"{}\", \"sketch_cascade\": {}, \"cache_capacity\": {}, \"cache_shards\": {}, \"stores\": {}, \"seed\": {}}},\n",
            f.requests,
            self.opts.clients,
            e.workers,
            e.shards,
            e.scan_threads,
            e.max_batch,
            e.max_delay.as_micros(),
            e.queue_capacity,
            base.items,
            base.dim,
            f.mix.recall,
            f.mix.topk,
            f.mix.factorize,
            base.repeat_frac,
            match e.sketch_bits {
                Some(b) => b.to_string(),
                None => "null".into(),
            },
            base.backing.name(),
            match base.sketch_cascade {
                Some(b) => b.to_string(),
                None => "null".into(),
            },
            e.cache_capacity,
            e.cache_shards,
            f.stores.len(),
            f.seed
        ));
        out.push_str(&format!(
            "  \"baseline\": {{\"qps\": {:.3}, \"latency\": {}}},\n",
            self.baseline_qps,
            lat(&self.baseline_latency)
        ));
        out.push_str(&format!("  \"closed_loop\": {},\n", pass(&self.closed)));
        match &self.open {
            Some((rate, p)) => out.push_str(&format!(
                "  \"open_loop\": {{\"offered_qps\": {:.3}, \"pass\": {}}},\n",
                rate,
                pass(p)
            )),
            None => out.push_str("  \"open_loop\": null,\n"),
        }
        // the socket pass (PR 9) — null unless --wire ran
        match &self.wire {
            Some(w) => out.push_str(&format!(
                "  \"wire\": {{\"pass\": {}, \"net_errors\": {}, \"counters\": {{\"accepted\": {}, \"frames_in\": {}, \"frames_out\": {}, \"bytes_in\": {}, \"bytes_out\": {}, \"protocol_errors\": {}, \"refused\": {}, \"slowloris_reaped\": {}, \"halfopen_reaped\": {}, \"disconnects\": {}}}}},\n",
                pass(&w.pass),
                w.net_errors,
                w.counters.accepted,
                w.counters.frames_in,
                w.counters.frames_out,
                w.counters.bytes_in,
                w.counters.bytes_out,
                w.counters.protocol_errors,
                w.counters.refused,
                w.counters.slowloris_reaped,
                w.counters.halfopen_reaped,
                w.counters.disconnects
            )),
            None => out.push_str("  \"wire\": null,\n"),
        }
        out.push_str(&format!("  \"speedup_qps\": {:.3},\n", self.speedup_qps()));
        out.push_str(&format!(
            "  \"batching\": {{\"batches\": {}, \"mean_batch\": {:.3}, \"max_batch\": {}}},\n",
            self.stats.batches, self.stats.mean_batch, self.stats.max_batch
        ));
        // engine-wide aggregates (concatenated shards, merged prune,
        // summed cache) — kept for single-store consumers
        out.push_str(&format!("  \"shards\": {},\n", shards_json(&self.stats.shards)));
        out.push_str(&format!("  \"prune\": {},\n", prune_json(&self.stats.prune)));
        out.push_str(&format!("  \"cache\": {},\n", cache_json(&self.stats.cache)));
        // engine-wide per-class stage-latency decomposition (PR 7):
        // p99 = queue-wait + batch-wait + kernel + fill, first-class
        out.push_str(&format!("  \"stages\": {},\n", stages_json(&self.stats.stages)));
        // end-of-run queue gauges: global depth + per-lane DRR state
        out.push_str(&format!(
            "  \"queue\": {},\n",
            queue_json(self.stats.queue_depth, &self.stats.lanes)
        ));
        // chaos verdict (separate engine; see module docs) — null unless
        // --chaos ran
        let churn_json = |c: &Option<ChurnReport>| match c {
            Some(c) => {
                let finals: Vec<String> = c
                    .final_epochs
                    .iter()
                    .map(|(name, e)| format!("{{\"name\": \"{name}\", \"epoch\": {e}}}"))
                    .collect();
                format!(
                    "{{\"ops\": {}, \"inserts\": {}, \"deletes\": {}, \"creates\": {}, \"drops\": {}, \"op_failures\": {}, \"wrong_epoch\": {}, \"unknown_ok\": {}, \"unknown_bad\": {}, \"panics\": {}, \"monotonic\": {}, \"probed\": {}, \"probe_pass\": {}, \"final_epochs\": [{}]}}",
                    c.ops,
                    c.inserts,
                    c.deletes,
                    c.creates,
                    c.drops,
                    c.op_failures,
                    c.wrong_epoch,
                    c.unknown_ok,
                    c.unknown_bad,
                    c.panics,
                    c.monotonic,
                    c.probed,
                    c.probe_pass,
                    finals.join(", ")
                )
            }
            None => "null".into(),
        };
        let net_json = |n: &Option<NetChaosReport>| match n {
            Some(n) => format!(
                "{{\"offered\": {}, \"completed\": {}, \"refused\": {}, \"expired\": {}, \"mismatches\": {}, \"net_errors\": {}, \"accounting_exact\": {}, \"reaped\": {}, \"reap_within_deadline\": {}, \"protocol_errors\": {}, \"disconnects\": {}, \"victim_clean\": {}, \"probe_pass\": {}}}",
                n.offered,
                n.completed,
                n.refused,
                n.expired,
                n.mismatches,
                n.net_errors,
                n.accounting_exact,
                n.reaped,
                n.reap_within_deadline,
                n.protocol_errors,
                n.disconnects,
                n.victim_clean,
                n.probe_pass
            ),
            None => "null".into(),
        };
        match &self.chaos {
            Some(c) => {
                out.push_str(&format!(
                    "  \"chaos\": {{\"scenario\": \"{}\", \"fairness_pass\": {}, \"liveness_pass\": {}, \"churn\": {}, \"net\": {}, \"stores\": [",
                    c.scenario.name(),
                    c.fairness_pass,
                    c.liveness_pass,
                    churn_json(&c.churn),
                    net_json(&c.net)
                ));
                for (i, o) in c.stores.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    out.push_str(&format!(
                        "{{\"name\": \"{}\", \"flooder\": {}, \"offered\": {}, \"completed\": {}, \"rejected\": {}, \"rejected_tenant\": {}, \"expired\": {}, \"internal\": {}, \"degraded\": {}, \"mismatches\": {}}}",
                        o.name,
                        o.flooder,
                        o.offered,
                        o.completed,
                        o.rejected,
                        o.rejected_tenant,
                        o.expired,
                        o.internal,
                        o.degraded,
                        o.mismatches
                    ));
                }
                out.push_str("]},\n");
            }
            None => out.push_str("  \"chaos\": null,\n"),
        }
        // per-store blocks: each carries the simd tier + store count so
        // multi-store runs stay attributable next to the PR 4
        // simd_speedups gate
        out.push_str("  \"stores\": [\n");
        for (i, section) in self.stats.stores.iter().enumerate() {
            let profile = f.stores.get(i);
            out.push_str(&format!(
                "    {{\"id\": {}, \"name\": \"{}\", \"epoch\": {}, \"live\": {}, \"simd\": \"{simd_tier}\", \"store_count\": {}, \"dim\": {}, \"items\": {}, \"weight\": {}, \"repeat_frac\": {:.3}, \"sketch_bits\": {}, \"sketch_cascade\": {}, \"backing\": \"{}\", \"memory\": {}, \"quota\": {}, \"completed\": {}, \"rejected_tenant\": {}, \"expired_dropped\": {}, \"degraded\": {}, \"internal\": {}, \"latency\": {}, \"shards\": {}, \"prune\": {}, \"cache\": {}}}{}\n",
                section.id.index(),
                section.name,
                section.epoch,
                section.live,
                f.stores.len(),
                profile.map_or(0, |p| p.dim),
                profile.map_or(0, |p| p.items),
                profile.map_or(0, |p| p.weight),
                profile.map_or(0.0, |p| p.repeat_frac),
                profile
                    .and_then(|p| p.sketch_bits)
                    .map_or("null".into(), |b| b.to_string()),
                profile
                    .and_then(|p| p.sketch_cascade)
                    .map_or("null".into(), |b| b.to_string()),
                // backing as the live snapshot reports it; fall back to
                // the profile for stores dropped before the snapshot
                section
                    .memory
                    .map(|m| m.backing)
                    .unwrap_or_else(|| profile.map_or("ram", |p| p.backing.name())),
                memory_json(&section.memory),
                profile
                    .and_then(|p| p.quota)
                    .map_or("null".into(), |q| q.to_string()),
                section.completed,
                section.rejected_tenant,
                section.expired_dropped,
                section.degraded,
                section.internal,
                lat(&section.latency),
                shards_json(&section.shards),
                prune_json(&section.prune),
                cache_json(&section.cache),
                if i + 1 < self.stats.stores.len() { "," } else { "" },
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the serve bench JSON. Precedence: explicit `--json` flag
    /// (`opts.json_path`), then the `NSCOG_SERVE_JSON` environment
    /// variable, then `BENCH_serve.json`.
    pub fn write_json(&self) -> std::io::Result<String> {
        let path = self.opts.json_path.clone().unwrap_or_else(|| {
            std::env::var("NSCOG_SERVE_JSON").unwrap_or_else(|_| "BENCH_serve.json".into())
        });
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }

    /// `BENCH_serve_trace.json`: ring dump, per-class stage-latency
    /// decompositions (engine-wide and per store), queue gauges, and the
    /// measured-roofline placement of each request class against the
    /// calibrated host platform. `None` unless the run traced.
    pub fn trace_json(&self) -> Option<String> {
        let log = self.trace.as_ref()?;
        let host = Platform::host();
        let f = &self.opts.fixture;
        let simd_tier = crate::vsa::kernels::active_tier().name();
        let mut out = String::from("{\n  \"bench\": \"serve_trace\",\n");
        out.push_str(&format!("  \"simd\": \"{simd_tier}\",\n"));
        out.push_str(&format!("  \"store_count\": {},\n", f.stores.len()));
        out.push_str(&format!("  \"requests\": {},\n", f.requests));
        out.push_str(&format!(
            "  \"ring\": {{\"capacity\": {}, \"events_recorded\": {}, \"events_dropped\": {}}},\n",
            log.capacity,
            log.events.len(),
            log.dropped
        ));
        out.push_str(&format!(
            "  \"platform\": {{\"name\": \"{}\", \"peak_flops\": {:e}, \"dram_bw\": {:e}, \"ridge_intensity\": {:e}}},\n",
            host.name,
            host.peak_flops,
            host.dram_bw,
            roofline::ridge_intensity(&host)
        ));
        out.push_str(&format!("  \"stages\": {},\n", stages_json(&self.stats.stages)));
        out.push_str(&format!(
            "  \"roofline\": {},\n",
            roofline_json(&self.stats.kernel_work, &host)
        ));
        out.push_str(&format!(
            "  \"queue\": {},\n",
            queue_json(self.stats.queue_depth, &self.stats.lanes)
        ));
        out.push_str("  \"stores\": [\n");
        for (i, section) in self.stats.stores.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"id\": {}, \"name\": \"{}\", \"stages\": {}, \"roofline\": {}}}{}\n",
                section.id.index(),
                section.name,
                stages_json(&section.stages),
                roofline_json(&section.kernel_work, &host),
                if i + 1 < self.stats.stores.len() { "," } else { "" },
            ));
        }
        out.push_str("  ],\n  \"events\": [\n");
        for (i, ev) in log.events.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"seq\": {}, \"store\": {}, \"epoch\": {}, \"kind\": \"{}\", \"queue_s\": {:e}, \"batch_s\": {:e}, \"kernel_s\": {:e}, \"fill_s\": {:e}, \"total_s\": {:e}, \"degraded\": {}, \"cache_hit\": {}}}{}\n",
                ev.seq,
                ev.store.index(),
                ev.epoch,
                ev.kind.label(),
                ev.stages.queue_s,
                ev.stages.batch_s,
                ev.stages.kernel_s,
                ev.stages.fill_s,
                ev.total_s,
                ev.degraded,
                ev.cache_hit,
                if i + 1 < log.events.len() { "," } else { "" },
            ));
        }
        out.push_str("  ]\n}\n");
        Some(out)
    }

    /// Write the trace JSON, if this run traced. Precedence: explicit
    /// `--trace-json` flag, then `NSCOG_SERVE_TRACE_JSON`, then
    /// `BENCH_serve_trace.json`. Returns the written path.
    pub fn write_trace_json(&self) -> std::io::Result<Option<String>> {
        let Some(json) = self.trace_json() else {
            return Ok(None);
        };
        let path = self.opts.trace_json_path.clone().unwrap_or_else(|| {
            std::env::var("NSCOG_SERVE_TRACE_JSON")
                .unwrap_or_else(|_| "BENCH_serve_trace.json".into())
        });
        std::fs::write(&path, json)?;
        Ok(Some(path))
    }
}

/// Run the full serve benchmark: baseline, closed loop, optional open
/// loop; every engine response verified against its store's sequential
/// oracle.
pub fn run_bench(opts: BenchOpts) -> BenchReport {
    let fixture = Fixture::build(opts.fixture.clone());
    // the timed baseline pass doubles as the oracle for both generators
    let (oracle, base_lat, base_wall) = fixture.baseline_run();
    let baseline_qps = if base_wall > 0.0 {
        fixture.requests.len() as f64 / base_wall
    } else {
        0.0
    };
    let mut ecfg = opts.engine.clone();
    if opts.trace {
        ecfg.trace_capacity = Some(opts.trace_capacity.max(1));
    }
    let engine = ServeEngine::start_registry(fixture.registry(&ecfg), ecfg)
        .expect("spawn serve workers");
    let closed = run_closed_loop(&engine, &fixture, opts.clients, &oracle);
    let open = opts.open_loop_qps.map(|rate| {
        (
            rate,
            PassSummary::of(&run_open_loop(&engine, &fixture, rate, opts.clients, &oracle)),
        )
    });
    // the socket pass runs on the same engine, after the in-process
    // passes, so the wire-vs-in-process delta is apples-to-apples
    let engine = Arc::new(engine);
    let wire = opts
        .wire
        .then(|| run_wire_pass(&engine, &fixture, opts.clients, &oracle));
    let stats = engine.stats();
    let trace = engine.trace_snapshot().map(|(events, dropped)| TraceLog {
        capacity: engine.trace_capacity().unwrap_or(0),
        events,
        dropped,
    });
    match Arc::try_unwrap(engine) {
        Ok(e) => e.shutdown(),
        Err(_) => {} // a straggler clone's drop aborts the engine
    }
    // chaos runs last, on its own engine, so the clean numbers above are
    // already banked when the failure injection starts
    let chaos = opts.chaos.map(|sc| run_chaos(&fixture, &opts, sc));
    BenchReport {
        baseline_qps,
        baseline_latency: LatencySummary::of(&base_lat),
        closed: PassSummary::of(&closed),
        open,
        wire,
        stats,
        chaos,
        trace,
        opts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_profile() -> StoreProfile {
        StoreProfile {
            name: "default".into(),
            items: 24,
            dim: 512,
            topk_k: 3,
            fact_factors: 3,
            fact_items: 6,
            fact_dim: 256,
            fact_iters: 20,
            weight: 1,
            repeat_frac: 0.0,
            sketch_bits: None,
            quota: None,
            backing: StoreBacking::Ram,
            sketch_cascade: None,
        }
    }

    fn tiny_fixture() -> FixtureConfig {
        FixtureConfig {
            stores: vec![tiny_profile()],
            noise_frac: 0.2,
            requests: 60,
            mix: LoadMix {
                recall: 4,
                topk: 1,
                factorize: 1,
            },
            seed: 7,
        }
    }

    #[test]
    fn fixture_is_deterministic_and_mixed() {
        let a = Fixture::build(tiny_fixture());
        let b = Fixture::build(tiny_fixture());
        assert_eq!(a.requests, b.requests);
        assert_eq!(a.requests.len(), 60);
        let kinds: std::collections::BTreeSet<&'static str> =
            a.requests.iter().map(|r| r.kind().label()).collect();
        assert_eq!(kinds.len(), 3, "all three classes present: {kinds:?}");
    }

    #[test]
    fn closed_loop_matches_oracle_bit_exactly() {
        let fixture = Fixture::build(tiny_fixture());
        let cfg = EngineConfig {
            workers: 2,
            shards: 3,
            max_batch: 8,
            max_delay: Duration::from_millis(1),
            ..EngineConfig::default()
        };
        let engine = ServeEngine::start_registry(fixture.registry(&cfg), cfg).expect("spawn serve workers");
        let report = run_closed_loop(&engine, &fixture, 6, &fixture.oracle());
        assert_eq!(report.ok, 60);
        assert_eq!(report.rejected, 0);
        assert_eq!(report.expired, 0);
        assert_eq!(report.mismatches, 0, "batched responses diverged from oracle");
        engine.shutdown();
    }

    #[test]
    fn ca90_backed_store_with_cascade_matches_oracle_bit_exactly() {
        // the full serve path on a seeds-only store with the two-level
        // sketch cascade enabled: answers stay bit-exact against the
        // sequential oracle, and the stats snapshot shows the compressed
        // row footprint (seeds, not materialized rows)
        let mut cfg = tiny_fixture();
        cfg.stores[0].dim = 1024;
        cfg.stores[0].backing = StoreBacking::Ca90;
        cfg.stores[0].sketch_bits = Some(256);
        cfg.stores[0].sketch_cascade = Some(128);
        let fixture = Fixture::build(cfg);
        assert!(fixture.stores[0].codebook.is_ca90());
        let ecfg = EngineConfig {
            workers: 2,
            shards: 2,
            max_batch: 8,
            max_delay: Duration::from_millis(1),
            ..EngineConfig::default()
        };
        let engine = ServeEngine::start_registry(fixture.registry(&ecfg), ecfg)
            .expect("spawn serve workers");
        let report = run_closed_loop(&engine, &fixture, 4, &fixture.oracle());
        assert_eq!(report.ok, 60);
        assert_eq!(report.mismatches, 0, "ca90 + cascade diverged from oracle");
        let stats = engine.stats();
        let mem = stats.stores[0].memory.expect("live store reports memory");
        assert_eq!(mem.backing, "ca90");
        // 24 seeds × 64 B each, vs 24 × 128 B materialized rows
        assert!(
            mem.row_bytes < 24 * 1024 / 8,
            "seeds-only rows not compressed: {} bytes",
            mem.row_bytes
        );
        assert!(mem.sketch_bytes > 0, "cascade sketch levels resident");
        assert!(stats.stores[0].prune.items > 0, "sketched scans ran");
        engine.shutdown();
    }

    #[test]
    fn open_loop_paces_and_completes() {
        let fixture = Fixture::build(FixtureConfig {
            requests: 40,
            ..tiny_fixture()
        });
        let cfg = EngineConfig::default();
        let engine = ServeEngine::start_registry(fixture.registry(&cfg), cfg).expect("spawn serve workers");
        // high rate so the test stays fast; still a schedule, not a loop
        let report = run_open_loop(&engine, &fixture, 4000.0, 4, &fixture.oracle());
        assert_eq!(report.ok + report.rejected + report.expired, 40);
        assert_eq!(report.mismatches, 0);
        assert!(report.wall_s >= 40.0 / 4000.0 * 0.5);
        engine.shutdown();
    }

    #[test]
    fn multi_store_mix_is_skewed_and_every_store_matches_its_oracle() {
        // three stores with different dims and popularity weights: the
        // schedule must cover all of them, skew toward store 0, and the
        // engine must answer every request from the right store
        let mut cfg = tiny_fixture();
        cfg.requests = 120;
        cfg.stores = vec![
            StoreProfile {
                name: "s0".into(),
                weight: 4,
                ..tiny_profile()
            },
            StoreProfile {
                name: "s1".into(),
                dim: 1024,
                items: 40,
                topk_k: 5,
                weight: 2,
                ..tiny_profile()
            },
            StoreProfile {
                name: "s2".into(),
                dim: 2048,
                items: 16,
                weight: 1,
                ..tiny_profile()
            },
        ];
        let a = Fixture::build(cfg.clone());
        let b = Fixture::build(cfg);
        let counts = |f: &Fixture| {
            let mut c = vec![0usize; f.stores.len()];
            for r in &f.requests {
                c[r.store.index()] += 1;
            }
            c
        };
        assert_eq!(a.requests, b.requests, "multi-store schedule stays deterministic");
        let c = counts(&a);
        assert!(c.iter().all(|&n| n > 0), "every store receives traffic: {c:?}");
        assert!(c[0] > c[2], "weight-4 store must out-draw weight-1: {c:?}");

        let ecfg = EngineConfig {
            workers: 3,
            shards: 2,
            max_batch: 8,
            max_delay: Duration::from_millis(1),
            ..EngineConfig::default()
        };
        let engine = ServeEngine::start_registry(a.registry(&ecfg), ecfg).expect("spawn serve workers");
        let report = run_closed_loop(&engine, &a, 6, &a.oracle());
        assert_eq!(report.ok, 120);
        assert_eq!(
            report.mismatches, 0,
            "every response must match its own store's oracle"
        );
        let snap = engine.stats();
        assert_eq!(snap.stores.len(), 3);
        let completed: Vec<u64> = snap.stores.iter().map(|s| s.completed).collect();
        assert_eq!(completed.iter().sum::<u64>(), 120);
        assert_eq!(
            completed,
            c.iter().map(|&n| n as u64).collect::<Vec<_>>(),
            "per-store completion counts must match the schedule"
        );
        engine.shutdown();
    }

    #[test]
    fn bench_report_json_is_well_formed() {
        let mut opts = BenchOpts::smoke();
        opts.fixture.requests = 60;
        opts.fixture.stores[0].dim = 512;
        opts.fixture.stores[0].items = 24;
        opts.with_stores(2);
        opts.clients = 4;
        let report = run_bench(opts);
        assert_eq!(report.closed.mismatches, 0);
        let json = report.to_json();
        let parsed = crate::util::json::Json::parse(&json).expect("invalid JSON emitted");
        assert_eq!(
            parsed.get("bench").and_then(|b| b.as_str()),
            Some("serve")
        );
        assert_eq!(
            parsed.get("simd").and_then(|s| s.as_str()),
            Some(crate::vsa::kernels::active_tier().name()),
            "serve JSON must attribute its numbers to the dispatch tier"
        );
        assert!(parsed.get("closed_loop").is_some());
        assert!(parsed.get("speedup_qps").is_some());
        assert!(parsed.get("prune").is_some());
        assert!(parsed.get("cache").is_some());
        assert_eq!(
            parsed.get("store_count").and_then(|n| n.as_f64()),
            Some(2.0)
        );
        let stores = parsed
            .get("stores")
            .and_then(|s| s.as_arr())
            .expect("per-store blocks present");
        assert_eq!(stores.len(), 2);
        for block in stores {
            assert_eq!(
                block.get("simd").and_then(|s| s.as_str()),
                Some(crate::vsa::kernels::active_tier().name()),
                "each per-store block carries the simd tier"
            );
            assert_eq!(
                block.get("store_count").and_then(|n| n.as_f64()),
                Some(2.0),
                "each per-store block carries the store count"
            );
            assert!(block.get("prune").is_some());
            assert!(block.get("completed").is_some());
            for key in ["rejected_tenant", "expired_dropped", "degraded", "internal"] {
                assert_eq!(
                    block.get(key).and_then(|n| n.as_f64()),
                    Some(0.0),
                    "clean pass must report a zero {key} counter per store"
                );
            }
            assert!(
                block.get("quota").is_some(),
                "per-store block must surface the admission quota (null when unset)"
            );
        }
        // no chaos requested: the key must still be present, and null
        let chaos = parsed.get("chaos").expect("chaos key always emitted");
        assert!(chaos.as_arr().is_none() && chaos.as_f64().is_none() && chaos.as_str().is_none());
        // no --wire: same contract, key present and null
        let wire = parsed.get("wire").expect("wire key always emitted");
        assert!(wire.as_arr().is_none() && wire.as_f64().is_none() && wire.as_str().is_none());
        // stage decomposition and end-of-run queue gauges (PR 7)
        let stage_blocks = parsed
            .get("stages")
            .and_then(|s| s.as_arr())
            .expect("per-class stage decomposition present");
        assert_eq!(stage_blocks.len(), 3, "one stage block per request class");
        let queue = parsed.get("queue").expect("queue gauges present");
        assert_eq!(
            queue.get("depth").and_then(|d| d.as_f64()),
            Some(0.0),
            "queue drained by end of a clean run"
        );
        assert_eq!(
            queue.get("lanes").and_then(|l| l.as_arr()).map(|l| l.len()),
            Some(2),
            "one lane gauge per registered store"
        );
        // untraced run: no ring dump and no trace JSON
        assert!(report.trace.is_none() && report.trace_json().is_none());
        // table renders without panicking
        let _ = report.table().to_string();
    }

    #[test]
    fn traced_bench_emits_parseable_trace_json_with_exact_drop_ledger() {
        let mut opts = BenchOpts::smoke();
        opts.fixture.requests = 60;
        opts.fixture.stores[0].dim = 512;
        opts.fixture.stores[0].items = 24;
        opts.clients = 4;
        opts.trace = true;
        opts.trace_capacity = 32; // < requests: the ring must wrap
        let report = run_bench(opts);
        assert_eq!(report.closed.mismatches, 0);
        let log = report.trace.as_ref().expect("--trace run keeps the ring dump");
        assert_eq!(log.capacity, 32);
        assert_eq!(log.events.len(), 32, "wrapped ring holds exactly its capacity");
        assert_eq!(
            log.events.len() + log.dropped as usize,
            report.closed.ok,
            "every completed response traced once; overflow drops counted exactly"
        );
        let seqs: Vec<u64> = log.events.iter().map(|e| e.seq).collect();
        assert!(
            seqs.windows(2).all(|w| w[0] < w[1]),
            "dump is oldest-first after drop-oldest: {seqs:?}"
        );
        for ev in &log.events {
            let s = &ev.stages;
            assert!(
                s.queue_s >= 0.0 && s.batch_s >= 0.0 && s.kernel_s >= 0.0 && s.fill_s >= 0.0,
                "stage spans are non-negative: {s:?}"
            );
            assert!(
                s.sum() <= ev.total_s + 1e-9,
                "stage decomposition exceeds e2e latency: {s:?} vs {}",
                ev.total_s
            );
        }
        let json = report.trace_json().expect("trace JSON emitted");
        let parsed = crate::util::json::Json::parse(&json).expect("invalid trace JSON emitted");
        assert_eq!(
            parsed.get("bench").and_then(|b| b.as_str()),
            Some("serve_trace")
        );
        let ring = parsed.get("ring").expect("ring ledger present");
        assert_eq!(
            ring.get("events_dropped").and_then(|d| d.as_f64()),
            Some(log.dropped as f64)
        );
        assert_eq!(
            parsed.get("events").and_then(|e| e.as_arr()).map(|e| e.len()),
            Some(32)
        );
        // roofline bridge: recall dominates the smoke mix, so its class
        // must carry a live memory-/compute-bound verdict
        let roofline = parsed
            .get("roofline")
            .and_then(|r| r.as_arr())
            .expect("roofline blocks");
        assert_eq!(roofline.len(), 3, "one roofline block per request class");
        let recall = roofline
            .iter()
            .find(|b| b.get("kind").and_then(|k| k.as_str()) == Some("recall"))
            .expect("recall roofline block");
        assert!(
            recall.get("calls").and_then(|c| c.as_f64()) > Some(0.0),
            "recall class saw kernel calls"
        );
        let verdict = recall
            .get("measured")
            .and_then(|m| m.get("memory_bound"))
            .expect("trafficked class carries a measured bound verdict");
        // binary cleanup scans stream 3 ops per 8 bytes: far left of any
        // CPU ridge, so the live verdict must say memory-bound
        assert_eq!(verdict, &crate::util::json::Json::Bool(true));
        // per class: sum of stage means reconciles with the e2e mean
        for st in parsed.get("stages").and_then(|s| s.as_arr()).unwrap() {
            let n = st.get("n").and_then(|n| n.as_f64()).unwrap();
            if n == 0.0 {
                continue;
            }
            let sum = st.get("stage_mean_sum_s").and_then(|x| x.as_f64()).unwrap();
            let total = st
                .get("total")
                .and_then(|t| t.get("mean_s"))
                .and_then(|x| x.as_f64())
                .unwrap();
            assert!(
                sum <= total * 1.01 + 1e-9,
                "stage means over-attribute: {sum} > {total}"
            );
        }
        // per-store blocks mirror the engine-wide shape
        let stores = parsed.get("stores").and_then(|s| s.as_arr()).unwrap();
        assert_eq!(stores.len(), 1);
        assert!(stores[0].get("stages").and_then(|s| s.as_arr()).is_some());
        assert!(stores[0].get("roofline").and_then(|r| r.as_arr()).is_some());
    }

    fn chaos_fixture(stores: usize) -> BenchOpts {
        let mut opts = BenchOpts::smoke();
        opts.fixture.requests = 90;
        opts.with_stores(stores);
        for p in &mut opts.fixture.stores {
            p.dim = 512;
            p.items = 24;
            p.fact_dim = 256;
            p.fact_items = 6;
            p.fact_iters = 20;
            p.repeat_frac = 0.0;
        }
        opts.clients = 4;
        opts
    }

    #[test]
    fn chaos_flood_keeps_victims_whole() {
        let opts = chaos_fixture(3);
        let fixture = Fixture::build(opts.fixture.clone());
        let report = run_chaos(&fixture, &opts, ChaosScenario::Flood);
        assert_eq!(report.scenario.name(), "flood");
        assert!(
            report.fairness_pass,
            "flooded tenant must not damage its neighbours: {:?}",
            report.stores
        );
        assert!(report.liveness_pass, "engine must answer exactly after the flood");
        assert!(report.stores[0].flooder);
        assert!(
            report.stores[0].rejected_tenant > 0,
            "the flooder's own lane quota must bite: {:?}",
            report.stores[0]
        );
        for s in &report.stores[1..] {
            assert!(!s.flooder);
            assert_eq!(s.rejected_tenant, 0, "victim hit a tenant quota: {s:?}");
            assert_eq!(s.mismatches, 0);
        }
    }

    #[test]
    fn chaos_deadline_storm_expires_exactly_the_storm_half() {
        let opts = chaos_fixture(2);
        let fixture = Fixture::build(opts.fixture.clone());
        let report = run_chaos(&fixture, &opts, ChaosScenario::DeadlineStorm);
        assert_eq!(report.scenario.name(), "deadline");
        assert!(
            report.fairness_pass,
            "already-dead requests must expire without hurting live ones: {:?}",
            report.stores
        );
        assert!(report.liveness_pass);
        let expired: usize = report.stores.iter().map(|s| s.expired).sum();
        assert!(expired > 0, "the storm half must actually expire");
    }

    #[test]
    fn chaos_churn_verifies_every_answer_against_its_epoch_window() {
        let mut opts = chaos_fixture(2);
        opts.clients = 4;
        opts.churn_ops = 30;
        opts.churn_rate = 600.0;
        let fixture = Fixture::build(opts.fixture.clone());
        let report = run_chaos(&fixture, &opts, ChaosScenario::Churn);
        assert_eq!(report.scenario.name(), "churn");
        let churn = report.churn.as_ref().expect("churn scenario carries its ledger");
        assert_eq!(churn.ops, 30);
        assert_eq!(
            churn.inserts + churn.deletes + churn.creates + churn.drops + churn.op_failures,
            churn.ops,
            "every op accounted: {churn:?}"
        );
        assert_eq!(churn.op_failures, 0, "driver-issued mutations never refused");
        assert_eq!(churn.wrong_epoch, 0, "answer outside its seal window: {churn:?}");
        assert_eq!(churn.unknown_bad, 0, "live store answered UnknownStore: {churn:?}");
        assert_eq!(churn.panics, 0, "mutation raced a worker into a panic");
        assert!(churn.monotonic, "epochs must grow strictly monotonically");
        assert!(churn.probed >= 1, "anchor store survives and is probed");
        assert!(churn.probe_pass, "post-churn probe must be bit-exact: {churn:?}");
        assert!(report.fairness_pass && report.liveness_pass);
        assert_eq!(report.stores.len(), churn.final_epochs.len());
        // the anchor tenant keeps its name and was mutated at least once
        // in expectation (30 ops over ≤ a handful of stores); don't
        // assert per-op distribution, only that mutation really happened
        assert!(
            churn.inserts + churn.deletes > 0,
            "churn must actually mutate items: {churn:?}"
        );
        let traffic: usize = report.stores.iter().map(|s| s.offered).sum();
        assert!(traffic > 0, "traffic threads must have raced the churn");
    }

    #[test]
    fn wire_pass_serves_the_whole_schedule_bit_exactly_over_sockets() {
        let mut opts = BenchOpts::smoke();
        opts.fixture.requests = 60;
        opts.fixture.stores[0].dim = 512;
        opts.fixture.stores[0].items = 24;
        opts.with_stores(2);
        opts.clients = 4;
        opts.wire = true;
        let report = run_bench(opts);
        let w = report.wire.as_ref().expect("--wire run keeps the socket pass");
        assert_eq!(w.net_errors, 0, "clean loopback run must not drop calls");
        assert_eq!(w.pass.ok, 60);
        assert_eq!(w.pass.mismatches, 0, "socket responses diverged from oracle");
        assert_eq!(w.pass.rejected + w.pass.rejected_tenant + w.pass.expired, 0);
        // one connection per client thread; retries may reconnect, so >=
        assert!(w.counters.accepted >= 4, "{:?}", w.counters);
        assert!(w.counters.frames_in >= 60 && w.counters.frames_out >= 60);
        assert_eq!(w.counters.protocol_errors, 0);
        let json = report.to_json();
        let parsed = crate::util::json::Json::parse(&json).expect("invalid JSON emitted");
        let wire = parsed.get("wire").expect("wire block emitted");
        assert_eq!(wire.get("net_errors").and_then(|n| n.as_f64()), Some(0.0));
        assert_eq!(
            wire.get("pass").and_then(|p| p.get("ok")).and_then(|n| n.as_f64()),
            Some(60.0)
        );
        assert!(
            wire.get("counters")
                .and_then(|c| c.get("frames_in"))
                .and_then(|n| n.as_f64())
                >= Some(60.0)
        );
    }

    #[test]
    fn chaos_garbage_answers_protocol_errors_and_keeps_victims_bit_exact() {
        let opts = chaos_fixture(2);
        let fixture = Fixture::build(opts.fixture.clone());
        let report = run_chaos(&fixture, &opts, ChaosScenario::Garbage);
        assert_eq!(report.scenario.name(), "garbage");
        let net = report.net.as_ref().expect("network scenario carries its wire ledger");
        assert!(
            net.protocol_errors >= 1,
            "garbage must draw protocol error frames: {net:?}"
        );
        assert!(net.victim_clean, "victims noticed the attacker: {net:?}");
        assert!(net.accounting_exact, "{net:?}");
        assert_eq!(net.completed + net.refused + net.expired, net.offered);
        assert!(report.fairness_pass && report.liveness_pass, "{net:?}");
        assert!(report.churn.is_none());
    }

    #[test]
    fn chaos_slowloris_reaps_the_staller_and_victims_keep_serving() {
        let opts = chaos_fixture(2);
        let fixture = Fixture::build(opts.fixture.clone());
        let report = run_chaos(&fixture, &opts, ChaosScenario::Slowloris);
        assert_eq!(report.scenario.name(), "slowloris");
        let net = report.net.as_ref().expect("network scenario carries its wire ledger");
        assert!(
            net.reaped >= 1 && net.reap_within_deadline,
            "stalled writer must be reaped: {net:?}"
        );
        assert_eq!(net.mismatches, 0, "victims must stay bit-exact: {net:?}");
        assert_eq!(net.net_errors, 0, "the stall must never reach other connections: {net:?}");
        assert!(report.fairness_pass && report.liveness_pass, "{net:?}");
    }

    #[test]
    fn with_stores_expands_with_skewed_weights_and_alternating_dims() {
        let mut opts = BenchOpts::smoke();
        opts.with_stores(3);
        let s = &opts.fixture.stores;
        assert_eq!(s.len(), 3);
        assert_eq!(
            s.iter().map(|p| p.name.as_str()).collect::<Vec<_>>(),
            ["s0", "s1", "s2"]
        );
        assert_eq!(s[0].weight, 4);
        assert_eq!(s[1].weight, 2);
        assert_eq!(s[2].weight, 1);
        assert_eq!(s[0].dim, 2048);
        assert_eq!(s[1].dim, 4096, "odd stores double the base dim");
        assert_eq!(s[2].dim, 2048);
        assert!(s.iter().all(|p| (p.repeat_frac - 0.25).abs() < 1e-12));
    }

    #[test]
    fn repeated_mix_is_deterministic_and_cache_serves_it_exactly() {
        // dim 2048: rows are several bound chunks long, so the serve
        // scans actually prune (512-bit rows are a single chunk)
        let mut cfg = tiny_fixture();
        cfg.requests = 80;
        cfg.stores[0].dim = 2048;
        cfg.stores[0].repeat_frac = 0.5;
        let a = Fixture::build(cfg.clone());
        let b = Fixture::build(cfg);
        assert_eq!(a.requests, b.requests, "repeats must stay deterministic");
        // repeats exist: at least one exact duplicate request
        let dup = a
            .requests
            .iter()
            .enumerate()
            .any(|(i, r)| a.requests[..i].contains(r));
        assert!(dup, "repeat_frac=0.5 over 80 requests must produce repeats");
        let ecfg = EngineConfig {
            workers: 2,
            shards: 3,
            ..EngineConfig::default()
        };
        let engine = ServeEngine::start_registry(a.registry(&ecfg), ecfg).expect("spawn serve workers");
        let report = run_closed_loop(&engine, &a, 6, &a.oracle());
        assert_eq!(report.ok, 80);
        assert_eq!(report.mismatches, 0, "cached responses diverged from oracle");
        let snap = engine.stats();
        let cache = snap.cache.expect("default engine cache enabled");
        assert!(cache.hits > 0, "repeated mix must produce cache hits");
        assert!(
            snap.prune.words_streamed < snap.prune.words_total,
            "noisy-member serve scans must prune: {:?}",
            snap.prune
        );
        engine.shutdown();
    }
}
