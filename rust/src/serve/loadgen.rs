//! Synthetic load generation and the `nscog serve-bench` report.
//!
//! A [`Fixture`] deterministically generates an NVSA-style request mix —
//! noisy cleanup recalls, top-k recalls, and resonator factorizations —
//! plus the sequential unbatched oracle every engine response is checked
//! against. Two generator shapes drive the engine:
//!
//! - **closed loop**: `clients` threads submit back-to-back (each new
//!   request waits for the previous response) — measures saturated
//!   throughput and is what forms large micro-batches;
//! - **open loop**: arrivals follow a fixed-rate schedule regardless of
//!   completions (the production-realistic shape) — measures latency
//!   under a target offered load, including queueing delay.
//!
//! `run_bench` compares both against the unbatched single-thread baseline
//! and emits `BENCH_serve.json` (path override: `NSCOG_SERVE_JSON`).

use super::engine::{EngineConfig, ServeEngine};
use super::queue::Priority;
use super::stats::{LatencySummary, StatsSnapshot};
use super::{ServeError, ServeRequest, ServeResponse};
use crate::util::bench::Table;
use crate::util::Rng;
use crate::vsa::{BinaryCodebook, CleanupMemory, RealCodebook, Resonator};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// Relative request-class weights.
#[derive(Debug, Clone, Copy)]
pub struct LoadMix {
    pub recall: u32,
    pub topk: u32,
    pub factorize: u32,
}

impl LoadMix {
    fn total(&self) -> u32 {
        self.recall + self.topk + self.factorize
    }
}

/// Fixture sizing (problem shapes + request schedule).
#[derive(Debug, Clone)]
pub struct FixtureConfig {
    /// Cleanup-memory items / hypervector dimension.
    pub items: usize,
    pub dim: usize,
    /// Fraction of bits flipped on recall queries.
    pub noise_frac: f64,
    /// `k` for top-k recall requests.
    pub topk_k: usize,
    /// Resonator shape: factors × items-per-factor × dimension, max iters.
    pub fact_factors: usize,
    pub fact_items: usize,
    pub fact_dim: usize,
    pub fact_iters: usize,
    /// Total requests and their class mix.
    pub requests: usize,
    pub mix: LoadMix,
    /// Fraction of requests that repeat an earlier cacheable request
    /// verbatim (production recall traffic repeats; this is what the
    /// response cache monetizes). 0 disables repeats.
    pub repeat_frac: f64,
    pub seed: u64,
}

/// Deterministic workload: stores, request schedule, and oracle inputs.
pub struct Fixture {
    pub codebook: BinaryCodebook,
    pub cleanup: CleanupMemory,
    pub resonator: Resonator,
    pub requests: Vec<ServeRequest>,
    pub cfg: FixtureConfig,
}

impl Fixture {
    /// Build stores and a request schedule, all derived from `cfg.seed`.
    pub fn build(cfg: FixtureConfig) -> Fixture {
        assert!(cfg.mix.total() > 0, "empty request mix");
        let mut rng = Rng::new(cfg.seed);
        let codebook = BinaryCodebook::random(&mut rng, cfg.items, cfg.dim);
        let resonator = Resonator::new(
            (0..cfg.fact_factors)
                .map(|_| RealCodebook::random_bipolar(&mut rng, cfg.fact_items, cfg.fact_dim))
                .collect(),
            cfg.fact_iters,
        );
        let flips = (cfg.dim as f64 * cfg.noise_frac) as usize;
        let repeat_threshold = (cfg.repeat_frac.clamp(0.0, 1.0) * 1e6) as usize;
        let mut requests: Vec<ServeRequest> = Vec::with_capacity(cfg.requests);
        // indices of earlier cacheable (recall / top-k) requests
        let mut repeatable: Vec<usize> = Vec::new();
        for _ in 0..cfg.requests {
            if repeat_threshold > 0
                && !repeatable.is_empty()
                && rng.below(1_000_000) < repeat_threshold
            {
                let src = repeatable[rng.below(repeatable.len())];
                let repeat = requests[src].clone();
                repeatable.push(requests.len());
                requests.push(repeat);
                continue;
            }
            let roll = rng.below(cfg.mix.total() as usize) as u32;
            if roll < cfg.mix.recall + cfg.mix.topk {
                repeatable.push(requests.len());
                let mut query = codebook.item(rng.below(cfg.items)).clone();
                for i in rng.sample_indices(cfg.dim, flips) {
                    query.set(i, !query.get(i));
                }
                if roll < cfg.mix.recall {
                    requests.push(ServeRequest::Recall { query });
                } else {
                    requests.push(ServeRequest::RecallTopK {
                        query,
                        k: cfg.topk_k,
                    });
                }
            } else {
                let truth: Vec<usize> = (0..cfg.fact_factors)
                    .map(|_| rng.below(cfg.fact_items))
                    .collect();
                requests.push(ServeRequest::Factorize {
                    scene: resonator.compose(&truth),
                });
            }
        }
        Fixture {
            cleanup: CleanupMemory::new(codebook.clone()),
            codebook,
            resonator,
            requests,
            cfg,
        }
    }

    /// Answer one request with the sequential, unbatched, unsharded
    /// kernels — the correctness oracle and the baseline's inner loop.
    pub fn oracle_answer(&self, req: &ServeRequest) -> ServeResponse {
        match req {
            ServeRequest::Recall { query } => {
                let (index, cosine) = self.cleanup.recall(query);
                ServeResponse::Recall { index, cosine }
            }
            ServeRequest::RecallTopK { query, k } => ServeResponse::RecallTopK {
                hits: self.cleanup.recall_topk(query, *k),
            },
            ServeRequest::Factorize { scene } => {
                let r = self.resonator.factorize(scene);
                ServeResponse::Factorize {
                    indices: r.indices,
                    iterations: r.iterations,
                    converged: r.converged,
                }
            }
        }
    }

    /// Sequential oracle for the whole schedule (untimed convenience).
    pub fn oracle(&self) -> Vec<ServeResponse> {
        self.requests.iter().map(|r| self.oracle_answer(r)).collect()
    }

    /// Run the whole schedule sequentially (the unbatched single-thread
    /// baseline): responses, per-request latencies, and wall time.
    pub fn baseline_run(&self) -> (Vec<ServeResponse>, Vec<f64>, f64) {
        let t0 = Instant::now();
        let mut responses = Vec::with_capacity(self.requests.len());
        let mut latencies = Vec::with_capacity(self.requests.len());
        for req in &self.requests {
            let s = Instant::now();
            responses.push(self.oracle_answer(req));
            latencies.push(s.elapsed().as_secs_f64());
        }
        (responses, latencies, t0.elapsed().as_secs_f64())
    }
}

/// Outcome of one generator run against an engine.
#[derive(Debug)]
pub struct LoadReport {
    pub wall_s: f64,
    /// Per-request end-to-end latency (seconds), request order.
    pub latencies_s: Vec<f64>,
    pub outcomes: Vec<Result<ServeResponse, ServeError>>,
    pub ok: usize,
    pub rejected: usize,
    pub expired: usize,
    /// Ok responses that differ from the sequential oracle (must be 0).
    pub mismatches: usize,
}

impl LoadReport {
    fn assemble(
        wall_s: f64,
        mut tagged: Vec<(usize, Result<ServeResponse, ServeError>, f64)>,
        oracle: &[ServeResponse],
    ) -> LoadReport {
        tagged.sort_by_key(|&(i, _, _)| i);
        let mut latencies_s = Vec::with_capacity(tagged.len());
        let mut outcomes = Vec::with_capacity(tagged.len());
        let (mut ok, mut rejected, mut expired, mut mismatches) = (0, 0, 0, 0);
        for (i, outcome, lat) in tagged {
            match &outcome {
                Ok(resp) => {
                    ok += 1;
                    if resp != &oracle[i] {
                        mismatches += 1;
                    }
                }
                Err(ServeError::Overloaded) | Err(ServeError::ShuttingDown) => rejected += 1,
                Err(ServeError::DeadlineExceeded) => expired += 1,
                // the fixture never generates these, so either means the
                // engine under test is misconfigured — flag it
                Err(ServeError::Unsupported) | Err(ServeError::InvalidDimension) => {
                    mismatches += 1
                }
            }
            latencies_s.push(lat);
            outcomes.push(outcome);
        }
        LoadReport {
            wall_s,
            latencies_s,
            outcomes,
            ok,
            rejected,
            expired,
            mismatches,
        }
    }

    /// Completed-request throughput.
    pub fn qps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.ok as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Latency summary over successful requests only.
    pub fn latency(&self) -> Option<LatencySummary> {
        let ok_lats: Vec<f64> = self
            .outcomes
            .iter()
            .zip(&self.latencies_s)
            .filter(|(o, _)| o.is_ok())
            .map(|(_, &l)| l)
            .collect();
        LatencySummary::of(&ok_lats)
    }
}

/// Closed loop: `clients` threads each submit their share of the schedule
/// back-to-back. Request `i` goes to client `i % clients`, preserving a
/// deterministic assignment. `oracle` is the per-request expected
/// response set ([`Fixture::oracle`] / `baseline_run`) — precomputed by
/// the caller so one oracle pass can serve several generator runs.
pub fn run_closed_loop(
    engine: &ServeEngine,
    fixture: &Fixture,
    clients: usize,
    oracle: &[ServeResponse],
) -> LoadReport {
    let requests = &fixture.requests;
    assert_eq!(oracle.len(), requests.len());
    let clients = clients.clamp(1, requests.len().max(1));
    let t0 = Instant::now();
    let tagged: Vec<(usize, Result<ServeResponse, ServeError>, f64)> =
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..clients)
                .map(|c| {
                    s.spawn(move || {
                        let mut out = Vec::new();
                        for (i, req) in requests.iter().enumerate() {
                            if i % clients != c {
                                continue;
                            }
                            let start = Instant::now();
                            let outcome = engine.submit(req.clone());
                            out.push((i, outcome, start.elapsed().as_secs_f64()));
                        }
                        out
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("load client panicked"))
                .collect()
        });
    LoadReport::assemble(t0.elapsed().as_secs_f64(), tagged, oracle)
}

/// Open loop: arrivals paced at `rate_qps` from a shared schedule,
/// dispatched non-blocking by `senders` threads; responses are harvested
/// after dispatch, so slow completions never stall later arrivals.
/// Latency is measured enqueue → worker-fill (queueing included).
/// `oracle` as in [`run_closed_loop`].
pub fn run_open_loop(
    engine: &ServeEngine,
    fixture: &Fixture,
    rate_qps: f64,
    senders: usize,
    oracle: &[ServeResponse],
) -> LoadReport {
    assert!(rate_qps > 0.0);
    let requests = &fixture.requests;
    assert_eq!(oracle.len(), requests.len());
    let senders = senders.clamp(1, requests.len().max(1));
    let interval = Duration::from_secs_f64(1.0 / rate_qps);
    let next = AtomicUsize::new(0);
    // small lead so every sender thread is running before arrival 0
    let epoch = Instant::now() + Duration::from_millis(10);
    let deadline = engine.config().default_deadline;
    let t0 = Instant::now();
    let tagged: Vec<(usize, Result<ServeResponse, ServeError>, f64)> =
        std::thread::scope(|s| {
            let next = &next;
            let handles: Vec<_> = (0..senders)
                .map(|_| {
                    s.spawn(move || {
                        let mut pending = Vec::new();
                        let mut done = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= requests.len() {
                                break;
                            }
                            let scheduled = epoch + interval.mul_f64(i as f64);
                            let now = Instant::now();
                            if scheduled > now {
                                std::thread::sleep(scheduled - now);
                            }
                            match engine.submit_async(
                                requests[i].clone(),
                                Priority::Normal,
                                deadline,
                            ) {
                                Ok(p) => pending.push((i, p)),
                                Err(e) => done.push((i, Err(e), 0.0)),
                            }
                        }
                        for (i, p) in pending {
                            let (outcome, lat) = p.wait_with_latency();
                            done.push((i, outcome, lat.as_secs_f64()));
                        }
                        done
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("load sender panicked"))
                .collect()
        });
    LoadReport::assemble(t0.elapsed().as_secs_f64(), tagged, oracle)
}

/// Everything `nscog serve-bench` needs for one run.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub fixture: FixtureConfig,
    pub engine: EngineConfig,
    /// Closed-loop client threads.
    pub clients: usize,
    /// Open-loop offered rate; `None` skips the open-loop pass.
    pub open_loop_qps: Option<f64>,
    pub json_path: Option<String>,
}

impl BenchOpts {
    /// CI smoke shape: bounded requests, deterministic seed, small enough
    /// to finish in a few seconds even unoptimized.
    pub fn smoke() -> BenchOpts {
        BenchOpts {
            fixture: FixtureConfig {
                items: 96,
                dim: 2048,
                noise_frac: 0.2,
                topk_k: 3,
                fact_factors: 3,
                fact_items: 8,
                fact_dim: 512,
                fact_iters: 30,
                requests: 400,
                mix: LoadMix {
                    recall: 6,
                    topk: 1,
                    factorize: 1,
                },
                repeat_frac: 0.25,
                seed: 2024,
            },
            engine: EngineConfig {
                workers: 2,
                shards: 4,
                scan_threads: 1,
                max_batch: 16,
                max_delay: Duration::from_micros(300),
                queue_capacity: 512,
                default_deadline: Duration::from_secs(30),
                ..EngineConfig::default()
            },
            clients: 8,
            open_loop_qps: None,
            json_path: None,
        }
    }

    /// Default standalone-bench shape: paper-scale cleanup memory
    /// (120×8192, the Tab. VII REACT/MULT store) and more load.
    pub fn standard() -> BenchOpts {
        BenchOpts {
            fixture: FixtureConfig {
                items: 120,
                dim: 8192,
                noise_frac: 0.2,
                topk_k: 5,
                fact_factors: 3,
                fact_items: 10,
                fact_dim: 1024,
                fact_iters: 60,
                requests: 2000,
                mix: LoadMix {
                    recall: 6,
                    topk: 1,
                    factorize: 1,
                },
                repeat_frac: 0.25,
                seed: 2024,
            },
            engine: EngineConfig::default(),
            clients: 16,
            open_loop_qps: None,
            json_path: None,
        }
    }
}

/// One generator pass, summarized for the report.
#[derive(Debug, Clone)]
pub struct PassSummary {
    pub qps: f64,
    pub latency: Option<LatencySummary>,
    pub ok: usize,
    pub rejected: usize,
    pub expired: usize,
    pub mismatches: usize,
}

impl PassSummary {
    fn of(r: &LoadReport) -> PassSummary {
        PassSummary {
            qps: r.qps(),
            latency: r.latency(),
            ok: r.ok,
            rejected: r.rejected,
            expired: r.expired,
            mismatches: r.mismatches,
        }
    }
}

/// Full serve-bench result.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub opts: BenchOpts,
    pub baseline_qps: f64,
    pub baseline_latency: Option<LatencySummary>,
    pub closed: PassSummary,
    pub open: Option<(f64, PassSummary)>,
    pub stats: StatsSnapshot,
}

impl BenchReport {
    /// QPS speedup of batched-sharded closed-loop serving over the
    /// unbatched single-thread baseline.
    pub fn speedup_qps(&self) -> f64 {
        if self.baseline_qps > 0.0 {
            self.closed.qps / self.baseline_qps
        } else {
            0.0
        }
    }

    /// Render the result table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(&["pass", "qps", "p50", "p99", "ok", "rej", "exp", "mismatch"]);
        let fmt_lat = |l: &Option<LatencySummary>, f: fn(&LatencySummary) -> f64| {
            l.as_ref()
                .map(|s| crate::util::stats::fmt_time(f(s)))
                .unwrap_or_else(|| "-".into())
        };
        t.row(&[
            "baseline (seq)".into(),
            format!("{:.0}", self.baseline_qps),
            fmt_lat(&self.baseline_latency, |s| s.p50_s),
            fmt_lat(&self.baseline_latency, |s| s.p99_s),
            format!("{}", self.opts.fixture.requests),
            "0".into(),
            "0".into(),
            "0".into(),
        ]);
        let mut pass_row = |name: String, p: &PassSummary| {
            t.row(&[
                name,
                format!("{:.0}", p.qps),
                fmt_lat(&p.latency, |s| s.p50_s),
                fmt_lat(&p.latency, |s| s.p99_s),
                format!("{}", p.ok),
                format!("{}", p.rejected),
                format!("{}", p.expired),
                format!("{}", p.mismatches),
            ]);
        };
        pass_row("closed-loop".into(), &self.closed);
        if let Some((rate, p)) = &self.open {
            pass_row(format!("open-loop @{rate:.0}qps"), p);
        }
        t
    }

    /// Machine-readable JSON (hand-rolled like `BENCH_hotpath.json`).
    pub fn to_json(&self) -> String {
        let lat = |l: &Option<LatencySummary>| match l {
            Some(s) => format!(
                "{{\"n\": {}, \"mean_s\": {:e}, \"p50_s\": {:e}, \"p99_s\": {:e}, \"max_s\": {:e}}}",
                s.n, s.mean_s, s.p50_s, s.p99_s, s.max_s
            ),
            None => "null".into(),
        };
        let pass = |p: &PassSummary| {
            format!(
                "{{\"qps\": {:.3}, \"latency\": {}, \"ok\": {}, \"rejected\": {}, \"expired\": {}, \"mismatches\": {}}}",
                p.qps,
                lat(&p.latency),
                p.ok,
                p.rejected,
                p.expired,
                p.mismatches
            )
        };
        let f = &self.opts.fixture;
        let e = &self.opts.engine;
        let mut out = String::from("{\n  \"bench\": \"serve\",\n");
        // which kernel code path produced these numbers (PERF.md
        // attribution): the process-wide SIMD dispatch tier
        out.push_str(&format!(
            "  \"simd\": \"{}\",\n",
            crate::vsa::kernels::active_tier().name()
        ));
        out.push_str(&format!(
            "  \"config\": {{\"requests\": {}, \"clients\": {}, \"workers\": {}, \"shards\": {}, \"scan_threads\": {}, \"max_batch\": {}, \"max_delay_us\": {}, \"queue_capacity\": {}, \"items\": {}, \"dim\": {}, \"mix\": \"{}:{}:{}\", \"repeat_frac\": {:.3}, \"sketch_bits\": {}, \"cache_capacity\": {}, \"cache_shards\": {}, \"seed\": {}}},\n",
            f.requests,
            self.opts.clients,
            e.workers,
            e.shards,
            e.scan_threads,
            e.max_batch,
            e.max_delay.as_micros(),
            e.queue_capacity,
            f.items,
            f.dim,
            f.mix.recall,
            f.mix.topk,
            f.mix.factorize,
            f.repeat_frac,
            match e.sketch_bits {
                Some(b) => b.to_string(),
                None => "null".into(),
            },
            e.cache_capacity,
            e.cache_shards,
            f.seed
        ));
        out.push_str(&format!(
            "  \"baseline\": {{\"qps\": {:.3}, \"latency\": {}}},\n",
            self.baseline_qps,
            lat(&self.baseline_latency)
        ));
        out.push_str(&format!("  \"closed_loop\": {},\n", pass(&self.closed)));
        match &self.open {
            Some((rate, p)) => out.push_str(&format!(
                "  \"open_loop\": {{\"offered_qps\": {:.3}, \"pass\": {}}},\n",
                rate,
                pass(p)
            )),
            None => out.push_str("  \"open_loop\": null,\n"),
        }
        out.push_str(&format!("  \"speedup_qps\": {:.3},\n", self.speedup_qps()));
        out.push_str(&format!(
            "  \"batching\": {{\"batches\": {}, \"mean_batch\": {:.3}, \"max_batch\": {}}},\n",
            self.stats.batches, self.stats.mean_batch, self.stats.max_batch
        ));
        out.push_str("  \"shards\": [");
        for (i, sh) in self.stats.shards.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!(
                "{{\"scans\": {}, \"busy_s\": {:e}}}",
                sh.scans, sh.busy_s
            ));
        }
        out.push_str("],\n");
        let p = &self.stats.prune;
        out.push_str(&format!(
            "  \"prune\": {{\"items\": {}, \"sketch_rejected\": {}, \"early_terminated\": {}, \"words_streamed\": {}, \"words_total\": {}, \"sketch_reject_rate\": {:.4}, \"words_frac\": {:.4}}},\n",
            p.items,
            p.sketch_rejected,
            p.early_terminated,
            p.words_streamed,
            p.words_total,
            p.sketch_reject_rate(),
            p.words_frac()
        ));
        match &self.stats.cache {
            Some(c) => out.push_str(&format!(
                "  \"cache\": {{\"hits\": {}, \"misses\": {}, \"inserts\": {}, \"evictions\": {}, \"entries\": {}, \"hit_rate\": {:.4}}}\n",
                c.hits,
                c.misses,
                c.inserts,
                c.evictions,
                c.entries,
                c.hit_rate()
            )),
            None => out.push_str("  \"cache\": null\n"),
        }
        out.push_str("}\n");
        out
    }

    /// Write the serve bench JSON. Precedence: explicit `--json` flag
    /// (`opts.json_path`), then the `NSCOG_SERVE_JSON` environment
    /// variable, then `BENCH_serve.json`.
    pub fn write_json(&self) -> std::io::Result<String> {
        let path = self.opts.json_path.clone().unwrap_or_else(|| {
            std::env::var("NSCOG_SERVE_JSON").unwrap_or_else(|_| "BENCH_serve.json".into())
        });
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }
}

/// Run the full serve benchmark: baseline, closed loop, optional open
/// loop; every engine response verified against the sequential oracle.
pub fn run_bench(opts: BenchOpts) -> BenchReport {
    let fixture = Fixture::build(opts.fixture.clone());
    // the timed baseline pass doubles as the oracle for both generators
    let (oracle, base_lat, base_wall) = fixture.baseline_run();
    let baseline_qps = if base_wall > 0.0 {
        fixture.requests.len() as f64 / base_wall
    } else {
        0.0
    };
    let engine = ServeEngine::start(
        &fixture.codebook,
        Some(fixture.resonator.clone()),
        opts.engine.clone(),
    );
    let closed = run_closed_loop(&engine, &fixture, opts.clients, &oracle);
    let open = opts.open_loop_qps.map(|rate| {
        (
            rate,
            PassSummary::of(&run_open_loop(&engine, &fixture, rate, opts.clients, &oracle)),
        )
    });
    let stats = engine.stats();
    engine.shutdown();
    BenchReport {
        baseline_qps,
        baseline_latency: LatencySummary::of(&base_lat),
        closed: PassSummary::of(&closed),
        open,
        stats,
        opts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_fixture() -> FixtureConfig {
        FixtureConfig {
            items: 24,
            dim: 512,
            noise_frac: 0.2,
            topk_k: 3,
            fact_factors: 3,
            fact_items: 6,
            fact_dim: 256,
            fact_iters: 20,
            requests: 60,
            mix: LoadMix {
                recall: 4,
                topk: 1,
                factorize: 1,
            },
            repeat_frac: 0.0,
            seed: 7,
        }
    }

    #[test]
    fn fixture_is_deterministic_and_mixed() {
        let a = Fixture::build(tiny_fixture());
        let b = Fixture::build(tiny_fixture());
        assert_eq!(a.requests, b.requests);
        assert_eq!(a.requests.len(), 60);
        let kinds: std::collections::BTreeSet<&'static str> =
            a.requests.iter().map(|r| r.kind().label()).collect();
        assert_eq!(kinds.len(), 3, "all three classes present: {kinds:?}");
    }

    #[test]
    fn closed_loop_matches_oracle_bit_exactly() {
        let fixture = Fixture::build(tiny_fixture());
        let engine = ServeEngine::start(
            &fixture.codebook,
            Some(fixture.resonator.clone()),
            EngineConfig {
                workers: 2,
                shards: 3,
                max_batch: 8,
                max_delay: Duration::from_millis(1),
                ..EngineConfig::default()
            },
        );
        let report = run_closed_loop(&engine, &fixture, 6, &fixture.oracle());
        assert_eq!(report.ok, 60);
        assert_eq!(report.rejected, 0);
        assert_eq!(report.expired, 0);
        assert_eq!(report.mismatches, 0, "batched responses diverged from oracle");
        engine.shutdown();
    }

    #[test]
    fn open_loop_paces_and_completes() {
        let fixture = Fixture::build(FixtureConfig {
            requests: 40,
            ..tiny_fixture()
        });
        let engine = ServeEngine::start(
            &fixture.codebook,
            Some(fixture.resonator.clone()),
            EngineConfig::default(),
        );
        // high rate so the test stays fast; still a schedule, not a loop
        let report = run_open_loop(&engine, &fixture, 4000.0, 4, &fixture.oracle());
        assert_eq!(report.ok + report.rejected + report.expired, 40);
        assert_eq!(report.mismatches, 0);
        assert!(report.wall_s >= 40.0 / 4000.0 * 0.5);
        engine.shutdown();
    }

    #[test]
    fn bench_report_json_is_well_formed() {
        let mut opts = BenchOpts::smoke();
        opts.fixture.requests = 40;
        opts.fixture.dim = 512;
        opts.fixture.items = 24;
        opts.clients = 4;
        let report = run_bench(opts);
        assert_eq!(report.closed.mismatches, 0);
        let json = report.to_json();
        let parsed = crate::util::json::Json::parse(&json).expect("invalid JSON emitted");
        assert_eq!(
            parsed.get("bench").and_then(|b| b.as_str()),
            Some("serve")
        );
        assert_eq!(
            parsed.get("simd").and_then(|s| s.as_str()),
            Some(crate::vsa::kernels::active_tier().name()),
            "serve JSON must attribute its numbers to the dispatch tier"
        );
        assert!(parsed.get("closed_loop").is_some());
        assert!(parsed.get("speedup_qps").is_some());
        assert!(parsed.get("prune").is_some());
        assert!(parsed.get("cache").is_some());
        // table renders without panicking
        let _ = report.table().to_string();
    }

    #[test]
    fn repeated_mix_is_deterministic_and_cache_serves_it_exactly() {
        // dim 2048: rows are several bound chunks long, so the serve
        // scans actually prune (512-bit rows are a single chunk)
        let cfg = FixtureConfig {
            repeat_frac: 0.5,
            requests: 80,
            dim: 2048,
            ..tiny_fixture()
        };
        let a = Fixture::build(cfg.clone());
        let b = Fixture::build(cfg);
        assert_eq!(a.requests, b.requests, "repeats must stay deterministic");
        // repeats exist: at least one exact duplicate request
        let dup = a
            .requests
            .iter()
            .enumerate()
            .any(|(i, r)| a.requests[..i].contains(r));
        assert!(dup, "repeat_frac=0.5 over 80 requests must produce repeats");
        let engine = ServeEngine::start(
            &a.codebook,
            Some(a.resonator.clone()),
            EngineConfig {
                workers: 2,
                shards: 3,
                ..EngineConfig::default()
            },
        );
        let report = run_closed_loop(&engine, &a, 6, &a.oracle());
        assert_eq!(report.ok, 80);
        assert_eq!(report.mismatches, 0, "cached responses diverged from oracle");
        let snap = engine.stats();
        let cache = snap.cache.expect("default engine cache enabled");
        assert!(cache.hits > 0, "repeated mix must produce cache hits");
        assert!(
            snap.prune.words_streamed < snap.prune.words_total,
            "noisy-member serve scans must prune: {:?}",
            snap.prune
        );
        engine.shutdown();
    }
}
