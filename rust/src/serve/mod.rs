//! `serve/` — sharded, dynamically-batched VSA query serving engine.
//!
//! The paper's characterization (Sec. V) shows the symbolic kernels —
//! cleanup scans and resonator iteration — are memory-bound with little
//! intra-query parallelism; its cross-layer remedy is batching plus
//! parallel scheduling. PR 1 built the batched kernels
//! ([`crate::vsa::codebook`]'s `nearest_batch`, [`crate::vsa::cleanup`]'s
//! `recall_batch`, [`crate::vsa::Resonator::factorize_with`]); this module
//! builds the request path that actually *forms* those batches under
//! concurrent load:
//!
//! - [`shard`]: codebooks partitioned into contiguous shards, scanned on
//!   worker threads via [`crate::util::parallel`], per-shard top-k merged
//!   under the same (score desc, index asc) order as the unsharded scan.
//! - [`queue`]: a bounded admission queue with deadlines, reject-on-full
//!   backpressure, and FIFO-within-priority ordering.
//! - [`batcher`]: a dynamic micro-batcher coalescing concurrent requests
//!   into single batched-kernel calls under a max-batch/max-delay policy,
//!   reusing one [`crate::vsa::ResonatorScratch`] per worker.
//! - [`engine`]: the persistent worker event loop behind a blocking
//!   [`engine::ServeEngine::submit`] client API.
//! - [`stats`]: per-shard, per-batch, and per-class latency / throughput /
//!   batch-occupancy metrics.
//! - [`cache`]: a bounded, sharded response cache probed at
//!   batch-formation time — repeated queries bypass the kernels entirely,
//!   with exact (full-equality-verified) keys over query × class × k.
//! - [`loadgen`]: open- and closed-loop synthetic load generators and the
//!   `nscog serve-bench` report (`BENCH_serve.json`).
//!
//! The per-shard scans themselves run through the bound-pruned kernel
//! paths (see [`crate::vsa::sketch`]), whose [`crate::vsa::PruneStats`]
//! surface in [`StatsSnapshot`] and `BENCH_serve.json`.
//!
//! Correctness contract: every batched/sharded/cached response is
//! bit-identical to the sequential oracle
//! (`CleanupMemory::recall`/`recall_topk`, `Resonator::factorize`) —
//! enforced by `rust/tests/serve_e2e.rs`.

pub mod batcher;
pub mod cache;
pub mod engine;
pub mod loadgen;
pub mod queue;
pub mod shard;
pub mod stats;

pub use cache::{CacheConfig, CacheCounters, ResponseCache};
pub use engine::{EngineConfig, PendingResponse, ServeEngine};
pub use queue::Priority;
pub use shard::{ShardedBinaryCodebook, ShardedCleanup, ShardedRealCodebook};
pub use stats::{LatencySummary, StatsSnapshot};

use crate::vsa::{BinaryHV, RealHV};
use std::fmt;

/// A client request against the serving engine.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeRequest {
    /// Cleanup-memory recall: nearest stored item for a (noisy) query.
    Recall { query: BinaryHV },
    /// Top-`k` cleanup recall (ranked candidates, e.g. for re-ranking).
    RecallTopK { query: BinaryHV, k: usize },
    /// Resonator factorization of a composed scene.
    Factorize { scene: RealHV },
}

/// Request class, used for batching group and per-class metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestKind {
    Recall,
    RecallTopK,
    Factorize,
}

impl ServeRequest {
    pub fn kind(&self) -> RequestKind {
        match self {
            ServeRequest::Recall { .. } => RequestKind::Recall,
            ServeRequest::RecallTopK { .. } => RequestKind::RecallTopK,
            ServeRequest::Factorize { .. } => RequestKind::Factorize,
        }
    }
}

impl RequestKind {
    pub fn label(&self) -> &'static str {
        match self {
            RequestKind::Recall => "recall",
            RequestKind::RecallTopK => "recall_topk",
            RequestKind::Factorize => "factorize",
        }
    }
}

/// A completed response.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeResponse {
    Recall {
        index: usize,
        cosine: f64,
    },
    RecallTopK {
        /// (item index, normalized score), ordered (score desc, index asc).
        hits: Vec<(usize, f64)>,
    },
    Factorize {
        indices: Vec<usize>,
        iterations: usize,
        converged: bool,
    },
}

/// Why a request did not produce a [`ServeResponse`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeError {
    /// Admission queue full — backpressure; the caller should shed load
    /// or retry later.
    Overloaded,
    /// The request's deadline expired before a worker picked it up.
    DeadlineExceeded,
    /// Engine is shutting down (or was already shut down).
    ShuttingDown,
    /// The engine was built without the capability this request needs
    /// (e.g. a factorize request and no resonator configured).
    Unsupported,
    /// The request payload's dimension doesn't match the engine's store —
    /// refused up front so a malformed request can never panic (and kill)
    /// a worker thread.
    InvalidDimension,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Overloaded => write!(f, "admission queue full (backpressure)"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded in queue"),
            ServeError::ShuttingDown => write!(f, "engine shutting down"),
            ServeError::Unsupported => write!(f, "request kind not supported by this engine"),
            ServeError::InvalidDimension => {
                write!(f, "request dimension does not match the engine's store")
            }
        }
    }
}

impl std::error::Error for ServeError {}
