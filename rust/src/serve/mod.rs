//! `serve/` — sharded, dynamically-batched, multi-store VSA query
//! serving engine.
//!
//! The paper's characterization (Sec. V) shows the symbolic kernels —
//! cleanup scans and resonator iteration — are memory-bound with little
//! intra-query parallelism; its cross-layer remedy is batching plus
//! parallel scheduling. PR 1 built the batched kernels
//! ([`crate::vsa::codebook`]'s `nearest_batch`, [`crate::vsa::cleanup`]'s
//! `recall_batch`, [`crate::vsa::Resonator::factorize_with`]); this module
//! builds the request path that actually *forms* those batches under
//! concurrent load:
//!
//! - [`registry`]: N named stores behind one queue — each its own sharded
//!   codebook, resonator shape, response cache, and prune/latency
//!   accounting; requests route on a [`StoreId`]. Stores are live-mutable
//!   via epoch-based snapshot swap: item inserts/deletes and store
//!   create/drop publish immutable [`registry::StoreSnapshot`]s at
//!   monotonically increasing epochs while traffic flows; in-flight
//!   batches finish on the snapshot they sealed, and the response cache
//!   keys on `(store, epoch)` so a stale hit is structurally impossible.
//! - [`shard`]: codebooks partitioned into contiguous shards, scanned on
//!   worker threads via [`crate::util::parallel`], per-shard top-k merged
//!   under the same (score desc, index asc) order as the unsharded scan.
//! - [`queue`]: a bounded admission queue with deadlines, per-store
//!   admission quotas, deficit-round-robin (weighted) pop scheduling
//!   across stores, reject-on-full backpressure, and
//!   FIFO-within-priority ordering inside each store's lane.
//! - [`faults`]: a deterministic fault-injection harness (seeded via
//!   [`crate::util::Rng`]) — artificial kernel latency, forced admission
//!   rejections, and worker-thread panics — used by the chaos scenarios
//!   in [`loadgen`] and the containment tests.
//! - [`batcher`]: a dynamic micro-batcher coalescing concurrent requests
//!   into batched-kernel calls under a max-batch/max-delay policy — one
//!   call per `(store, request class)` group, so a batched kernel call
//!   never mixes stores (or dimensions) — reusing per-store
//!   [`crate::vsa::ResonatorScratch`] buffers per worker.
//! - [`engine`]: the persistent worker event loop behind a blocking
//!   [`engine::ServeEngine::submit`] client API (plus the non-blocking
//!   [`engine::PendingResponse::try_wait`] poll).
//! - [`stats`]: per-store, per-shard, per-batch, and per-class latency /
//!   throughput / batch-occupancy metrics, including the always-on
//!   per-stage (queue/batch/kernel/fill) P² latency decomposition.
//! - [`trace`]: per-request lifecycle stage marks carried on the ticket
//!   and an optional fixed-capacity drop-oldest ring buffer of trace
//!   events (`serve-bench --trace` → `BENCH_serve_trace.json`), plus the
//!   measured FLOPs/bytes accounting behind the live roofline bridge.
//! - [`cache`]: bounded, sharded per-store response caches probed at
//!   batch-formation time — repeated queries bypass the kernels entirely,
//!   with exact (full-equality-verified) keys over query × class × k ×
//!   store.
//! - [`loadgen`]: open- and closed-loop synthetic multi-tenant load
//!   generators (skewed store popularity, per-store repeat fractions) and
//!   the `nscog serve-bench` report (`BENCH_serve.json`).
//! - [`net`]: the std-only TCP front-end — length-prefixed binary frame
//!   codec decoding straight into [`ServeRequest`], per-connection
//!   reader/writer threads fed by the engine's
//!   [`queue::CompletionQueue`], slow-loris / half-open reaping,
//!   admission-coupled backpressure (a full lane answers an error frame,
//!   never buffers unboundedly), graceful drain shutdown, and a
//!   retry/backoff client with idempotent request ids
//!   (`nscog serve --listen`, `serve-bench --wire`, network chaos).
//!
//! The per-shard scans themselves run through the bound-pruned kernel
//! paths (see [`crate::vsa::sketch`]), whose [`crate::vsa::PruneStats`]
//! surface per store in [`StatsSnapshot`] and `BENCH_serve.json`.
//!
//! Correctness contract: every batched/sharded/cached response is
//! bit-identical to *its own store's* sequential oracle
//! (`CleanupMemory::recall`/`recall_topk`, `Resonator::factorize`) —
//! enforced by `rust/tests/serve_e2e.rs`, including interleaved
//! cross-store traffic over stores with different dimensions.

pub mod batcher;
pub mod cache;
pub mod engine;
pub mod faults;
pub mod loadgen;
pub mod net;
pub mod queue;
pub mod registry;
pub mod shard;
pub mod stats;
pub mod trace;

pub use cache::{CacheConfig, CacheCounters, ResponseCache};
pub use engine::{EngineConfig, PendingResponse, ServeEngine};
pub use faults::{FaultConfig, FaultPlan};
pub use net::{NetClient, NetConfig, NetCounters, NetServer};
pub use queue::{Completion, CompletionQueue, LaneGauge, Priority};
pub use registry::{Hysteresis, MutateError, StoreId, StoreRegistry, StoreSpec};
pub use shard::{ShardedBinaryCodebook, ShardedCleanup, ShardedRealCodebook};
pub use stats::{LatencySummary, StageSummary, StatsSnapshot, StoreSnapshot};
pub use trace::{KernelWork, StageMarks, StageSample, TraceEvent, TraceRing};

use crate::vsa::{BinaryHV, RealHV};
use std::fmt;

/// The operation a request asks of its target store.
#[derive(Debug, Clone, PartialEq)]
pub enum RequestOp {
    /// Cleanup-memory recall: nearest stored item for a (noisy) query.
    Recall { query: BinaryHV },
    /// Top-`k` cleanup recall (ranked candidates, e.g. for re-ranking).
    RecallTopK { query: BinaryHV, k: usize },
    /// Resonator factorization of a composed scene.
    Factorize { scene: RealHV },
}

impl RequestOp {
    pub fn kind(&self) -> RequestKind {
        match self {
            RequestOp::Recall { .. } => RequestKind::Recall,
            RequestOp::RecallTopK { .. } => RequestKind::RecallTopK,
            RequestOp::Factorize { .. } => RequestKind::Factorize,
        }
    }
}

/// A client request against the serving engine: the store it targets
/// plus the operation. The `recall`/`recall_topk`/`factorize`
/// constructors target [`StoreId::DEFAULT`] (store 0 — the single-store
/// engines' only store); the `*_on` variants name a store explicitly.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeRequest {
    pub store: StoreId,
    pub op: RequestOp,
}

impl ServeRequest {
    pub fn recall(query: BinaryHV) -> ServeRequest {
        Self::recall_on(StoreId::DEFAULT, query)
    }

    pub fn recall_on(store: StoreId, query: BinaryHV) -> ServeRequest {
        ServeRequest {
            store,
            op: RequestOp::Recall { query },
        }
    }

    pub fn recall_topk(query: BinaryHV, k: usize) -> ServeRequest {
        Self::recall_topk_on(StoreId::DEFAULT, query, k)
    }

    pub fn recall_topk_on(store: StoreId, query: BinaryHV, k: usize) -> ServeRequest {
        ServeRequest {
            store,
            op: RequestOp::RecallTopK { query, k },
        }
    }

    pub fn factorize(scene: RealHV) -> ServeRequest {
        Self::factorize_on(StoreId::DEFAULT, scene)
    }

    pub fn factorize_on(store: StoreId, scene: RealHV) -> ServeRequest {
        ServeRequest {
            store,
            op: RequestOp::Factorize { scene },
        }
    }

    pub fn kind(&self) -> RequestKind {
        self.op.kind()
    }
}

/// Request class, used for batching group and per-class metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestKind {
    Recall,
    RecallTopK,
    Factorize,
}

impl RequestKind {
    /// Every class, in [`RequestKind::index`] order — the canonical
    /// iteration order for per-class arrays in stats and trace reports.
    pub const ALL: [RequestKind; 3] =
        [RequestKind::Recall, RequestKind::RecallTopK, RequestKind::Factorize];

    pub fn label(&self) -> &'static str {
        match self {
            RequestKind::Recall => "recall",
            RequestKind::RecallTopK => "recall_topk",
            RequestKind::Factorize => "factorize",
        }
    }

    /// Dense index into per-class arrays (`[T; 3]`), matching
    /// [`RequestKind::ALL`].
    pub fn index(&self) -> usize {
        match self {
            RequestKind::Recall => 0,
            RequestKind::RecallTopK => 1,
            RequestKind::Factorize => 2,
        }
    }
}

/// A completed response.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeResponse {
    Recall {
        index: usize,
        cosine: f64,
    },
    RecallTopK {
        /// (item index, normalized score), ordered (score desc, index asc).
        hits: Vec<(usize, f64)>,
    },
    Factorize {
        indices: Vec<usize>,
        iterations: usize,
        converged: bool,
    },
    /// Served under a store's degraded mode (queue depth over its
    /// [`registry::StoreSpec::degrade_depth`] threshold): `inner` is the
    /// bit-exact answer to the *reduced* request — e.g. a top-k truncated
    /// to the store's `degrade_k` cap. The wrapper makes the reduction
    /// visible to the client instead of silently returning fewer hits.
    Degraded { inner: Box<ServeResponse> },
}

/// Why a request did not produce a [`ServeResponse`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeError {
    /// Admission queue full — backpressure; the caller should shed load
    /// or retry later.
    Overloaded,
    /// The request's deadline expired before a worker picked it up.
    DeadlineExceeded,
    /// Engine is shutting down (or was already shut down).
    ShuttingDown,
    /// The target store was built without the capability this request
    /// needs (e.g. a factorize request and no resonator configured).
    Unsupported,
    /// The request payload's dimension doesn't match its target store —
    /// refused up front so a malformed request can never panic (and kill)
    /// a worker thread.
    InvalidDimension,
    /// The request names a [`StoreId`] that is not live: never issued
    /// (refused at admission) or dropped. A store dropped *after* this
    /// request was admitted surfaces the same error at execute time —
    /// the admit-vs-drop race is answered, never served from a retired
    /// snapshot.
    UnknownStore,
    /// The *target store's* admission quota is exhausted (or the store is
    /// degraded and shedding its expensive request class). Unlike
    /// [`ServeError::Overloaded`] this is tenant-local: other stores'
    /// admission is unaffected, so a flooding tenant sheds its own
    /// traffic.
    TenantOverloaded,
    /// A worker panicked while this request's batch was in flight; the
    /// panic was contained (the worker respawned) and every ticket of the
    /// poisoned batch is answered with this error instead of hanging.
    Internal,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Overloaded => write!(f, "admission queue full (backpressure)"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded in queue"),
            ServeError::ShuttingDown => write!(f, "engine shutting down"),
            ServeError::Unsupported => write!(f, "request kind not supported by its target store"),
            ServeError::InvalidDimension => {
                write!(f, "request dimension does not match its target store")
            }
            ServeError::UnknownStore => {
                write!(f, "request names a store id the engine has not registered")
            }
            ServeError::TenantOverloaded => {
                write!(f, "target store's admission quota exhausted (tenant backpressure)")
            }
            ServeError::Internal => {
                write!(f, "worker panicked while serving this batch (contained)")
            }
        }
    }
}

impl std::error::Error for ServeError {}
