//! Bounded admission queue with deadlines, backpressure, and
//! FIFO-within-priority ordering.
//!
//! Admission control is reject-on-full: a full queue refuses new tickets
//! immediately (the client sees [`ServeError::Overloaded`]) instead of
//! building an unbounded backlog — under overload, latency is traded for
//! an explicit error the caller can act on. Deadlines are checked by the
//! worker at pop time; an expired ticket is answered with
//! [`ServeError::DeadlineExceeded`] without touching the kernels.

use super::{ServeError, ServeRequest, ServeResponse};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Two-level priority: `High` tickets always pop before `Normal` ones;
/// within a level, strictly FIFO.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Priority {
    High,
    Normal,
}

/// One-shot response slot a client blocks on and a worker fills once.
#[derive(Debug, Clone)]
pub struct ResponseSlot {
    inner: Arc<SlotInner>,
}

#[derive(Debug)]
struct SlotInner {
    /// `(outcome, completion time)`, set exactly once.
    done: Mutex<Option<(Result<ServeResponse, ServeError>, Instant)>>,
    ready: Condvar,
}

impl ResponseSlot {
    pub fn new() -> ResponseSlot {
        ResponseSlot {
            inner: Arc::new(SlotInner {
                done: Mutex::new(None),
                ready: Condvar::new(),
            }),
        }
    }

    /// Fill the slot (first fill wins; later fills are ignored).
    pub fn fill(&self, outcome: Result<ServeResponse, ServeError>) {
        let mut g = self.inner.done.lock().expect("slot poisoned");
        if g.is_none() {
            *g = Some((outcome, Instant::now()));
            self.inner.ready.notify_all();
        }
    }

    /// Block until the slot is filled; returns the outcome and the instant
    /// the worker filled it (for open-loop latency accounting).
    pub fn wait_timed(&self) -> (Result<ServeResponse, ServeError>, Instant) {
        let mut g = self.inner.done.lock().expect("slot poisoned");
        loop {
            if let Some(done) = g.take() {
                return done;
            }
            g = self.inner.ready.wait(g).expect("slot poisoned");
        }
    }

    /// Block until the slot is filled.
    pub fn wait(&self) -> Result<ServeResponse, ServeError> {
        self.wait_timed().0
    }

    /// Non-blocking poll: take the outcome if the worker has filled the
    /// slot, `None` otherwise (the slot stays waitable). Backs
    /// [`super::engine::PendingResponse::try_wait`].
    pub fn try_take(&self) -> Option<(Result<ServeResponse, ServeError>, Instant)> {
        self.inner.done.lock().expect("slot poisoned").take()
    }

    /// Block until the slot is filled or `until` passes; `None` on
    /// timeout (the slot stays waitable). Backs
    /// [`super::engine::PendingResponse::wait_timeout`].
    pub fn wait_until(&self, until: Instant) -> Option<(Result<ServeResponse, ServeError>, Instant)> {
        let mut g = self.inner.done.lock().expect("slot poisoned");
        loop {
            if let Some(done) = g.take() {
                return Some(done);
            }
            let now = Instant::now();
            if now >= until {
                return None;
            }
            let (g2, _timeout) = self
                .inner
                .ready
                .wait_timeout(g, until - now)
                .expect("slot poisoned");
            g = g2;
        }
    }
}

impl Default for ResponseSlot {
    fn default() -> Self {
        ResponseSlot::new()
    }
}

/// A queued request: payload plus admission metadata.
#[derive(Debug)]
pub struct Ticket {
    pub request: ServeRequest,
    pub priority: Priority,
    pub slot: ResponseSlot,
    /// When the ticket entered the queue (latency measurement origin).
    pub enqueued: Instant,
    /// Absolute deadline; expired tickets are answered, not executed.
    pub deadline: Instant,
}

impl Ticket {
    pub fn expired(&self, now: Instant) -> bool {
        now >= self.deadline
    }
}

/// Why [`AdmissionQueue::push`] refused a ticket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitError {
    Full,
    Closed,
}

impl AdmitError {
    pub fn to_serve_error(self) -> ServeError {
        match self {
            AdmitError::Full => ServeError::Overloaded,
            AdmitError::Closed => ServeError::ShuttingDown,
        }
    }
}

struct QueueState {
    high: VecDeque<Ticket>,
    normal: VecDeque<Ticket>,
    closed: bool,
}

impl QueueState {
    fn len(&self) -> usize {
        self.high.len() + self.normal.len()
    }

    fn take(&mut self) -> Option<Ticket> {
        self.high.pop_front().or_else(|| self.normal.pop_front())
    }
}

/// Bounded MPMC admission queue (mutex + condvar; std-only).
pub struct AdmissionQueue {
    capacity: usize,
    state: Mutex<QueueState>,
    available: Condvar,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> AdmissionQueue {
        AdmissionQueue {
            capacity: capacity.max(1),
            state: Mutex::new(QueueState {
                high: VecDeque::new(),
                normal: VecDeque::new(),
                closed: false,
            }),
            available: Condvar::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.state.lock().expect("queue poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admit a ticket, or hand it back with the rejection reason
    /// (reject-on-full backpressure; closed queues admit nothing).
    pub fn push(&self, ticket: Ticket) -> Result<(), (Ticket, AdmitError)> {
        let mut st = self.state.lock().expect("queue poisoned");
        if st.closed {
            return Err((ticket, AdmitError::Closed));
        }
        if st.len() >= self.capacity {
            return Err((ticket, AdmitError::Full));
        }
        match ticket.priority {
            Priority::High => st.high.push_back(ticket),
            Priority::Normal => st.normal.push_back(ticket),
        }
        drop(st);
        self.available.notify_one();
        Ok(())
    }

    /// Close the queue: no further admissions; blocked poppers drain what
    /// remains, then observe `None`.
    pub fn close(&self) {
        let mut st = self.state.lock().expect("queue poisoned");
        st.closed = true;
        drop(st);
        self.available.notify_all();
    }

    /// Pop the next ticket, blocking while the queue is empty and open.
    /// Returns `None` once the queue is closed and drained.
    pub fn pop_blocking(&self) -> Option<Ticket> {
        let mut st = self.state.lock().expect("queue poisoned");
        loop {
            if let Some(t) = st.take() {
                return Some(t);
            }
            if st.closed {
                return None;
            }
            st = self.available.wait(st).expect("queue poisoned");
        }
    }

    /// Pop the next ticket if one arrives before `until`; `None` on
    /// timeout or when closed-and-drained. Used by the micro-batcher to
    /// wait out the remainder of a batch window.
    pub fn pop_until(&self, until: Instant) -> Option<Ticket> {
        let mut st = self.state.lock().expect("queue poisoned");
        loop {
            if let Some(t) = st.take() {
                return Some(t);
            }
            if st.closed {
                return None;
            }
            let now = Instant::now();
            if now >= until {
                return None;
            }
            let (g, _timeout) = self
                .available
                .wait_timeout(st, until - now)
                .expect("queue poisoned");
            st = g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vsa::BinaryHV;

    fn ticket(tag: usize, priority: Priority) -> Ticket {
        // encode `tag` in the top-k `k` field so pops are identifiable
        let now = Instant::now();
        Ticket {
            request: ServeRequest::recall_topk(BinaryHV::zeros(64), tag),
            priority,
            slot: ResponseSlot::new(),
            enqueued: now,
            deadline: now + Duration::from_secs(60),
        }
    }

    fn tag_of(t: &Ticket) -> usize {
        match t.request.op {
            super::super::RequestOp::RecallTopK { k, .. } => k,
            _ => unreachable!(),
        }
    }

    #[test]
    fn fifo_within_priority_high_first() {
        let q = AdmissionQueue::new(8);
        q.push(ticket(0, Priority::Normal)).unwrap();
        q.push(ticket(1, Priority::High)).unwrap();
        q.push(ticket(2, Priority::Normal)).unwrap();
        q.push(ticket(3, Priority::High)).unwrap();
        let order: Vec<usize> = (0..4)
            .map(|_| tag_of(&q.pop_blocking().unwrap()))
            .collect();
        assert_eq!(order, [1, 3, 0, 2]);
    }

    #[test]
    fn rejects_when_full_then_admits_after_drain() {
        let q = AdmissionQueue::new(2);
        q.push(ticket(0, Priority::Normal)).unwrap();
        q.push(ticket(1, Priority::Normal)).unwrap();
        let (_, why) = q.push(ticket(2, Priority::Normal)).unwrap_err();
        assert_eq!(why, AdmitError::Full);
        assert_eq!(q.len(), 2);
        assert_eq!(tag_of(&q.pop_blocking().unwrap()), 0);
        q.push(ticket(3, Priority::Normal)).unwrap();
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_drains_then_none_and_rejects_new() {
        let q = AdmissionQueue::new(4);
        q.push(ticket(0, Priority::Normal)).unwrap();
        q.close();
        let (_, why) = q.push(ticket(1, Priority::Normal)).unwrap_err();
        assert_eq!(why, AdmitError::Closed);
        assert_eq!(tag_of(&q.pop_blocking().unwrap()), 0);
        assert!(q.pop_blocking().is_none());
        assert!(q.pop_until(Instant::now() + Duration::from_millis(1)).is_none());
    }

    #[test]
    fn pop_until_times_out_empty() {
        let q = AdmissionQueue::new(4);
        let t0 = Instant::now();
        assert!(q.pop_until(t0 + Duration::from_millis(10)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(10));
    }

    #[test]
    fn pop_unblocks_on_cross_thread_push() {
        let q = std::sync::Arc::new(AdmissionQueue::new(4));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_blocking().map(|t| tag_of(&t)));
        std::thread::sleep(Duration::from_millis(20));
        q.push(ticket(7, Priority::Normal)).unwrap();
        assert_eq!(h.join().unwrap(), Some(7));
    }

    #[test]
    fn slot_fill_once_and_wait() {
        let slot = ResponseSlot::new();
        let s2 = slot.clone();
        let h = std::thread::spawn(move || s2.wait());
        std::thread::sleep(Duration::from_millis(10));
        slot.fill(Err(ServeError::DeadlineExceeded));
        slot.fill(Err(ServeError::Overloaded)); // ignored: first fill wins
        assert_eq!(h.join().unwrap(), Err(ServeError::DeadlineExceeded));
    }

    #[test]
    fn slot_try_take_and_wait_until() {
        let slot = ResponseSlot::new();
        assert!(slot.try_take().is_none(), "unfilled slot polls empty");
        // timeout path leaves the slot waitable
        assert!(slot
            .wait_until(Instant::now() + Duration::from_millis(5))
            .is_none());
        slot.fill(Err(ServeError::Overloaded));
        let (outcome, _) = slot.try_take().expect("filled slot polls ready");
        assert_eq!(outcome, Err(ServeError::Overloaded));
        // take-once semantics: a second poll sees nothing
        assert!(slot.try_take().is_none());

        // wait_until returns as soon as a cross-thread fill lands
        let slot = ResponseSlot::new();
        let s2 = slot.clone();
        let h = std::thread::spawn(move || {
            s2.wait_until(Instant::now() + Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(10));
        slot.fill(Err(ServeError::DeadlineExceeded));
        let (outcome, _) = h.join().unwrap().expect("fill beats the deadline");
        assert_eq!(outcome, Err(ServeError::DeadlineExceeded));
    }

    #[test]
    fn expired_ticket_detection() {
        let now = Instant::now();
        let mut t = ticket(0, Priority::Normal);
        t.deadline = now;
        assert!(t.expired(now));
        assert!(t.expired(now + Duration::from_millis(1)));
        t.deadline = now + Duration::from_secs(1);
        assert!(!t.expired(now));
    }
}
