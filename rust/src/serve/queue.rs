//! Bounded admission queue with deadlines, per-store quotas,
//! deficit-round-robin pop scheduling, and FIFO-within-priority ordering
//! inside each store's lane.
//!
//! Admission control is reject-on-full at two levels: a full queue
//! refuses new tickets immediately (the client sees
//! [`ServeError::Overloaded`]), and a store whose *own* lane has reached
//! its quota is refused with [`ServeError::TenantOverloaded`] while every
//! other store keeps admitting — under a one-tenant flood, the flooding
//! store sheds its own traffic instead of starving the queue for
//! everyone. Pop ordering is deficit round robin across store lanes:
//! each scheduler round, lane `i` pops up to `weight_i` tickets before
//! the rotation advances — or `weight_i × 2` ([`HIGH_BOOST`]) while the
//! lane holds high-priority tickets at refill time, so priority buys
//! *cross-tenant* share, not just intra-lane ordering. The boost is a
//! bounded multiplier, never preemption: every backlogged lane still
//! replenishes to at least its weight each rotation, so no mix of
//! priorities can starve a competing store (property-tested below).
//! Service share under contention follows the configured weights and
//! idle stores cost nothing. Deadlines are checked by the worker at pop
//! time; an expired ticket is answered with
//! [`ServeError::DeadlineExceeded`] without touching the kernels.
//!
//! Lock-poisoning policy: every `Mutex`/`Condvar` acquisition recovers a
//! poisoned guard with `unwrap_or_else(|p| p.into_inner())`. The queue's
//! invariants (lane deques consistent with the cached total length) are
//! only mutated in straight-line code that cannot panic mid-update, so a
//! guard poisoned by a *different* panicking thread is still consistent —
//! recovering it keeps the engine serving instead of cascading the panic
//! into every client.

use super::registry::StoreId;
use super::trace::StageMarks;
use super::{ServeError, ServeRequest, ServeResponse};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Two-level priority: within a store's lane, `High` tickets always pop
/// before `Normal` ones; within a level, strictly FIFO. Across lanes,
/// a high-priority backlog boosts the lane's DRR refill by
/// [`HIGH_BOOST`] — extra share, never preemption, so one store's
/// `High` traffic can lean on but not starve another store (fairness
/// still bounds priority between tenants).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Priority {
    High,
    Normal,
}

/// Multiplier on a lane's DRR refill while the lane holds high-priority
/// tickets at replenish time: priority buys up to `HIGH_BOOST × weight`
/// pops per rotation instead of `weight`. Bounded (not absolute
/// preemption) so every competing backlogged lane keeps ≥ `weight` pops
/// per rotation — the starvation-freedom invariant.
pub const HIGH_BOOST: u32 = 2;

/// One finished request, as delivered through a [`CompletionQueue`]:
/// the caller-chosen tag (e.g. a wire request id), the outcome, and the
/// enqueue/fill instants for latency accounting.
#[derive(Debug, Clone)]
pub struct Completion {
    pub tag: u64,
    pub outcome: Result<ServeResponse, ServeError>,
    pub enqueued: Instant,
    pub completed: Instant,
}

impl Completion {
    /// End-to-end latency (enqueue → worker fill).
    pub fn latency(&self) -> Duration {
        self.completed.duration_since(self.enqueued)
    }
}

#[derive(Debug, Default)]
struct CqState {
    done: VecDeque<Completion>,
    closed: bool,
}

#[derive(Debug)]
struct CqInner {
    state: Mutex<CqState>,
    ready: Condvar,
}

/// MPMC completion queue — the push half of the async API. Slots built
/// with [`ResponseSlot::with_completion`] deliver their outcome here the
/// moment a worker fills them, so a consumer (e.g. a connection writer
/// thread) harvests finished responses with one blocking pop instead of
/// polling every in-flight ticket via `try_wait`. Completions arrive in
/// fill order, which is NOT submit order — the `tag` is how a consumer
/// matches a completion back to its request.
#[derive(Debug, Clone, Default)]
pub struct CompletionQueue {
    inner: Arc<CqInner>,
}

impl Default for CqInner {
    fn default() -> CqInner {
        CqInner {
            state: Mutex::new(CqState::default()),
            ready: Condvar::new(),
        }
    }
}

impl CompletionQueue {
    pub fn new() -> CompletionQueue {
        CompletionQueue::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, CqState> {
        self.inner.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Deliver one completion. Returns `false` (and drops it) if the
    /// queue is closed — a worker filling a slot after its connection
    /// died must not panic or grow an unread queue forever.
    pub fn push(&self, c: Completion) -> bool {
        let mut st = self.lock();
        if st.closed {
            return false;
        }
        st.done.push_back(c);
        drop(st);
        self.inner.ready.notify_one();
        true
    }

    /// Pop the next completion, blocking while the queue is empty and
    /// open; `None` once the queue is closed and drained.
    pub fn pop_blocking(&self) -> Option<Completion> {
        let mut st = self.lock();
        loop {
            if let Some(c) = st.done.pop_front() {
                return Some(c);
            }
            if st.closed {
                return None;
            }
            st = self.inner.ready.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Pop the next completion if one arrives before `until`; `None` on
    /// timeout or when closed-and-drained (disambiguate via
    /// [`CompletionQueue::is_closed`]).
    pub fn pop_until(&self, until: Instant) -> Option<Completion> {
        let mut st = self.lock();
        loop {
            if let Some(c) = st.done.pop_front() {
                return Some(c);
            }
            if st.closed {
                return None;
            }
            let now = Instant::now();
            if now >= until {
                return None;
            }
            let (g, _timeout) = self
                .inner
                .ready
                .wait_timeout(st, until - now)
                .unwrap_or_else(|p| p.into_inner());
            st = g;
        }
    }

    /// Take everything already completed, without blocking.
    pub fn drain_ready(&self) -> Vec<Completion> {
        self.lock().done.drain(..).collect()
    }

    /// Close the queue: further pushes are dropped, blocked poppers
    /// drain what remains and then observe `None`.
    pub fn close(&self) {
        let mut st = self.lock();
        st.closed = true;
        drop(st);
        self.inner.ready.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.lock().closed
    }

    pub fn len(&self) -> usize {
        self.lock().done.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Where a slot reports its completion (set at construction, delivered
/// on first fill only).
#[derive(Debug)]
struct CompletionHook {
    cq: CompletionQueue,
    tag: u64,
    enqueued: Instant,
}

/// One-shot response slot a client blocks on and a worker fills once.
#[derive(Debug, Clone)]
pub struct ResponseSlot {
    inner: Arc<SlotInner>,
}

#[derive(Debug)]
struct SlotInner {
    /// `(outcome, completion time)`, set exactly once.
    done: Mutex<Option<(Result<ServeResponse, ServeError>, Instant)>>,
    ready: Condvar,
    /// Completion-queue delivery, when the slot was built with
    /// [`ResponseSlot::with_completion`].
    hook: Option<CompletionHook>,
}

impl ResponseSlot {
    pub fn new() -> ResponseSlot {
        ResponseSlot {
            inner: Arc::new(SlotInner {
                done: Mutex::new(None),
                ready: Condvar::new(),
                hook: None,
            }),
        }
    }

    /// A slot that additionally delivers its outcome to `cq` as a
    /// [`Completion`] tagged `tag` when first filled. The blocking /
    /// polling waiters keep working; the completion is a second copy of
    /// the outcome, pushed exactly once (first fill only).
    pub fn with_completion(cq: CompletionQueue, tag: u64) -> ResponseSlot {
        ResponseSlot {
            inner: Arc::new(SlotInner {
                done: Mutex::new(None),
                ready: Condvar::new(),
                hook: Some(CompletionHook {
                    cq,
                    tag,
                    enqueued: Instant::now(),
                }),
            }),
        }
    }

    /// Fill the slot (first fill wins; later fills are ignored). This
    /// idempotence is what worker-panic containment leans on: the
    /// respawning worker blanket-fills a poisoned batch's slots with
    /// [`ServeError::Internal`], and any slot the batch had already
    /// answered keeps its real outcome. Returns whether THIS call
    /// answered the slot (containment counts only tickets it actually
    /// poisoned). Slots built with [`ResponseSlot::with_completion`]
    /// also push a [`Completion`] — on the winning fill only.
    pub fn fill(&self, outcome: Result<ServeResponse, ServeError>) -> bool {
        let mut g = self.inner.done.lock().unwrap_or_else(|p| p.into_inner());
        if g.is_none() {
            let completed = Instant::now();
            if let Some(h) = &self.inner.hook {
                h.cq.push(Completion {
                    tag: h.tag,
                    outcome: outcome.clone(),
                    enqueued: h.enqueued,
                    completed,
                });
            }
            *g = Some((outcome, completed));
            self.inner.ready.notify_all();
            true
        } else {
            false
        }
    }

    /// Block until the slot is filled; returns the outcome and the instant
    /// the worker filled it (for open-loop latency accounting).
    pub fn wait_timed(&self) -> (Result<ServeResponse, ServeError>, Instant) {
        let mut g = self.inner.done.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(done) = g.take() {
                return done;
            }
            g = self.inner.ready.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Block until the slot is filled.
    pub fn wait(&self) -> Result<ServeResponse, ServeError> {
        self.wait_timed().0
    }

    /// Non-blocking poll: take the outcome if the worker has filled the
    /// slot, `None` otherwise (the slot stays waitable). Backs
    /// [`super::engine::PendingResponse::try_wait`].
    pub fn try_take(&self) -> Option<(Result<ServeResponse, ServeError>, Instant)> {
        self.inner
            .done
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .take()
    }

    /// Block until the slot is filled or `until` passes; `None` on
    /// timeout (the slot stays waitable). Backs
    /// [`super::engine::PendingResponse::wait_timeout`].
    pub fn wait_until(&self, until: Instant) -> Option<(Result<ServeResponse, ServeError>, Instant)> {
        let mut g = self.inner.done.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(done) = g.take() {
                return Some(done);
            }
            let now = Instant::now();
            if now >= until {
                return None;
            }
            let (g2, _timeout) = self
                .inner
                .ready
                .wait_timeout(g, until - now)
                .unwrap_or_else(|p| p.into_inner());
            g = g2;
        }
    }
}

impl Default for ResponseSlot {
    fn default() -> Self {
        ResponseSlot::new()
    }
}

/// A queued request: payload plus admission metadata.
#[derive(Debug)]
pub struct Ticket {
    pub request: ServeRequest,
    pub priority: Priority,
    pub slot: ResponseSlot,
    /// When the ticket entered the queue (latency measurement origin).
    pub enqueued: Instant,
    /// Absolute deadline; expired tickets are answered, not executed.
    pub deadline: Instant,
    /// Lifecycle stage marks (`marks.admit == enqueued`); the queue
    /// stamps `popped` at pop time, the batcher stamps the rest.
    pub marks: StageMarks,
}

impl Ticket {
    pub fn expired(&self, now: Instant) -> bool {
        now >= self.deadline
    }
}

/// Why [`AdmissionQueue::push`] refused a ticket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitError {
    /// Global queue capacity exhausted (every tenant is backpressured).
    Full,
    /// The ticket's own store has reached its admission quota; other
    /// stores' lanes still admit.
    TenantFull,
    Closed,
}

impl AdmitError {
    pub fn to_serve_error(self) -> ServeError {
        match self {
            AdmitError::Full => ServeError::Overloaded,
            AdmitError::TenantFull => ServeError::TenantOverloaded,
            AdmitError::Closed => ServeError::ShuttingDown,
        }
    }
}

/// Point-in-time reading of one store lane's scheduling state, reported
/// by [`AdmissionQueue::gauges`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneGauge {
    pub store: StoreId,
    /// Waiting tickets (both priority levels).
    pub len: usize,
    /// Waiting high-priority tickets (subset of `len`).
    pub high: usize,
    /// Pops remaining in the lane's current DRR turn.
    pub deficit: u32,
    pub weight: u32,
    pub quota: usize,
}

/// Scheduling parameters of one store's lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneSpec {
    /// Deficit-round-robin weight: pops per scheduler round while the
    /// lane is backlogged (clamped to ≥ 1).
    pub weight: u32,
    /// Admission quota: max tickets of this store waiting at once
    /// (clamped to ≥ 1; a lane at quota refuses with
    /// [`AdmitError::TenantFull`]).
    pub quota: usize,
}

struct Lane {
    high: VecDeque<Ticket>,
    normal: VecDeque<Ticket>,
    weight: u32,
    quota: usize,
    /// Pops remaining in this lane's current DRR turn; replenished to
    /// `weight` when the rotation arrives at a backlogged lane.
    deficit: u32,
}

impl Lane {
    fn new(spec: LaneSpec) -> Lane {
        Lane {
            high: VecDeque::new(),
            normal: VecDeque::new(),
            weight: spec.weight.max(1),
            quota: spec.quota.max(1),
            deficit: 0,
        }
    }

    fn len(&self) -> usize {
        self.high.len() + self.normal.len()
    }

    fn take(&mut self) -> Option<Ticket> {
        self.high.pop_front().or_else(|| self.normal.pop_front())
    }
}

struct QueueState {
    lanes: Vec<Lane>,
    /// Total queued tickets across lanes (kept in lockstep with the lane
    /// deques; cached so `push` is O(1)).
    len: usize,
    /// DRR rotation position.
    cursor: usize,
    closed: bool,
}

impl QueueState {
    /// Deficit-round-robin pop: serve the cursor lane until its deficit
    /// runs out or it empties, then advance. With unit ticket cost this
    /// gives each backlogged lane `weight` consecutive pops per round —
    /// boosted to `weight × HIGH_BOOST` while the lane holds
    /// high-priority tickets at refill time.
    fn take(&mut self) -> Option<Ticket> {
        if self.len == 0 {
            return None;
        }
        loop {
            let i = self.cursor % self.lanes.len();
            let lane = &mut self.lanes[i];
            if lane.len() == 0 {
                // Idle lanes forfeit their turn (and any stale deficit):
                // unused share is redistributed, not banked.
                lane.deficit = 0;
                self.cursor = i + 1;
                continue;
            }
            if lane.deficit == 0 {
                lane.deficit = if lane.high.is_empty() {
                    lane.weight
                } else {
                    lane.weight.saturating_mul(HIGH_BOOST)
                };
            }
            lane.deficit -= 1;
            let t = lane.take();
            if lane.deficit == 0 {
                self.cursor = i + 1;
            }
            self.len -= 1;
            return t;
        }
    }
}

/// Bounded MPMC admission queue (mutex + condvar; std-only) with one
/// lane per store.
pub struct AdmissionQueue {
    capacity: usize,
    state: Mutex<QueueState>,
    available: Condvar,
}

impl AdmissionQueue {
    /// Queue with no preconfigured lanes: each store id gets a lane on
    /// first push with weight 1 and quota = global capacity — exactly the
    /// pre-isolation behavior (only the global bound applies).
    pub fn new(capacity: usize) -> AdmissionQueue {
        Self::with_lanes(capacity, &[])
    }

    /// Queue with one preconfigured lane per store, indexed by
    /// [`StoreId`] order. Stores beyond `lanes` still get default lanes
    /// lazily (weight 1, quota = capacity).
    pub fn with_lanes(capacity: usize, lanes: &[LaneSpec]) -> AdmissionQueue {
        let capacity = capacity.max(1);
        AdmissionQueue {
            capacity,
            state: Mutex::new(QueueState {
                lanes: lanes.iter().map(|&s| Lane::new(s)).collect(),
                len: 0,
                cursor: 0,
                closed: false,
            }),
            available: Condvar::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, QueueState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Configure (or reconfigure) `store`'s lane at runtime — the
    /// serve-time store-creation path, so a hot-swapped store gets its
    /// spec'd weight and quota instead of the lazy defaults. Queued
    /// tickets and any unspent deficit are preserved; missing lanes up
    /// to `store` are created with defaults (weight 1, quota =
    /// capacity).
    pub fn set_lane(&self, store: StoreId, spec: LaneSpec) {
        let mut st = self.lock();
        let idx = store.index();
        if idx >= st.lanes.len() {
            let cap = self.capacity;
            st.lanes.resize_with(idx + 1, || {
                Lane::new(LaneSpec {
                    weight: 1,
                    quota: cap,
                })
            });
        }
        let lane = &mut st.lanes[idx];
        lane.weight = spec.weight.max(1);
        lane.quota = spec.quota.max(1);
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.lock().len
    }

    /// Waiting tickets in `store`'s lane — the batcher's degraded-mode
    /// depth probe. Stores without a lane yet report 0.
    pub fn lane_len(&self, store: StoreId) -> usize {
        let st = self.lock();
        st.lanes.get(store.index()).map_or(0, |l| l.len())
    }

    /// One consistent reading of the queue's scheduling state: total
    /// depth plus per-lane depth/deficit gauges (all under one lock, so
    /// lane lengths sum to the total). Surfaced through
    /// [`super::stats::StatsSnapshot`] and `BENCH_serve.json` so overload
    /// incidents are diagnosable from the bench artifact rather than
    /// only live `lane_len` probes.
    pub fn gauges(&self) -> (usize, Vec<LaneGauge>) {
        let st = self.lock();
        let lanes = st
            .lanes
            .iter()
            .enumerate()
            .map(|(i, l)| LaneGauge {
                store: StoreId(i),
                len: l.len(),
                high: l.high.len(),
                deficit: l.deficit,
                weight: l.weight,
                quota: l.quota,
            })
            .collect();
        (st.len, lanes)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admit a ticket, or hand it back with the rejection reason.
    /// Rejection is two-level: global capacity first
    /// ([`AdmitError::Full`] — everyone is backpressured), then the
    /// target store's own quota ([`AdmitError::TenantFull`] — only this
    /// tenant is shedding). Closed queues admit nothing.
    pub fn push(&self, ticket: Ticket) -> Result<(), (Ticket, AdmitError)> {
        let store = ticket.request.store.index();
        let mut st = self.lock();
        if st.closed {
            return Err((ticket, AdmitError::Closed));
        }
        if st.len >= self.capacity {
            return Err((ticket, AdmitError::Full));
        }
        if store >= st.lanes.len() {
            let cap = self.capacity;
            st.lanes.resize_with(store + 1, || {
                Lane::new(LaneSpec {
                    weight: 1,
                    quota: cap,
                })
            });
        }
        let lane = &mut st.lanes[store];
        if lane.len() >= lane.quota {
            return Err((ticket, AdmitError::TenantFull));
        }
        match ticket.priority {
            Priority::High => lane.high.push_back(ticket),
            Priority::Normal => lane.normal.push_back(ticket),
        }
        st.len += 1;
        drop(st);
        self.available.notify_one();
        Ok(())
    }

    /// Close the queue: no further admissions; blocked poppers drain what
    /// remains, then observe `None`.
    pub fn close(&self) {
        let mut st = self.lock();
        st.closed = true;
        drop(st);
        self.available.notify_all();
    }

    /// Whether [`AdmissionQueue::close`] has been called. The batcher
    /// probes this to skip holding a batch window open during shutdown.
    pub fn is_closed(&self) -> bool {
        self.lock().closed
    }

    /// Remove and return every queued ticket in one locked sweep — the
    /// abort-shutdown path ([`super::engine::ServeEngine`]'s `Drop` /
    /// `shutdown_now`), which answers each drained ticket
    /// [`ServeError::ShuttingDown`] instead of executing it. Tickets a
    /// worker popped before the sweep are unaffected (it answers them
    /// itself); the queue is left empty.
    pub fn drain_all(&self) -> Vec<Ticket> {
        let mut st = self.lock();
        let mut out = Vec::with_capacity(st.len);
        for lane in &mut st.lanes {
            out.extend(lane.high.drain(..));
            out.extend(lane.normal.drain(..));
            lane.deficit = 0;
        }
        st.len = 0;
        out
    }

    /// Pop the next ticket (DRR order), blocking while the queue is empty
    /// and open. Returns `None` once the queue is closed and drained.
    pub fn pop_blocking(&self) -> Option<Ticket> {
        let mut st = self.lock();
        loop {
            if let Some(mut t) = st.take() {
                t.marks.popped = Some(Instant::now());
                return Some(t);
            }
            if st.closed {
                return None;
            }
            st = self.available.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Pop the next ticket if one arrives before `until`; `None` on
    /// timeout or when closed-and-drained. Used by the micro-batcher to
    /// wait out the remainder of a batch window.
    pub fn pop_until(&self, until: Instant) -> Option<Ticket> {
        let mut st = self.lock();
        loop {
            if let Some(mut t) = st.take() {
                t.marks.popped = Some(Instant::now());
                return Some(t);
            }
            if st.closed {
                return None;
            }
            let now = Instant::now();
            if now >= until {
                return None;
            }
            let (g, _timeout) = self
                .available
                .wait_timeout(st, until - now)
                .unwrap_or_else(|p| p.into_inner());
            st = g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vsa::BinaryHV;

    fn ticket_on(store: usize, tag: usize, priority: Priority) -> Ticket {
        // encode `tag` in the top-k `k` field so pops are identifiable
        let now = Instant::now();
        Ticket {
            request: ServeRequest::recall_topk_on(StoreId(store), BinaryHV::zeros(64), tag),
            priority,
            slot: ResponseSlot::new(),
            enqueued: now,
            deadline: now + Duration::from_secs(60),
            marks: StageMarks::new(now),
        }
    }

    fn ticket(tag: usize, priority: Priority) -> Ticket {
        ticket_on(0, tag, priority)
    }

    fn tag_of(t: &Ticket) -> usize {
        match t.request.op {
            super::super::RequestOp::RecallTopK { k, .. } => k,
            _ => unreachable!(),
        }
    }

    #[test]
    fn fifo_within_priority_high_first() {
        let q = AdmissionQueue::new(8);
        q.push(ticket(0, Priority::Normal)).unwrap();
        q.push(ticket(1, Priority::High)).unwrap();
        q.push(ticket(2, Priority::Normal)).unwrap();
        q.push(ticket(3, Priority::High)).unwrap();
        let order: Vec<usize> = (0..4)
            .map(|_| tag_of(&q.pop_blocking().unwrap()))
            .collect();
        assert_eq!(order, [1, 3, 0, 2]);
    }

    #[test]
    fn rejects_when_full_then_admits_after_drain() {
        let q = AdmissionQueue::new(2);
        q.push(ticket(0, Priority::Normal)).unwrap();
        q.push(ticket(1, Priority::Normal)).unwrap();
        let (_, why) = q.push(ticket(2, Priority::Normal)).unwrap_err();
        assert_eq!(why, AdmitError::Full);
        assert_eq!(q.len(), 2);
        assert_eq!(tag_of(&q.pop_blocking().unwrap()), 0);
        q.push(ticket(3, Priority::Normal)).unwrap();
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_drains_then_none_and_rejects_new() {
        let q = AdmissionQueue::new(4);
        q.push(ticket(0, Priority::Normal)).unwrap();
        q.close();
        let (_, why) = q.push(ticket(1, Priority::Normal)).unwrap_err();
        assert_eq!(why, AdmitError::Closed);
        assert_eq!(tag_of(&q.pop_blocking().unwrap()), 0);
        assert!(q.pop_blocking().is_none());
        assert!(q.pop_until(Instant::now() + Duration::from_millis(1)).is_none());
    }

    #[test]
    fn pop_until_times_out_empty() {
        let q = AdmissionQueue::new(4);
        let t0 = Instant::now();
        assert!(q.pop_until(t0 + Duration::from_millis(10)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(10));
    }

    #[test]
    fn pop_unblocks_on_cross_thread_push() {
        let q = std::sync::Arc::new(AdmissionQueue::new(4));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_blocking().map(|t| tag_of(&t)));
        std::thread::sleep(Duration::from_millis(20));
        q.push(ticket(7, Priority::Normal)).unwrap();
        assert_eq!(h.join().unwrap(), Some(7));
    }

    #[test]
    fn tenant_quota_rejects_only_its_own_store() {
        let q = AdmissionQueue::with_lanes(
            16,
            &[
                LaneSpec { weight: 1, quota: 2 },
                LaneSpec { weight: 1, quota: 8 },
            ],
        );
        q.push(ticket_on(0, 0, Priority::Normal)).unwrap();
        q.push(ticket_on(0, 1, Priority::Normal)).unwrap();
        let (_, why) = q.push(ticket_on(0, 2, Priority::Normal)).unwrap_err();
        assert_eq!(why, AdmitError::TenantFull);
        assert_eq!(
            why.to_serve_error(),
            ServeError::TenantOverloaded,
            "tenant quota maps to the tenant-local error"
        );
        // the other store's lane is unaffected by store 0 being at quota
        q.push(ticket_on(1, 10, Priority::Normal)).unwrap();
        assert_eq!(q.lane_len(StoreId(0)), 2);
        assert_eq!(q.lane_len(StoreId(1)), 1);
        // draining store 0 reopens its lane
        let _ = q.pop_blocking().unwrap();
        let _ = q.pop_blocking().unwrap();
        let _ = q.pop_blocking().unwrap();
        q.push(ticket_on(0, 3, Priority::Normal)).unwrap();
    }

    #[test]
    fn drr_pop_order_follows_weights() {
        // store 0 weight 2, store 1 weight 1: backlogged rotation pops
        // two of store 0 for every one of store 1.
        let q = AdmissionQueue::with_lanes(
            32,
            &[
                LaneSpec { weight: 2, quota: 32 },
                LaneSpec { weight: 1, quota: 32 },
            ],
        );
        for i in 0..6 {
            q.push(ticket_on(0, i, Priority::Normal)).unwrap();
        }
        for i in 0..3 {
            q.push(ticket_on(1, 100 + i, Priority::Normal)).unwrap();
        }
        let order: Vec<usize> = (0..9)
            .map(|_| tag_of(&q.pop_blocking().unwrap()))
            .collect();
        assert_eq!(order, [0, 1, 100, 2, 3, 101, 4, 5, 102]);
    }

    #[test]
    fn drr_skips_idle_lanes_without_banking_deficit() {
        let q = AdmissionQueue::with_lanes(
            32,
            &[
                LaneSpec { weight: 4, quota: 32 },
                LaneSpec { weight: 1, quota: 32 },
            ],
        );
        // only store 1 has traffic: it pops immediately, every time,
        // regardless of store 0's larger weight.
        for i in 0..3 {
            q.push(ticket_on(1, i, Priority::Normal)).unwrap();
        }
        let order: Vec<usize> = (0..3)
            .map(|_| tag_of(&q.pop_blocking().unwrap()))
            .collect();
        assert_eq!(order, [0, 1, 2]);
    }

    #[test]
    fn high_priority_backlog_buys_cross_tenant_share() {
        // equal weights; lane 0 all High, lane 1 all Normal: lane 0's
        // refill doubles (HIGH_BOOST = 2), so the contended share is
        // 2:1 — priority bought cross-tenant throughput, not just
        // intra-lane ordering.
        let q = AdmissionQueue::with_lanes(
            32,
            &[
                LaneSpec { weight: 1, quota: 32 },
                LaneSpec { weight: 1, quota: 32 },
            ],
        );
        for i in 0..6 {
            q.push(ticket_on(0, i, Priority::High)).unwrap();
        }
        for i in 0..3 {
            q.push(ticket_on(1, 100 + i, Priority::Normal)).unwrap();
        }
        let order: Vec<usize> = (0..9)
            .map(|_| tag_of(&q.pop_blocking().unwrap()))
            .collect();
        assert_eq!(order, [0, 1, 100, 2, 3, 101, 4, 5, 102]);
    }

    #[test]
    fn priority_boost_decays_when_the_high_backlog_drains() {
        // lane 0 starts with 2 High then Normal-only; once the High
        // tickets are gone its refill drops back to its weight and the
        // rotation returns to 1:1.
        let q = AdmissionQueue::with_lanes(
            32,
            &[
                LaneSpec { weight: 1, quota: 32 },
                LaneSpec { weight: 1, quota: 32 },
            ],
        );
        q.push(ticket_on(0, 0, Priority::High)).unwrap();
        q.push(ticket_on(0, 1, Priority::High)).unwrap();
        for i in 2..5 {
            q.push(ticket_on(0, i, Priority::Normal)).unwrap();
        }
        for i in 0..4 {
            q.push(ticket_on(1, 100 + i, Priority::Normal)).unwrap();
        }
        let order: Vec<usize> = (0..9)
            .map(|_| tag_of(&q.pop_blocking().unwrap()))
            .collect();
        // boosted round: 0,1 then lane 1; after the High drain, 1:1
        assert_eq!(order, [0, 1, 100, 2, 101, 3, 102, 4, 103]);
    }

    #[test]
    fn no_priority_mix_starves_a_backlogged_lane() {
        // Property: under any weights (1..=3) and any priority mix,
        // while a lane still has waiting tickets it goes at most
        // Σ_{other lanes} (HIGH_BOOST × weight) consecutive pops
        // without service — the DRR rotation bounds priority's reach.
        crate::util::prop::forall(
            0xD1,
            40,
            |rng| {
                let n_lanes = 2 + rng.below(3);
                let weights: Vec<u32> = (0..n_lanes).map(|_| 1 + rng.below(3) as u32).collect();
                let backlogs: Vec<Vec<Priority>> = (0..n_lanes)
                    .map(|_| {
                        (0..4 + rng.below(12))
                            .map(|_| {
                                if rng.chance(0.5) {
                                    Priority::High
                                } else {
                                    Priority::Normal
                                }
                            })
                            .collect()
                    })
                    .collect();
                (weights, backlogs)
            },
            |(weights, backlogs)| {
                let specs: Vec<LaneSpec> = weights
                    .iter()
                    .map(|&w| LaneSpec { weight: w, quota: 256 })
                    .collect();
                let q = AdmissionQueue::with_lanes(256, &specs);
                let mut remaining = vec![0usize; specs.len()];
                for (lane, prios) in backlogs.iter().enumerate() {
                    for &p in prios {
                        q.push(ticket_on(lane, lane, p)).unwrap();
                        remaining[lane] += 1;
                    }
                }
                let total: usize = remaining.iter().sum();
                let mut since_served = vec![0usize; specs.len()];
                for _ in 0..total {
                    let lane = tag_of(&q.pop_blocking().unwrap());
                    remaining[lane] -= 1;
                    since_served[lane] = 0;
                    for other in 0..specs.len() {
                        if other == lane {
                            continue;
                        }
                        if remaining[other] > 0 {
                            since_served[other] += 1;
                            let bound: usize = weights
                                .iter()
                                .enumerate()
                                .filter(|&(j, _)| j != other)
                                .map(|(_, &w)| (w * HIGH_BOOST) as usize)
                                .sum();
                            assert!(
                                since_served[other] <= bound,
                                "lane {other} starved: {since_served:?} > bound {bound} \
                                 (weights {weights:?})"
                            );
                        }
                    }
                }
                true
            },
        );
    }

    #[test]
    fn set_lane_reconfigures_at_runtime_preserving_tickets() {
        let q = AdmissionQueue::new(16);
        q.push(ticket_on(0, 0, Priority::Normal)).unwrap();
        // lazily created lane has quota = capacity; tighten it live
        q.set_lane(StoreId(0), LaneSpec { weight: 3, quota: 1 });
        let (_, why) = q.push(ticket_on(0, 1, Priority::Normal)).unwrap_err();
        assert_eq!(why, AdmitError::TenantFull, "new quota applies immediately");
        // the queued ticket survived the reconfigure
        assert_eq!(q.lane_len(StoreId(0)), 1);
        assert_eq!(tag_of(&q.pop_blocking().unwrap()), 0);
        // set_lane creates missing lanes (a hot-swapped store's id)
        q.set_lane(StoreId(2), LaneSpec { weight: 2, quota: 4 });
        let (_, lanes) = q.gauges();
        assert_eq!(lanes.len(), 3);
        assert_eq!((lanes[2].weight, lanes[2].quota), (2, 4));
        assert_eq!((lanes[1].weight, lanes[1].quota), (1, 16), "gap lane gets defaults");
    }

    #[test]
    fn slot_fill_once_and_wait() {
        let slot = ResponseSlot::new();
        let s2 = slot.clone();
        let h = std::thread::spawn(move || s2.wait());
        std::thread::sleep(Duration::from_millis(10));
        slot.fill(Err(ServeError::DeadlineExceeded));
        slot.fill(Err(ServeError::Overloaded)); // ignored: first fill wins
        assert_eq!(h.join().unwrap(), Err(ServeError::DeadlineExceeded));
    }

    #[test]
    fn slot_try_take_and_wait_until() {
        let slot = ResponseSlot::new();
        assert!(slot.try_take().is_none(), "unfilled slot polls empty");
        // timeout path leaves the slot waitable
        assert!(slot
            .wait_until(Instant::now() + Duration::from_millis(5))
            .is_none());
        slot.fill(Err(ServeError::Overloaded));
        let (outcome, _) = slot.try_take().expect("filled slot polls ready");
        assert_eq!(outcome, Err(ServeError::Overloaded));
        // take-once semantics: a second poll sees nothing
        assert!(slot.try_take().is_none());

        // wait_until returns as soon as a cross-thread fill lands
        let slot = ResponseSlot::new();
        let s2 = slot.clone();
        let h = std::thread::spawn(move || {
            s2.wait_until(Instant::now() + Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(10));
        slot.fill(Err(ServeError::DeadlineExceeded));
        let (outcome, _) = h.join().unwrap().expect("fill beats the deadline");
        assert_eq!(outcome, Err(ServeError::DeadlineExceeded));
    }

    #[test]
    fn expired_ticket_detection() {
        let now = Instant::now();
        let mut t = ticket(0, Priority::Normal);
        t.deadline = now;
        assert!(t.expired(now));
        assert!(t.expired(now + Duration::from_millis(1)));
        t.deadline = now + Duration::from_secs(1);
        assert!(!t.expired(now));
    }

    #[test]
    fn pop_stamps_the_queue_pop_mark() {
        let q = AdmissionQueue::new(4);
        let t = ticket(0, Priority::Normal);
        assert!(t.marks.popped.is_none());
        q.push(t).unwrap();
        let popped = q.pop_blocking().unwrap();
        let mark = popped.marks.popped.expect("pop_blocking stamps popped");
        assert!(mark >= popped.marks.admit, "pop mark is monotone vs admit");
        // pop_until stamps too
        q.push(ticket(1, Priority::Normal)).unwrap();
        let popped = q
            .pop_until(Instant::now() + Duration::from_millis(50))
            .unwrap();
        assert!(popped.marks.popped.is_some());
    }

    #[test]
    fn gauges_report_depth_deficit_and_lane_config() {
        let q = AdmissionQueue::with_lanes(
            32,
            &[
                LaneSpec { weight: 2, quota: 8 },
                LaneSpec { weight: 1, quota: 4 },
            ],
        );
        for i in 0..3 {
            q.push(ticket_on(0, i, Priority::Normal)).unwrap();
        }
        q.push(ticket_on(1, 100, Priority::High)).unwrap();
        let (depth, lanes) = q.gauges();
        assert_eq!(depth, 4);
        assert_eq!(lanes.len(), 2);
        assert_eq!(lanes.iter().map(|l| l.len).sum::<usize>(), depth);
        assert_eq!(lanes[0].store, StoreId(0));
        assert_eq!(lanes[0].len, 3);
        assert_eq!(lanes[0].high, 0);
        assert_eq!((lanes[0].weight, lanes[0].quota), (2, 8));
        assert_eq!(lanes[1].len, 1);
        assert_eq!(lanes[1].high, 1);
        assert_eq!((lanes[1].weight, lanes[1].quota), (1, 4));
        // Mid-turn, lane 0 holds unspent deficit: weight 2 replenished,
        // one pop consumed.
        let _ = q.pop_blocking().unwrap();
        let (depth, lanes) = q.gauges();
        assert_eq!(depth, 3);
        assert_eq!(lanes[0].deficit, 1);
    }

    #[test]
    fn completion_slot_delivers_exactly_once_in_fill_order() {
        let cq = CompletionQueue::new();
        let a = ResponseSlot::with_completion(cq.clone(), 7);
        let b = ResponseSlot::with_completion(cq.clone(), 9);
        assert!(b.fill(Err(ServeError::Overloaded)));
        assert!(a.fill(Err(ServeError::Internal)));
        // second fill loses the race: no duplicate completion
        assert!(!a.fill(Err(ServeError::Overloaded)));
        let tags: Vec<u64> = cq.drain_ready().iter().map(|c| c.tag).collect();
        assert_eq!(tags, [9, 7], "completions arrive in fill order, tagged");
        // the slot's own waiters still work alongside the hook
        assert_eq!(a.wait(), Err(ServeError::Internal));
        // a plain slot pushes nothing
        ResponseSlot::new().fill(Err(ServeError::Overloaded));
        assert!(cq.is_empty());
    }

    #[test]
    fn completion_queue_close_drains_then_drops() {
        let cq = CompletionQueue::new();
        let slot = ResponseSlot::with_completion(cq.clone(), 1);
        slot.fill(Err(ServeError::DeadlineExceeded));
        cq.close();
        // already-delivered completions drain...
        let c = cq.pop_blocking().expect("pre-close completion survives");
        assert_eq!(c.tag, 1);
        assert_eq!(c.outcome, Err(ServeError::DeadlineExceeded));
        assert!(c.completed >= c.enqueued);
        // ...then closed-and-empty pops return None without blocking
        assert!(cq.pop_blocking().is_none());
        // fills after close are dropped, not buffered and not a panic
        let late = ResponseSlot::with_completion(cq.clone(), 2);
        assert!(late.fill(Err(ServeError::Internal)));
        assert!(cq.is_empty());
        assert_eq!(late.wait(), Err(ServeError::Internal), "slot waiters unaffected");
        // timed pop times out cleanly on an open empty queue
        let open = CompletionQueue::new();
        assert!(open.pop_until(Instant::now() + Duration::from_millis(5)).is_none());
        assert!(!open.is_closed());
    }

    #[test]
    fn drain_all_empties_every_lane_and_reports_closed() {
        let q = AdmissionQueue::with_lanes(
            16,
            &[
                LaneSpec { weight: 1, quota: 8 },
                LaneSpec { weight: 1, quota: 8 },
            ],
        );
        for i in 0..3 {
            q.push(ticket_on(0, i, Priority::Normal)).unwrap();
        }
        q.push(ticket_on(1, 10, Priority::High)).unwrap();
        assert!(!q.is_closed());
        q.close();
        assert!(q.is_closed());
        let drained = q.drain_all();
        assert_eq!(drained.len(), 4, "every queued ticket handed back");
        assert_eq!(q.len(), 0);
        assert!(q.drain_all().is_empty(), "second sweep finds nothing");
        // closed-and-drained: poppers observe None immediately
        assert!(q.pop_blocking().is_none());
    }
}
