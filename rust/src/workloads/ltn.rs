//! LTN — Logic Tensor Networks (Badreddine et al. [26]): fuzzy first-order
//! logic grounded in tensors.  The neural phase (MLP predicate grounding)
//! runs as the `ltn_grounding` HLO artifact; the symbolic phase evaluates
//! fuzzy connectives and quantifier aggregations over the grounded truth
//! degrees (product t-norm / pMeanError, as in the reference
//! implementation).

use super::Workload;
use crate::profiler::memstat::MemoryStats;
use crate::profiler::taxonomy::{OpCategory, PhaseKind};
use crate::profiler::trace::Trace;

/// Fuzzy-logic operators (product real logic).
pub mod fuzzy {
    /// t-norm (AND).
    pub fn and(a: f64, b: f64) -> f64 {
        a * b
    }

    /// t-conorm (OR).
    pub fn or(a: f64, b: f64) -> f64 {
        a + b - a * b
    }

    pub fn not(a: f64) -> f64 {
        1.0 - a
    }

    /// Reichenbach implication.
    pub fn implies(a: f64, b: f64) -> f64 {
        1.0 - a + a * b
    }

    /// `forall` as pMeanError aggregation (p=2): 1 - mean((1-x)^p)^(1/p).
    pub fn forall(xs: &[f64], p: f64) -> f64 {
        if xs.is_empty() {
            return 1.0;
        }
        let m = xs.iter().map(|x| (1.0 - x).powf(p)).sum::<f64>() / xs.len() as f64;
        1.0 - m.powf(1.0 / p)
    }

    /// `exists` as pMean aggregation.
    pub fn exists(xs: &[f64], p: f64) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        (xs.iter().map(|x| x.powf(p)).sum::<f64>() / xs.len() as f64).powf(1.0 / p)
    }
}

/// An axiom over grounded predicate truth tables.
#[derive(Debug, Clone)]
pub enum Axiom {
    /// ∀x: P(x) → Q(x)
    ForallImplies { p: usize, q: usize },
    /// ∀x: ¬(P(x) ∧ Q(x))   (mutual exclusion)
    ForallNand { p: usize, q: usize },
    /// ∃x: P(x)
    Exists { p: usize },
}

/// Knowledge-base satisfaction over a batch of groundings.
/// `truth[s][p]` = degree of predicate `p` on sample `s`.
pub fn satisfaction(truth: &[Vec<f64>], axioms: &[Axiom], p_agg: f64) -> f64 {
    let per_axiom: Vec<f64> = axioms
        .iter()
        .map(|ax| match ax {
            Axiom::ForallImplies { p, q } => {
                let vals: Vec<f64> = truth
                    .iter()
                    .map(|t| fuzzy::implies(t[*p], t[*q]))
                    .collect();
                fuzzy::forall(&vals, p_agg)
            }
            Axiom::ForallNand { p, q } => {
                let vals: Vec<f64> = truth
                    .iter()
                    .map(|t| fuzzy::not(fuzzy::and(t[*p], t[*q])))
                    .collect();
                fuzzy::forall(&vals, p_agg)
            }
            Axiom::Exists { p } => {
                let vals: Vec<f64> = truth.iter().map(|t| t[*p]).collect();
                fuzzy::exists(&vals, p_agg)
            }
        })
        .collect();
    fuzzy::forall(&per_axiom, p_agg)
}

/// LTN workload (crabs-style tabular querying task).
#[derive(Debug, Clone)]
pub struct Ltn {
    /// Grounding batch size.
    pub batch: usize,
    /// Predicate count.
    pub predicates: usize,
    /// Axiom count.
    pub axioms: usize,
    /// Query batches per characterization run.
    pub queries: usize,
}

impl Default for Ltn {
    fn default() -> Self {
        Ltn {
            batch: 512,
            predicates: 6,
            axioms: 24,
            queries: 16,
        }
    }
}

impl Workload for Ltn {
    fn name(&self) -> &'static str {
        "LTN"
    }

    fn ns_category(&self) -> &'static str {
        "Neuro→Symbolic"
    }

    fn trace(&self) -> Trace {
        let mut tr = Trace::new("LTN");
        let b = self.batch as u64;
        let p = self.predicates as u64;
        for _ in 0..self.queries {
            // ---- neural: MLP grounding (heavy MatMul, the paper's note) -
            let m1 = tr.add(
                "mlp1",
                OpCategory::MatMul,
                PhaseKind::Neural,
                2 * b * 8 * 64,
                (b * 8 + 8 * 64) * 4,
                b * 64 * 4,
                &[],
            );
            let e1 = tr.add(
                "elu1",
                OpCategory::VectorElem,
                PhaseKind::Neural,
                b * 64 * 4,
                b * 64 * 8,
                0,
                &[m1],
            );
            let m2 = tr.add(
                "mlp2",
                OpCategory::MatMul,
                PhaseKind::Neural,
                2 * b * 64 * 64,
                (b * 64 + 64 * 64) * 4,
                b * 64 * 4,
                &[e1],
            );
            let m3 = tr.add(
                "mlp_head",
                OpCategory::MatMul,
                PhaseKind::Neural,
                2 * b * 64 * p,
                b * 64 * 4,
                b * p * 4,
                &[m2],
            );
            let sig = tr.add(
                "sigmoid",
                OpCategory::VectorElem,
                PhaseKind::Neural,
                b * p * 4,
                b * p * 8,
                0,
                &[m3],
            );
            // ---- symbolic: fuzzy connectives + quantifier aggregations --
            // Each axiom evaluation re-grounds its predicates on the
            // axiom's variable tuples through the MLP (neural), then
            // applies the fuzzy connective and quantifier (symbolic) —
            // the paper measures LTN near 48/52 neural/symbolic.
            let mut last = sig;
            for ax in 0..self.axioms as u64 {
                let reground = tr.add(
                    format!("axiom_grounding{ax}"),
                    OpCategory::MatMul,
                    PhaseKind::Neural,
                    2 * b * 64 * p,
                    (b * 64 + 64 * p) * 4,
                    b * p * 4,
                    &[m2],
                );
                let embed = tr.add(
                    "tuple_embed",
                    OpCategory::DataTransform,
                    PhaseKind::Neural,
                    b * p,
                    b * p * 8,
                    b * p * 4,
                    &[reground],
                );
                let conn = tr.add(
                    format!("fuzzy_connective{ax}"),
                    OpCategory::VectorElem,
                    PhaseKind::Symbolic,
                    b * 3,
                    b * 16,
                    b * 8,
                    &[embed],
                );
                let agg = tr.add(
                    format!("quantifier_agg{ax}"),
                    OpCategory::VectorElem,
                    PhaseKind::Symbolic,
                    b * 4,
                    b * 8,
                    8,
                    &[conn],
                );
                let logic = tr.add(
                    "axiom_logic",
                    OpCategory::Other,
                    PhaseKind::Symbolic,
                    8,
                    64,
                    8,
                    &[agg],
                );
                last = logic;
            }
            tr.add(
                "kb_satisfaction",
                OpCategory::Other,
                PhaseKind::Symbolic,
                self.axioms as u64 * 4,
                self.axioms as u64 * 8,
                8,
                &[last],
            );
        }
        tr
    }

    fn memory(&self) -> MemoryStats {
        MemoryStats {
            weights_bytes: (8 * 64 + 64 * 64 + 64 * self.predicates as u64) * 4,
            codebook_bytes: self.axioms as u64 * 64,
            neural_working_bytes: self.batch as u64 * 64 * 4,
            symbolic_working_bytes: self.batch as u64 * self.predicates as u64 * 8,
        }
    }

    fn symbolic_depends_on_neural(&self) -> bool {
        false // logic compiles into constraints on the network output
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fuzzy_ops_boundary_values() {
        assert_eq!(fuzzy::and(1.0, 1.0), 1.0);
        assert_eq!(fuzzy::and(1.0, 0.0), 0.0);
        assert_eq!(fuzzy::or(0.0, 0.0), 0.0);
        assert_eq!(fuzzy::or(1.0, 0.0), 1.0);
        assert_eq!(fuzzy::implies(0.0, 0.0), 1.0);
        assert_eq!(fuzzy::implies(1.0, 0.0), 0.0);
        assert_eq!(fuzzy::not(0.3), 0.7);
    }

    #[test]
    fn forall_rewards_uniform_truth() {
        let all_true = vec![1.0; 10];
        let mostly = vec![0.9; 10];
        let half = vec![0.5; 10];
        assert!(fuzzy::forall(&all_true, 2.0) > fuzzy::forall(&mostly, 2.0));
        assert!(fuzzy::forall(&mostly, 2.0) > fuzzy::forall(&half, 2.0));
    }

    #[test]
    fn exists_detects_single_witness() {
        let mut xs = vec![0.05; 20];
        let none = fuzzy::exists(&xs, 6.0);
        xs[7] = 0.95;
        let one = fuzzy::exists(&xs, 6.0);
        assert!(one > 2.0 * none, "{one} vs {none}");
    }

    #[test]
    fn satisfaction_of_consistent_kb_is_high() {
        // P → Q where Q is true whenever P is
        let truth: Vec<Vec<f64>> = (0..50)
            .map(|i| {
                let p = if i % 2 == 0 { 0.95 } else { 0.05 };
                vec![p, p] // Q tracks P
            })
            .collect();
        let sat = satisfaction(&truth, &[Axiom::ForallImplies { p: 0, q: 1 }], 2.0);
        assert!(sat > 0.85, "sat {sat}");
        // contradictory KB scores low
        let bad: Vec<Vec<f64>> = (0..50).map(|_| vec![0.95, 0.05]).collect();
        let sat_bad = satisfaction(&bad, &[Axiom::ForallImplies { p: 0, q: 1 }], 2.0);
        assert!(sat_bad < 0.4, "sat_bad {sat_bad}");
    }

    #[test]
    fn nand_axiom_enforces_exclusion() {
        let exclusive: Vec<Vec<f64>> = (0..20)
            .map(|i| if i % 2 == 0 { vec![0.9, 0.1] } else { vec![0.1, 0.9] })
            .collect();
        let overlapping: Vec<Vec<f64>> = (0..20).map(|_| vec![0.9, 0.9]).collect();
        let ax = [Axiom::ForallNand { p: 0, q: 1 }];
        assert!(satisfaction(&exclusive, &ax, 2.0) > satisfaction(&overlapping, &ax, 2.0));
    }
}
