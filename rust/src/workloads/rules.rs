//! Probabilistic rule engine shared by PrAE and NVSA: rule-likelihood
//! scoring (abduction) and rule execution (prediction) over per-attribute
//! PMFs — the paper's "probabilistic abduction and execution" core.

use super::raven::Rule;

/// Normalize a PMF in place (no-op for all-zero input).
pub fn normalize(pmf: &mut [f64]) {
    let s: f64 = pmf.iter().sum();
    if s > 1e-300 {
        for p in pmf.iter_mut() {
            *p /= s;
        }
    }
}

/// Likelihood that a complete row of PMFs follows `rule`.
pub fn row_likelihood(rule: Rule, row: &[&[f64]], k: usize) -> f64 {
    let g = row.len();
    match rule {
        Rule::Constant => (0..k).map(|v| row.iter().map(|p| p[v]).product::<f64>()).sum(),
        Rule::Progression(s) => (0..k)
            .map(|v0| {
                (0..g)
                    .map(|c| row[c][((v0 as i64 + s as i64 * c as i64).rem_euclid(k as i64)) as usize])
                    .product::<f64>()
            })
            .sum(),
        Rule::Arithmetic => {
            // last = sum of predecessors mod k; marginalize predecessors.
            // dist over running sum:
            let mut sum_dist = vec![0.0f64; k];
            sum_dist[0] = 1.0;
            let mut lik = 0.0;
            let mut joint = 1.0;
            let _ = joint;
            // convolve predecessor PMFs
            for c in 0..g - 1 {
                let mut next = vec![0.0f64; k];
                for (s0, &ps) in sum_dist.iter().enumerate() {
                    if ps == 0.0 {
                        continue;
                    }
                    for (v, &pv) in row[c].iter().enumerate() {
                        next[(s0 + v) % k] += ps * pv;
                    }
                }
                sum_dist = next;
            }
            for (v, &pl) in row[g - 1].iter().enumerate() {
                lik += sum_dist[v] * pl;
            }
            joint = lik;
            joint
        }
        Rule::DistributeThree => {
            // rows are cyclic rotations of a value multiset; score all
            // rotations of the row's own argmax multiset.
            let base: Vec<usize> = row
                .iter()
                .map(|p| argmax(p))
                .collect();
            (0..g)
                .map(|r| {
                    (0..g)
                        .map(|c| row[c][base[(c + r) % g]])
                        .product::<f64>()
                })
                .sum::<f64>()
                / g as f64
        }
    }
}

/// Abduce the rule for an attribute from the complete rows.
/// `rows[r]` holds the PMFs of row r's panels. Returns (best rule,
/// normalized posterior over `Rule::ALL`).
pub fn abduce(rows: &[Vec<&[f64]>], k: usize) -> (Rule, Vec<f64>) {
    let mut post: Vec<f64> = Rule::ALL
        .iter()
        .map(|r| {
            rows.iter()
                .map(|row| row_likelihood(*r, row, k).max(1e-12))
                .product::<f64>()
        })
        .collect();
    normalize(&mut post);
    let best = post
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    (Rule::ALL[best], post)
}

/// Execute `rule` on a partial last row (g-1 known PMFs) to predict the
/// missing panel's PMF.
pub fn execute(rule: Rule, partial: &[&[f64]], k: usize, first_row: &[&[f64]]) -> Vec<f64> {
    let g = partial.len() + 1;
    let mut pred = vec![0.0f64; k];
    match rule {
        Rule::Constant => {
            for (v, p) in pred.iter_mut().enumerate() {
                *p = partial.iter().map(|q| q[v]).product();
            }
        }
        Rule::Progression(s) => {
            for (v, p) in pred.iter_mut().enumerate() {
                // v = v0 + s*(g-1); check consistency of all known cells
                let v0 = (v as i64 - s as i64 * (g as i64 - 1)).rem_euclid(k as i64);
                *p = (0..g - 1)
                    .map(|c| {
                        partial[c][((v0 + s as i64 * c as i64).rem_euclid(k as i64)) as usize]
                    })
                    .product();
            }
        }
        Rule::Arithmetic => {
            let mut sum_dist = vec![0.0f64; k];
            sum_dist[0] = 1.0;
            for q in partial {
                let mut next = vec![0.0f64; k];
                for (s0, &ps) in sum_dist.iter().enumerate() {
                    if ps == 0.0 {
                        continue;
                    }
                    for (v, &pv) in q.iter().enumerate() {
                        next[(s0 + v) % k] += ps * pv;
                    }
                }
                sum_dist = next;
            }
            pred = sum_dist;
        }
        Rule::DistributeThree => {
            // remaining value of the first row's multiset after removing
            // the partial row's argmaxes
            let mut multiset: Vec<usize> = first_row.iter().map(|p| argmax(p)).collect();
            for q in partial {
                let v = argmax(q);
                if let Some(pos) = multiset.iter().position(|&m| m == v) {
                    multiset.remove(pos);
                }
            }
            if multiset.is_empty() {
                pred = vec![1.0 / k as f64; k];
            } else {
                for m in multiset {
                    pred[m] += 1.0;
                }
            }
        }
    }
    normalize(&mut pred);
    if pred.iter().sum::<f64>() < 0.5 {
        // degenerate: fall back to uniform
        pred = vec![1.0 / k as f64; k];
    }
    pred
}

/// Argmax of a PMF.
pub fn argmax(p: &[f64]) -> usize {
    p.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::workloads::raven::{self, N_ATTRS};

    fn peaked(v: usize, k: usize) -> Vec<f64> {
        let mut p = vec![0.02 / (k - 1) as f64; k];
        p[v] = 0.98;
        normalize(&mut p);
        p
    }

    #[test]
    fn constant_rule_scores_highest_on_constant_row() {
        let k = 8;
        let row: Vec<Vec<f64>> = vec![peaked(3, k), peaked(3, k), peaked(3, k)];
        let refs: Vec<&[f64]> = row.iter().map(|p| p.as_slice()).collect();
        let lc = row_likelihood(Rule::Constant, &refs, k);
        let lp = row_likelihood(Rule::Progression(1), &refs, k);
        assert!(lc > 10.0 * lp, "{lc} vs {lp}");
    }

    #[test]
    fn progression_execute_predicts_next() {
        let k = 8;
        let partial: Vec<Vec<f64>> = vec![peaked(2, k), peaked(3, k)];
        let refs: Vec<&[f64]> = partial.iter().map(|p| p.as_slice()).collect();
        let first: Vec<Vec<f64>> = vec![peaked(0, k), peaked(1, k), peaked(2, k)];
        let frefs: Vec<&[f64]> = first.iter().map(|p| p.as_slice()).collect();
        let pred = execute(Rule::Progression(1), &refs, k, &frefs);
        assert_eq!(argmax(&pred), 4);
    }

    #[test]
    fn arithmetic_execute_predicts_sum() {
        let k = 8;
        let partial: Vec<Vec<f64>> = vec![peaked(5, k), peaked(6, k)];
        let refs: Vec<&[f64]> = partial.iter().map(|p| p.as_slice()).collect();
        let pred = execute(Rule::Arithmetic, &refs, k, &refs);
        assert_eq!(argmax(&pred), (5 + 6) % 8);
    }

    #[test]
    fn abduction_recovers_generator_rules() {
        let mut rng = Rng::new(7);
        let mut correct = 0;
        let mut total = 0;
        for _ in 0..40 {
            let inst = raven::generate(&mut rng, 3, 8);
            let pmfs = raven::panel_pmfs(&inst, 0.97);
            for a in 0..N_ATTRS {
                // two complete rows
                let rows: Vec<Vec<&[f64]>> = (0..2)
                    .map(|r| {
                        (0..3)
                            .map(|c| pmfs[r * 3 + c][a].as_slice())
                            .collect()
                    })
                    .collect();
                let (got, post) = abduce(&rows, 8);
                assert!((post.iter().sum::<f64>() - 1.0).abs() < 1e-9);
                total += 1;
                // rule identity can be ambiguous (e.g. constant rows also
                // fit D3 rotations); count exact matches
                if got == inst.rules[a] {
                    correct += 1;
                }
            }
        }
        assert!(
            correct as f64 / total as f64 > 0.6,
            "rule recovery too weak: {correct}/{total}"
        );
    }

    #[test]
    fn pmfs_stay_normalized_through_execute() {
        let k = 8;
        let partial: Vec<Vec<f64>> = vec![peaked(1, k), peaked(4, k)];
        let refs: Vec<&[f64]> = partial.iter().map(|p| p.as_slice()).collect();
        for rule in Rule::ALL {
            let pred = execute(rule, &refs, k, &refs);
            assert!((pred.iter().sum::<f64>() - 1.0).abs() < 1e-9, "{rule:?}");
        }
    }
}
