//! LNN — Logical Neural Networks (Riegel et al. [23]): weighted real-
//! valued logic over a formula syntax tree with truth *bounds* [L, U]
//! per node, inferred by iterated upward (evaluation) and downward
//! (backward bound-tightening) passes of Łukasiewicz logic — the paper's
//! bidirectional-dataflow, data-movement-bound workload.

use super::Workload;
use crate::profiler::memstat::MemoryStats;
use crate::profiler::taxonomy::{OpCategory, PhaseKind};
use crate::profiler::trace::Trace;
use crate::util::Rng;

/// Formula tree node.
#[derive(Debug, Clone)]
pub enum Node {
    /// Leaf proposition with initial bounds.
    Prop { lower: f64, upper: f64 },
    /// Weighted Łukasiewicz conjunction.
    And(Vec<usize>),
    /// Weighted Łukasiewicz disjunction.
    Or(Vec<usize>),
    Not(usize),
    /// Implication lhs → rhs.
    Implies(usize, usize),
}

/// A logical neural network: nodes in topological order (children before
/// parents) with per-node truth bounds.
#[derive(Debug, Clone)]
pub struct LnnGraph {
    pub nodes: Vec<Node>,
    pub bounds: Vec<(f64, f64)>,
}

fn clamp01(x: f64) -> f64 {
    x.clamp(0.0, 1.0)
}

impl LnnGraph {
    pub fn new(nodes: Vec<Node>) -> Self {
        let bounds = nodes
            .iter()
            .map(|n| match n {
                Node::Prop { lower, upper } => (*lower, *upper),
                _ => (0.0, 1.0),
            })
            .collect();
        LnnGraph { nodes, bounds }
    }

    /// One upward pass: recompute parent bounds from children
    /// (Łukasiewicz t-norms on interval arithmetic). Returns the largest
    /// bound change.
    pub fn upward(&mut self) -> f64 {
        let mut delta: f64 = 0.0;
        for i in 0..self.nodes.len() {
            let nb = match &self.nodes[i] {
                Node::Prop { .. } => self.bounds[i],
                Node::And(cs) => {
                    let l = clamp01(
                        cs.iter().map(|&c| self.bounds[c].0).sum::<f64>()
                            - (cs.len() as f64 - 1.0),
                    );
                    let u = clamp01(
                        cs.iter().map(|&c| self.bounds[c].1).sum::<f64>()
                            - (cs.len() as f64 - 1.0),
                    );
                    (l, u)
                }
                Node::Or(cs) => {
                    let l = clamp01(cs.iter().map(|&c| self.bounds[c].0).sum::<f64>());
                    let u = clamp01(cs.iter().map(|&c| self.bounds[c].1).sum::<f64>());
                    (l, u)
                }
                Node::Not(c) => (1.0 - self.bounds[*c].1, 1.0 - self.bounds[*c].0),
                Node::Implies(a, b) => {
                    // a→b ≡ min(1, 1 - L_a + U_b) style Łukasiewicz
                    let l = clamp01(1.0 - self.bounds[*a].1 + self.bounds[*b].0);
                    let u = clamp01(1.0 - self.bounds[*a].0 + self.bounds[*b].1);
                    (l, u)
                }
            };
            // bounds only tighten (monotone inference)
            let tightened = (nb.0.max(self.bounds[i].0), nb.1.min(self.bounds[i].1));
            let nb = if tightened.0 <= tightened.1 {
                tightened
            } else {
                nb // inconsistency: keep raw (caller can detect)
            };
            delta = delta
                .max((nb.0 - self.bounds[i].0).abs())
                .max((nb.1 - self.bounds[i].1).abs());
            self.bounds[i] = nb;
        }
        delta
    }

    /// One downward pass: propagate implication heads back to tighten
    /// antecedent/consequent bounds (modus ponens / tollens).
    pub fn downward(&mut self) -> f64 {
        let mut delta: f64 = 0.0;
        for i in (0..self.nodes.len()).rev() {
            if let Node::Implies(a, b) = self.nodes[i] {
                let (l_i, _) = self.bounds[i];
                let (l_a, u_a) = self.bounds[a];
                let (l_b, u_b) = self.bounds[b];
                // if implication is known true and antecedent true, the
                // consequent's lower bound rises: L_b ≥ L_a + L_i - 1.
                let new_lb = clamp01(l_a + l_i - 1.0).max(l_b);
                // modus tollens: U_a ≤ 1 - L_i + U_b
                let new_ua = clamp01(1.0 - l_i + u_b).min(u_a);
                delta = delta.max(new_lb - l_b).max(u_a - new_ua);
                self.bounds[b].0 = new_lb;
                self.bounds[a].1 = new_ua;
            }
        }
        delta
    }

    /// Run inference to convergence; returns pass count.
    pub fn infer(&mut self, max_passes: usize, tol: f64) -> usize {
        for p in 0..max_passes {
            let d = self.upward() + self.downward();
            if d < tol {
                return p + 1;
            }
        }
        max_passes
    }

    /// Whether any node's bounds crossed (contradiction).
    pub fn contradiction(&self) -> bool {
        self.bounds.iter().any(|&(l, u)| l > u + 1e-9)
    }
}

/// Generate a synthetic knowledge base: implication chains over
/// propositions (substitutes LUBM/TPTP — see DESIGN.md).
pub fn synthetic_kb(rng: &mut Rng, n_props: usize, n_rules: usize) -> LnnGraph {
    let mut nodes: Vec<Node> = (0..n_props)
        .map(|_| {
            if rng.chance(0.3) {
                Node::Prop {
                    lower: 1.0,
                    upper: 1.0,
                } // known fact
            } else {
                Node::Prop {
                    lower: 0.0,
                    upper: 1.0,
                } // unknown
            }
        })
        .collect();
    for _ in 0..n_rules {
        let body_n = 1 + rng.below(3);
        let body: Vec<usize> = (0..body_n).map(|_| rng.below(n_props)).collect();
        let head = rng.below(n_props);
        let and = if body.len() > 1 {
            nodes.push(Node::And(body));
            nodes.len() - 1
        } else {
            body[0]
        };
        nodes.push(Node::Implies(and, head));
        let idx = nodes.len() - 1;
        // assert the rule as true knowledge
        if let Node::Implies(..) = nodes[idx] {}
    }
    let mut g = LnnGraph::new(nodes);
    // rules are axioms: set their bounds to [1,1]
    for (i, n) in g.nodes.iter().enumerate() {
        if matches!(n, Node::Implies(..)) {
            g.bounds[i] = (1.0, 1.0);
        }
    }
    g
}

/// LNN workload descriptor.
#[derive(Debug, Clone)]
pub struct Lnn {
    pub n_props: usize,
    pub n_rules: usize,
    pub passes: usize,
}

impl Default for Lnn {
    fn default() -> Self {
        Lnn {
            n_props: 256,
            n_rules: 384,
            passes: 6,
        }
    }
}

impl Workload for Lnn {
    fn name(&self) -> &'static str {
        "LNN"
    }

    fn ns_category(&self) -> &'static str {
        "Neuro:Symbolic→Neuro"
    }

    fn trace(&self) -> Trace {
        let mut tr = Trace::new("LNN");
        let p = self.n_props as u64;
        let r = self.n_rules as u64;
        // ---- neural: predicate grounding MLP over entity features ------
        let b = 32u64;
        let g1 = tr.add(
            "ground_mlp1",
            OpCategory::MatMul,
            PhaseKind::Neural,
            2 * b * 16 * 32 * p / 8,
            (b * 16 + 16 * 32) * 4 * p / 8,
            b * 32 * 4,
            &[],
        );
        let g2 = tr.add(
            "ground_mlp2",
            OpCategory::MatMul,
            PhaseKind::Neural,
            2 * b * 32 * 2 * p / 8,
            b * 32 * 4 * p / 8,
            b * 2 * 4,
            &[g1],
        );
        // sparse syntax-tree embedding ops (paper: vector/elementwise
        // heavy + bidirectional data movement)
        let emb = tr.add(
            "tree_embed",
            OpCategory::VectorElem,
            PhaseKind::Neural,
            (p + r) * 64,
            (p + r) * 64 * 8,
            (p + r) * 64 * 4,
            &[g2],
        );
        let mv = tr.add(
            "bounds_h2d",
            OpCategory::DataMovement,
            PhaseKind::Neural,
            0,
            (p + r) * 16,
            (p + r) * 16,
            &[emb],
        );
        // ---- bidirectional bound inference -------------------------------
        // LNN's network *is* the formula tree: each pass evaluates the
        // parameterized neuron activations (neural: weighted Łukasiewicz
        // connectives as vector ops, plus the unique bidirectional
        // dataflow's gather/scatter), then applies the logical rule
        // semantics (symbolic). The paper measures the split near 55/45.
        let mut last = mv;
        for pass in 0..self.passes as u64 {
            let neuron_up = tr.add(
                format!("neuron_eval_up{pass}"),
                OpCategory::VectorElem,
                PhaseKind::Neural,
                (p + 3 * r) * 8,
                (p + 3 * r) * 24,
                (p + 3 * r) * 16,
                &[last],
            );
            let gather = tr.add(
                "bounds_gather",
                OpCategory::DataMovement,
                PhaseKind::Neural,
                0,
                (p + 3 * r) * 16,
                (p + 3 * r) * 16,
                &[neuron_up],
            );
            let up = tr.add(
                format!("upward_logic{pass}"),
                OpCategory::VectorElem,
                PhaseKind::Symbolic,
                (p + 3 * r) * 6,
                (p + 3 * r) * 16,
                (p + 3 * r) * 16,
                &[gather],
            );
            let neuron_down = tr.add(
                format!("neuron_eval_down{pass}"),
                OpCategory::VectorElem,
                PhaseKind::Neural,
                r * 10,
                r * 48,
                r * 32,
                &[up],
            );
            // the backward bound scatter is symbolic bookkeeping — this
            // irregular movement is why LNN (symbolic) is data-movement
            // bound in the paper's Fig. 3a
            let scatter = tr.add(
                "bounds_scatter",
                OpCategory::DataMovement,
                PhaseKind::Symbolic,
                0,
                r * 32,
                r * 32,
                &[neuron_down],
            );
            let down = tr.add(
                format!("downward_logic{pass}"),
                OpCategory::VectorElem,
                PhaseKind::Symbolic,
                r * 8,
                r * 48,
                r * 32,
                &[scatter],
            );
            let logic = tr.add(
                format!("rule_eval{pass}"),
                OpCategory::Other,
                PhaseKind::Symbolic,
                r * 4,
                r * 24,
                r * 8,
                &[down],
            );
            tr.set_sparsity(up, 0.92);
            tr.set_sparsity(down, 0.92);
            last = logic;
        }
        tr
    }

    fn memory(&self) -> MemoryStats {
        let p = self.n_props as u64;
        let r = self.n_rules as u64;
        MemoryStats {
            weights_bytes: (16 * 32 + 32 * 2) * 4 * p / 8,
            codebook_bytes: (p + 3 * r) * 64, // KB: syntax tree + rule params
            neural_working_bytes: 32 * 32 * 4,
            symbolic_working_bytes: (p + 3 * r) * 16 * 2,
        }
    }

    fn symbolic_depends_on_neural(&self) -> bool {
        false // symbolic knowledge is compiled into the network structure
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modus_ponens() {
        // A=true, A→B  ⊢  B=true
        let mut g = LnnGraph::new(vec![
            Node::Prop { lower: 1.0, upper: 1.0 },
            Node::Prop { lower: 0.0, upper: 1.0 },
            Node::Implies(0, 1),
        ]);
        g.bounds[2] = (1.0, 1.0);
        g.infer(10, 1e-9);
        assert!(g.bounds[1].0 > 0.99, "B lower bound {:?}", g.bounds[1]);
        assert!(!g.contradiction());
    }

    #[test]
    fn modus_tollens() {
        // B=false, A→B  ⊢  A=false
        let mut g = LnnGraph::new(vec![
            Node::Prop { lower: 0.0, upper: 1.0 },
            Node::Prop { lower: 0.0, upper: 0.0 },
            Node::Implies(0, 1),
        ]);
        g.bounds[2] = (1.0, 1.0);
        g.infer(10, 1e-9);
        assert!(g.bounds[0].1 < 0.01, "A upper bound {:?}", g.bounds[0]);
    }

    #[test]
    fn chained_inference_propagates() {
        // A, A→B, B→C  ⊢  C
        let mut g = LnnGraph::new(vec![
            Node::Prop { lower: 1.0, upper: 1.0 },
            Node::Prop { lower: 0.0, upper: 1.0 },
            Node::Prop { lower: 0.0, upper: 1.0 },
            Node::Implies(0, 1),
            Node::Implies(1, 2),
        ]);
        g.bounds[3] = (1.0, 1.0);
        g.bounds[4] = (1.0, 1.0);
        let passes = g.infer(20, 1e-9);
        assert!(g.bounds[2].0 > 0.99, "C {:?}", g.bounds[2]);
        assert!(passes >= 2, "chain needs multiple bidirectional passes");
    }

    #[test]
    fn and_bounds_lukasiewicz() {
        let mut g = LnnGraph::new(vec![
            Node::Prop { lower: 0.8, upper: 0.8 },
            Node::Prop { lower: 0.7, upper: 0.7 },
            Node::And(vec![0, 1]),
        ]);
        g.upward();
        let (l, u) = g.bounds[2];
        assert!((l - 0.5).abs() < 1e-9 && (u - 0.5).abs() < 1e-9);
    }

    #[test]
    fn synthetic_kb_inference_converges() {
        let mut rng = Rng::new(3);
        let mut g = synthetic_kb(&mut rng, 128, 200);
        let passes = g.infer(50, 1e-9);
        assert!(passes < 50, "should converge, took {passes}");
        // facts should have propagated: some unknown props now bounded
        let derived = g
            .nodes
            .iter()
            .enumerate()
            .filter(|(i, n)| matches!(n, Node::Prop { lower, .. } if *lower == 0.0) && g.bounds[*i].0 > 0.5)
            .count();
        assert!(derived > 0, "no derivations happened");
    }
}
