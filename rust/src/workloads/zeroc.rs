//! ZeroC — zero-shot concept recognition and acquisition (Wu et al.
//! [29]): concepts are nodes of a symbolic graph with relation edges;
//! recognition scores a candidate composite concept by summing
//! energy-based-model evaluations (neural, the dominant cost — ZeroC is
//! the one workload where *neural* dominates: 73.2% of runtime) over the
//! graph's nodes and relation-consistency terms over its edges.

use super::Workload;
use crate::profiler::memstat::MemoryStats;
use crate::profiler::taxonomy::{OpCategory, PhaseKind};
use crate::profiler::trace::Trace;
use crate::util::Rng;

/// A concept graph: nodes are primitive concepts (embedding ids), edges
/// are relations between them.
#[derive(Debug, Clone, PartialEq)]
pub struct ConceptGraph {
    pub nodes: Vec<usize>,
    /// (a, b, relation) with a/b indexing `nodes`.
    pub edges: Vec<(usize, usize, usize)>,
}

impl ConceptGraph {
    /// A simple two-node relation concept (e.g. "line perpendicular to
    /// line" in the paper's hierarchy).
    pub fn pair(a: usize, b: usize, rel: usize) -> ConceptGraph {
        ConceptGraph {
            nodes: vec![a, b],
            edges: vec![(0, 1, rel)],
        }
    }
}

/// Energy-based recognizer over synthetic embeddings: primitive concept
/// `c` observed in an image patch has low energy iff the patch embedding
/// matches the concept embedding (quadratic energy).
pub struct ZeroCEngine {
    pub n_concepts: usize,
    pub n_relations: usize,
    pub emb_dim: usize,
    concept_emb: Vec<Vec<f64>>,
    relation_emb: Vec<Vec<f64>>,
}

impl ZeroCEngine {
    pub fn new(n_concepts: usize, n_relations: usize, emb_dim: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut emb = |n: usize| -> Vec<Vec<f64>> {
            (0..n)
                .map(|_| (0..emb_dim).map(|_| rng.normal()).collect())
                .collect()
        };
        let concept_emb = emb(n_concepts);
        let relation_emb = emb(n_relations);
        ZeroCEngine {
            n_concepts,
            n_relations,
            emb_dim,
            concept_emb,
            relation_emb,
        }
    }

    /// Node energy: squared distance between patch and concept embedding.
    pub fn node_energy(&self, patch: &[f64], concept: usize) -> f64 {
        patch
            .iter()
            .zip(&self.concept_emb[concept])
            .map(|(p, c)| (p - c).powi(2))
            .sum()
    }

    /// Relation energy between two patches under relation `rel`.
    pub fn relation_energy(&self, pa: &[f64], pb: &[f64], rel: usize) -> f64 {
        // E = || (pa - pb) - r ||^2 : the relation embedding is the
        // expected displacement in embedding space.
        pa.iter()
            .zip(pb)
            .zip(&self.relation_emb[rel])
            .map(|((a, b), r)| ((a - b) - r).powi(2))
            .sum()
    }

    /// Total energy of assigning `patches[i]` to `graph.nodes[i]`.
    pub fn graph_energy(&self, graph: &ConceptGraph, patches: &[Vec<f64>]) -> f64 {
        assert_eq!(graph.nodes.len(), patches.len());
        let node_e: f64 = graph
            .nodes
            .iter()
            .zip(patches)
            .map(|(&c, p)| self.node_energy(p, c))
            .sum();
        let edge_e: f64 = graph
            .edges
            .iter()
            .map(|&(a, b, r)| self.relation_energy(&patches[a], &patches[b], r))
            .sum();
        node_e + edge_e
    }

    /// Zero-shot recognition: score every candidate composite graph and
    /// return the argmin (lowest energy).
    pub fn recognize(&self, candidates: &[ConceptGraph], patches: &[Vec<f64>]) -> usize {
        candidates
            .iter()
            .enumerate()
            .map(|(i, g)| (i, self.graph_energy(g, patches)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    }

    /// Sample a patch embedding for concept `c` with Gaussian noise.
    pub fn sample_patch(&self, c: usize, noise: f64, rng: &mut Rng) -> Vec<f64> {
        self.concept_emb[c]
            .iter()
            .map(|v| v + rng.normal() * noise)
            .collect()
    }
}

/// ZeroC workload descriptor.
#[derive(Debug, Clone)]
pub struct ZeroC {
    pub n_concepts: usize,
    pub n_relations: usize,
    pub emb_dim: usize,
    /// Candidate composite graphs per recognition query.
    pub candidates: usize,
    /// Queries per characterization batch; each runs an EBM ensemble.
    pub queries: usize,
    /// Energy-model ensemble size (SGLD-style repeated evaluations).
    pub ensemble: usize,
}

impl Default for ZeroC {
    fn default() -> Self {
        ZeroC {
            n_concepts: 16,
            n_relations: 4,
            emb_dim: 64,
            candidates: 16,
            queries: 4,
            ensemble: 16,
        }
    }
}

impl Workload for ZeroC {
    fn name(&self) -> &'static str {
        "ZeroC"
    }

    fn ns_category(&self) -> &'static str {
        "Neuro[Symbolic]"
    }

    fn trace(&self) -> Trace {
        let mut tr = Trace::new("ZeroC");
        let b = 8u64; // patches per query
        for _q in 0..self.queries {
            // ---- neural: energy-based ConvNet ensemble (dominant) -------
            let mut ens_ids = Vec::new();
            for e in 0..self.ensemble as u64 {
                let mut hw = 32u64;
                let mut prev: Vec<usize> = vec![];
                for (ci, co) in [(1u64, 8u64), (8, 16)] {
                    let conv = tr.add(
                        format!("ebm_conv{ci}x{co}_e{e}"),
                        OpCategory::Conv,
                        PhaseKind::Neural,
                        2 * b * hw * hw * 9 * ci * co,
                        b * hw * hw * (ci + co) * 4,
                        b * hw * hw * co * 4,
                        &prev,
                    );
                    let act = tr.add(
                        "swish",
                        OpCategory::VectorElem,
                        PhaseKind::Neural,
                        b * hw * hw * co * 4,
                        b * hw * hw * co * 8,
                        0,
                        &[conv],
                    );
                    prev = vec![act];
                    hw /= 2;
                }
                let film = tr.add(
                    "concept_film",
                    OpCategory::MatMul,
                    PhaseKind::Neural,
                    2 * b * 64 * 1024,
                    (b * 64 + 64 * 1024) * 4,
                    b * 1024 * 4,
                    &prev,
                );
                let head = tr.add(
                    "energy_head",
                    OpCategory::MatMul,
                    PhaseKind::Neural,
                    2 * b * 1024,
                    b * 1024 * 4,
                    b * 4,
                    &[film],
                );
                ens_ids.push(head);
            }
            // ---- symbolic: graph composition search ----------------------
            let assemble = tr.add(
                "graph_assemble",
                OpCategory::DataTransform,
                PhaseKind::Symbolic,
                self.candidates as u64 * 8,
                self.candidates as u64 * 64,
                self.candidates as u64 * 64,
                &ens_ids,
            );
            let mut last = assemble;
            for c in 0..self.candidates as u64 {
                let edge = tr.add(
                    format!("relation_energy_c{c}"),
                    OpCategory::VectorElem,
                    PhaseKind::Symbolic,
                    3 * self.emb_dim as u64,
                    3 * self.emb_dim as u64 * 8,
                    8,
                    &[assemble],
                );
                let score = tr.add(
                    "graph_score",
                    OpCategory::Other,
                    PhaseKind::Symbolic,
                    8,
                    64,
                    8,
                    &[edge],
                );
                tr.set_sparsity(edge, 0.91);
                last = score;
            }
            tr.add(
                "argmin_select",
                OpCategory::Other,
                PhaseKind::Symbolic,
                self.candidates as u64,
                self.candidates as u64 * 8,
                8,
                &[last],
            );
        }
        tr
    }

    fn memory(&self) -> MemoryStats {
        MemoryStats {
            weights_bytes: (9 * 8 + 9 * 8 * 16 + 64 * 1024 + 1024) * 4,
            codebook_bytes: ((self.n_concepts + self.n_relations) * self.emb_dim * 8) as u64,
            // paper: ZeroC (neuro) processes images in a large ensemble →
            // big neural working set
            neural_working_bytes: (self.ensemble * 8 * 32 * 32 * 16 * 4) as u64,
            symbolic_working_bytes: (self.candidates * self.emb_dim * 8) as u64,
        }
    }

    fn symbolic_depends_on_neural(&self) -> bool {
        false // concept graphs compile into the EBM's conditioning
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recognizes_correct_composite() {
        let e = ZeroCEngine::new(16, 4, 64, 1);
        let mut rng = Rng::new(2);
        // true concept: pair(3, 7, rel 1) with patches displaced by rel emb
        let pa = e.sample_patch(3, 0.05, &mut rng);
        // place pb so that (pa - pb) ≈ relation_emb[1]
        let pb: Vec<f64> = pa
            .iter()
            .zip(&e.relation_emb[1])
            .map(|(a, r)| a - r)
            .collect();
        // pb should also be near concept 7 for node energy; use direct emb
        let mut candidates = vec![ConceptGraph::pair(3, 7, 1)];
        for i in 0..8 {
            candidates.push(ConceptGraph::pair((i + 1) % 16, (i + 9) % 16, i % 4));
        }
        // bias node emb of 7 towards pb so the task is solvable zero-shot
        let mut engine = e;
        engine.concept_emb[7] = pb.clone();
        let got = engine.recognize(&candidates, &[pa, pb]);
        assert_eq!(got, 0);
    }

    #[test]
    fn node_energy_zero_for_exact_match() {
        let e = ZeroCEngine::new(8, 2, 32, 3);
        let patch = e.concept_emb[5].clone();
        assert!(e.node_energy(&patch, 5) < 1e-12);
        assert!(e.node_energy(&patch, 2) > 1.0);
    }

    #[test]
    fn noise_monotonically_raises_energy() {
        let e = ZeroCEngine::new(8, 2, 32, 4);
        let mut rng = Rng::new(5);
        let clean = e.sample_patch(1, 0.01, &mut rng);
        let noisy = e.sample_patch(1, 1.0, &mut rng);
        assert!(e.node_energy(&clean, 1) < e.node_energy(&noisy, 1));
    }

    #[test]
    fn graph_energy_sums_nodes_and_edges() {
        let e = ZeroCEngine::new(8, 2, 16, 6);
        let g = ConceptGraph::pair(0, 1, 0);
        let patches = vec![e.concept_emb[0].clone(), e.concept_emb[1].clone()];
        let total = e.graph_energy(&g, &patches);
        let manual = e.node_energy(&patches[0], 0)
            + e.node_energy(&patches[1], 1)
            + e.relation_energy(&patches[0], &patches[1], 0);
        assert!((total - manual).abs() < 1e-9);
    }
}
