//! Synthetic Raven's-Progressive-Matrices generator (substitutes RAVEN /
//! I-RAVEN, which are unavailable — see DESIGN.md).
//!
//! A task instance is a `g×g` grid of panels; each panel has `N_ATTRS`
//! categorical attributes with `ATTR_K` values.  Per attribute, one rule
//! governs the rows: Constant, Progression(±step), Arithmetic (c = a + b
//! mod K), or DistributeThree.  The last panel is hidden; 8 candidate
//! answers contain the truth plus 7 attribute-perturbed distractors.
//! This preserves exactly the structure NVSA/PrAE reason over.

use crate::util::Rng;

/// Attribute count (type, size, color) and values per attribute.
pub const N_ATTRS: usize = 3;

/// A row-governing rule for one attribute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    Constant,
    Progression(i8),
    Arithmetic,
    DistributeThree,
}

impl Rule {
    pub const ALL: [Rule; 5] = [
        Rule::Constant,
        Rule::Progression(1),
        Rule::Progression(2),
        Rule::Arithmetic,
        Rule::DistributeThree,
    ];

    pub fn label(&self) -> String {
        match self {
            Rule::Constant => "Constant".into(),
            Rule::Progression(s) => format!("Progression{s:+}"),
            Rule::Arithmetic => "Arithmetic".into(),
            Rule::DistributeThree => "Distribute3".into(),
        }
    }

    /// Produce one row of `g` attribute values consistent with the rule.
    fn fill_row(&self, rng: &mut Rng, g: usize, k: usize, row: usize) -> Vec<u8> {
        match self {
            Rule::Constant => {
                let v = rng.below(k) as u8;
                vec![v; g]
            }
            Rule::Progression(step) => {
                let start = rng.below(k) as i64;
                (0..g)
                    .map(|i| {
                        let v = start + *step as i64 * i as i64;
                        (v.rem_euclid(k as i64)) as u8
                    })
                    .collect()
            }
            Rule::Arithmetic => {
                // last = sum of predecessors (mod k)
                let mut vals: Vec<u8> = (0..g - 1).map(|_| rng.below(k) as u8).collect();
                let sum: i64 = vals.iter().map(|&v| v as i64).sum();
                vals.push((sum.rem_euclid(k as i64)) as u8);
                vals
            }
            Rule::DistributeThree => {
                // a fixed value multiset, rotated per row
                let mut base: Vec<u8> = (0..g).map(|i| (i * 2 % k) as u8).collect();
                base.rotate_left(row % g);
                base
            }
        }
    }
}

/// One RPM task instance.
#[derive(Debug, Clone)]
pub struct RpmInstance {
    /// Grid side (2 for 2×2, 3 for 3×3).
    pub grid: usize,
    /// Values per attribute.
    pub attr_k: usize,
    /// Panel attributes, row-major; `grid*grid` panels (incl. answer).
    pub panels: Vec<[u8; N_ATTRS]>,
    /// Governing rule per attribute.
    pub rules: [Rule; N_ATTRS],
    /// 8 candidate panels; `candidates[answer]` is the truth.
    pub candidates: Vec<[u8; N_ATTRS]>,
    /// Index of the correct candidate.
    pub answer: usize,
}

impl RpmInstance {
    /// Context panels (all but the hidden last one).
    pub fn context(&self) -> &[[u8; N_ATTRS]] {
        &self.panels[..self.panels.len() - 1]
    }

    /// The hidden ground-truth panel.
    pub fn truth(&self) -> [u8; N_ATTRS] {
        *self.panels.last().unwrap()
    }
}

/// Generate one task instance.
pub fn generate(rng: &mut Rng, grid: usize, attr_k: usize) -> RpmInstance {
    assert!(grid >= 2 && attr_k >= 4);
    let rules: [Rule; N_ATTRS] = [
        Rule::ALL[rng.below(Rule::ALL.len())],
        Rule::ALL[rng.below(Rule::ALL.len())],
        Rule::ALL[rng.below(Rule::ALL.len())],
    ];
    let mut rows: Vec<Vec<[u8; N_ATTRS]>> = Vec::with_capacity(grid);
    for r in 0..grid {
        let mut row = vec![[0u8; N_ATTRS]; grid];
        for (a, rule) in rules.iter().enumerate() {
            let vals = rule.fill_row(rng, grid, attr_k, r);
            for (c, v) in vals.into_iter().enumerate() {
                row[c][a] = v;
            }
        }
        rows.push(row);
    }
    let panels: Vec<[u8; N_ATTRS]> = rows.into_iter().flatten().collect();
    let truth = *panels.last().unwrap();

    // candidates: truth + 7 perturbations (unique)
    let mut candidates = vec![truth];
    while candidates.len() < 8 {
        let mut c = truth;
        let a = rng.below(N_ATTRS);
        c[a] = ((c[a] as usize + 1 + rng.below(attr_k - 1)) % attr_k) as u8;
        if !candidates.contains(&c) {
            candidates.push(c);
        }
    }
    let answer = rng.below(8);
    candidates.swap(0, answer);
    RpmInstance {
        grid,
        attr_k,
        panels,
        rules,
        candidates,
        answer,
    }
}

/// Soft-evidence PMFs for the context panels: a near-one-hot distribution
/// per attribute, as the neural frontend would produce (`temperature`
/// controls how peaked; 0.9 mass on the true value at 0.9).
pub fn panel_pmfs(inst: &RpmInstance, confidence: f64) -> Vec<[Vec<f64>; N_ATTRS]> {
    inst.context()
        .iter()
        .map(|panel| {
            let mut out: [Vec<f64>; N_ATTRS] =
                [Vec::new(), Vec::new(), Vec::new()];
            for a in 0..N_ATTRS {
                let mut pmf = vec![(1.0 - confidence) / (inst.attr_k - 1) as f64; inst.attr_k];
                pmf[panel[a] as usize] = confidence;
                out[a] = pmf;
            }
            out
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_consistent_rows() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let inst = generate(&mut rng, 3, 8);
            assert_eq!(inst.panels.len(), 9);
            assert_eq!(inst.candidates.len(), 8);
            // each rule must hold on every row
            for (a, rule) in inst.rules.iter().enumerate() {
                for r in 0..3 {
                    let row: Vec<u8> =
                        (0..3).map(|c| inst.panels[r * 3 + c][a]).collect();
                    check_rule(*rule, &row, 8);
                }
            }
        }
    }

    fn check_rule(rule: Rule, row: &[u8], k: usize) {
        match rule {
            Rule::Constant => assert!(row.iter().all(|&v| v == row[0])),
            Rule::Progression(s) => {
                for w in row.windows(2) {
                    let d = (w[1] as i64 - w[0] as i64).rem_euclid(k as i64);
                    assert_eq!(d, (s as i64).rem_euclid(k as i64));
                }
            }
            Rule::Arithmetic => {
                let sum: i64 = row[..row.len() - 1].iter().map(|&v| v as i64).sum();
                assert_eq!(row[row.len() - 1] as i64, sum.rem_euclid(k as i64));
            }
            Rule::DistributeThree => {
                // multiset preserved across rows — checked implicitly by
                // construction; here just bounds
                assert!(row.iter().all(|&v| (v as usize) < k));
            }
        }
    }

    #[test]
    fn answer_is_truth() {
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let inst = generate(&mut rng, 3, 8);
            assert_eq!(inst.candidates[inst.answer], inst.truth());
        }
    }

    #[test]
    fn distractors_differ_from_truth() {
        let mut rng = Rng::new(3);
        let inst = generate(&mut rng, 3, 8);
        for (i, c) in inst.candidates.iter().enumerate() {
            if i != inst.answer {
                assert_ne!(*c, inst.truth());
            }
        }
    }

    #[test]
    fn grid2_supported() {
        let mut rng = Rng::new(4);
        let inst = generate(&mut rng, 2, 8);
        assert_eq!(inst.panels.len(), 4);
        assert_eq!(inst.context().len(), 3);
    }

    #[test]
    fn pmfs_are_distributions_peaked_at_truth() {
        let mut rng = Rng::new(5);
        let inst = generate(&mut rng, 3, 8);
        let pmfs = panel_pmfs(&inst, 0.9);
        assert_eq!(pmfs.len(), 8);
        for (p, panel) in pmfs.iter().zip(inst.context()) {
            for a in 0..N_ATTRS {
                let s: f64 = p[a].iter().sum();
                assert!((s - 1.0).abs() < 1e-9);
                let argmax = p[a]
                    .iter()
                    .enumerate()
                    .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                    .unwrap()
                    .0;
                assert_eq!(argmax, panel[a] as usize);
            }
        }
    }
}
