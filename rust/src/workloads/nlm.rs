//! NLM — Neural Logic Machines (Dong et al. [30]): multi-layer relational
//! reasoning over predicate tensors.  The learned per-arity MLPs run as
//! the `nlm_layer` HLO artifact; the *symbolic wiring* — expand (arity
//! up), reduce (∃/∀ as max/min), and permutation of argument orders —
//! executes here, and is what the paper characterizes as the sequential
//! logic-deduction bottleneck.

use super::Workload;
use crate::profiler::memstat::MemoryStats;
use crate::profiler::taxonomy::{OpCategory, PhaseKind};
use crate::profiler::trace::Trace;

/// Predicate tensors for one reasoning state: unary (N×C) and binary
/// (N×N×C) truth degrees over N objects.
#[derive(Debug, Clone)]
pub struct PredState {
    pub n: usize,
    pub c: usize,
    pub unary: Vec<f64>,
    pub binary: Vec<f64>,
}

impl PredState {
    pub fn new(n: usize, c: usize) -> Self {
        PredState {
            n,
            c,
            unary: vec![0.0; n * c],
            binary: vec![0.0; n * n * c],
        }
    }

    #[inline]
    pub fn u(&self, i: usize, ch: usize) -> f64 {
        self.unary[i * self.c + ch]
    }

    #[inline]
    pub fn b(&self, i: usize, j: usize, ch: usize) -> f64 {
        self.binary[(i * self.n + j) * self.c + ch]
    }

    #[inline]
    pub fn set_b(&mut self, i: usize, j: usize, ch: usize, v: f64) {
        self.binary[(i * self.n + j) * self.c + ch] = v;
    }
}

/// Expand: unary → binary by broadcasting over the second argument.
pub fn expand(s: &PredState) -> Vec<f64> {
    let (n, c) = (s.n, s.c);
    let mut out = vec![0.0; n * n * c];
    for i in 0..n {
        for j in 0..n {
            for ch in 0..c {
                out[(i * n + j) * c + ch] = s.u(i, ch);
            }
        }
    }
    out
}

/// Reduce with ∃ (max over the second argument): binary → unary.
pub fn reduce_exists(s: &PredState) -> Vec<f64> {
    let (n, c) = (s.n, s.c);
    let mut out = vec![f64::NEG_INFINITY; n * c];
    for i in 0..n {
        for j in 0..n {
            for ch in 0..c {
                let v = s.b(i, j, ch);
                let o = &mut out[i * c + ch];
                if v > *o {
                    *o = v;
                }
            }
        }
    }
    out
}

/// Reduce with ∀ (min over the second argument): binary → unary.
pub fn reduce_forall(s: &PredState) -> Vec<f64> {
    let (n, c) = (s.n, s.c);
    let mut out = vec![f64::INFINITY; n * c];
    for i in 0..n {
        for j in 0..n {
            for ch in 0..c {
                let v = s.b(i, j, ch);
                let o = &mut out[i * c + ch];
                if v < *o {
                    *o = v;
                }
            }
        }
    }
    out
}

/// Permute: swap the two arguments of every binary predicate.
pub fn transpose(s: &PredState) -> Vec<f64> {
    let (n, c) = (s.n, s.c);
    let mut out = vec![0.0; n * n * c];
    for i in 0..n {
        for j in 0..n {
            for ch in 0..c {
                out[(j * n + i) * c + ch] = s.b(i, j, ch);
            }
        }
    }
    out
}

/// Transitive-closure deduction via NLM wiring: repeated
/// `R(i,k) ← ∃j R(i,j) ∧ R(j,k)` using max-min composition — the family
/// tree / path-finding pattern the paper's NLM benchmark runs.
pub fn transitive_closure(adj: &[Vec<bool>], layers: usize) -> Vec<Vec<bool>> {
    let n = adj.len();
    let mut r: Vec<Vec<f64>> = adj
        .iter()
        .map(|row| row.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect())
        .collect();
    for _ in 0..layers {
        let mut next = r.clone();
        for i in 0..n {
            for k in 0..n {
                let mut best: f64 = r[i][k];
                for j in 0..n {
                    best = best.max(r[i][j].min(r[j][k]));
                }
                next[i][k] = best;
            }
        }
        r = next;
    }
    r.into_iter()
        .map(|row| row.into_iter().map(|v| v > 0.5).collect())
        .collect()
}

/// NLM workload descriptor (family-graph reasoning).
#[derive(Debug, Clone)]
pub struct Nlm {
    pub objects: usize,
    pub channels: usize,
    pub layers: usize,
    pub batch: usize,
}

impl Default for Nlm {
    fn default() -> Self {
        Nlm {
            objects: 16,
            channels: 16,
            layers: 6,
            batch: 8,
        }
    }
}

impl Workload for Nlm {
    fn name(&self) -> &'static str {
        "NLM"
    }

    fn ns_category(&self) -> &'static str {
        "Neuro:Symbolic→Neuro"
    }

    fn trace(&self) -> Trace {
        let mut tr = Trace::new("NLM");
        let (n, c, b) = (
            self.objects as u64,
            self.channels as u64,
            self.batch as u64,
        );
        let mut last: Vec<usize> = vec![];
        for layer in 0..self.layers {
            // ---- symbolic wiring: expand / reduce / permute --------------
            let ex = tr.add(
                format!("expand_l{layer}"),
                OpCategory::DataTransform,
                PhaseKind::Symbolic,
                b * n * n * c,
                b * n * c * 8,
                b * n * n * c * 8,
                &last,
            );
            let re = tr.add(
                format!("reduce_exists_l{layer}"),
                OpCategory::VectorElem,
                PhaseKind::Symbolic,
                b * n * n * c,
                b * n * n * c * 8,
                b * n * c * 8,
                &last,
            );
            let rf = tr.add(
                format!("reduce_forall_l{layer}"),
                OpCategory::VectorElem,
                PhaseKind::Symbolic,
                b * n * n * c,
                b * n * n * c * 8,
                b * n * c * 8,
                &last,
            );
            let perm = tr.add(
                format!("permute_l{layer}"),
                OpCategory::DataTransform,
                PhaseKind::Symbolic,
                b * n * n * c,
                b * n * n * c * 8,
                b * n * n * c * 8,
                &last,
            );
            let cat = tr.add(
                format!("concat_l{layer}"),
                OpCategory::DataTransform,
                PhaseKind::Symbolic,
                0,
                b * n * n * c * 4 * 8,
                b * n * n * c * 4 * 8,
                &[ex, re, rf, perm],
            );
            let deduce = tr.add(
                format!("logic_deduce_l{layer}"),
                OpCategory::Other,
                PhaseKind::Symbolic,
                b * n * n * c,
                b * n * n * c * 8,
                b * n * n * c * 8,
                &[cat],
            );
            // ---- neural: shared per-arity MLPs ---------------------------
            let mlp_u = tr.add(
                format!("unary_mlp_l{layer}"),
                OpCategory::MatMul,
                PhaseKind::Neural,
                2 * b * n * (3 * c) * c,
                b * n * 3 * c * 4,
                b * n * c * 4,
                &[deduce],
            );
            let mlp_b1 = tr.add(
                format!("binary_mlp1_l{layer}"),
                OpCategory::MatMul,
                PhaseKind::Neural,
                2 * b * n * n * (4 * c) * (8 * c),
                b * n * n * 4 * c * 4,
                b * n * n * 8 * c * 4,
                &[deduce],
            );
            let mlp_b2 = tr.add(
                format!("binary_mlp2_l{layer}"),
                OpCategory::MatMul,
                PhaseKind::Neural,
                2 * b * n * n * (8 * c) * c,
                b * n * n * 8 * c * 4,
                b * n * n * c * 4,
                &[mlp_b1],
            );
            let act = tr.add(
                "sigmoid",
                OpCategory::VectorElem,
                PhaseKind::Neural,
                b * n * n * c * 4,
                b * n * n * c * 8,
                0,
                &[mlp_b2],
            );
            last = vec![mlp_u, act];
        }
        tr
    }

    fn memory(&self) -> MemoryStats {
        let c = self.channels as u64;
        MemoryStats {
            weights_bytes: self.layers as u64 * (3 * c * c + 4 * c * c) * 4,
            codebook_bytes: 0,
            neural_working_bytes: (self.batch * self.objects * self.objects * self.channels * 4)
                as u64,
            symbolic_working_bytes: (self.batch
                * self.objects
                * self.objects
                * self.channels
                * 8
                * 4) as u64,
        }
    }

    fn symbolic_depends_on_neural(&self) -> bool {
        false // wiring interleaves with (compiles into) the layers
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> PredState {
        let mut s = PredState::new(3, 2);
        s.unary = vec![0.1, 0.9, 0.5, 0.2, 0.8, 0.7];
        for i in 0..3 {
            for j in 0..3 {
                for ch in 0..2 {
                    s.set_b(i, j, ch, (i * 3 + j) as f64 / 10.0 + ch as f64 * 0.01);
                }
            }
        }
        s
    }

    #[test]
    fn expand_broadcasts_unary() {
        let s = state();
        let e = expand(&s);
        for j in 0..3 {
            assert_eq!(e[(1 * 3 + j) * 2], s.u(1, 0));
        }
    }

    #[test]
    fn reduces_are_max_min() {
        let s = state();
        let ex = reduce_exists(&s);
        let fa = reduce_forall(&s);
        // row 0, channel 0: values 0.0, 0.1, 0.2
        assert!((ex[0] - 0.2).abs() < 1e-12);
        assert!((fa[0] - 0.0).abs() < 1e-12);
        assert!(ex.iter().zip(&fa).all(|(e, f)| e >= f));
    }

    #[test]
    fn transpose_swaps_arguments() {
        let s = state();
        let t = transpose(&s);
        for i in 0..3 {
            for j in 0..3 {
                for ch in 0..2 {
                    assert_eq!(t[(j * 3 + i) * 2 + ch], s.b(i, j, ch));
                }
            }
        }
    }

    #[test]
    fn transitive_closure_family_chain() {
        // parent chain 0→1→2→3: grandparent relations must appear
        let n = 4;
        let mut adj = vec![vec![false; n]; n];
        adj[0][1] = true;
        adj[1][2] = true;
        adj[2][3] = true;
        let tc = transitive_closure(&adj, 3);
        assert!(tc[0][2] && tc[0][3] && tc[1][3]);
        assert!(!tc[3][0], "closure must not invert edges");
    }

    #[test]
    fn closure_depth_needs_layers() {
        // a chain of length 8 is not closed by a single layer
        let n = 9;
        let mut adj = vec![vec![false; n]; n];
        for i in 0..8 {
            adj[i][i + 1] = true;
        }
        let shallow = transitive_closure(&adj, 1);
        let deep = transitive_closure(&adj, 4);
        assert!(!shallow[0][8]);
        assert!(deep[0][8], "deduction deepens with layers (NLM claim)");
    }
}
