//! PrAE — Probabilistic Abduction and Execution learner (Zhang et al.
//! [22]): neural ConvNet frontend produces per-panel attribute PMFs; the
//! symbolic backend abduces the governing rule per attribute, executes it
//! to predict the missing panel's scene distribution, and selects the
//! candidate with maximal probability (paper Sec. III-H).

use super::raven::{self, RpmInstance, N_ATTRS};
use super::rules;
use super::Workload;
use crate::profiler::memstat::MemoryStats;
use crate::profiler::sparsity::{sparsity_f64, SparsityPoint};
use crate::profiler::taxonomy::{OpCategory, PhaseKind};
use crate::profiler::trace::Trace;

/// PrAE workload at a configurable task size.
#[derive(Debug, Clone)]
pub struct Prae {
    /// RPM grid side.
    pub grid: usize,
    /// Values per attribute.
    pub attr_k: usize,
    /// Task instances per characterization batch.
    pub instances: usize,
}

impl Default for Prae {
    fn default() -> Self {
        Prae {
            grid: 3,
            attr_k: 8,
            instances: 4,
        }
    }
}

/// Outcome of solving one instance.
#[derive(Debug, Clone)]
pub struct PraeSolution {
    pub chosen: usize,
    pub correct: bool,
    /// Abduced rule per attribute.
    pub rules: [raven::Rule; N_ATTRS],
    /// Predicted PMF per attribute for the missing panel.
    pub predicted: Vec<Vec<f64>>,
}

impl Prae {
    /// Solve one RPM instance from panel PMFs (pure symbolic phase).
    pub fn solve(&self, inst: &RpmInstance, pmfs: &[[Vec<f64>; N_ATTRS]]) -> PraeSolution {
        let g = inst.grid;
        let k = inst.attr_k;
        let mut abduced = [raven::Rule::Constant; N_ATTRS];
        let mut predicted: Vec<Vec<f64>> = Vec::with_capacity(N_ATTRS);
        for a in 0..N_ATTRS {
            // complete rows: 0..g-1
            let rows: Vec<Vec<&[f64]>> = (0..g - 1)
                .map(|r| (0..g).map(|c| pmfs[r * g + c][a].as_slice()).collect())
                .collect();
            let (rule, _post) = rules::abduce(&rows, k);
            abduced[a] = rule;
            let partial: Vec<&[f64]> = (0..g - 1)
                .map(|c| pmfs[(g - 1) * g + c][a].as_slice())
                .collect();
            let first_row: Vec<&[f64]> =
                (0..g).map(|c| pmfs[c][a].as_slice()).collect();
            predicted.push(rules::execute(rule, &partial, k, &first_row));
        }
        // candidate scoring: product over attributes
        let mut best = (0usize, f64::NEG_INFINITY);
        for (i, cand) in inst.candidates.iter().enumerate() {
            let score: f64 = (0..N_ATTRS)
                .map(|a| predicted[a][cand[a] as usize].max(1e-12).ln())
                .sum();
            if score > best.1 {
                best = (i, score);
            }
        }
        PraeSolution {
            chosen: best.0,
            correct: best.0 == inst.answer,
            rules: abduced,
            predicted,
        }
    }

    /// Accuracy over `n` random instances with frontend confidence `conf`.
    pub fn accuracy(&self, n: usize, conf: f64, seed: u64) -> f64 {
        let mut rng = crate::util::Rng::new(seed);
        let mut correct = 0;
        for _ in 0..n {
            let inst = raven::generate(&mut rng, self.grid, self.attr_k);
            let pmfs = raven::panel_pmfs(&inst, conf);
            if self.solve(&inst, &pmfs).correct {
                correct += 1;
            }
        }
        correct as f64 / n as f64
    }

    /// Fig. 5-style sparsity of the symbolic scene representation: the
    /// joint (panel × attribute-value) probability tensors are near
    /// one-hot, hence highly sparse.
    pub fn measure_sparsity(&self, seed: u64) -> Vec<SparsityPoint> {
        let mut rng = crate::util::Rng::new(seed);
        let inst = raven::generate(&mut rng, self.grid, self.attr_k);
        let pmfs = raven::panel_pmfs(&inst, 0.95);
        let names = ["type", "size", "color"];
        let mut out = Vec::new();
        for a in 0..N_ATTRS {
            let joint: Vec<f64> = pmfs.iter().flat_map(|p| p[a].clone()).collect();
            out.push(SparsityPoint {
                module: "scene_prob".into(),
                attribute: names[a].into(),
                sparsity: sparsity_f64(&joint, 0.02),
            });
        }
        out
    }
}

impl Workload for Prae {
    fn name(&self) -> &'static str {
        "PrAE"
    }

    fn ns_category(&self) -> &'static str {
        "Neuro|Symbolic"
    }

    fn trace(&self) -> Trace {
        let mut tr = Trace::new("PrAE");
        let g = self.grid;
        let k = self.attr_k as u64;
        let panels = (g * g - 1 + 8) as u64; // context + candidates
        for _ in 0..self.instances {
            // ---- neural frontend: shared ConvNet + attribute heads -----
            let mut prev = Vec::new();
            let img = 32u64;
            let convs = [(1u64, 8u64), (8, 16)];
            let mut hw = img;
            let mut last = None;
            for (ci, co) in convs {
                let flops = 2 * panels * hw * hw * 9 * ci * co;
                let bytes = panels * hw * hw * (ci + co) * 4;
                let id = tr.add(
                    format!("conv{ci}x{co}"),
                    OpCategory::Conv,
                    PhaseKind::Neural,
                    flops,
                    bytes,
                    panels * hw * hw * co * 4,
                    &prev,
                );
                let relu = tr.add(
                    "relu",
                    OpCategory::VectorElem,
                    PhaseKind::Neural,
                    panels * hw * hw * co,
                    panels * hw * hw * co * 4,
                    panels * hw * hw * co * 4,
                    &[id],
                );
                let pool = tr.add(
                    "maxpool",
                    OpCategory::DataTransform,
                    PhaseKind::Neural,
                    panels * hw * hw * co / 4,
                    panels * hw * hw * co * 4,
                    panels * hw * hw * co,
                    &[relu],
                );
                prev = vec![pool];
                hw /= 2;
                last = Some(pool);
            }
            let feat = 8 * 8 * 16u64;
            let trunk = tr.add(
                "dense_trunk",
                OpCategory::MatMul,
                PhaseKind::Neural,
                2 * panels * feat * 128,
                panels * feat * 4 + feat * 128 * 4,
                panels * 128 * 4,
                &[last.unwrap()],
            );
            let mut head_ids = Vec::new();
            for a in 0..N_ATTRS {
                let h = tr.add(
                    format!("attr_head{a}"),
                    OpCategory::MatMul,
                    PhaseKind::Neural,
                    2 * panels * 128 * k,
                    panels * 128 * 4,
                    panels * k * 4,
                    &[trunk],
                );
                let sm = tr.add(
                    "softmax",
                    OpCategory::VectorElem,
                    PhaseKind::Neural,
                    panels * k * 4,
                    panels * k * 4,
                    panels * k * 4,
                    &[h],
                );
                head_ids.push(sm);
            }
            // ---- symbolic: abduction + execution on PMFs ----------------
            // scene distribution assembly (outer products over attrs)
            let scene = tr.add(
                "scene_assembly",
                OpCategory::DataTransform,
                PhaseKind::Symbolic,
                panels * k * k,
                panels * k * 4 * 3,
                panels * k * k * 8,
                &head_ids,
            );
            let mut sp = tr.len() - 1;
            tr.set_sparsity(sp, 0.96);
            for a in 0..N_ATTRS {
                let dep = head_ids[a];
                for rule in 0..raven::Rule::ALL.len() {
                    for _row in 0..g - 1 {
                        let id = tr.add(
                            format!("likelihood_a{a}_r{rule}"),
                            OpCategory::VectorElem,
                            PhaseKind::Symbolic,
                            k * k * g as u64,
                            k * k * 8,
                            k * 8,
                            &[dep, scene],
                        );
                        tr.set_sparsity(id, 0.90);
                    }
                    // posterior update per rule
                    tr.add(
                        "posterior",
                        OpCategory::Other,
                        PhaseKind::Symbolic,
                        raven::Rule::ALL.len() as u64,
                        64,
                        64,
                        &[],
                    );
                }
                let ex = tr.add(
                    format!("execute_a{a}"),
                    OpCategory::VectorElem,
                    PhaseKind::Symbolic,
                    k * k * g as u64,
                    k * k * 8,
                    k * 8,
                    &[dep],
                );
                tr.set_sparsity(ex, 0.93);
                sp = ex;
            }
            // candidate scoring + argmax
            for c in 0..8 {
                tr.add(
                    format!("cand_score{c}"),
                    OpCategory::VectorElem,
                    PhaseKind::Symbolic,
                    3 * k,
                    3 * k * 8,
                    8,
                    &[sp],
                );
            }
            tr.add(
                "answer_argmax",
                OpCategory::Other,
                PhaseKind::Symbolic,
                8,
                64,
                8,
                &[],
            );
        }
        tr
    }

    fn memory(&self) -> MemoryStats {
        let feat = 8 * 8 * 16u64;
        MemoryStats {
            weights_bytes: (9 * 8 + 9 * 8 * 16 + feat * 128 + 128 * 8 * 3) * 4,
            codebook_bytes: 0, // PrAE keeps raw PMFs (no codebooks)
            neural_working_bytes: 16 * 32 * 32 * 16 * 4,
            // exhaustive symbolic search over intermediate scene tensors
            symbolic_working_bytes: (self.grid * self.grid) as u64
                * (self.attr_k as u64).pow(2)
                * 8
                * 64,
        }
    }

    fn symbolic_depends_on_neural(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_clean_instances() {
        let p = Prae::default();
        let acc = p.accuracy(40, 0.97, 11);
        assert!(acc > 0.8, "accuracy {acc}");
    }

    #[test]
    fn accuracy_degrades_with_noise() {
        let p = Prae::default();
        let hi = p.accuracy(30, 0.97, 12);
        let lo = p.accuracy(30, 0.35, 12);
        assert!(hi > lo, "hi {hi} lo {lo}");
    }

    #[test]
    fn grid2_instances_solve() {
        let p = Prae {
            grid: 2,
            ..Default::default()
        };
        let acc = p.accuracy(30, 0.97, 13);
        assert!(acc > 0.6, "accuracy {acc}");
    }

    #[test]
    fn scene_sparsity_above_90pct() {
        let p = Prae::default();
        for pt in p.measure_sparsity(1) {
            assert!(pt.sparsity > 0.8, "{pt:?}");
        }
    }

    #[test]
    fn trace_symbolic_depends_on_neural() {
        let p = Prae::default();
        let tr = p.trace();
        tr.validate().unwrap();
        // at least one symbolic op depends on a neural op
        let has_cross = tr.ops.iter().any(|o| {
            o.phase == PhaseKind::Symbolic
                && o.deps
                    .iter()
                    .any(|&d| tr.ops[d].phase == PhaseKind::Neural)
        });
        assert!(has_cross);
    }
}
