//! The accelerator evaluation suite (Tab. VII): MULT, TREE, FACT, REACT —
//! multi-layer cognition workloads compiled to Instruction-Word programs
//! for the VSA processor, plus matching GPU-baseline operator traces for
//! the Fig. 11b comparison.

use crate::accel::compiler::{KernelCompiler, Operand, VecRef};
use crate::accel::isa::ControlMethod;
use crate::accel::pipeline::{Accelerator, SimReport};
use crate::accel::{AccelConfig, Program};
use crate::profiler::taxonomy::{OpCategory, PhaseKind};
use crate::profiler::trace::Trace;
use crate::util::Rng;
use crate::vsa::hypervector::majority;
use crate::vsa::{BinaryCodebook, BinaryHV, CleanupMemory};

/// Hypervector dimensionality for the accelerator suite (16 folds of the
/// 512-bit bus — typical HDC scale).
pub const SUITE_DIM: usize = 8192;

/// Which suite workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SuiteKind {
    /// Multi-modal perception: encode samples, classify against
    /// prototypes (300 samples, 120 items, 16 prototypes, 100 queries).
    Mult,
    /// Tree encoding and search (positional binding + cleanup).
    Tree,
    /// Resonator-network factorization (60 iterations, 120 items,
    /// 13 prototypes → 3 factors).
    Fact,
    /// Reactive behaviour learning and recall (500 samples, 55 items,
    /// 160 recalls).
    React,
}

impl SuiteKind {
    pub const ALL: [SuiteKind; 4] = [
        SuiteKind::Mult,
        SuiteKind::Tree,
        SuiteKind::Fact,
        SuiteKind::React,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            SuiteKind::Mult => "MULT",
            SuiteKind::Tree => "TREE",
            SuiteKind::Fact => "FACT",
            SuiteKind::React => "REACT",
        }
    }
}

/// Tab. VII problem sizes (scaled-down sample counts keep simulation
/// time reasonable while preserving op mix; scale factors noted).
#[derive(Debug, Clone)]
pub struct SuiteParams {
    pub n_items: usize,
    pub n_prototypes: usize,
    pub n_samples: usize,
    pub n_queries: usize,
    pub bind_arity: usize,
    pub fact_iters: usize,
    pub fact_factors: usize,
}

impl SuiteParams {
    pub fn paper(kind: SuiteKind) -> SuiteParams {
        match kind {
            SuiteKind::Mult => SuiteParams {
                n_items: 120,
                n_prototypes: 16,
                n_samples: 30, // paper: 300 (×0.1 scale)
                n_queries: 10, // paper: 100
                bind_arity: 3,
                fact_iters: 0,
                fact_factors: 0,
            },
            SuiteKind::Tree => SuiteParams {
                n_items: 120,
                n_prototypes: 0,
                n_samples: 12, // trees encoded
                n_queries: 12, // leaf searches
                bind_arity: 4, // tree depth via positional binding
                fact_iters: 0,
                fact_factors: 0,
            },
            SuiteKind::Fact => SuiteParams {
                n_items: 39, // 13 per factor × 3 factors (paper: 120/13)
                n_prototypes: 13,
                n_samples: 1,
                n_queries: 0,
                bind_arity: 3,
                fact_iters: 6, // paper: 60 (×0.1 scale)
                fact_factors: 3,
            },
            SuiteKind::React => SuiteParams {
                n_items: 55,
                n_prototypes: 0,
                n_samples: 10, // paper: 500 learning samples (model m built once)
                n_queries: 16, // paper: 160 recalls (×0.1)
                bind_arity: 3,
                fact_iters: 0,
                fact_factors: 0,
            },
        }
    }
}

/// A compiled suite workload: programs to run in sequence + the expected
/// functional results for validation.
pub struct CompiledSuite {
    pub kind: SuiteKind,
    pub acc: Accelerator,
    pub compiler: KernelCompiler,
    pub programs: Vec<Program>,
    pub codebook: BinaryCodebook,
}

impl CompiledSuite {
    /// Build and compile a suite workload for an accelerator config.
    pub fn build(kind: SuiteKind, cfg: AccelConfig, seed: u64) -> CompiledSuite {
        let params = SuiteParams::paper(kind);
        let mut rng = Rng::new(seed);
        let mut acc = Accelerator::new(cfg.clone());
        let codebook = BinaryCodebook::random(&mut rng, params.n_items, SUITE_DIM);
        // Scratch is sized per workload: the big MULT/TREE codebooks fill
        // tile SRAM on Acc2 (the paper's CA-90 compressed storage exists
        // exactly because of this pressure).
        let scratch_slots = if kind == SuiteKind::Fact {
            2 + params.fact_factors + 1
        } else {
            2
        };
        let layout = acc.load_items(codebook.items(), scratch_slots);
        let kc = KernelCompiler::new(cfg, layout);
        let mut programs = Vec::new();

        match kind {
            SuiteKind::Mult => {
                // encode each sample as a weighted bundle of bound item
                // pairs, then search prototypes (first n_prototypes items)
                for s in 0..params.n_samples {
                    let groups: Vec<(Vec<Operand>, i32)> = (0..params.bind_arity)
                        .map(|j| {
                            let a = (s * 7 + j * 13) % params.n_items;
                            let b = (s * 11 + j * 5) % params.n_items;
                            (
                                vec![
                                    Operand::plain(VecRef::Item(a)),
                                    Operand::plain(VecRef::Item(b)),
                                ],
                                1 + (j as i32 % 3),
                            )
                        })
                        .collect();
                    programs.push(kc.weighted_bundle(&groups, 0));
                }
                for _q in 0..params.n_queries {
                    programs.push(kc.search(0, params.n_prototypes));
                }
            }
            SuiteKind::Tree => {
                // encode trees with positional (permuted) binding of node
                // items, then search the full item memory for leaves
                for s in 0..params.n_samples {
                    let ops: Vec<Operand> = (0..params.bind_arity)
                        .map(|lvl| {
                            Operand::permuted(
                                VecRef::Item((s * 17 + lvl * 3) % params.n_items),
                                lvl as i32,
                            )
                        })
                        .collect();
                    programs.push(kc.bind(&ops, 0));
                }
                for _q in 0..params.n_queries {
                    programs.push(kc.search(0, params.n_items));
                }
            }
            SuiteKind::Fact => {
                // scene = bind of one item per factor; resonator sweeps
                let n = params.n_prototypes;
                let truth: Vec<usize> = (0..params.fact_factors)
                    .map(|f| f * n + rng.below(n))
                    .collect();
                let scene_ops: Vec<Operand> = truth
                    .iter()
                    .map(|&g| Operand::plain(VecRef::Item(g)))
                    .collect();
                // scratch 0: scene; 1..=F: estimates; F+1: xhat workspace
                programs.push(kc.bind(&scene_ops, 0));
                for _it in 0..params.fact_iters {
                    for f in 0..params.fact_factors {
                        // xhat = scene ⊗ other estimates
                        let mut ops = vec![Operand::plain(VecRef::Scratch(0))];
                        for of in 0..params.fact_factors {
                            if of != f {
                                ops.push(Operand::plain(VecRef::Scratch(1 + of)));
                            }
                        }
                        let xhat_slot = 1 + params.fact_factors;
                        programs.push(kc.bind(&ops, xhat_slot));
                        let factor_items: Vec<usize> = (f * n..(f + 1) * n).collect();
                        programs.push(kc.project(xhat_slot, &factor_items, 1 + f));
                    }
                }
                // final cleanup per factor
                for f in 0..params.fact_factors {
                    programs.push(kc.search(1 + f, params.n_items));
                }
            }
            SuiteKind::React => {
                // learn: model = Σ_k (s_k ⊗ a_k ⊗ v_k) over samples
                let groups: Vec<(Vec<Operand>, i32)> = (0..params.n_samples)
                    .map(|s| {
                        (
                            (0..params.bind_arity)
                                .map(|j| {
                                    Operand::plain(VecRef::Item(
                                        (s * 3 + j * 19) % params.n_items,
                                    ))
                                })
                                .collect(),
                            1,
                        )
                    })
                    .collect();
                programs.push(kc.weighted_bundle(&groups, 0));
                // recall: unbind cue then cleanup-memory search over items
                for q in 0..params.n_queries {
                    let cue = vec![
                        Operand::plain(VecRef::Scratch(0)),
                        Operand::plain(VecRef::Item(q % params.n_items)),
                        Operand::plain(VecRef::Item((q * 7 + 1) % params.n_items)),
                    ];
                    programs.push(kc.bind(&cue, 1));
                    programs.push(kc.search(1, params.n_items));
                }
            }
        }
        CompiledSuite {
            kind,
            acc,
            compiler: kc,
            programs,
            codebook,
        }
    }

    /// Run all programs under a control method; returns the merged report.
    pub fn run(&mut self, control: ControlMethod) -> SimReport {
        let mut total: Option<SimReport> = None;
        for p in &self.programs {
            // searches need fresh DC state
            if p.label.starts_with("search") {
                self.acc.reset_search();
            }
            let r = self.acc.run(p, control);
            match &mut total {
                None => total = Some(r),
                Some(t) => t.merge(&r),
            }
        }
        let mut r = total.expect("suite has programs");
        r.label = self.kind.label().to_string();
        r
    }
}

/// Host-side functional baseline of the REACT recall loop: learn the
/// behaviour model as a majority bundle of bound (state ⊗ action ⊗ value)
/// triples, unbind each recall cue, and clean up against item memory —
/// the same program structure `CompiledSuite::build` compiles for the
/// accelerator, here routed through the word-sliced [`majority`] kernel
/// and the query-blocked [`CleanupMemory::recall_batch`] scan. This is
/// the CPU reference point the accelerator's bind+search programs are
/// compared against.
pub fn react_host_recall(seed: u64) -> Vec<(usize, f64)> {
    let p = SuiteParams::paper(SuiteKind::React);
    let mut rng = Rng::new(seed);
    let codebook = BinaryCodebook::random(&mut rng, p.n_items, SUITE_DIM);
    // learn: model = majority_k (s_k ⊗ a_k ⊗ v_k), same index schedule
    // as the compiled weighted_bundle program
    let samples: Vec<BinaryHV> = (0..p.n_samples)
        .map(|s| {
            let mut acc = codebook.item((s * 3) % p.n_items).clone();
            for j in 1..p.bind_arity {
                acc.bind_assign(codebook.item((s * 3 + j * 19) % p.n_items));
            }
            acc
        })
        .collect();
    let refs: Vec<&BinaryHV> = samples.iter().collect();
    let model = majority(&refs, seed ^ 0x5eed);
    // recall: cue_q = model ⊗ item(q) ⊗ item(7q+1), then one batched
    // cleanup scan over all cues instead of a per-query search loop
    let cues: Vec<BinaryHV> = (0..p.n_queries)
        .map(|q| {
            let mut cue = model.clone();
            cue.bind_assign(codebook.item(q % p.n_items));
            cue.bind_assign(codebook.item((q * 7 + 1) % p.n_items));
            cue
        })
        .collect();
    CleanupMemory::new(codebook).recall_batch(&cues)
}

/// GPU-baseline operator trace for a suite workload (Fig. 11b): the same
/// VSA operations as individually-launched GPU kernels over small
/// vectors — launch-overhead dominated, exactly the paper's observation
/// that "the GPU-memory interface is not optimized for VSA data
/// transfer".
pub fn gpu_trace(kind: SuiteKind) -> Trace {
    let p = SuiteParams::paper(kind);
    let d = SUITE_DIM as u64;
    let mut tr = Trace::new(kind.label());
    let vec_bytes = d / 8;
    let bind = |tr: &mut Trace, n: usize| {
        for _ in 0..n {
            tr.add(
                "vsa_bind",
                OpCategory::VectorElem,
                PhaseKind::Symbolic,
                d,
                3 * vec_bytes,
                vec_bytes,
                &[],
            );
            tr.add(
                "h2d_operands",
                OpCategory::DataMovement,
                PhaseKind::Symbolic,
                0,
                2 * vec_bytes,
                2 * vec_bytes,
                &[],
            );
        }
    };
    let search = |tr: &mut Trace, n_items: usize, n: usize| {
        for _ in 0..n {
            tr.add(
                "similarity_batch",
                OpCategory::VectorElem,
                PhaseKind::Symbolic,
                2 * n_items as u64 * d,
                n_items as u64 * vec_bytes + vec_bytes,
                n_items as u64 * 4,
                &[],
            );
            tr.add(
                "argmax",
                OpCategory::VectorElem,
                PhaseKind::Symbolic,
                n_items as u64,
                n_items as u64 * 4,
                8,
                &[],
            );
            tr.add(
                "d2h_result",
                OpCategory::DataMovement,
                PhaseKind::Symbolic,
                0,
                64,
                64,
                &[],
            );
        }
    };
    match kind {
        SuiteKind::Mult => {
            bind(&mut tr, p.n_samples * p.bind_arity * 2);
            search(&mut tr, p.n_prototypes, p.n_queries);
        }
        SuiteKind::Tree => {
            bind(&mut tr, p.n_samples * p.bind_arity * 2);
            search(&mut tr, p.n_items, p.n_queries);
        }
        SuiteKind::Fact => {
            bind(&mut tr, 1 + p.fact_iters * p.fact_factors * p.fact_factors);
            // per iteration per factor: similarity + weighted projection
            for _ in 0..p.fact_iters * p.fact_factors {
                search(&mut tr, p.n_prototypes, 1);
                bind(&mut tr, 2); // weighting + accumulation kernels
            }
            search(&mut tr, p.n_items, p.fact_factors);
        }
        SuiteKind::React => {
            bind(&mut tr, p.n_samples * p.bind_arity);
            for _ in 0..p.n_queries {
                bind(&mut tr, 2);
                search(&mut tr, p.n_items, 1);
            }
        }
    }
    tr
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_suites_compile_and_run() {
        for kind in SuiteKind::ALL {
            let mut s = CompiledSuite::build(kind, AccelConfig::acc4(), 42);
            assert!(!s.programs.is_empty(), "{kind:?}");
            let r = s.run(ControlMethod::Mopc);
            assert!(r.cycles > 0);
            assert!(r.energy_j() > 0.0);
        }
    }

    #[test]
    fn fact_recovers_factors_on_accelerator() {
        let mut s = CompiledSuite::build(SuiteKind::Fact, AccelConfig::acc4(), 7);
        // init estimates: majority-bundle of each factor's codebook (host
        // staging, as documented)
        let n = SuiteParams::paper(SuiteKind::Fact).n_prototypes;
        for f in 0..3 {
            let items: Vec<&crate::vsa::BinaryHV> =
                (f * n..(f + 1) * n).map(|g| s.codebook.item(g)).collect();
            let est = crate::vsa::hypervector::majority(&items, 99);
            let layout = s.compiler.layout.clone();
            s.acc.stage_scratch(&layout, 1 + f, &est);
        }
        s.run(ControlMethod::Mopc);
        // after the run the final searches have been applied sequentially;
        // validate the last factor's estimate decodes to a real item
        let layout = s.compiler.layout.clone();
        let est2 = s.acc.read_scratch(&layout, 0, 3);
        let (idx, score) = s.codebook.nearest(&est2);
        assert!(score > 0, "estimate should correlate with an item");
        assert!((2 * n..3 * n).contains(&idx), "factor-2 estimate should decode within its codebook: {idx}");
    }

    #[test]
    fn mopc_speedup_in_paper_band() {
        // Fig. 9: MOPC speedup 1.8–2.3× over SOPC for the resonator.
        let mut a = CompiledSuite::build(SuiteKind::Fact, AccelConfig::acc4(), 1);
        let mut b = CompiledSuite::build(SuiteKind::Fact, AccelConfig::acc4(), 1);
        let rs = a.run(ControlMethod::Sopc);
        let rm = b.run(ControlMethod::Mopc);
        let speedup = rs.time_s / rm.time_s;
        assert!(
            (1.5..3.0).contains(&speedup),
            "MOPC speedup {speedup:.2} outside plausible band"
        );
    }

    #[test]
    fn react_scales_better_than_mult_with_tiles() {
        // Fig. 11a: REACT gains more from Acc8 than MULT does.
        let time = |kind, cfg: AccelConfig| {
            let mut s = CompiledSuite::build(kind, cfg, 3);
            s.run(ControlMethod::Mopc).time_s
        };
        let mult_gain = time(SuiteKind::Mult, AccelConfig::acc2())
            / time(SuiteKind::Mult, AccelConfig::acc8());
        let react_gain = time(SuiteKind::React, AccelConfig::acc2())
            / time(SuiteKind::React, AccelConfig::acc8());
        assert!(
            react_gain > mult_gain,
            "REACT {react_gain:.2}x vs MULT {mult_gain:.2}x"
        );
        assert!(react_gain > 1.2);
    }

    #[test]
    fn react_host_recall_decodes_learned_values() {
        let recalls = react_host_recall(42);
        let p = SuiteParams::paper(SuiteKind::React);
        assert_eq!(recalls.len(), p.n_queries);
        for (q, &(idx, cos)) in recalls.iter().enumerate() {
            assert!(idx < p.n_items, "query {q} decoded out of range");
            assert!((-1.0..=1.0).contains(&cos), "query {q} cosine {cos}");
        }
        // the whole pipeline (majority bundle → unbind → batched scan)
        // is deterministic from the seed
        assert_eq!(recalls, react_host_recall(42));
    }

    #[test]
    fn gpu_traces_are_launch_bound() {
        let gpu = crate::platform::Platform::v100();
        for kind in SuiteKind::ALL {
            let tr = gpu_trace(kind);
            let tb = gpu.trace_time(&tr, None);
            let launches = tr.len() as f64 * gpu.kernel_launch_s;
            assert!(
                launches / tb.total > 0.5,
                "{kind:?}: GPU VSA should be launch-dominated"
            );
        }
    }
}
