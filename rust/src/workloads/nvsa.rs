//! NVSA — Neuro-Vector-Symbolic Architecture (Hersche et al. [7]): the
//! PrAE task solved in holographic hypervector space.  Panel PMFs are
//! lifted to hypervectors (PMF-to-VSA weighted bundling over attribute
//! codebooks), rules are abduced and executed probabilistically, and
//! candidate panels are selected by VSA similarity — the paper's
//! flagship symbolic-bottleneck workload (92.1% symbolic runtime).

use super::raven::{self, RpmInstance, N_ATTRS};
use super::rules;
use super::Workload;
use crate::profiler::memstat::MemoryStats;
use crate::profiler::sparsity::{sparsity_f64, SparsityPoint};
use crate::profiler::taxonomy::{OpCategory, PhaseKind};
use crate::profiler::trace::Trace;
use crate::util::Rng;
use crate::vsa::{RealCodebook, RealHV};

/// NVSA workload configuration.
#[derive(Debug, Clone)]
pub struct Nvsa {
    pub grid: usize,
    pub attr_k: usize,
    /// Hypervector dimensionality.
    pub hd_dim: usize,
    /// Task instances per characterization batch.
    pub instances: usize,
}

impl Default for Nvsa {
    fn default() -> Self {
        Nvsa {
            grid: 3,
            attr_k: 8,
            hd_dim: 1024,
            instances: 4,
        }
    }
}

/// The VSA-side state: one codebook per attribute.
pub struct NvsaEngine {
    pub cfg: Nvsa,
    pub codebooks: Vec<RealCodebook>,
}

/// Result of one NVSA solve.
#[derive(Debug, Clone)]
pub struct NvsaSolution {
    pub chosen: usize,
    pub correct: bool,
    /// Sparsity measurements harvested during the solve (Fig. 5).
    pub sparsity: Vec<SparsityPoint>,
}

impl NvsaEngine {
    pub fn new(cfg: Nvsa, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let codebooks = (0..N_ATTRS)
            .map(|_| RealCodebook::random_bipolar(&mut rng, cfg.attr_k, cfg.hd_dim))
            .collect();
        NvsaEngine { cfg, codebooks }
    }

    /// Solve one instance through hypervector space: PMFs → vectors →
    /// rule abduction/execution → VSA candidate similarity.
    pub fn solve(&self, inst: &RpmInstance, pmfs: &[[Vec<f64>; N_ATTRS]]) -> NvsaSolution {
        let g = inst.grid;
        let k = inst.attr_k;
        let mut sparsity = Vec::new();
        let attr_names = ["type", "size", "color"];

        // PMF-to-VSA: lift every context panel's attribute PMFs, grouped
        // per attribute so the decode below runs as one batched scan per
        // attribute instead of one per panel
        let mut attr_vecs: Vec<Vec<RealHV>> =
            (0..N_ATTRS).map(|_| Vec::with_capacity(pmfs.len())).collect();
        for p in pmfs {
            for a in 0..N_ATTRS {
                attr_vecs[a].push(self.codebooks[a].weighted_bundle(&p[a]));
            }
        }
        // Fig. 5: sparsity of the PMF→VSA input distributions
        for a in 0..N_ATTRS {
            let joint: Vec<f64> = pmfs.iter().flat_map(|p| p[a].clone()).collect();
            sparsity.push(SparsityPoint {
                module: "pmf_to_vsa".into(),
                attribute: attr_names[a].into(),
                sparsity: sparsity_f64(&joint, 0.02),
            });
        }

        // Rule abduction per attribute: decode vectors back to PMFs
        // (VSA-to-PMF) and score rules probabilistically.
        let mut predicted: Vec<Vec<f64>> = Vec::with_capacity(N_ATTRS);
        for a in 0..N_ATTRS {
            // VSA-to-PMF through the bound-ordered ReLU-pruned batched
            // scan (result identical to per-panel `to_pmf`: the only
            // skipped rows are ones the ReLU provably zeroes)
            let (decoded, _prune) = self.codebooks[a]
                .to_pmf_batch_pruned_with(&attr_vecs[a], crate::util::parallel::configured_threads());
            let joint: Vec<f64> = decoded.iter().flatten().copied().collect();
            sparsity.push(SparsityPoint {
                module: "vsa_to_pmf".into(),
                attribute: attr_names[a].into(),
                sparsity: sparsity_f64(&joint, 0.02),
            });
            let rows: Vec<Vec<&[f64]>> = (0..g - 1)
                .map(|r| (0..g).map(|c| decoded[r * g + c].as_slice()).collect())
                .collect();
            let (rule, post) = rules::abduce(&rows, k);
            sparsity.push(SparsityPoint {
                module: "prob_compute".into(),
                attribute: attr_names[a].into(),
                sparsity: sparsity_f64(&post, 0.02),
            });
            let partial: Vec<&[f64]> = (0..g - 1)
                .map(|c| decoded[(g - 1) * g + c].as_slice())
                .collect();
            let first_row: Vec<&[f64]> =
                (0..g).map(|c| decoded[c].as_slice()).collect();
            predicted.push(rules::execute(rule, &partial, k, &first_row));
        }

        // Answer selection in VSA space: lift the predicted PMFs and each
        // candidate's one-hot PMFs; pick the candidate whose bound
        // representation is most similar to the prediction.
        let pred_vecs: Vec<RealHV> = (0..N_ATTRS)
            .map(|a| self.codebooks[a].weighted_bundle(&predicted[a]))
            .collect();
        let mut best = (0usize, f64::NEG_INFINITY);
        for (i, cand) in inst.candidates.iter().enumerate() {
            let mut score = 0.0;
            for a in 0..N_ATTRS {
                let cv = self.codebooks[a].item(cand[a] as usize);
                score += pred_vecs[a].cosine(cv);
            }
            if score > best.1 {
                best = (i, score);
            }
        }
        NvsaSolution {
            chosen: best.0,
            correct: best.0 == inst.answer,
            sparsity,
        }
    }

    /// Accuracy over `n` random instances.
    pub fn accuracy(&self, n: usize, conf: f64, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let mut correct = 0;
        for _ in 0..n {
            let inst = raven::generate(&mut rng, self.cfg.grid, self.cfg.attr_k);
            let pmfs = raven::panel_pmfs(&inst, conf);
            if self.solve(&inst, &pmfs).correct {
                correct += 1;
            }
        }
        correct as f64 / n as f64
    }
}

impl Workload for Nvsa {
    fn name(&self) -> &'static str {
        "NVSA"
    }

    fn ns_category(&self) -> &'static str {
        "Neuro|Symbolic"
    }

    fn trace(&self) -> Trace {
        let mut tr = Trace::new("NVSA");
        let g = self.grid as u64;
        let k = self.attr_k as u64;
        let d = self.hd_dim as u64;
        let panels = g * g - 1 + 8;
        for _ in 0..self.instances {
            // ---- neural frontend (same ConvNet skeleton as PrAE) --------
            let mut hw = 32u64;
            let mut prev: Vec<usize> = Vec::new();
            for (ci, co) in [(1u64, 8u64), (8, 16)] {
                let conv = tr.add(
                    format!("conv{ci}x{co}"),
                    OpCategory::Conv,
                    PhaseKind::Neural,
                    2 * panels * hw * hw * 9 * ci * co,
                    panels * hw * hw * (ci + co) * 4,
                    panels * hw * hw * co * 4,
                    &prev,
                );
                let relu = tr.add(
                    "relu",
                    OpCategory::VectorElem,
                    PhaseKind::Neural,
                    panels * hw * hw * co,
                    panels * hw * hw * co * 8,
                    0,
                    &[conv],
                );
                prev = vec![relu];
                hw /= 2;
            }
            let feat = 8 * 8 * 16u64;
            let trunk = tr.add(
                "dense_trunk",
                OpCategory::MatMul,
                PhaseKind::Neural,
                2 * panels * feat * 128,
                (panels * feat + feat * 128) * 4,
                panels * 128 * 4,
                &prev,
            );
            let mut heads = Vec::new();
            for a in 0..N_ATTRS {
                let h = tr.add(
                    format!("attr_head{a}"),
                    OpCategory::MatMul,
                    PhaseKind::Neural,
                    2 * panels * 128 * k,
                    panels * 128 * 4,
                    panels * k * 4,
                    &[trunk],
                );
                heads.push(h);
            }
            // ---- symbolic: VSA pipeline ---------------------------------
            let mut pmf2vsa = Vec::new();
            for (a, &h) in heads.iter().enumerate() {
                // PMF→VSA weighted bundling (per panel; streaming)
                let id = tr.add(
                    format!("pmf_to_vsa_a{a}"),
                    OpCategory::VectorElem,
                    PhaseKind::Symbolic,
                    2 * panels * k * d,
                    (panels * k + k * d) * 4,
                    panels * d * 4,
                    &[h],
                );
                tr.set_sparsity(id, 0.96);
                pmf2vsa.push(id);
            }
            for (a, &pv) in pmf2vsa.iter().enumerate() {
                // VSA→PMF similarity decode
                let dec = tr.add(
                    format!("vsa_to_pmf_a{a}"),
                    OpCategory::VectorElem,
                    PhaseKind::Symbolic,
                    2 * panels * k * d,
                    (panels * d + k * d) * 4,
                    panels * k * 4,
                    &[pv],
                );
                tr.set_sparsity(dec, 0.95);
                // rule likelihood scans: per rule per row, vector-symbolic
                // bind + similarity streams. The scans are SEQUENTIAL —
                // the paper attributes NVSA's symbolic dominance to "the
                // sequential and computational-intensive rule detection".
                // contexts: complete rows AND columns are checked (the
                // row/column duality is what makes total runtime grow
                // superlinearly with task size, Fig. 2c)
                let contexts = 2 * (g - 1);
                let mut seq_dep = dec;
                for rule in 0..raven::Rule::ALL.len() {
                    for _ctx in 0..contexts {
                        let bind = tr.add(
                            format!("rule_bind_a{a}_r{rule}"),
                            OpCategory::VectorElem,
                            PhaseKind::Symbolic,
                            g * d,
                            g * d * 8,
                            d * 4,
                            &[seq_dep],
                        );
                        let sim = tr.add(
                            "rule_similarity",
                            OpCategory::VectorElem,
                            PhaseKind::Symbolic,
                            2 * k * d,
                            (k * d + d) * 4,
                            k * 4,
                            &[bind],
                        );
                        tr.set_sparsity(sim, 0.90);
                        seq_dep = tr.add(
                            "rule_posterior",
                            OpCategory::Other,
                            PhaseKind::Symbolic,
                            16,
                            128,
                            64,
                            &[sim],
                        );
                    }
                }
                // execution: predicted panel vector (after the sequential
                // rule search concludes)
                let ex = tr.add(
                    format!("rule_execute_a{a}"),
                    OpCategory::VectorElem,
                    PhaseKind::Symbolic,
                    2 * k * d,
                    k * d * 4,
                    d * 4,
                    &[seq_dep],
                );
                tr.set_sparsity(ex, 0.93);
                // candidate similarity
                for c in 0..8 {
                    tr.add(
                        format!("cand_sim{c}"),
                        OpCategory::VectorElem,
                        PhaseKind::Symbolic,
                        2 * d,
                        2 * d * 4,
                        8,
                        &[ex],
                    );
                }
            }
            tr.add("answer_argmax", OpCategory::Other, PhaseKind::Symbolic, 24, 192, 8, &[]);
            // host↔device shuttling between neural & symbolic stages
            tr.add(
                "pmf_transfer",
                OpCategory::DataMovement,
                PhaseKind::Symbolic,
                0,
                panels * k * 3 * 4,
                panels * k * 3 * 4,
                &heads,
            );
        }
        tr
    }

    fn memory(&self) -> MemoryStats {
        let d = self.hd_dim as u64;
        let k = self.attr_k as u64;
        let feat = 8 * 8 * 16u64;
        MemoryStats {
            weights_bytes: (9 * 8 + 9 * 8 * 16 + feat * 128 + 128 * k * 3) * 4,
            // holographic codebooks dominate storage (paper: >90%): the
            // combination codebook must cover all attribute combinations
            // (k^3 entries) to guarantee quasi-orthogonality.
            codebook_bytes: (N_ATTRS as u64 * k * d + k * k * k * d) * 4,
            neural_working_bytes: 16 * 32 * 32 * 16 * 4,
            symbolic_working_bytes: (self.grid * self.grid + 8) as u64 * d * 4 * N_ATTRS as u64,
        }
    }

    fn symbolic_depends_on_neural(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_clean_instances_via_vsa() {
        let e = NvsaEngine::new(Nvsa::default(), 3);
        let acc = e.accuracy(30, 0.97, 21);
        assert!(acc > 0.75, "accuracy {acc}");
    }

    #[test]
    fn sparsity_points_cover_three_modules() {
        let e = NvsaEngine::new(Nvsa::default(), 4);
        let mut rng = Rng::new(5);
        let inst = raven::generate(&mut rng, 3, 8);
        let pmfs = raven::panel_pmfs(&inst, 0.95);
        let sol = e.solve(&inst, &pmfs);
        let modules: std::collections::BTreeSet<_> =
            sol.sparsity.iter().map(|p| p.module.clone()).collect();
        assert!(modules.contains("pmf_to_vsa"));
        assert!(modules.contains("vsa_to_pmf"));
        assert!(modules.contains("prob_compute"));
        // paper: high sparsity (>95%) on the PMF-side modules
        for p in &sol.sparsity {
            if p.module == "pmf_to_vsa" {
                assert!(p.sparsity > 0.85, "{p:?}");
            }
        }
    }

    #[test]
    fn scales_with_grid() {
        let small = Nvsa {
            grid: 2,
            ..Default::default()
        };
        let big = Nvsa::default();
        let gpu = crate::platform::Platform::rtx2080ti();
        let t_small = gpu.trace_time(&small.trace(), None).total;
        let t_big = gpu.trace_time(&big.trace(), None).total;
        assert!(t_big > 1.5 * t_small, "{t_big} vs {t_small}");
    }

    #[test]
    fn codebooks_dominate_storage() {
        let m = Nvsa::default().memory();
        assert!(m.codebook_bytes > m.weights_bytes);
        assert!(m.static_fraction() > 0.5);
    }
}
