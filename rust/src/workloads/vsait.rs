//! VSAIT — VSA-based unpaired image-to-image translation (Theiss et al.
//! [21]): ConvNet features are projected into random hypervector space,
//! bound with an invertible domain key, and translated by codebook
//! lookup.  The symbolic phase's binding/unbinding consistency is what
//! prevents semantic flipping — measured here as flip rate.

use super::Workload;
use crate::profiler::memstat::MemoryStats;
use crate::profiler::taxonomy::{OpCategory, PhaseKind};
use crate::profiler::trace::Trace;
use crate::util::Rng;
use crate::vsa::{BinaryCodebook, BinaryHV};

/// VSAIT workload descriptor.
#[derive(Debug, Clone)]
pub struct Vsait {
    /// Images per translation batch.
    pub batch: usize,
    /// Feature patches per image.
    pub patches: usize,
    /// Hypervector dimensionality.
    pub hd_dim: usize,
    /// Semantic classes in the target codebook.
    pub classes: usize,
}

impl Default for Vsait {
    fn default() -> Self {
        Vsait {
            batch: 4,
            patches: 64,
            hd_dim: 2048,
            classes: 19, // Cityscapes-like label set
        }
    }
}

/// The symbolic translation engine.
pub struct VsaitEngine {
    pub cfg: Vsait,
    /// Source→target domain key (invertible binding).
    pub key: BinaryHV,
    /// Target-domain semantic prototypes.
    pub target_codebook: BinaryCodebook,
}

impl VsaitEngine {
    pub fn new(cfg: Vsait, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let key = BinaryHV::random(&mut rng, cfg.hd_dim);
        let target_codebook = BinaryCodebook::random(&mut rng, cfg.classes, cfg.hd_dim);
        VsaitEngine {
            cfg,
            key,
            target_codebook,
        }
    }

    /// Translate one patch hypervector: bind with the domain key and find
    /// the nearest target prototype. Returns (class, noisy round-trip).
    pub fn translate(&self, patch: &BinaryHV) -> (usize, BinaryHV) {
        let mapped = patch.bind(&self.key);
        let (class, _) = self.target_codebook.nearest(&mapped);
        // inverse mapping (bind is self-inverse) reconstructs the source
        let back = mapped.bind(&self.key);
        (class, back)
    }

    /// Batched [`Self::translate`]: one query-blocked codebook scan for
    /// the whole patch set. Result `i` equals `translate(&patches[i])`.
    pub fn translate_batch(&self, patches: &[BinaryHV]) -> Vec<(usize, BinaryHV)> {
        let mapped: Vec<BinaryHV> = patches.iter().map(|p| p.bind(&self.key)).collect();
        let nearest = self.target_codebook.nearest_batch(&mapped);
        mapped
            .into_iter()
            .zip(nearest)
            .map(|(m, (class, _))| (class, m.bind(&self.key)))
            .collect()
    }

    /// Semantic-flip rate: fraction of patches whose class changes when
    /// the patch is perturbed by `noise_frac` bit flips.  VSAIT's claim:
    /// hypervector binding keeps this low. All 2·n translations run as
    /// one batched scan (identical results to the per-patch loop — the
    /// RNG consumption order is unchanged).
    pub fn flip_rate(&self, n_patches: usize, noise_frac: f64, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let mut queries = Vec::with_capacity(2 * n_patches);
        for _ in 0..n_patches {
            // patch = noisy prototype so it has a well-defined class
            let class = rng.below(self.cfg.classes);
            let proto = self.target_codebook.item(class).bind(&self.key);
            let mut patch = proto.clone();
            for i in rng.sample_indices(self.cfg.hd_dim, (self.cfg.hd_dim as f64 * 0.05) as usize)
            {
                patch.set(i, !patch.get(i));
            }
            let mut noisy = patch.clone();
            let flip_n = (self.cfg.hd_dim as f64 * noise_frac) as usize;
            for i in rng.sample_indices(self.cfg.hd_dim, flip_n) {
                noisy.set(i, !noisy.get(i));
            }
            queries.push(patch);
            queries.push(noisy);
        }
        let translated = self.translate_batch(&queries);
        let flips = translated
            .chunks(2)
            .filter(|pair| pair[0].0 != pair[1].0)
            .count();
        flips as f64 / n_patches as f64
    }
}

impl Workload for Vsait {
    fn name(&self) -> &'static str {
        "VSAIT"
    }

    fn ns_category(&self) -> &'static str {
        "Neuro|Symbolic"
    }

    fn trace(&self) -> Trace {
        let mut tr = Trace::new("VSAIT");
        let b = self.batch as u64;
        let np = self.patches as u64;
        let d = self.hd_dim as u64;
        let cls = self.classes as u64;
        // ---- neural: generator ConvNet (GAN-style, heavier) -------------
        let mut hw = 32u64;
        let mut prev: Vec<usize> = vec![];
        for (ci, co) in [(3u64, 16u64), (16, 32), (32, 64)] {
            let conv = tr.add(
                format!("gen_conv{ci}x{co}"),
                OpCategory::Conv,
                PhaseKind::Neural,
                2 * b * hw * hw * 9 * ci * co,
                b * hw * hw * (ci + co) * 4,
                b * hw * hw * co * 4,
                &prev,
            );
            let act = tr.add(
                "relu",
                OpCategory::VectorElem,
                PhaseKind::Neural,
                b * hw * hw * co,
                b * hw * hw * co * 8,
                0,
                &[conv],
            );
            prev = vec![act];
            hw /= 2;
        }
        // residual blocks + decoder (GAN generator is encoder-decoder)
        for blk in 0..4u64 {
            let conv = tr.add(
                format!("res_block{blk}"),
                OpCategory::Conv,
                PhaseKind::Neural,
                2 * b * 16 * 16 * 9 * 64 * 64,
                b * 16 * 16 * 128 * 4,
                b * 16 * 16 * 64 * 4,
                &prev,
            );
            let act = tr.add(
                "relu",
                OpCategory::VectorElem,
                PhaseKind::Neural,
                b * 16 * 16 * 64,
                b * 16 * 16 * 64 * 8,
                0,
                &[conv],
            );
            prev = vec![act];
        }
        for (ci, co, res) in [(64u64, 32u64, 16u64), (32, 16, 32), (16, 3, 32)] {
            let conv = tr.add(
                format!("dec_conv{ci}x{co}"),
                OpCategory::Conv,
                PhaseKind::Neural,
                2 * b * res * res * 9 * ci * co,
                b * res * res * (ci + co) * 4,
                b * res * res * co * 4,
                &prev,
            );
            prev = vec![conv];
        }
        let feat_dim = 256u64;
        let proj_in = tr.add(
            "feature_collect",
            OpCategory::DataTransform,
            PhaseKind::Neural,
            b * np * feat_dim,
            b * np * feat_dim * 4,
            b * np * feat_dim * 4,
            &prev,
        );
        // ---- symbolic: random projection + bind + lookup per patch ------
        let proj = tr.add(
            "hv_projection",
            OpCategory::MatMul,
            PhaseKind::Symbolic,
            2 * b * np * feat_dim * d,
            (b * np * feat_dim + feat_dim * d) * 4,
            b * np * d / 8,
            &[proj_in],
        );
        let sgn = tr.add(
            "bipolarize",
            OpCategory::VectorElem,
            PhaseKind::Symbolic,
            b * np * d,
            b * np * d * 4,
            b * np * d / 8,
            &[proj],
        );
        let mut last = sgn;
        for p in 0..np {
            // per-patch streaming binds and codebook lookups (small,
            // launch-bound on GPU — the paper's inefficiency)
            let bind = tr.add(
                format!("key_bind_p{p}"),
                OpCategory::VectorElem,
                PhaseKind::Symbolic,
                b * d / 8,
                b * d / 4,
                b * d / 8,
                &[sgn],
            );
            let lookup = tr.add(
                format!("codebook_lookup_p{p}"),
                OpCategory::VectorElem,
                PhaseKind::Symbolic,
                2 * b * cls * d,
                (cls * d / 8 + b * d / 8) * 2,
                b * cls * 4,
                &[bind],
            );
            let unbind = tr.add(
                format!("inv_bind_p{p}"),
                OpCategory::VectorElem,
                PhaseKind::Symbolic,
                b * d / 8,
                b * d / 4,
                b * d / 8,
                &[lookup],
            );
            tr.set_sparsity(lookup, 0.90);
            last = unbind;
        }
        tr.add(
            "consistency_check",
            OpCategory::Other,
            PhaseKind::Symbolic,
            b * np,
            b * np * 8,
            8,
            &[last],
        );
        tr
    }

    fn memory(&self) -> MemoryStats {
        let d = self.hd_dim as u64;
        MemoryStats {
            weights_bytes: (9 * 3 * 16 + 9 * 16 * 32 + 9 * 32 * 64) as u64 * 4,
            codebook_bytes: (256 * d * 4) + self.classes as u64 * d / 8,
            neural_working_bytes: self.batch as u64 * 32 * 32 * 64 * 4,
            symbolic_working_bytes: (self.batch * self.patches) as u64 * d / 8 * 3,
        }
    }

    fn symbolic_depends_on_neural(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binding_roundtrip_is_exact() {
        let e = VsaitEngine::new(Vsait::default(), 1);
        let mut rng = Rng::new(2);
        let patch = BinaryHV::random(&mut rng, e.cfg.hd_dim);
        let (_, back) = e.translate(&patch);
        assert_eq!(back, patch, "bind∘bind must be identity");
    }

    #[test]
    fn clean_prototypes_classify_correctly() {
        let e = VsaitEngine::new(Vsait::default(), 3);
        for class in 0..e.cfg.classes {
            let patch = e.target_codebook.item(class).bind(&e.key);
            let (c, _) = e.translate(&patch);
            assert_eq!(c, class);
        }
    }

    #[test]
    fn translate_batch_matches_single() {
        let e = VsaitEngine::new(Vsait::default(), 8);
        let mut rng = Rng::new(9);
        let patches: Vec<BinaryHV> = (0..7)
            .map(|_| BinaryHV::random(&mut rng, e.cfg.hd_dim))
            .collect();
        let batch = e.translate_batch(&patches);
        for (i, p) in patches.iter().enumerate() {
            assert_eq!(batch[i], e.translate(p), "patch {i}");
        }
    }

    #[test]
    fn semantic_flip_rate_low_under_moderate_noise() {
        let e = VsaitEngine::new(Vsait::default(), 4);
        let rate = e.flip_rate(60, 0.10, 5);
        assert!(rate < 0.1, "flip rate {rate} too high — VSAIT robustness broken");
    }

    #[test]
    fn flip_rate_rises_with_noise() {
        // At 50% bit flips the patch is fully decorrelated from its
        // prototype, so the class becomes essentially random.
        let e = VsaitEngine::new(Vsait::default(), 6);
        let low = e.flip_rate(60, 0.05, 7);
        let high = e.flip_rate(60, 0.50, 7);
        assert!(high > low, "low {low} high {high}");
        assert!(high > 0.3, "high-noise flip rate {high} suspiciously low");
        assert!(low < 0.1, "hypervector robustness lost: {low}");
    }
}
