//! The seven neuro-symbolic workload models (paper Tab. III): LNN, LTN,
//! NVSA, NLM, VSAIT, ZeroC, PrAE — plus the accelerator evaluation suite
//! MULT/TREE/FACT/REACT (Tab. VII).
//!
//! Each workload provides:
//! - an executable symbolic engine (real computation over synthetic data
//!   matched to the paper's dataset shapes — see DESIGN.md substitutions);
//! - a [`Trace`] of its operator graph (categories, FLOPs, bytes, deps)
//!   sized to the engine's actual loop structure, which the platform
//!   models turn into Figs. 2/3/4 and Tab. IV;
//! - memory statistics (Fig. 3b).
//!
//! Neural phases execute as AOT HLO artifacts via [`crate::runtime`]; the
//! traces account for them with the L2 models' layer shapes.

pub mod lnn;
pub mod ltn;
pub mod nlm;
pub mod nvsa;
pub mod prae;
pub mod raven;
pub mod rules;
pub mod suite;
pub mod vsait;
pub mod zeroc;

use crate::profiler::memstat::MemoryStats;
use crate::profiler::trace::Trace;

/// A characterizable neuro-symbolic workload.
pub trait Workload {
    /// Short name (LNN, LTN, NVSA, ...).
    fn name(&self) -> &'static str;
    /// Kautz-taxonomy category (Tab. I).
    fn ns_category(&self) -> &'static str;
    /// Operator trace at the configured size.
    fn trace(&self) -> Trace;
    /// Storage + working-set memory statistics.
    fn memory(&self) -> MemoryStats;
    /// Whether the symbolic phase consumes neural outputs (critical-path
    /// dependency, Fig. 4) — false means symbolic knowledge is compiled
    /// *into* the neural structure instead.
    fn symbolic_depends_on_neural(&self) -> bool;
}

/// All seven paper workloads at their default (paper-matched) sizes.
pub fn all_workloads() -> Vec<Box<dyn Workload>> {
    vec![
        Box::new(lnn::Lnn::default()),
        Box::new(ltn::Ltn::default()),
        Box::new(nvsa::Nvsa::default()),
        Box::new(nlm::Nlm::default()),
        Box::new(vsait::Vsait::default()),
        Box::new(zeroc::ZeroC::default()),
        Box::new(prae::Prae::default()),
    ]
}

/// Validate one workload's trace, tagging errors with the workload name.
pub fn validate_trace(name: &str, trace: &Trace) -> Result<(), String> {
    if trace.is_empty() {
        return Err(format!("{name}: trace is empty"));
    }
    trace.validate().map_err(|e| format!("{name}: {e}"))
}

/// Validate every registered workload, collecting failures instead of
/// aborting on the first one — `figures`/`characterize` report the bad
/// workloads and keep going with the rest.
pub fn validate_all() -> Result<(), Vec<String>> {
    let errors: Vec<String> = all_workloads()
        .iter()
        .filter_map(|w| validate_trace(w.name(), &w.trace()).err())
        .collect();
    if errors.is_empty() {
        Ok(())
    } else {
        Err(errors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seven_workloads_registered() {
        let ws = all_workloads();
        assert_eq!(ws.len(), 7);
        let names: Vec<_> = ws.iter().map(|w| w.name()).collect();
        assert_eq!(names, ["LNN", "LTN", "NVSA", "NLM", "VSAIT", "ZeroC", "PrAE"]);
    }

    #[test]
    fn all_traces_validate() {
        // validate_all collects every failure (rather than panicking on
        // the first), so a regression names all broken workloads at once
        assert_eq!(validate_all(), Ok(()));
    }

    #[test]
    fn validate_trace_reports_name_and_reason() {
        use crate::profiler::taxonomy::{OpCategory, PhaseKind};
        let empty = Trace::new("X");
        let err = validate_trace("X", &empty).unwrap_err();
        assert!(err.contains("X") && err.contains("empty"), "{err}");
        let mut bad = Trace::new("Y");
        bad.add("op", OpCategory::Other, PhaseKind::Symbolic, 1, 1, 1, &[]);
        bad.ops[0].deps.push(5); // forward dependency: invalid
        let err = validate_trace("Y", &bad).unwrap_err();
        assert!(err.starts_with("Y:"), "{err}");
        // a good trace passes
        let mut ok = Trace::new("Z");
        ok.add("op", OpCategory::Other, PhaseKind::Symbolic, 1, 1, 1, &[]);
        assert!(validate_trace("Z", &ok).is_ok());
    }

    /// Fig. 2a calibration: symbolic runtime share on the RTX model must
    /// land in the paper's reported band per workload (±8 points).
    #[test]
    fn fig2a_symbolic_fractions_match_paper() {
        let paper: &[(&str, f64)] = &[
            ("LNN", 45.4),
            ("LTN", 52.0),
            ("NVSA", 92.1),
            ("NLM", 60.6),
            ("VSAIT", 83.7),
            ("ZeroC", 26.8),
            ("PrAE", 80.5),
        ];
        let gpu = crate::platform::Platform::rtx2080ti();
        for w in all_workloads() {
            let expected = paper.iter().find(|(n, _)| *n == w.name()).unwrap().1;
            let tb = gpu.trace_time(&w.trace(), None);
            let got = tb.symbolic_fraction() * 100.0;
            assert!(
                (got - expected).abs() <= 8.0,
                "{}: symbolic {got:.1}% vs paper {expected:.1}%",
                w.name()
            );
        }
    }
}

#[cfg(test)]
mod calib_debug {
    /// Prints the Fig. 2a fractions (run with --nocapture for tuning).
    #[test]
    fn print_symbolic_fractions() {
        let gpu = crate::platform::Platform::rtx2080ti();
        for w in super::all_workloads() {
            let tb = gpu.trace_time(&w.trace(), None);
            println!(
                "{:<6} total {:>10.4} ms  symbolic {:>5.1}%",
                w.name(),
                tb.total * 1e3,
                tb.symbolic_fraction() * 100.0
            );
        }
    }
}
