//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python never runs at request time — `make artifacts` lowers the L2 JAX
//! graphs once; this module compiles each `artifacts/*.hlo.txt` with the
//! PJRT CPU client and exposes typed f32 execution.

pub mod artifact;
pub mod client;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use client::{Executable, Runtime, Tensor};
