//! PJRT CPU client wrapper: compile HLO text once, execute many times.
//!
//! Follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//! HLO *text* is the interchange format (serialized protos from jax≥0.5
//! carry 64-bit ids that xla_extension 0.5.1 rejects).

use super::artifact::{ArtifactSpec, Manifest};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;

/// A dense f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with f32 input tensors; returns f32 outputs.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape != spec.shape {
                return Err(anyhow!(
                    "{}: input shape {:?} != expected {:?}",
                    self.spec.name,
                    t.shape,
                    spec.shape
                ));
            }
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&t.data).reshape(&dims)?;
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let elems = result.to_tuple()?;
        let mut outs = Vec::with_capacity(elems.len());
        for (lit, spec) in elems.into_iter().zip(&self.spec.outputs) {
            let data = lit.to_vec::<f32>()?;
            outs.push(Tensor::new(spec.shape.clone(), data));
        }
        Ok(outs)
    }
}

/// The PJRT runtime: one CPU client + a cache of compiled artifacts.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<String, Executable>,
}

impl Runtime {
    /// Create from the default artifacts directory.
    pub fn new() -> Result<Runtime> {
        let manifest = Manifest::load_default().map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            manifest,
            client,
            cache: HashMap::new(),
        })
    }

    /// Platform string of the PJRT client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an artifact by name.
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let spec = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
                .clone();
            let path = spec
                .file
                .to_str()
                .ok_or_else(|| anyhow!("bad path"))?
                .to_string();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            self.cache.insert(name.to_string(), Executable { spec, exe });
        }
        Ok(&self.cache[name])
    }

    /// One-shot convenience: load + run.
    pub fn run(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.load(name)?;
        self.cache[name].run(inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_built() -> bool {
        crate::config::artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn pmf_to_vsa_artifact_matches_rust_engine() {
        if !artifacts_built() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut rt = Runtime::new().unwrap();
        let dims = rt.manifest.dims;
        // one-hot PMFs: output rows must equal codebook rows
        let mut pmf = Tensor::zeros(vec![dims.panels, dims.attr_k]);
        for p in 0..dims.panels {
            pmf.data[p * dims.attr_k + (p % dims.attr_k)] = 1.0;
        }
        let mut cb = Tensor::zeros(vec![dims.attr_k, dims.hd_dim]);
        let mut rng = crate::util::Rng::new(5);
        for v in cb.data.iter_mut() {
            *v = rng.bipolar();
        }
        let out = rt.run("pmf_to_vsa", &[pmf, cb.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![dims.panels, dims.hd_dim]);
        for p in 0..dims.panels {
            let k = p % dims.attr_k;
            let row = &out[0].data[p * dims.hd_dim..(p + 1) * dims.hd_dim];
            let cb_row = &cb.data[k * dims.hd_dim..(k + 1) * dims.hd_dim];
            assert_eq!(row, cb_row, "one-hot bundle must copy the item");
        }
    }

    #[test]
    fn nvsa_frontend_produces_pmfs() {
        if !artifacts_built() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut rt = Runtime::new().unwrap();
        let dims = rt.manifest.dims;
        let mut rng = crate::util::Rng::new(7);
        let mut panels = Tensor::zeros(vec![dims.panels, dims.img, dims.img, 1]);
        for v in panels.data.iter_mut() {
            *v = rng.normal() as f32;
        }
        let outs = rt.run("nvsa_frontend", &[panels]).unwrap();
        assert_eq!(outs.len(), dims.n_attrs);
        for pmf in &outs {
            assert_eq!(pmf.shape, vec![dims.panels, dims.attr_k]);
            for p in 0..dims.panels {
                let row = &pmf.data[p * dims.attr_k..(p + 1) * dims.attr_k];
                let s: f32 = row.iter().sum();
                assert!((s - 1.0).abs() < 1e-4, "softmax rows sum to 1: {s}");
                assert!(row.iter().all(|&x| x >= 0.0));
            }
        }
    }

    #[test]
    fn resonator_step_artifact_matches_rust() {
        if !artifacts_built() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut rt = Runtime::new().unwrap();
        let dims = rt.manifest.dims;
        let d = dims.hd_dim;
        let n = dims.codebook_n;
        let mut rng = crate::util::Rng::new(11);
        let bip = |rng: &mut crate::util::Rng, n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.bipolar()).collect()
        };
        let scene = bip(&mut rng, d);
        let o1 = bip(&mut rng, d);
        let o2 = bip(&mut rng, d);
        let cb = bip(&mut rng, n * d);
        let outs = rt
            .run(
                "resonator_step",
                &[
                    Tensor::new(vec![d], scene.clone()),
                    Tensor::new(vec![d], o1.clone()),
                    Tensor::new(vec![d], o2.clone()),
                    Tensor::new(vec![n, d], cb.clone()),
                ],
            )
            .unwrap();
        // reference: rust implementation
        let xhat: Vec<f64> = (0..d)
            .map(|i| (scene[i] * o1[i] * o2[i]) as f64)
            .collect();
        let scores: Vec<f64> = (0..n)
            .map(|j| (0..d).map(|i| cb[j * d + i] as f64 * xhat[i]).sum())
            .collect();
        for (j, &s) in scores.iter().enumerate() {
            assert!(
                (outs[1].data[j] as f64 - s).abs() < 1e-2 * (s.abs() + 1.0),
                "score {j}: {} vs {s}",
                outs[1].data[j]
            );
        }
        for i in 0..d {
            let proj: f64 = (0..n).map(|j| scores[j] * cb[j * d + i] as f64).sum();
            let expect = if proj >= 0.0 { 1.0 } else { -1.0 };
            assert_eq!(outs[0].data[i], expect as f32, "est lane {i}");
        }
    }
}
