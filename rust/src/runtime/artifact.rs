//! Artifact manifest: shapes and dtypes of every AOT entry point, written
//! by `python/compile/aot.py` as `artifacts/manifest.json`.

use crate::config::ModelDims;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Tensor shape + dtype.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Option<TensorSpec> {
        Some(TensorSpec {
            shape: j
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|x| x.as_usize().unwrap_or(0))
                .collect(),
            dtype: j.get("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One AOT'd entry point.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The full manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dims: ModelDims,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| e.to_string())?;
        let dims = ModelDims::from_manifest(&j);
        let mut artifacts = BTreeMap::new();
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or("manifest missing 'artifacts'")?;
        for (name, spec) in arts {
            let file = dir.join(
                spec.get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| format!("{name}: missing file"))?,
            );
            let parse_list = |key: &str| -> Result<Vec<TensorSpec>, String> {
                spec.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| format!("{name}: missing {key}"))?
                    .iter()
                    .map(|t| {
                        TensorSpec::from_json(t).ok_or_else(|| format!("{name}: bad {key}"))
                    })
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file,
                    inputs: parse_list("inputs")?,
                    outputs: parse_list("outputs")?,
                },
            );
        }
        Ok(Manifest {
            dims,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Manifest, String> {
        Self::load(&crate::config::artifacts_dir())
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.get(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_available() -> bool {
        crate::config::artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest_when_present() {
        if !manifest_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load_default().unwrap();
        assert_eq!(m.dims.hd_dim, 1024);
        let fe = m.get("nvsa_frontend").expect("nvsa_frontend artifact");
        assert_eq!(fe.inputs.len(), 1);
        assert_eq!(fe.inputs[0].shape, vec![16, 32, 32, 1]);
        assert_eq!(fe.outputs.len(), 3);
        assert!(fe.file.exists(), "{}", fe.file.display());
        // all 13 artifacts present
        assert!(m.artifacts.len() >= 13, "{}", m.artifacts.len());
    }

    #[test]
    fn tensor_spec_numel() {
        let t = TensorSpec {
            shape: vec![2, 3, 4],
            dtype: "float32".into(),
        };
        assert_eq!(t.numel(), 24);
    }
}
