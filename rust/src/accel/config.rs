//! Accelerator configurations (paper Tab. VI: Acc2 / Acc4 / Acc8).

/// Parameterized multi-tile VSA accelerator configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct AccelConfig {
    /// Instance name ("Acc2", ...).
    pub name: String,
    /// Global bus / datapath width in bits (`W`).
    pub bus_width: usize,
    /// Number of tiles (`K`).
    pub n_tiles: usize,
    /// CA-90 register-file entries per tile (`R`).
    pub ca90_rf: usize,
    /// BND register-file accumulators (`B`), shared VOP.
    pub bnd_rf: usize,
    /// DSUM registers per tile (`D`).
    pub dsum_rf: usize,
    /// Distance accumulator bit-width (`C`).
    pub distance_bits: u32,
    /// BND accumulator lane bit-width (`H`).
    pub bnd_bits: u32,
    /// Total SRAM capacity in bytes (across tiles).
    pub memory_bytes: usize,
    /// Clock frequency (28 nm synthesis target).
    pub clock_hz: f64,
}

impl AccelConfig {
    /// Tab. VI row `Acc2`.
    pub fn acc2() -> Self {
        AccelConfig {
            name: "Acc2".into(),
            bus_width: 512,
            n_tiles: 2,
            ca90_rf: 2,
            bnd_rf: 2,
            dsum_rf: 2,
            distance_bits: 12,
            bnd_bits: 8,
            memory_bytes: 128 * 1024,
            clock_hz: 500e6,
        }
    }

    /// Tab. VI row `Acc4`.
    pub fn acc4() -> Self {
        AccelConfig {
            name: "Acc4".into(),
            n_tiles: 4,
            ca90_rf: 4,
            bnd_rf: 4,
            dsum_rf: 4,
            memory_bytes: 256 * 1024,
            ..Self::acc2()
        }
    }

    /// Tab. VI row `Acc8`.
    pub fn acc8() -> Self {
        AccelConfig {
            name: "Acc8".into(),
            n_tiles: 8,
            ca90_rf: 8,
            bnd_rf: 8,
            dsum_rf: 8,
            memory_bytes: 512 * 1024,
            ..Self::acc2()
        }
    }

    /// All three paper instances.
    pub fn paper_instances() -> Vec<AccelConfig> {
        vec![Self::acc2(), Self::acc4(), Self::acc8()]
    }

    /// SRAM bytes per tile.
    pub fn sram_per_tile(&self) -> usize {
        self.memory_bytes / self.n_tiles
    }

    /// `u64` words per fold (bus transaction).
    pub fn fold_words(&self) -> usize {
        self.bus_width / 64
    }

    /// SRAM capacity per tile in fold slots.
    pub fn sram_folds_per_tile(&self) -> usize {
        self.sram_per_tile() * 8 / self.bus_width
    }

    /// Leakage power (W). Measured values from the paper's synthesis:
    /// 1.7 mW (Acc2) → 5.2 mW (Acc8); Acc4 interpolated.
    pub fn leakage_w(&self) -> f64 {
        match self.n_tiles {
            0..=2 => 1.7e-3,
            3..=4 => 3.0e-3,
            _ => 5.2e-3,
        }
    }

    /// Seconds per cycle.
    pub fn cycle_time(&self) -> f64 {
        1.0 / self.clock_hz
    }
}

impl Default for AccelConfig {
    fn default() -> Self {
        Self::acc4()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table_vi_values() {
        let a2 = AccelConfig::acc2();
        assert_eq!((a2.bus_width, a2.n_tiles, a2.dsum_rf), (512, 2, 2));
        assert_eq!(a2.memory_bytes, 128 * 1024);
        let a8 = AccelConfig::acc8();
        assert_eq!(a8.n_tiles, 8);
        assert_eq!(a8.memory_bytes, 512 * 1024);
        assert_eq!(a8.distance_bits, 12);
        assert_eq!(a8.bnd_bits, 8);
    }

    #[test]
    fn leakage_triples_acc2_to_acc8() {
        let ratio = AccelConfig::acc8().leakage_w() / AccelConfig::acc2().leakage_w();
        assert!((ratio - 3.06).abs() < 0.1, "paper reports ~3x: {ratio}");
    }

    #[test]
    fn sram_fold_capacity() {
        let a2 = AccelConfig::acc2();
        // 64 KiB per tile / 64 B per fold = 1024 folds.
        assert_eq!(a2.sram_folds_per_tile(), 1024);
        assert_eq!(a2.fold_words(), 8);
    }
}
